"""L1 correctness: every Pallas kernel vs its pure-jnp/numpy oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    apply_banded_axis,
    apply_banded_last,
    bias_correct,
    diff_band,
    gaussian_band,
    gaussian_blur3d,
    gradient_magnitude3d,
    magnitude3,
)
from compile.kernels import ref

RNG = np.random.default_rng(0)


def rand(shape, seed=None):
    r = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(r.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------- banded ops
class TestBandedOperators:
    @pytest.mark.parametrize("n", [8, 16, 64])
    @pytest.mark.parametrize("sigma", [0.5, 1.0, 4.0])
    def test_gaussian_band_rows_sum_to_one(self, n, sigma):
        b = gaussian_band(n, sigma)
        np.testing.assert_allclose(b.sum(axis=1), np.ones(n), rtol=1e-6)

    def test_gaussian_band_zero_sigma_is_identity(self):
        np.testing.assert_array_equal(gaussian_band(16, 0.0), np.eye(16, dtype=np.float32))

    def test_gaussian_band_symmetric_interior(self):
        b = gaussian_band(64, 2.0)
        # interior rows are shifted copies (Toeplitz)
        np.testing.assert_allclose(b[20, 14:27], b[30, 24:37], rtol=1e-6)

    def test_gaussian_band_is_banded(self):
        sigma = 1.5
        r = int(np.ceil(3 * sigma))
        b = gaussian_band(32, sigma)
        for i in range(32):
            for j in range(32):
                if abs(i - j) > r:
                    assert b[i, j] == 0.0

    def test_diff_band_matches_numpy_gradient(self):
        x = np.asarray(rand((64,)))
        d = diff_band(64) @ x
        np.testing.assert_allclose(d, np.gradient(x), rtol=1e-5, atol=1e-6)

    def test_diff_band_kills_constants(self):
        d = diff_band(32) @ np.ones(32, dtype=np.float32)
        np.testing.assert_allclose(d, np.zeros(32), atol=1e-7)


class TestApplyBanded:
    @pytest.mark.parametrize("m,n,block_m", [(256, 64, 256), (512, 64, 128), (1024, 32, 256)])
    def test_matches_ref_last(self, m, n, block_m):
        x = rand((m, n))
        band = jnp.asarray(gaussian_band(n, 1.0))
        got = apply_banded_last(x, band, block_m=block_m)
        np.testing.assert_allclose(got, ref.ref_apply_banded_last(x, band), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("axis", [0, 1, 2])
    def test_matches_ref_axis(self, axis):
        x = rand((16, 24, 32))
        band = jnp.asarray(gaussian_band(x.shape[axis], 1.5))
        got = apply_banded_axis(x, band, axis)
        want = ref.ref_apply_banded_axis(x, band, axis)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_bad_block_raises(self):
        x = rand((100, 64))
        band = jnp.asarray(gaussian_band(64, 1.0))
        with pytest.raises(ValueError):
            apply_banded_last(x, band, block_m=64)

    def test_identity_band_is_noop(self):
        x = rand((256, 64))
        got = apply_banded_last(x, jnp.eye(64), block_m=128)
        np.testing.assert_allclose(got, x, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        logm=st.integers(min_value=1, max_value=4),
        n=st.sampled_from([16, 32, 64]),
        sigma=st.floats(min_value=0.2, max_value=5.0),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_banded_last(self, logm, n, sigma, seed):
        m = 64 * (2**logm)
        x = rand((m, n), seed=seed)
        band = jnp.asarray(gaussian_band(n, sigma))
        got = apply_banded_last(x, band, block_m=64)
        np.testing.assert_allclose(got, ref.ref_apply_banded_last(x, band), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- gaussian blur
class TestGaussianBlur3d:
    def test_matches_ref(self):
        x = rand((32, 32, 32))
        np.testing.assert_allclose(
            gaussian_blur3d(x, 1.0), ref.ref_gaussian_blur3d(x, 1.0), rtol=1e-5, atol=1e-5
        )

    def test_anisotropic_matches_ref(self):
        x = rand((16, 32, 64))
        s = (0.5, 2.0, 0.0)
        np.testing.assert_allclose(
            gaussian_blur3d(x, s), ref.ref_gaussian_blur3d(x, s), rtol=1e-5, atol=1e-5
        )

    def test_preserves_constant_volume(self):
        x = jnp.full((16, 16, 16), 3.25, dtype=jnp.float32)
        np.testing.assert_allclose(gaussian_blur3d(x, 2.0), x, rtol=1e-5)

    def test_reduces_variance(self):
        x = rand((32, 32, 32))
        assert float(jnp.var(gaussian_blur3d(x, 2.0))) < float(jnp.var(x))

    def test_preserves_mean_approximately(self):
        x = rand((32, 32, 32)) + 10.0
        got = float(jnp.mean(gaussian_blur3d(x, 1.5)))
        assert abs(got - float(jnp.mean(x))) < 0.05

    def test_zero_sigma_noop(self):
        x = rand((16, 16, 16))
        np.testing.assert_allclose(gaussian_blur3d(x, 0.0), x)

    def test_rank_mismatch_raises(self):
        with pytest.raises(ValueError):
            gaussian_blur3d(rand((16, 16, 16)), (1.0, 2.0))

    @settings(max_examples=10, deadline=None)
    @given(
        sigma=st.floats(min_value=0.3, max_value=6.0),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_blur(self, sigma, seed):
        x = rand((16, 16, 16), seed=seed)
        np.testing.assert_allclose(
            gaussian_blur3d(x, sigma), ref.ref_gaussian_blur3d(x, sigma), rtol=2e-5, atol=2e-5
        )


# ---------------------------------------------------------- gradient kernels
class TestGradient:
    def test_matches_banded_ref(self):
        x = rand((24, 24, 24))
        np.testing.assert_allclose(
            gradient_magnitude3d(x), ref.ref_gradient_magnitude3d(x), rtol=1e-5, atol=1e-5
        )

    def test_matches_independent_numpy_oracle(self):
        x = rand((16, 24, 32))
        got = np.asarray(gradient_magnitude3d(x))
        want = ref.ref_gradient_magnitude3d_numpy(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_constant_volume_has_zero_gradient(self):
        x = jnp.full((16, 16, 16), 7.0, dtype=jnp.float32)
        np.testing.assert_allclose(gradient_magnitude3d(x), jnp.zeros_like(x), atol=1e-6)

    def test_linear_ramp_gradient(self):
        # v(x,y,z) = 2x has |∇v| = 2 everywhere (unit spacing).
        i = jnp.arange(16, dtype=jnp.float32)
        x = jnp.broadcast_to(2.0 * i[:, None, None], (16, 16, 16))
        np.testing.assert_allclose(gradient_magnitude3d(x), jnp.full((16, 16, 16), 2.0), rtol=1e-5)


class TestElementwise:
    def test_magnitude3_matches_ref(self):
        a, b, c = rand((32, 32, 32)), rand((32, 32, 32)), rand((32, 32, 32))
        np.testing.assert_allclose(
            magnitude3(a, b, c), ref.ref_magnitude3(a, b, c), rtol=1e-6, atol=1e-6
        )

    def test_magnitude3_odd_size_falls_back_to_smaller_block(self):
        a = rand((5, 7, 9))
        np.testing.assert_allclose(
            magnitude3(a, a, a), ref.ref_magnitude3(a, a, a), rtol=1e-6, atol=1e-6
        )

    def test_bias_correct_matches_ref(self):
        v = rand((32, 32, 32)) + 5.0
        s = ref.ref_gaussian_blur3d(v, 4.0)
        np.testing.assert_allclose(
            bias_correct(v, s), ref.ref_bias_correct(v, s), rtol=1e-5, atol=1e-5
        )

    def test_bias_correct_flattens_synthetic_bias(self):
        # A smooth multiplicative field applied to a constant volume should be
        # mostly removed: corrected variance << biased variance.
        i = jnp.linspace(0.5, 1.5, 32)
        field = i[:, None, None] * i[None, :, None] * i[None, None, :]
        biased = 10.0 * field.astype(jnp.float32)
        smooth = ref.ref_gaussian_blur3d(biased, 8.0)
        corrected = bias_correct(biased, smooth)
        assert float(jnp.std(corrected)) < 0.5 * float(jnp.std(biased))
