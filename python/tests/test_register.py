"""Resample kernel vs oracle + atlas registration recovery tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import resample3d
from compile.kernels.ref import ref_resample3d


def smooth_phantom(n=64, seed=0):
    r = np.random.default_rng(seed)
    g = np.indices((n, n, n)).astype(np.float32)
    c = (n - 1) / 2.0
    d = np.sqrt(((g - c) ** 2).sum(axis=0))
    vol = np.exp(-((d / (n / 4.0)) ** 2)).astype(np.float32)
    vol += 0.3 * np.exp(-(((g[0] - c - 8) / 6) ** 2 + ((g[1] - c) / 6) ** 2 + ((g[2] - c) / 6) ** 2))
    vol += 0.01 * r.standard_normal((n, n, n)).astype(np.float32)
    return jnp.asarray(vol)


class TestResample:
    def test_identity_grid_is_noop(self):
        vol = smooth_phantom(16)
        i = jnp.arange(16, dtype=jnp.float32)
        gx, gy, gz = jnp.meshgrid(i, i, i, indexing="ij")
        out = resample3d(vol, gx, gy, gz)
        np.testing.assert_allclose(out, vol, rtol=1e-5, atol=1e-5)

    def test_matches_ref_on_random_coords(self):
        vol = smooth_phantom(16)
        r = np.random.default_rng(1)
        xs = jnp.asarray(r.uniform(-2, 18, (1024,)), dtype=jnp.float32)
        ys = jnp.asarray(r.uniform(-2, 18, (1024,)), dtype=jnp.float32)
        zs = jnp.asarray(r.uniform(-2, 18, (1024,)), dtype=jnp.float32)
        got = resample3d(vol, xs, ys, zs)
        want = ref_resample3d(vol, xs, ys, zs)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_integer_coords_hit_exact_voxels(self):
        vol = smooth_phantom(8)
        xs = jnp.asarray([0.0, 3.0, 7.0 - 1e-5])
        out = resample3d(vol, xs, xs, xs)
        np.testing.assert_allclose(out[0], vol[0, 0, 0], rtol=1e-4)
        np.testing.assert_allclose(out[1], vol[3, 3, 3], rtol=1e-4)

    def test_halfway_coords_average_neighbours(self):
        vol = jnp.zeros((4, 4, 4), dtype=jnp.float32).at[1, 1, 1].set(2.0).at[2, 1, 1].set(4.0)
        out = resample3d(vol, jnp.asarray([1.5]), jnp.asarray([1.0]), jnp.asarray([1.0]))
        np.testing.assert_allclose(out[0], 3.0, rtol=1e-6)

    def test_out_of_bounds_clamps(self):
        vol = smooth_phantom(8)
        out = resample3d(vol, jnp.asarray([-5.0, 100.0]), jnp.asarray([0.0, 7.0]), jnp.asarray([0.0, 7.0]))
        np.testing.assert_allclose(out[0], vol[0, 0, 0], rtol=1e-4)
        np.testing.assert_allclose(out[1], vol[7, 7, 7], rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_matches_ref(self, seed):
        vol = smooth_phantom(8, seed=seed)
        r = np.random.default_rng(seed)
        xs = jnp.asarray(r.uniform(0, 7, (256,)), dtype=jnp.float32)
        ys = jnp.asarray(r.uniform(0, 7, (256,)), dtype=jnp.float32)
        zs = jnp.asarray(r.uniform(0, 7, (256,)), dtype=jnp.float32)
        np.testing.assert_allclose(
            resample3d(vol, xs, ys, zs), ref_resample3d(vol, xs, ys, zs), rtol=2e-5, atol=2e-5
        )


class TestAtlasRegister:
    @pytest.fixture(scope="class")
    def reg(self):
        return model.jit_register()

    def test_identity_registration_stays_near_zero(self, reg):
        fixed = smooth_phantom(64, seed=2)
        theta, warped, mse, trace = reg(fixed, fixed)
        assert np.abs(np.asarray(theta)[:3]).max() < 0.2, theta
        assert float(mse) < 1e-4

    def test_translation_recovered(self, reg):
        fixed = smooth_phantom(64, seed=3)
        # moving = fixed shifted by (-3, 2, 0): sampling moving at x+t maps
        # back onto fixed when t = true shift
        i = jnp.arange(64, dtype=jnp.float32)
        gx, gy, gz = jnp.meshgrid(i, i, i, indexing="ij")
        from compile.kernels.ref import ref_resample3d as rs
        moving = rs(fixed, gx + 3.0, gy - 2.0, gz)
        # warped(x) = moving(x + t) = fixed(x + t + 3) ⇒ recovery is t = −shift
        theta, warped, mse, trace = reg(jnp.asarray(moving), fixed)
        t = np.asarray(theta)
        assert abs(t[0] + 3.0) < 0.25, t
        assert abs(t[1] - 2.0) < 0.25, t
        assert abs(t[2]) < 0.25, t
        assert float(mse) < 1e-4

    def test_mse_decreases(self, reg):
        fixed = smooth_phantom(64, seed=4)
        i = jnp.arange(64, dtype=jnp.float32)
        gx, gy, gz = jnp.meshgrid(i, i, i, indexing="ij")
        from compile.kernels.ref import ref_resample3d as rs
        moving = rs(fixed, gx + 2.0, gy, gz)
        _, _, mse, trace = reg(jnp.asarray(moving), fixed)
        trace = np.asarray(trace)
        assert trace[-1] < trace[0] * 0.5, trace[:5]
        assert float(mse) <= trace[0]

    def test_scale_recovered(self, reg):
        fixed = smooth_phantom(64, seed=5)
        c = 31.5
        i = jnp.arange(64, dtype=jnp.float32)
        gx, gy, gz = jnp.meshgrid(i, i, i, indexing="ij")
        from compile.kernels.ref import ref_resample3d as rs
        s_true = 1.08
        moving = rs(fixed, s_true * (gx - c) + c, s_true * (gy - c) + c, s_true * (gz - c) + c)
        # composing warp with moving's scale must invert it: exp(θ₃) ≈ 1/s
        theta, _, _, _ = reg(jnp.asarray(moving), fixed)
        s_rec = float(np.exp(np.asarray(theta)[3]))
        assert abs(s_rec - 1.0 / s_true) < 0.02, s_rec
