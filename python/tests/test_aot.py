"""AOT path: artifacts lower, manifest is consistent, HLO text is loadable."""

import hashlib
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    arts = aot.build_artifacts()
    manifest = {"artifacts": []}
    for name, (lowered, inputs, outputs) in arts.items():
        text = aot.to_hlo_text(lowered)
        p = out / f"{name}.hlo.txt"
        p.write_text(text)
        manifest["artifacts"].append(
            {"name": name, "file": p.name, "sha256": hashlib.sha256(text.encode()).hexdigest(),
             "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
             "outputs": outputs}
        )
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out, manifest


def test_artifact_set(built):
    _, manifest = built
    assert {a["name"] for a in manifest["artifacts"]} == {
        "seg_pipeline",
        "dwi_preproc",
        "atlas_register",
    }


def test_hlo_text_nonempty_and_parsable_header(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert len(text) > 1000
        assert text.startswith("HloModule")
        assert "ENTRY" in text


def test_hlo_entry_is_tuple(built):
    out, _ = built
    text = (out / "seg_pipeline.hlo.txt").read_text()
    # return_tuple=True → root of entry computation is a tuple of 5 outputs
    assert "(f32[64,64,64]" in text.replace(" ", "")


def test_manifest_shapes_match_model(built):
    _, manifest = built
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    seg = by_name["seg_pipeline"]
    assert seg["inputs"][0]["shape"] == list(model.VOL_SHAPE)
    dwi = by_name["dwi_preproc"]
    assert dwi["inputs"][0]["shape"] == list(model.DWI_SHAPE)
    assert dwi["inputs"][1]["shape"] == [model.DWI_DIRS + 1]


def test_sha256_stable(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]


def test_large_constants_not_elided(built):
    """Default HLO printing elides big constants as `{...}`, which the
    xla_extension 0.5.1 text parser silently reads as ZEROS. Regression
    guard for the print_large_constants fix."""
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "{...}" not in text, f"{a['name']} has elided constants"


def test_no_unparseable_metadata(built):
    """jax ≥0.6 emits source_end_line metadata the 0.5.1 parser rejects."""
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "source_end_line" not in text


def test_no_mosaic_custom_calls(built):
    """interpret=True must lower Pallas to plain HLO the CPU client can run."""
    out, manifest = built
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()
