"""L2 correctness: pipeline graph invariants + shapes."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def synth_t1(seed=0):
    """Synthetic T1w: three intensity blobs + bias field + noise."""
    r = np.random.default_rng(seed)
    g = np.indices(model.VOL_SHAPE).astype(np.float32)
    c = np.array(model.VOL_SHAPE, dtype=np.float32)[:, None, None, None] / 2
    d = np.sqrt(((g - c) ** 2).sum(axis=0))
    vol = np.where(d < 12, 0.9, np.where(d < 20, 0.6, np.where(d < 28, 0.3, 0.05)))
    bias = np.linspace(0.8, 1.2, model.VOL_SHAPE[0])[:, None, None]
    vol = vol * bias + 0.02 * r.standard_normal(model.VOL_SHAPE)
    return jnp.asarray(vol, dtype=jnp.float32)


def synth_dwi(seed=0):
    r = np.random.default_rng(seed)
    b0 = np.abs(r.standard_normal(model.VOL_SHAPE)).astype(np.float32) + 1.0
    vols = [b0]
    for k in range(model.DWI_DIRS):
        att = 0.4 + 0.05 * k
        vols.append((b0 * att + 0.01 * r.standard_normal(model.VOL_SHAPE)).astype(np.float32))
    bvals = np.array([0.0] + [1000.0] * model.DWI_DIRS, dtype=np.float32)
    return jnp.asarray(np.stack(vols)), jnp.asarray(bvals)


class TestSegPipeline:
    @pytest.fixture(scope="class")
    def out(self):
        return model.jit_seg()(synth_t1())

    def test_output_arity_and_shapes(self, out):
        seg, volumes, means, edge_qa, snr_qa = out
        assert seg.shape == model.VOL_SHAPE
        assert volumes.shape == (model.N_TISSUES,)
        assert means.shape == (model.N_TISSUES,)
        assert edge_qa.shape == () and snr_qa.shape == ()

    def test_labels_in_range(self, out):
        seg = np.asarray(out[0])
        assert set(np.unique(seg)).issubset({0.0, 1.0, 2.0})

    def test_soft_volumes_conserve_voxels(self, out):
        total = float(np.asarray(out[1]).sum())
        assert abs(total - np.prod(model.VOL_SHAPE)) < 1.0

    def test_means_sorted_ascending(self, out):
        means = np.asarray(out[2])
        assert means[0] <= means[1] <= means[2]

    def test_means_in_normalized_range(self, out):
        means = np.asarray(out[2])
        assert (means >= 0).all() and (means <= 1).all()

    def test_qa_finite_positive(self, out):
        assert float(out[3]) > 0 and np.isfinite(float(out[3]))
        assert np.isfinite(float(out[4]))

    def test_segments_recover_blob_structure(self, out):
        # the bright core (label 2) should occupy fewer voxels than background
        seg = np.asarray(out[0])
        counts = [(seg == k).sum() for k in range(3)]
        assert counts[0] > counts[2]  # background class dominates

    def test_deterministic(self):
        a = model.jit_seg()(synth_t1(1))
        b = model.jit_seg()(synth_t1(1))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


class TestDwiPreproc:
    @pytest.fixture(scope="class")
    def out(self):
        dwi, bvals = synth_dwi()
        return model.jit_dwi()(dwi, bvals)

    def test_shapes(self, out):
        md, mean_adc, b0_snr = out
        assert md.shape == model.VOL_SHAPE
        assert mean_adc.shape == (model.DWI_DIRS,)
        assert b0_snr.shape == ()

    def test_adc_positive(self, out):
        assert (np.asarray(out[1]) > 0).all()

    def test_md_nonnegative_finite(self, out):
        md = np.asarray(out[0])
        assert np.isfinite(md).all() and (md >= 0).all()

    def test_stronger_attenuation_gives_larger_adc(self):
        # direction k has attenuation 0.4 + 0.05k → ADC decreases with k
        dwi, bvals = synth_dwi()
        _, mean_adc, _ = model.jit_dwi()(dwi, bvals)
        a = np.asarray(mean_adc)
        assert (np.diff(a) < 0).all()

    def test_unattenuated_signal_gives_near_zero_adc(self):
        dwi, bvals = synth_dwi()
        same = jnp.stack([dwi[0]] * (model.DWI_DIRS + 1))
        md, mean_adc, _ = model.jit_dwi()(same, bvals)
        assert float(np.abs(np.asarray(mean_adc)).max()) < 1e-4
