"""Structural perf assertions: every kernel's working set fits VMEM with
double-buffering headroom, and the estimates carry the expected roofline
classifications."""

from compile import estimate


def test_all_kernels_fit_vmem():
    for e in estimate.all_estimates():
        assert e.fits_vmem(), f"{e.name}: {e.vmem_per_step_bytes} > VMEM"


def test_double_buffer_headroom():
    # need 2× the block working set resident for overlap; the full-volume
    # resample kernel is exempt (volume is shared across steps)
    for e in estimate.all_estimates():
        if "resample" in e.name:
            continue
        assert 2 * e.vmem_per_step_bytes <= estimate.VMEM_BYTES, e.name


def test_small_filter_convs_are_memory_bound():
    # banded ops at n=64 have intensity ≈ 2n/3 per byte? — compute the
    # classification instead of hand-waving:
    g = estimate.gaussian3d_estimate()
    assert g.bound() in ("memory", "compute")
    # elementwise fusion is definitely memory-bound
    assert estimate.elementwise_estimate().bound() == "memory"
    assert estimate.resample_estimate().bound() == "memory"


def test_bigger_block_fewer_steps_same_traffic():
    a = estimate.banded_estimate(block_m=128)
    b = estimate.banded_estimate(block_m=512)
    assert a.grid_steps == 4 * b.grid_steps
    assert a.hbm_traffic_bytes == b.hbm_traffic_bytes
    assert b.vmem_per_step_bytes > a.vmem_per_step_bytes


def test_estimates_positive_and_fast():
    for e in estimate.all_estimates():
        assert e.est_seconds() > 0
        # every kernel instance should be sub-millisecond on TPU
        assert e.est_seconds() < 1e-3, f"{e.name}: {e.est_seconds()}"


def test_table_renders():
    t = estimate.format_table()
    assert "gaussian_blur3d" in t and "resample3d" in t
