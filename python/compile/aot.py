"""AOT lowering: JAX (L2+L1) → HLO **text** artifacts for the rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; the rust side unwraps with
``to_tuple()``.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print_large_constants. The default printer elides big
    # constants as `{...}`, which xla_extension 0.5.1's text parser silently
    # parses as ZEROS — the baked Gaussian band operators would all vanish
    # (bug found the hard way; see EXPERIMENTS.md §Debugging).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New jax emits `source_end_line`/`source_end_column` metadata the 0.5.1
    # text parser rejects — strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _spec(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dtype))


def build_artifacts():
    """Return {name: (lowered, input_specs, output_names)} for every artifact."""
    seg_in = [("vol", model.VOL_SHAPE, "float32")]
    dwi_in = [("dwi", model.DWI_SHAPE, "float32"), ("bvals", (model.DWI_DIRS + 1,), "float32")]
    arts = {
        "seg_pipeline": (
            jax.jit(model.seg_pipeline).lower(_spec(model.VOL_SHAPE)),
            seg_in,
            ["seg", "volumes", "means", "edge_qa", "snr_qa"],
        ),
        "dwi_preproc": (
            jax.jit(model.dwi_preproc).lower(
                _spec(model.DWI_SHAPE), _spec((model.DWI_DIRS + 1,))
            ),
            dwi_in,
            ["md_map", "mean_adc", "b0_snr"],
        ),
        "atlas_register": (
            jax.jit(model.atlas_register).lower(
                _spec(model.VOL_SHAPE), _spec(model.VOL_SHAPE)
            ),
            [("moving", model.VOL_SHAPE, "float32"), ("fixed", model.VOL_SHAPE, "float32")],
            ["theta", "warped", "final_mse", "mse_trace"],
        ),
    }
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for name, (lowered, inputs, outputs) in build_artifacts().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "sha256": digest,
                "inputs": [
                    {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in inputs
                ],
                "outputs": outputs,
                "return_tuple": True,
            }
        )
        print(f"wrote {path} ({len(text)} chars, sha256 {digest[:12]})")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
