"""L1 performance estimation: VMEM footprint + MXU/VPU utilization per
Pallas kernel, derived from BlockSpecs (interpret=True gives CPU-numpy
wall-clock only, which is NOT a TPU proxy — so the perf pass optimizes
*structure*: bytes moved, VMEM residency, MXU-shaped contractions).

Usage: ``cd python && python -m compile.estimate`` (table to stdout; also
invoked by pytest to assert the kernels stay within VMEM).
"""

from __future__ import annotations

from dataclasses import dataclass

# TPU-v4-ish envelope used for roofline estimates.
VMEM_BYTES = 16 * 2**20  # ~16 MiB per core
HBM_GBPS = 1200.0  # HBM bandwidth, GB/s
MXU_FLOPS = 137e12  # bf16 matmul peak, FLOP/s (f32 ≈ /4)
F32_MXU_FLOPS = MXU_FLOPS / 4


@dataclass
class KernelEstimate:
    name: str
    grid_steps: int
    vmem_per_step_bytes: int
    hbm_traffic_bytes: int
    flops: int
    #: arithmetic intensity (FLOP / HBM byte)
    def intensity(self) -> float:
        return self.flops / max(self.hbm_traffic_bytes, 1)

    def bound(self) -> str:
        # roofline knee: intensity where compute time == memory time
        knee = F32_MXU_FLOPS / (HBM_GBPS * 1e9)
        return "compute" if self.intensity() > knee else "memory"

    def est_seconds(self) -> float:
        t_mem = self.hbm_traffic_bytes / (HBM_GBPS * 1e9)
        t_flop = self.flops / F32_MXU_FLOPS
        return max(t_mem, t_flop)

    def fits_vmem(self) -> bool:
        return self.vmem_per_step_bytes <= VMEM_BYTES


def banded_estimate(m: int = 4096, n: int = 64, block_m: int = 1024) -> KernelEstimate:
    """apply_banded_last: (m,n) @ (n,n) tiled over block_m rows."""
    steps = m // block_m
    vmem = 4 * (block_m * n + n * n + block_m * n)  # in + operator + out
    hbm = 4 * (m * n + n * n + m * n)  # stream volume in+out, operator once
    flops = 2 * m * n * n  # dense contraction per element
    return KernelEstimate("banded_matmul(m=%d,n=%d,bm=%d)" % (m, n, block_m), steps, vmem, hbm, flops)


def gaussian3d_estimate(n: int = 64, block_m: int = 1024) -> KernelEstimate:
    """Three banded passes over an n³ volume."""
    one = banded_estimate(n * n, n, block_m)
    return KernelEstimate(
        f"gaussian_blur3d(n={n})",
        3 * one.grid_steps,
        one.vmem_per_step_bytes,
        3 * one.hbm_traffic_bytes,
        3 * one.flops,
    )


def elementwise_estimate(n: int = 262144, block: int = 32768, inputs: int = 3) -> KernelEstimate:
    vmem = 4 * block * (inputs + 1)
    hbm = 4 * n * (inputs + 1)
    flops = n * (2 * inputs + 1)  # mul+add chain + sqrt
    return KernelEstimate(f"magnitude3(n={n})", n // block, vmem, hbm, flops)


def resample_estimate(nvol: int = 64, nsamples: int = 262144, block: int = 32768) -> KernelEstimate:
    """Whole volume resident in VMEM + coordinate blocks streamed."""
    vol_bytes = 4 * nvol**3
    vmem = vol_bytes + 4 * block * 4  # volume + 3 coord blocks + out block
    hbm = vol_bytes + 4 * nsamples * 4
    flops = nsamples * 32  # 8 gathers + 7 lerps ≈ 32 flops each
    return KernelEstimate(f"resample3d(vol={nvol}³)", nsamples // block, vmem, hbm, flops)


def all_estimates():
    return [
        banded_estimate(),
        gaussian3d_estimate(),
        elementwise_estimate(),
        resample_estimate(),
    ]


def format_table() -> str:
    rows = [
        f"{'kernel':<34}{'steps':>6}{'VMEM/step':>12}{'HBM bytes':>12}"
        f"{'FLOPs':>12}{'intensity':>10}{'bound':>8}{'est µs':>8}"
    ]
    for e in all_estimates():
        rows.append(
            f"{e.name:<34}{e.grid_steps:>6}{e.vmem_per_step_bytes:>12,}"
            f"{e.hbm_traffic_bytes:>12,}{e.flops:>12,}{e.intensity():>10.2f}"
            f"{e.bound():>8}{e.est_seconds() * 1e6:>8.1f}"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    print(f"TPU envelope: VMEM {VMEM_BYTES // 2**20} MiB, HBM {HBM_GBPS:.0f} GB/s, "
          f"f32 MXU {F32_MXU_FLOPS / 1e12:.1f} TFLOP/s")
    print(format_table())
