"""Trilinear resampling Pallas kernel — the hot spot of atlas-based
registration (paper §2: "atlas-based registration" is one of the 16
pipelines).

Formulation for TPU: the moving volume (64³ f32 = 1 MiB) fits entirely in
VMEM, so the kernel holds the full volume per grid step and streams blocks
of sample coordinates past it. Each grid step gathers the 8 trilinear
neighbours for ``block`` sample points and blends them with the fractional
weights — a VPU gather+FMA pattern (the GPU paper idiom would be a texture
fetch; on TPU it's an explicit VMEM gather).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32768


def _resample_kernel(vol_ref, xs_ref, ys_ref, zs_ref, o_ref):
    """Gather trilinear samples at (xs, ys, zs) from the full volume."""
    vol = vol_ref[...]  # (nx, ny, nz) resident in VMEM
    nx, ny, nz = vol.shape
    xs, ys, zs = xs_ref[...], ys_ref[...], zs_ref[...]

    # clamp to the valid interpolation cube [0, n-1]
    xs = jnp.clip(xs, 0.0, nx - 1.000001)
    ys = jnp.clip(ys, 0.0, ny - 1.000001)
    zs = jnp.clip(zs, 0.0, nz - 1.000001)
    x0 = jnp.floor(xs).astype(jnp.int32)
    y0 = jnp.floor(ys).astype(jnp.int32)
    z0 = jnp.floor(zs).astype(jnp.int32)
    fx = xs - x0
    fy = ys - y0
    fz = zs - z0
    x1 = jnp.minimum(x0 + 1, nx - 1)
    y1 = jnp.minimum(y0 + 1, ny - 1)
    z1 = jnp.minimum(z0 + 1, nz - 1)

    flat = vol.reshape(-1)
    idx = lambda x, y, z: (x * ny + y) * nz + z  # noqa: E731

    c000 = flat[idx(x0, y0, z0)]
    c001 = flat[idx(x0, y0, z1)]
    c010 = flat[idx(x0, y1, z0)]
    c011 = flat[idx(x0, y1, z1)]
    c100 = flat[idx(x1, y0, z0)]
    c101 = flat[idx(x1, y0, z1)]
    c110 = flat[idx(x1, y1, z0)]
    c111 = flat[idx(x1, y1, z1)]

    c00 = c000 * (1 - fz) + c001 * fz
    c01 = c010 * (1 - fz) + c011 * fz
    c10 = c100 * (1 - fz) + c101 * fz
    c11 = c110 * (1 - fz) + c111 * fz
    c0 = c00 * (1 - fy) + c01 * fy
    c1 = c10 * (1 - fy) + c11 * fy
    o_ref[...] = c0 * (1 - fx) + c1 * fx


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _resample_flat(vol, xs, ys, zs, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    (n,) = xs.shape
    if n % block:
        raise ValueError(f"n={n} not divisible by block={block}")
    nx, ny, nz = vol.shape
    coord_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _resample_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((nx, ny, nz), lambda i: (0, 0, 0)),  # whole volume in VMEM
            coord_spec,
            coord_spec,
            coord_spec,
        ],
        out_specs=coord_spec,
        out_shape=jax.ShapeDtypeStruct((n,), vol.dtype),
        interpret=interpret,
    )(vol, xs, ys, zs)


def resample3d(vol, xs, ys, zs, *, block: int = DEFAULT_BLOCK):
    """Trilinear-sample ``vol`` at voxel coordinates (xs, ys, zs).

    Coordinates are in voxel units; out-of-bounds samples clamp to the
    border (the convention registration wants for overlapping FOVs).
    Shapes of xs/ys/zs must match; output has the same shape.
    """
    shape = xs.shape
    n = xs.size
    b = block
    while n % b:
        b //= 2
    out = _resample_flat(
        vol.astype(jnp.float32),
        xs.reshape(-1).astype(jnp.float32),
        ys.reshape(-1).astype(jnp.float32),
        zs.reshape(-1).astype(jnp.float32),
        block=max(b, 1),
    )
    return out.reshape(shape)
