"""Pure-jnp / numpy reference oracles for every Pallas kernel.

These are the CORE correctness signal: pytest asserts kernel == ref to
float tolerance across shape/sigma/dtype sweeps (including hypothesis-driven
ones). Keep them dead simple — clarity over speed.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .banded import diff_band, gaussian_band


def ref_apply_banded_last(x2d, band):
    return x2d @ band.T


def ref_apply_banded_axis(vol, band, axis):
    moved = jnp.moveaxis(vol, axis, -1)
    out = moved @ band.T
    return jnp.moveaxis(out, -1, axis)


def ref_gaussian_blur3d(vol, sigma):
    if np.isscalar(sigma):
        sigma = (float(sigma),) * 3
    out = vol
    for axis, s in enumerate(sigma):
        if s <= 0:
            continue
        band = gaussian_band(out.shape[axis], s, dtype=np.float32)
        out = ref_apply_banded_axis(out, band, axis)
    return out


def ref_gradient_magnitude3d(vol):
    ds = []
    for axis in range(3):
        band = diff_band(vol.shape[axis], dtype=np.float32)
        ds.append(ref_apply_banded_axis(vol, band, axis))
    return jnp.sqrt(ds[0] ** 2 + ds[1] ** 2 + ds[2] ** 2)


def ref_gradient_magnitude3d_numpy(vol):
    """Independent oracle: numpy.gradient, no shared banded machinery."""
    dx, dy, dz = np.gradient(np.asarray(vol))
    return np.sqrt(dx**2 + dy**2 + dz**2)


def ref_magnitude3(dx, dy, dz):
    return jnp.sqrt(dx * dx + dy * dy + dz * dz)


def ref_bias_correct(vol, smooth, eps=1e-3):
    bias = smooth / jnp.mean(smooth)
    return vol / jnp.maximum(bias, eps)


def ref_resample3d(vol, xs, ys, zs):
    """Trilinear sampling with border clamp — pure jnp oracle."""
    vol = jnp.asarray(vol, dtype=jnp.float32)
    nx, ny, nz = vol.shape
    xs = jnp.clip(jnp.asarray(xs, jnp.float32), 0.0, nx - 1.000001)
    ys = jnp.clip(jnp.asarray(ys, jnp.float32), 0.0, ny - 1.000001)
    zs = jnp.clip(jnp.asarray(zs, jnp.float32), 0.0, nz - 1.000001)
    x0 = jnp.floor(xs).astype(jnp.int32)
    y0 = jnp.floor(ys).astype(jnp.int32)
    z0 = jnp.floor(zs).astype(jnp.int32)
    fx, fy, fz = xs - x0, ys - y0, zs - z0
    x1 = jnp.minimum(x0 + 1, nx - 1)
    y1 = jnp.minimum(y0 + 1, ny - 1)
    z1 = jnp.minimum(z0 + 1, nz - 1)
    v = lambda x, y, z: vol[x, y, z]  # noqa: E731
    c00 = v(x0, y0, z0) * (1 - fz) + v(x0, y0, z1) * fz
    c01 = v(x0, y1, z0) * (1 - fz) + v(x0, y1, z1) * fz
    c10 = v(x1, y0, z0) * (1 - fz) + v(x1, y0, z1) * fz
    c11 = v(x1, y1, z0) * (1 - fz) + v(x1, y1, z1) * fz
    c0 = c00 * (1 - fy) + c01 * fy
    c1 = c10 * (1 - fy) + c11 * fy
    return c0 * (1 - fx) + c1 * fx
