"""Gradient-magnitude of a 3-D volume: banded central differences along each
axis + a fused elementwise magnitude kernel."""

from __future__ import annotations

import numpy as np

from .banded import apply_banded_axis, diff_band
from .elementwise import magnitude3


def gradient_magnitude3d(vol, *, block_m: int = 1024):
    """|∇v| with ``numpy.gradient`` boundary conventions (unit spacing)."""
    ds = []
    for axis in range(3):
        band = diff_band(vol.shape[axis], dtype=np.float32)
        ds.append(apply_banded_axis(vol, band, axis, block_m=block_m))
    return magnitude3(ds[0], ds[1], ds[2])
