"""Banded-operator application along an axis, as a Pallas kernel.

The medflow imaging hot spot is separable small-filter convolution (Gaussian
denoise / bias-field smoothing) and finite differences. On TPU the efficient
formulation is NOT a halo-exchange stencil (shared-memory idiom from GPU
papers) but a **banded matmul**: applying a length-(2r+1) filter along an
axis of size N equals multiplying by an (N, N) banded Toeplitz operator B.
That turns the stencil into an MXU-shaped ``(M, N) @ (N, N)`` contraction:

  * the volume is reshaped so the target axis is last → ``x2d: (M, N)``,
  * the grid tiles M into ``block_m`` rows; each grid step loads one
    ``(block_m, N)`` slab plus the full ``(N, N)`` operator into VMEM,
  * the kernel computes ``slab @ B.T`` with ``preferred_element_type=f32``.

VMEM per grid step (f32, N=64, block_m=256): slab 64 KiB + operator 16 KiB +
out 64 KiB = 144 KiB — comfortably inside ~16 MiB VMEM, leaving room for
double buffering (see DESIGN.md §Perf).

Edge handling: rows of B near the boundary hold the *truncated, renormalized*
filter, matching the classical "renormalized Gaussian at the border"
convention used by neuroimaging smoothers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Rows per grid step. Perf pass (EXPERIMENTS.md §Perf): 1024×64 f32 slabs →
# VMEM/step ≈ 528 KiB (×2 for double-buffering ≈ 1 MiB, well under 16 MiB)
# and 4× fewer grid steps than the original 256 — the interpret-mode grid
# loop is the dominant artifact cost on CPU-PJRT, and on TPU fewer, larger
# MXU contractions amortize issue overhead.
DEFAULT_BLOCK_M = 1024


def gaussian_band(n: int, sigma: float, dtype=np.float32) -> np.ndarray:
    """Dense (n, n) banded Toeplitz operator for a truncated Gaussian.

    Radius is ceil(3*sigma); each row is renormalized to sum to 1 so the
    operator is intensity-preserving on constant inputs (property-tested).
    Built with numpy at trace time — it is a compile-time constant baked
    into the HLO artifact.
    """
    if sigma <= 0:
        return np.eye(n, dtype=dtype)
    r = int(np.ceil(3.0 * sigma))
    offsets = np.arange(-r, r + 1)
    taps = np.exp(-0.5 * (offsets / sigma) ** 2)
    b = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        lo = max(0, i - r)
        hi = min(n, i + r + 1)
        row = taps[(lo - i) + r : (hi - i) + r]
        b[i, lo:hi] = row / row.sum()
    return b.astype(dtype)


def diff_band(n: int, dtype=np.float32) -> np.ndarray:
    """Central-difference operator (one-sided at the boundary).

    Row i of the result computes d[i] = (x[i+1] - x[i-1]) / 2 in the
    interior, with forward/backward differences at the two edges — the
    standard ``numpy.gradient`` convention, which ``ref.py`` mirrors.
    """
    b = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        if i == 0:
            b[0, 0], b[0, 1] = -1.0, 1.0
        elif i == n - 1:
            b[i, i - 1], b[i, i] = -1.0, 1.0
        else:
            b[i, i - 1], b[i, i + 1] = -0.5, 0.5
    return b.astype(dtype)


def _banded_kernel(x_ref, b_ref, o_ref):
    """One grid step: (block_m, n) slab times the full (n, n) operator."""
    o_ref[...] = jnp.dot(
        x_ref[...], b_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def apply_banded_last(x2d, band, *, block_m: int = DEFAULT_BLOCK_M, interpret: bool = True):
    """Apply the (n, n) banded operator to the last axis of ``x2d: (m, n)``.

    ``m`` must be divisible by ``block_m`` (callers pad; 64³ volumes give
    m = 4096 which all power-of-two blocks divide).
    """
    m, n = x2d.shape
    if m % block_m:
        raise ValueError(f"m={m} not divisible by block_m={block_m}")
    grid = (m // block_m,)
    return pl.pallas_call(
        _banded_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2d.dtype),
        interpret=interpret,
    )(x2d, band)


def apply_banded_axis(vol, band, axis: int, *, block_m: int = DEFAULT_BLOCK_M):
    """Apply a banded operator along ``axis`` of an N-D volume.

    Reshapes so the target axis is last (an XLA transpose that fuses with
    neighbouring ops), runs the Pallas banded matmul, and restores layout.
    """
    axis = axis % vol.ndim
    moved = jnp.moveaxis(vol, axis, -1)
    lead = moved.shape[:-1]
    n = moved.shape[-1]
    m = int(np.prod(lead)) if lead else 1
    bm = block_m
    while m % bm:
        bm //= 2  # degrade gracefully for odd leading sizes
    out2d = apply_banded_last(moved.reshape(m, n), band, block_m=max(bm, 1))
    return jnp.moveaxis(out2d.reshape(*lead, n), -1, axis)
