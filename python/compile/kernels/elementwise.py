"""Fused elementwise Pallas kernels.

These are bandwidth-bound VPU kernels: the grid walks flat chunks of the
volume, each grid step streaming ``block`` elements through VMEM once
instead of materializing the intermediates (dx², dy², dz², their sum) in
HBM, which is exactly the fusion a GPU paper would do in registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32768


def _magnitude3_kernel(dx_ref, dy_ref, dz_ref, o_ref):
    dx, dy, dz = dx_ref[...], dy_ref[...], dz_ref[...]
    o_ref[...] = jnp.sqrt(dx * dx + dy * dy + dz * dz)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _magnitude3_flat(dx, dy, dz, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    (n,) = dx.shape
    if n % block:
        raise ValueError(f"n={n} not divisible by block={block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _magnitude3_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), dx.dtype),
        interpret=interpret,
    )(dx, dy, dz)


def magnitude3(dx, dy, dz, *, block: int = DEFAULT_BLOCK):
    """sqrt(dx² + dy² + dz²), fused in one pass over the volume."""
    shape = dx.shape
    n = dx.size
    b = block
    while n % b:
        b //= 2
    out = _magnitude3_flat(dx.reshape(-1), dy.reshape(-1), dz.reshape(-1), block=max(b, 1))
    return out.reshape(shape)


def _bias_correct_kernel(v_ref, smooth_ref, mean_ref, o_ref):
    """corrected = v / max(smooth / global_mean, eps): one fused pass."""
    eps = 1e-3
    bias = smooth_ref[...] / mean_ref[0]
    o_ref[...] = v_ref[...] / jnp.maximum(bias, eps)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _bias_correct_flat(v, smooth, mean, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    (n,) = v.shape
    if n % block:
        raise ValueError(f"n={n} not divisible by block={block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _bias_correct_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), v.dtype),
        interpret=interpret,
    )(v, smooth, mean)


def bias_correct(vol, smooth, *, block: int = DEFAULT_BLOCK):
    """Divide out a multiplicative bias field estimated as smooth/mean(smooth)."""
    shape = vol.shape
    n = vol.size
    b = block
    while n % b:
        b //= 2
    mean = jnp.mean(smooth).reshape(1)
    out = _bias_correct_flat(vol.reshape(-1), smooth.reshape(-1), mean, block=max(b, 1))
    return out.reshape(shape)
