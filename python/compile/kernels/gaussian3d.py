"""Separable 3-D Gaussian smoothing built on the banded Pallas kernel.

A 3-D Gaussian factors into three 1-D passes; each pass is one banded
matmul along one axis (see ``banded.py``). Anisotropic sigmas are allowed
(bias-field estimation uses a broad sigma, denoising a narrow one).
"""

from __future__ import annotations

import numpy as np

from .banded import apply_banded_axis, gaussian_band


def gaussian_blur3d(vol, sigma, *, block_m: int = 1024):
    """Blur a 3-D volume with a (possibly anisotropic) Gaussian.

    ``sigma`` is a scalar or a 3-tuple of *compile-time* floats; the banded
    operators are baked into the artifact as constants.
    """
    if np.isscalar(sigma):
        sigma = (float(sigma),) * 3
    if len(sigma) != vol.ndim:
        raise ValueError(f"sigma rank {len(sigma)} != vol rank {vol.ndim}")
    out = vol
    for axis, s in enumerate(sigma):
        if s <= 0:
            continue
        band = gaussian_band(out.shape[axis], s, dtype=np.float32)
        out = apply_banded_axis(out, band, axis, block_m=block_m)
    return out
