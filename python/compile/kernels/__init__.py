"""L1 Pallas kernels for medflow imaging pipelines.

All kernels are lowered with ``interpret=True`` so they become plain HLO that
the CPU PJRT client (rust ``xla`` crate) can execute. On a real TPU the same
BlockSpecs map blocks into VMEM and the banded matmuls onto the MXU; see
DESIGN.md §Hardware-Adaptation.
"""

from .banded import apply_banded_last, apply_banded_axis, gaussian_band, diff_band
from .gaussian3d import gaussian_blur3d
from .grad3d import gradient_magnitude3d
from .elementwise import magnitude3, bias_correct
from .resample import resample3d

__all__ = [
    "apply_banded_last",
    "apply_banded_axis",
    "gaussian_band",
    "diff_band",
    "gaussian_blur3d",
    "gradient_magnitude3d",
    "magnitude3",
    "bias_correct",
    "resample3d",
]
