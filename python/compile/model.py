"""L2 — the JAX compute graphs for medflow's containerized pipelines.

These are the numeric cores of the paper's image-processing pipelines
(Freesurfer-like structural segmentation; PreQual-like DWI preprocessing),
written in JAX, calling the L1 Pallas kernels, and AOT-lowered by
``aot.py`` into ``artifacts/*.hlo.txt`` that the rust runtime executes via
PJRT. Python never runs on the job path.

Shapes are static (AOT): one T1w volume is ``(64, 64, 64) f32``; a DWI
shell is ``(7, 64, 64, 64) f32`` (one b0 + 6 directions). The rust
coordinator tiles larger scans onto these artifact shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import bias_correct, gaussian_blur3d, gradient_magnitude3d

VOL_SHAPE = (64, 64, 64)
DWI_DIRS = 6
DWI_SHAPE = (DWI_DIRS + 1, *VOL_SHAPE)

# Freesurfer-like pipeline constants (compile-time).
BIAS_SIGMA = 8.0  # broad field for bias estimation
DENOISE_SIGMA = 1.0
EM_ITERS = 8
N_TISSUES = 3  # CSF / GM / WM


def _em_step(carry, _):
    """One EM iteration of a 3-class Gaussian intensity mixture.

    carry = (v_flat, mu[3], var[3], pi[3]). The responsibilities are the
    classic soft assignment; mu/var/pi are the weighted MLE updates.
    """
    v, mu, var, pi = carry
    # log N(v | mu_k, var_k) + log pi_k, shape (n, 3)
    diff = v[:, None] - mu[None, :]
    log_p = -0.5 * diff**2 / var[None, :] - 0.5 * jnp.log(var[None, :]) + jnp.log(pi[None, :])
    log_p = log_p - jax.scipy.special.logsumexp(log_p, axis=1, keepdims=True)
    resp = jnp.exp(log_p)  # (n, 3)
    nk = jnp.sum(resp, axis=0) + 1e-6
    mu_new = (resp * v[:, None]).sum(axis=0) / nk
    var_new = (resp * (v[:, None] - mu_new[None, :]) ** 2).sum(axis=0) / nk + 1e-6
    pi_new = nk / v.shape[0]
    return (v, mu_new, var_new, pi_new), None


def seg_pipeline(vol):
    """Freesurfer/SLANT-like structural pipeline on one T1w volume.

    Stages: bias-field correction (Pallas Gaussian + fused divide) →
    denoise (Pallas Gaussian) → min-max normalization → K-step EM tissue
    classification → hard segmentation + per-tissue volumes/means + QA.

    Returns (tuple of arrays — the artifact output tuple):
      seg        (64³ f32)  hard labels 0/1/2 by ascending mean intensity
      posteriors (3, 64³ flat f32 reduced to per-tissue voxel counts) — see
                 ``volumes``
      volumes    (3,) f32   soft tissue volumes in voxels
      means      (3,) f32   tissue mean intensities (normalized units)
      edge_qa    () f32     mean gradient magnitude (sharpness QA)
      snr_qa     () f32     mean/std of corrected volume (SNR proxy)
    """
    vol = vol.astype(jnp.float32)
    smooth_broad = gaussian_blur3d(vol, BIAS_SIGMA)
    corrected = bias_correct(vol, smooth_broad)
    denoised = gaussian_blur3d(corrected, DENOISE_SIGMA)

    lo = jnp.min(denoised)
    hi = jnp.max(denoised)
    norm = (denoised - lo) / jnp.maximum(hi - lo, 1e-6)

    v = norm.reshape(-1)
    # Perf (EXPERIMENTS.md §Perf L2): fit the mixture on a 4× strided
    # subsample — statistically equivalent for a 3-class intensity mixture
    # over 64³ voxels (65k samples remain) and cuts the EM scan's HLO work
    # 4× — then compute responsibilities over the full volume once.
    v_fit = v[::4]
    mu0 = jnp.array([0.2, 0.5, 0.8], dtype=jnp.float32)
    var0 = jnp.full((N_TISSUES,), 0.02, dtype=jnp.float32)
    pi0 = jnp.full((N_TISSUES,), 1.0 / N_TISSUES, dtype=jnp.float32)
    (_, mu, var, pi), _ = jax.lax.scan(_em_step, (v_fit, mu0, var0, pi0), None, length=EM_ITERS)

    diff = v[:, None] - mu[None, :]
    log_p = -0.5 * diff**2 / var[None, :] - 0.5 * jnp.log(var[None, :]) + jnp.log(pi[None, :])
    log_p = log_p - jax.scipy.special.logsumexp(log_p, axis=1, keepdims=True)
    resp = jnp.exp(log_p)

    # Order classes by ascending mean so labels are stable (CSF < GM < WM).
    order = jnp.argsort(mu)
    resp = resp[:, order]
    mu_sorted = mu[order]

    seg = jnp.argmax(resp, axis=1).astype(jnp.float32).reshape(VOL_SHAPE)
    volumes = resp.sum(axis=0)
    edge_qa = jnp.mean(gradient_magnitude3d(norm))
    snr_qa = jnp.mean(corrected) / (jnp.std(corrected) + 1e-6)
    return seg, volumes, mu_sorted, edge_qa, snr_qa


def dwi_preproc(dwi, bvals):
    """PreQual-like DWI preprocessing on one 6-direction shell + b0.

    Stages: per-gradient Pallas Gaussian denoise → ADC per direction →
    mean-diffusivity map → per-direction mean ADC + SNR QA.

    Returns: (md_map (64³), mean_adc (6,), b0_snr ()).
    """
    dwi = dwi.astype(jnp.float32)
    denoised = jax.vmap(lambda v: gaussian_blur3d(v, DENOISE_SIGMA))(dwi)
    b0 = jnp.maximum(denoised[0], 1e-3)
    grads = jnp.maximum(denoised[1:], 1e-3)
    ratio = jnp.clip(grads / b0[None], 1e-4, 1.0)
    adc = -jnp.log(ratio) / jnp.maximum(bvals[1:, None, None, None], 1.0)
    md = jnp.mean(adc, axis=0)
    mean_adc = jnp.mean(adc, axis=(1, 2, 3))
    b0_snr = jnp.mean(b0) / (jnp.std(b0) + 1e-6)
    return md, mean_adc, b0_snr


# ---------------------------------------------------------------------------
# Atlas registration (the paper's "atlas-based registration" pipeline).
# 4-DOF (translation + isotropic log-scale) intensity-based registration by
# gradient descent with an *analytic* gradient (no autodiff through the
# Pallas resampler): ∂MSE/∂θ = E[residual · ∇M(φ(x)) · ∂φ/∂θ].
# ---------------------------------------------------------------------------

REG_ITERS = 60
# Sign-descent step sizes (voxels / log-units) with exponential decay: robust
# to the tiny raw-gradient magnitudes of normalized-intensity volumes and
# convergent in a fixed iteration count (AOT needs static control flow).
REG_STEP0 = jnp.array([0.5, 0.5, 0.5, 0.02], dtype=jnp.float32)
REG_DECAY = 0.93


def _warp_coords(theta):
    """Sampling grid for θ = (tx, ty, tz, log_s): x_m = s·(x_f - c) + c + t."""
    from compile.kernels import resample3d  # local import keeps namespace tidy

    del resample3d
    n = VOL_SHAPE[0]
    c = (n - 1) / 2.0
    i = jnp.arange(n, dtype=jnp.float32)
    gx, gy, gz = jnp.meshgrid(i, i, i, indexing="ij")
    s = jnp.exp(theta[3])
    xs = s * (gx - c) + c + theta[0]
    ys = s * (gy - c) + c + theta[1]
    zs = s * (gz - c) + c + theta[2]
    return gx, gy, gz, xs, ys, zs


def _reg_step(carry, k):
    from compile.kernels import resample3d

    moving, fixed, mgx, mgy, mgz, theta = carry
    gx, gy, gz, xs, ys, zs = _warp_coords(theta)
    warped = resample3d(moving, xs, ys, zs)
    wgx = resample3d(mgx, xs, ys, zs)
    wgy = resample3d(mgy, xs, ys, zs)
    wgz = resample3d(mgz, xs, ys, zs)
    r = warped - fixed
    n = r.size
    c = (VOL_SHAPE[0] - 1) / 2.0
    s = jnp.exp(theta[3])
    # ∂φ/∂t = 1; ∂φ/∂log_s = s·(x_f − c) per axis
    g_t = jnp.stack(
        [jnp.sum(r * wgx), jnp.sum(r * wgy), jnp.sum(r * wgz)]
    ) * (2.0 / n)
    g_s = (
        jnp.sum(r * (wgx * (gx - c) + wgy * (gy - c) + wgz * (gz - c)))
        * s
        * (2.0 / n)
    )
    grad = jnp.concatenate([g_t, g_s[None]])
    step = REG_STEP0 * (REG_DECAY**k)
    theta = theta - step * jnp.sign(grad)
    mse = jnp.mean(r * r)
    return (moving, fixed, mgx, mgy, mgz, theta), mse


def atlas_register(moving, fixed):
    """Register `moving` to `fixed` (both 64³ f32), 4-DOF.

    Returns (theta (4,), warped (64³), final_mse (), mse_trace (REG_ITERS,)).
    """
    from compile.kernels import apply_banded_axis, diff_band, gaussian_blur3d, resample3d
    import numpy as np

    moving = gaussian_blur3d(moving.astype(jnp.float32), 1.0)
    fixed = gaussian_blur3d(fixed.astype(jnp.float32), 1.0)
    # spatial gradients of the moving image (banded central differences)
    grads = []
    for axis in range(3):
        band = diff_band(VOL_SHAPE[axis], dtype=np.float32)
        grads.append(apply_banded_axis(moving, band, axis))
    theta0 = jnp.zeros((4,), dtype=jnp.float32)
    carry = (moving, fixed, grads[0], grads[1], grads[2], theta0)
    ks = jnp.arange(REG_ITERS, dtype=jnp.float32)
    (_, _, _, _, _, theta), mse_trace = jax.lax.scan(_reg_step, carry, ks)
    _, _, _, xs, ys, zs = _warp_coords(theta)
    warped = resample3d(moving, xs, ys, zs)
    final_mse = jnp.mean((warped - fixed) ** 2)
    return theta, warped, final_mse, mse_trace


def jit_seg():
    return jax.jit(lambda v: seg_pipeline(v))


def jit_dwi():
    return jax.jit(lambda d, b: dwi_preproc(d, b))


def jit_register():
    return jax.jit(lambda m, f: atlas_register(m, f))
