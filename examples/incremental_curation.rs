//! Incremental curation: the sharded index + processed-set in action.
//!
//! 1. Ingest a synthetic cohort (the entity index is built during ingest).
//! 2. Campaign #1 evaluates every session once.
//! 3. Campaign #2 over the unchanged archive performs **no full rescan** —
//!    every session is replayed from the persistent indexes.
//! 4. A newly acquired session arrives; campaign #3 evaluates only that
//!    delta.
//! 5. A prerequisite pipeline completes; exactly the blocked sessions are
//!    re-examined and unblock (`MissingPrior` → runnable).
//!
//! Run: `cargo run --release --example incremental_curation`

use medflow::archive::{Archive, SecurityTier};
use medflow::bids::{BidsName, Modality};
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::workload::{ingest_cohort, SynthCohort};

fn main() -> anyhow::Result<()> {
    let root = std::env::temp_dir().join(format!("medflow_inc_demo_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;

    // 1. ingest — the ingest path maintains the sharded entity index
    let mut archive = Archive::at(&root.join("store"))?;
    let cohort = SynthCohort {
        name: "INCDEMO".into(),
        participants: 6,
        sessions: 10,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 42)?;
    println!("ingested '{}' ({} subjects); index at {:?}", ds.name, ds.subjects()?.len(), ds.index_dir());

    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, None);
    let cfg = CampaignConfig::default();

    // 2. first campaign: every session evaluated once
    let r1 = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)?;
    println!(
        "campaign #1: {} completed, {} skipped | query evaluated {} sessions across {} shards",
        r1.completed, r1.skipped, r1.query_stats.sessions_examined, r1.query_stats.shards_scanned
    );

    // 3. second campaign over an unchanged archive: O(changes) = O(0)
    let r2 = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)?;
    println!(
        "campaign #2: {} completed | query evaluated {} sessions, replayed {} (no full rescan)",
        r2.completed, r2.query_stats.sessions_examined, r2.query_stats.sessions_replayed
    );
    assert_eq!(r2.query_stats.sessions_examined, 0);

    // 4. a new scanning session is acquired
    let new_scan = BidsName::new("0001", Some("99"), Modality::T1w);
    let p = ds.raw_path(&new_scan, "nii.gz");
    std::fs::create_dir_all(p.parent().unwrap())?;
    std::fs::write(&p, b"newscan")?;
    let r3 = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)?;
    println!(
        "campaign #3: {} completed | {} new sessions discovered, {} evaluated",
        r3.completed, r3.query_stats.new_sessions, r3.query_stats.sessions_examined
    );
    assert_eq!(r3.query_stats.new_sessions, 1);

    // 5. dependency unblocking: tractseg waits on prequal
    let blocked = coord.run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg)?;
    println!(
        "tractseg before prequal: {} runnable ({} blocked on MissingPrior)",
        blocked.completed,
        blocked.skipped
    );
    let _ = coord.run_campaign(&ds, "prequal", SubmitTarget::Hpc, &cfg)?;
    let unblocked = coord.run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg)?;
    println!(
        "tractseg after prequal: {} completed | only {} sessions re-examined",
        unblocked.completed, unblocked.query_stats.sessions_examined
    );

    std::fs::remove_dir_all(&root).ok();
    println!("incremental curation OK");
    Ok(())
}
