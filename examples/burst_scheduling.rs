//! Burst scheduling + backpressure (paper §2.3, Fig. 3): when ACCRE is in
//! a maintenance window, the coordinator's resource monitor redirects the
//! campaign to a local server with a bounded in-flight pool; when the
//! window ends, work returns to the HPC path.
//!
//! Run: `cargo run --release --example burst_scheduling`

use medflow::archive::{Archive, SecurityTier};
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::slurm::Maintenance;
use medflow::workload::{ingest_cohort, SynthCohort};

fn main() -> anyhow::Result<()> {
    let root = std::env::temp_dir().join(format!("medflow_burst_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;

    let mut archive = Archive::at(&root.join("store"))?;
    let cohort = SynthCohort {
        name: "BURST".into(),
        participants: 6,
        sessions: 10,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 3)?;

    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, None);

    // ACCRE maintenance for the first simulated day
    coord.add_maintenance(Maintenance {
        start_s: 0.0,
        end_s: 86_400.0,
    });

    // resource monitor → choose target at two submit times
    let during = coord.choose_target(3_600.0, 4);
    let after = coord.choose_target(100_000.0, 4);
    println!("submit during maintenance → {during:?}");
    println!("submit after maintenance  → {after:?}");
    assert!(matches!(during, SubmitTarget::LocalBurst { .. }));
    assert!(matches!(after, SubmitTarget::Hpc));

    // run the burst campaign (bounded to 4 in-flight jobs = backpressure)
    let cfg = CampaignConfig {
        local_max_in_flight: 4,
        ..Default::default()
    };
    let report = coord.run_campaign(&ds, "lesion_seg", during, &cfg)?;
    println!(
        "burst campaign: {} completed on local, makespan {:.1} h, cost ${:.2}",
        report.completed,
        report.makespan_s / 3600.0,
        report.total_cost_dollars
    );

    // the resource monitor also reports storage + cluster state
    let status = coord.resource_status(3_600.0, 0.0)?;
    println!(
        "resource status: maintenance={} general_store={} bytes",
        status.cluster_in_maintenance, status.general_store_used_bytes
    );
    assert!(status.cluster_in_maintenance);

    // after the window, the remaining pipeline runs on the HPC
    let r2 = coord.run_campaign(&ds, "biscuit", after, &cfg)?;
    println!(
        "post-maintenance campaign: {} completed on HPC (makespan {:.1} h)",
        r2.completed,
        r2.makespan_s / 3600.0
    );

    std::fs::remove_dir_all(&root).ok();
    println!("burst_scheduling OK");
    Ok(())
}
