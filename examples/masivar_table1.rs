//! **The end-to-end validation driver** (EXPERIMENTS.md §Table 1).
//!
//! Reproduces the paper's §2.4 experiment: six MASiVar-like T1w scans run
//! through the Freesurfer-like pipeline on HPC, cloud, and local compute
//! environments; a 1 GB × 100 bandwidth probe and a 64 B × 100 latency
//! probe between storage and compute; and the per-environment cost
//! accounting. The structural pipeline really executes (PJRT artifact,
//! 64³ volumes, EM tissue segmentation); wall-clock at paper scale comes
//! from the calibrated duration model.
//!
//! Run: `cargo run --release --example masivar_table1`

use medflow::compute::load_runtime;
use medflow::report::{format_table1, paper, table1};

fn main() -> anyhow::Result<()> {
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    if runtime.is_none() {
        println!("NOTE: artifacts/ missing — run `make artifacts` first for real compute.");
    }

    let cols = table1(runtime.as_ref(), 42, 100, 100)?;
    println!("{}", format_table1(&cols));

    // paper-vs-measured summary (the reproduction shape)
    println!("paper vs measured (total $ for 6 Freesurfer scans):");
    for (col, want) in cols.iter().zip([paper::HPC, paper::CLOUD, paper::LOCAL]) {
        println!(
            "  {:<24} paper ${:<6.2} measured ${:<6.2}",
            col.env.name(),
            want.4,
            col.total_cost_dollars
        );
    }
    let ratio = cols[1].total_cost_dollars / cols[0].total_cost_dollars;
    println!("cloud/HPC cost ratio: {ratio:.1}x (paper: ~18x)");
    assert!(ratio > 10.0, "headline claim: HPC must be >10x cheaper");

    let bw_ratio = cols[0].throughput_gbps.0 / cols[1].throughput_gbps.0;
    println!(
        "HPC/cloud throughput ratio: {bw_ratio:.2}x (paper: 0.60/0.33 = 1.8x)"
    );
    println!("masivar_table1 OK");
    Ok(())
}
