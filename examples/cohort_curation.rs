//! Multi-dataset curation at (scaled) paper scale: ingest all 20 Table 4
//! datasets as synthetic cohorts, validate each BIDS tree, run campaigns
//! of several pipelines, back everything up to the Glacier simulator, and
//! print the regenerated Table 4 inventory.
//!
//! Run: `cargo run --release --example cohort_curation`

use medflow::archive::Archive;
use medflow::backup::GlacierArchive;
use medflow::bids::{validate_dataset, Severity};
use medflow::compute::load_runtime;
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::report::{format_table4, table4};
use medflow::workload::{catalog, ingest_cohort, scale_entry};

fn main() -> anyhow::Result<()> {
    let root = std::env::temp_dir().join(format!("medflow_curation_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let bids_parent = root.join("bids");

    // 1. ingest all 20 datasets at 1/500 participant scale (structure-true)
    let mut archive = Archive::at(&root.join("store"))?;
    let mut datasets = Vec::new();
    for entry in catalog() {
        let cohort = scale_entry(&entry, 0.002);
        let ds = ingest_cohort(&mut archive, &bids_parent, &cohort, 8, 7)?;
        let errors = validate_dataset(&ds.root)
            .into_iter()
            .filter(|i| i.severity == Severity::Error)
            .count();
        assert_eq!(errors, 0, "{} must validate", entry.name);
        datasets.push(ds);
    }
    println!("ingested + validated {} datasets", datasets.len());

    // 2. Table 4 inventory over the real ingested trees
    let rows = table4(&archive, &bids_parent)?;
    println!("{}", format_table4(&rows));

    // 3. nightly backup of every dataset
    let mut glacier = GlacierArchive::new();
    for (name, _) in archive.datasets().collect::<Vec<_>>() {
        let usage = archive.usage(name)?;
        glacier.nightly_backup(1, name, usage.bytes);
    }
    println!(
        "glacier: {} bytes archived, ${:.4}/month",
        glacier.archived_bytes(),
        glacier.monthly_cost()
    );

    // 4. run two pipeline campaigns over the three largest cohorts
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, runtime.as_ref());
    let cfg = CampaignConfig::default();
    let mut total_cost = 0.0;
    for ds in datasets.iter().take(3) {
        for pipeline in ["freesurfer", "prequal"] {
            let r = coord.run_campaign(ds, pipeline, SubmitTarget::Hpc, &cfg)?;
            println!(
                "campaign {}/{}: completed {} skipped {} cost ${:.2} makespan {:.1} h",
                ds.name,
                pipeline,
                r.completed,
                r.skipped,
                r.total_cost_dollars,
                r.makespan_s / 3600.0
            );
            total_cost += r.total_cost_dollars;
        }
    }
    println!("total campaign cost: ${total_cost:.2}");

    std::fs::remove_dir_all(&root).ok();
    println!("cohort_curation OK");
    Ok(())
}
