//! Quickstart: the smallest end-to-end medflow flow.
//!
//! 1. Synthesize a tiny DICOM cohort and ingest it (archive + BIDS tree).
//! 2. Validate the BIDS dataset (Fig. 2 structure).
//! 3. Query for unprocessed sessions and run one Freesurfer-like campaign
//!    through the PJRT artifact on the simulated HPC.
//! 4. Print the provenance of one output.
//!
//! Run: `cargo run --release --example quickstart`

use medflow::archive::{Archive, SecurityTier};
use medflow::bids::{validate_dataset, BidsName, Modality, Severity};
use medflow::compute::load_runtime;
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::provenance::Provenance;
use medflow::workload::{ingest_cohort, SynthCohort};

fn main() -> anyhow::Result<()> {
    let root = std::env::temp_dir().join(format!("medflow_quickstart_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;

    // 1. ingest
    let mut archive = Archive::at(&root.join("store"))?;
    let cohort = SynthCohort {
        name: "QUICKSTART".into(),
        participants: 4,
        sessions: 6,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 16, 42)?;
    println!("ingested dataset '{}' with {} subjects", ds.name, ds.subjects()?.len());

    // 2. validate
    let issues = validate_dataset(&ds.root);
    let errors = issues.iter().filter(|i| i.severity == Severity::Error).count();
    println!("BIDS validation: {} issues ({} errors)", issues.len(), errors);
    assert_eq!(errors, 0, "ingest must produce a valid BIDS tree");

    // 3. campaign (uses the real PJRT artifact when artifacts/ is built)
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    if runtime.is_none() {
        println!("NOTE: artifacts/ not built — run `make artifacts` for real PJRT compute");
    }
    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, runtime.as_ref());
    let report = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &CampaignConfig::default())?;
    println!(
        "campaign: {} queried, {} completed, {} skipped, makespan {:.1} h, cost ${:.2}",
        report.queried,
        report.completed,
        report.skipped,
        report.makespan_s / 3600.0,
        report.total_cost_dollars
    );
    if report.artifact_exec_s > 0.0 {
        println!("mean PJRT artifact execution: {:.3} s/scan", report.artifact_exec_s);
    }
    println!("--- skip CSV ---\n{}", report.skip_csv);

    // 4. provenance of the first completed output
    'outer: for sub in ds.subjects()? {
        for ses in ds.sessions(&sub)? {
            let name = BidsName::new(&sub, ses.as_deref(), Modality::T1w);
            let p = ds.derivative_dir("freesurfer", &name).join("provenance.json");
            if p.exists() {
                let prov = Provenance::load(&p)?;
                println!(
                    "provenance: pipeline={} image={} env={} inputs={}",
                    prov.pipeline,
                    prov.container_image,
                    prov.compute_env,
                    prov.inputs.len()
                );
                break 'outer;
            }
        }
    }

    std::fs::remove_dir_all(&root).ok();
    println!("quickstart OK");
    Ok(())
}
