//! Bench: fault-resilience co-simulation — the ISSUE 4 tentpole numbers.
//!
//! Sweeps fault models {none, typical, harsh} × staged-campaign sizes
//! 10³–10⁵ through the in-engine failure injection (DESIGN.md §11:
//! `slurm::Scheduler` / `LanePool` per-attempt failures with requeue
//! backoff and timeout re-staging, `netsim::scheduler` checksum aborts
//! that re-enqueue and re-contend), asserting:
//!
//! * **fault-free parity** — zero-rate injectors wired into every live
//!   engine reproduce the frozen `sim_legacy` staged run record-for-
//!   record (the full battery lives in `rust/tests/engine_parity.rs`);
//! * **determinism** — the same seed replays the identical retry trace
//!   (every `FaultEvent`, every timing, bit-for-bit);
//! * **re-contention** — at 10⁵ jobs, harsh faults push the transfer
//!   queue-wait p95 *strictly* above the fault-free run: retried and
//!   re-staged transfers share the same bottleneck link, which the old
//!   post-hoc `apply_faults` scaling could never show;
//! * **perf smoke** — the 10⁵ faulty run stays under a generous
//!   wall-clock bound, so the injection machinery cannot silently
//!   reintroduce superlinear cost.
//!
//! Run: `cargo bench --bench fault_resilience` — or with `-- --test`
//! for the reduced CI sweep (parity + determinism + the 10⁵
//! harsh-vs-free re-contention gate).

use std::time::Instant;

use medflow::coordinator::staged::{
    run_staged, synthetic_fault_campaign as campaign, LanePool, SlurmSim, StagedJob, StagedOutcome,
};
use medflow::faults::{FaultAction, FaultModel, Injection};
use medflow::netsim::scheduler::TransferScheduler;
use medflow::netsim::Env;
use medflow::sim_legacy;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;
use medflow::util::units::percentiles;

const STREAM_CAP: usize = 16;
const WORKERS: usize = 512;
const SEED: u64 = 42;

/// Generous CI bound for the 10⁵-job faulty run (expected: seconds).
const SMOKE_BOUND_S: f64 = 180.0;

struct FaultRun {
    wall_s: f64,
    out: StagedOutcome,
    transfer_wait_p95_s: f64,
    compute_events: Vec<medflow::faults::FaultEvent>,
    transfer_events: Vec<medflow::faults::FaultEvent>,
    restages: usize,
    aborted: usize,
    wasted_compute_s: f64,
    wasted_transfer_s: f64,
}

/// One staged co-simulation through the lane-pool backend, optionally
/// under a fault model (compute bands with timeout parking + transfer
/// checksum band — the campaign split `coordinator` uses).
fn run_lanes(jobs: &[StagedJob], model: Option<FaultModel>, retries: u32) -> FaultRun {
    let mut lanes = LanePool::new(WORKERS);
    let mut transfers = TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
    if let Some(m) = model {
        lanes.set_faults(
            Injection::new(m.compute_only(), retries, SEED ^ 0xc0fe)
                .with_backoff(60.0)
                .with_parked_timeouts(),
        );
        transfers.set_faults(Injection::new(m.transfer_only(), retries, SEED ^ 0xfade));
    }
    let t0 = Instant::now();
    let out = run_staged(jobs, &mut lanes, &mut transfers);
    let wall_s = t0.elapsed().as_secs_f64();
    let waits: Vec<f64> = transfers.records().iter().map(|r| r.queue_wait_s()).collect();
    FaultRun {
        wall_s,
        transfer_wait_p95_s: percentiles(&waits, &[95.0])[0],
        compute_events: lanes.fault_events().to_vec(),
        transfer_events: transfers.fault_events().to_vec(),
        restages: lanes
            .fault_events()
            .iter()
            .filter(|e| e.action == FaultAction::Parked)
            .count(),
        aborted: lanes.aborted_ids().len() + transfers.aborted_ids().len(),
        wasted_compute_s: lanes.wasted_alloc_s(),
        wasted_transfer_s: transfers.wasted_wire_s(),
        out,
    }
}

fn json_run(jobs: usize, model: &str, r: &FaultRun) -> Json {
    let failed = (r.compute_events.len() + r.transfer_events.len()) as f64;
    let mut o = Json::obj();
    o.set("jobs", Json::num(jobs as f64))
        .set("model", Json::str(model))
        .set("wall_s", Json::num(r.wall_s))
        .set("sim_makespan_s", Json::num(r.out.makespan_s))
        .set("transfer_wait_p95_s", Json::num(r.transfer_wait_p95_s))
        .set("failed_attempts", Json::num(failed))
        .set("restages", Json::num(r.restages as f64))
        .set("aborted", Json::num(r.aborted as f64))
        .set("wasted_compute_s", Json::num(r.wasted_compute_s))
        .set("wasted_transfer_s", Json::num(r.wasted_transfer_s));
    Json::Obj(o)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Fault-resilience co-simulation sweep (DESIGN.md §11) ===");
    let mut runs: Vec<Json> = Vec::new();

    // --- fault-free parity: zero-rate injection vs the frozen engines ---
    {
        let n = 1_000;
        let jobs = campaign(n, SEED);
        let live = run_lanes(&jobs, Some(FaultModel::none()), 3);
        let mut frozen_lanes = sim_legacy::LanePool::new(WORKERS);
        let mut frozen_transfers =
            sim_legacy::TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
        let frozen = sim_legacy::run_staged(&jobs, &mut frozen_lanes, &mut frozen_transfers);
        assert_eq!(
            live.out.timings, frozen.timings,
            "zero-rate injection must reproduce the pre-injection engines f64-exactly"
        );
        assert_eq!(live.out.transfer, frozen.transfer);
        assert!(live.compute_events.is_empty() && live.transfer_events.is_empty());
        println!("parity OK: FaultModel::none() co-sim == sim_legacy at n={n}");
    }

    // --- determinism: same seed ⇒ identical retry traces ---
    {
        let n = 10_000;
        let jobs = campaign(n, SEED + 1);
        let a = run_lanes(&jobs, Some(FaultModel::harsh()), 3);
        let b = run_lanes(&jobs, Some(FaultModel::harsh()), 3);
        assert_eq!(a.out.timings, b.out.timings, "same seed must replay identically");
        assert_eq!(a.compute_events, b.compute_events);
        assert_eq!(a.transfer_events, b.transfer_events);
        assert!(
            !a.compute_events.is_empty(),
            "harsh rates over 10⁴ jobs must fail attempts"
        );
        println!(
            "determinism OK at n={n}: {} compute + {} transfer failures replay bit-identically",
            a.compute_events.len(),
            a.transfer_events.len()
        );
    }

    // --- the sweep: model × scale, re-contention gate at 10⁵ ---
    let points: &[usize] = if test_mode {
        &[1_000, 100_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    let models: &[(&str, Option<FaultModel>)] = if test_mode {
        &[("none", None), ("harsh", Some(FaultModel::harsh()))]
    } else {
        &[
            ("none", None),
            ("typical", Some(FaultModel::typical())),
            ("harsh", Some(FaultModel::harsh())),
        ]
    };
    for &n in points {
        let jobs = campaign(n, SEED + 2);
        let mut free_p95 = None;
        let mut free_makespan = None;
        for (name, model) in models {
            let r = run_lanes(&jobs, *model, 3);
            let completed = r.out.timings.iter().filter(|t| t.completed).count();
            assert_eq!(completed + r.aborted, n, "{name} n={n}: jobs conserved");
            metric(&format!("{name}.n{n}.wall_s"), r.wall_s, "s");
            metric(&format!("{name}.n{n}.sim_makespan_s"), r.out.makespan_s, "s");
            metric(&format!("{name}.n{n}.wait_p95_s"), r.transfer_wait_p95_s, "s");
            metric(
                &format!("{name}.n{n}.failed_attempts"),
                (r.compute_events.len() + r.transfer_events.len()) as f64,
                "",
            );
            runs.push(json_run(n, name, &r));
            match *model {
                None => {
                    free_p95 = Some(r.transfer_wait_p95_s);
                    free_makespan = Some(r.out.makespan_s);
                }
                Some(_) => {
                    let free_p95 = free_p95.expect("fault-free point runs first");
                    let free_makespan = free_makespan.expect("fault-free point runs first");
                    // comparative gates only where the law of large
                    // numbers holds (hundreds of failures expected); a
                    // 10³ campaign can see single-digit failures
                    if n >= 10_000 {
                        assert!(
                            r.out.makespan_s > free_makespan,
                            "{name} n={n}: retries must extend the makespan \
                             ({} vs fault-free {free_makespan})",
                            r.out.makespan_s
                        );
                    }
                    // the acceptance gate: retried jobs visibly re-contend
                    // — at 10⁵ the extra retry/re-stage transfers push
                    // queue-wait p95 strictly above the fault-free run
                    if n >= 100_000 && *name == "harsh" {
                        assert!(
                            r.transfer_wait_p95_s > free_p95,
                            "n={n}: harsh queue-wait p95 ({} s) must exceed \
                             fault-free ({free_p95} s) — retries are not re-contending",
                            r.transfer_wait_p95_s
                        );
                        assert!(
                            r.wall_s < SMOKE_BOUND_S,
                            "perf smoke: 10⁵ faulty jobs took {:.1} s (bound {SMOKE_BOUND_S} s)",
                            r.wall_s
                        );
                        assert!(r.restages > 0, "harsh timeouts must force re-staging");
                        println!(
                            "re-contention OK at n={n}: wait p95 {:.0} s (fault-free {:.0} s), \
                             {} restages, {} aborted",
                            r.transfer_wait_p95_s, free_p95, r.restages, r.aborted
                        );
                    }
                }
            }
        }
    }

    // --- SLURM backend point: cluster-slot re-contention + parking ---
    {
        let n = if test_mode { 10_000 } else { 50_000 };
        let jobs = campaign(n, SEED + 3);
        let mut sched = Scheduler::new(ClusterSpec::accre());
        sched.set_faults(
            Injection::new(FaultModel::harsh().compute_only(), 3, SEED ^ 0xacc)
                .with_backoff(60.0)
                .with_parked_timeouts(),
        );
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: 20_000,
        };
        let mut sim = SlurmSim::new(sched, "medflow", Some(handle));
        let mut transfers = TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
        transfers.set_faults(Injection::new(
            FaultModel::harsh().transfer_only(),
            3,
            SEED ^ 0xccc,
        ));
        let t0 = Instant::now();
        let out = run_staged(&jobs, &mut sim, &mut transfers);
        let wall_s = t0.elapsed().as_secs_f64();
        let completed = out.timings.iter().filter(|t| t.completed).count();
        let aborted = sim.scheduler().aborted_ids().len() + transfers.aborted_ids().len();
        assert_eq!(completed + aborted, n, "slurm co-sim conserves jobs");
        assert!(
            !sim.scheduler().fault_events().is_empty(),
            "harsh faults must fire on the cluster"
        );
        metric(&format!("slurm.n{n}.wall_s"), wall_s, "s");
        metric(
            &format!("slurm.n{n}.failed_attempts"),
            sim.scheduler().fault_events().len() as f64,
            "",
        );
        println!(
            "slurm co-sim OK at n={n}: {} failed attempts, {} aborted, wall {:.1} s",
            sim.scheduler().fault_events().len(),
            aborted,
            wall_s
        );
    }

    // regression gate against the committed baseline (checked before
    // full mode overwrites it below)
    gate_against_baseline(&runs);

    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("fault_resilience"))
            .set(
                "scenario",
                Json::str(
                    "staged campaign on Env::Hpc, stream cap 16, 512 lanes, retries 3, \
                     seed 42 (see benches/fault_resilience.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fault_resilience.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("fault_resilience OK");
}
