//! Bench: regenerates **Table 2** (deployment-method criteria) and times
//! the container-archive operations that motivate the Singularity choice
//! (build, lookup, fsck at a 16-image registry).
//!
//! Run: `cargo bench --bench table2_deployment`

use medflow::container::platforms::{design_criteria_score, methods};
use medflow::container::{ContainerArchive, ImageDef};
use medflow::pipeline::registry;
use medflow::report::format_table2;
use medflow::util::bench::{bench, metric};

fn main() -> anyhow::Result<()> {
    println!("=== Table 2: pipeline deployment methods ===");
    println!("{}", format_table2());

    for m in methods() {
        metric(
            &format!("criteria_score.{}", m.name.replace('/', "_")),
            design_criteria_score(&m) as f64,
            "violations (lower=better)",
        );
    }

    // the deployment mechanics medflow actually uses
    let root = std::env::temp_dir().join(format!("medflow_bench_t2_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let mut archive = ContainerArchive::open(&root)?;
    for spec in registry() {
        archive.build(ImageDef {
            pipeline: spec.name.to_string(),
            version: spec.version.to_string(),
            base_env: "ubuntu22.04+xla0.5.1".into(),
            artifact: spec.artifact.map(String::from),
        })?;
    }
    metric("registry_images", archive.len() as f64, "images");
    bench("container_lookup_latest", 10, 1000, || {
        archive.latest("freesurfer").unwrap().sha256.clone()
    });
    bench("container_archive_fsck_16_images", 2, 50, || {
        archive.fsck().unwrap()
    });
    bench("container_archive_reopen", 2, 50, || {
        ContainerArchive::open(&root).unwrap().len()
    });
    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
