// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Bench: heterogeneous multi-environment placement — the ISSUE 5
//! tentpole numbers. One campaign split across a constrained HPC
//! cluster, a wide cloud lane pool, and a few local workstations, all
//! co-simulated against one shared staging path
//! (`coordinator::placement`, DESIGN.md §12), asserting in **both**
//! modes:
//!
//! * **CheapestFirst ≤ all-cloud** — the cheapest policy's total
//!   dollars never exceed pinning the whole campaign to the cloud;
//! * **DeadlineAware ≤ all-HPC** — bursting to meet a deadline never
//!   ends later than the all-HPC run it bursts away from (and a tight
//!   deadline actually uses ≥ 2 backends);
//! * **zero-fault determinism** — the same seed replays the placement
//!   co-simulation timing-for-timing, f64-exactly;
//! * **undominated frontier** — the emitted cost-vs-makespan Pareto
//!   set contains no dominated point (pairwise-checked, not trusted).
//!
//! Run: `cargo bench --bench placement_frontier` — full mode sweeps a
//! larger campaign, prints the frontier rows, and writes
//! `BENCH_placement_frontier.json`; `-- --test` is the reduced CI
//! sweep. `--check-baseline <path>` gates this run's wall clocks
//! against a committed baseline (`util::bench::check_baseline`).

use std::time::Instant;

use medflow::coordinator::placement::{
    execute, frontier_sweep, BackendKind, BackendSpec, PlacementConfig, PlacementOutcome,
    PlacementPolicy,
};
use medflow::coordinator::staged::synthetic_fault_campaign;
use medflow::faults::FaultModel;
use medflow::netsim::Env;
use medflow::report::format_frontier;
use medflow::slurm::ClusterSpec;
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;

const SEED: u64 = 42;

/// A fleet where bursting matters: the HPC cluster holds 512 one-core
/// slots (64 nodes), the cloud pool is 4× wider, locals are scarce.
fn fleet() -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(64, 8, 64),
                max_concurrent: 512,
            },
            faults: None,
            transfer_streams: 8,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 2_048 },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 32 },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

struct Timed {
    wall_s: f64,
    out: PlacementOutcome,
}

fn run(
    jobs: &[medflow::coordinator::staged::StagedJob],
    fleet: &[BackendSpec],
    policy: PlacementPolicy,
    cfg: &PlacementConfig,
) -> Timed {
    let t0 = Instant::now();
    let out = execute(jobs, fleet, policy, cfg);
    Timed {
        wall_s: t0.elapsed().as_secs_f64(),
        out,
    }
}

fn json_run(jobs: usize, policy: &str, t: &Timed) -> Json {
    let per = |k: usize| t.out.per_backend.get(k).map_or(0, |u| u.jobs) as f64;
    let mut o = Json::obj();
    o.set("jobs", Json::num(jobs as f64))
        .set("policy", Json::str(policy))
        .set("wall_s", Json::num(t.wall_s))
        .set("total_dollars", Json::num(t.out.total_cost_dollars))
        .set("sim_makespan_s", Json::num(t.out.makespan_s))
        .set("hpc_jobs", Json::num(per(0)))
        .set("cloud_jobs", Json::num(per(1)))
        .set("local_jobs", Json::num(per(2)));
    Json::Obj(o)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Heterogeneous placement frontier (DESIGN.md §12) ===");
    let n = if test_mode { 5_000 } else { 50_000 };
    let jobs = synthetic_fault_campaign(n, SEED);
    let fleet = fleet();
    let cfg = PlacementConfig {
        seed: SEED,
        ..Default::default()
    };
    let mut runs: Vec<Json> = Vec::new();

    // --- all-one-backend anchors (the two Fig. 1 points, plus local) ---
    let all_hpc = run(&jobs, &fleet, PlacementPolicy::Pinned(0), &cfg);
    let all_cloud = run(&jobs, &fleet, PlacementPolicy::Pinned(1), &cfg);
    for (name, t) in [("all-hpc", &all_hpc), ("all-cloud", &all_cloud)] {
        metric(&format!("{name}.n{n}.dollars"), t.out.total_cost_dollars, "$");
        metric(&format!("{name}.n{n}.sim_makespan_s"), t.out.makespan_s, "s");
        metric(&format!("{name}.n{n}.wall_s"), t.wall_s, "s");
        runs.push(json_run(n, name, t));
    }
    let ratio = all_cloud.out.total_cost_dollars / all_hpc.out.total_cost_dollars;
    metric("cloud_over_hpc_dollars", ratio, "x (paper: ~20x)");
    assert!(ratio > 5.0, "cloud must cost several × HPC (got {ratio:.1}×)");

    // --- CheapestFirst: never costlier than all-cloud ---
    let cheapest = run(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg);
    metric(&format!("cheapest.n{n}.dollars"), cheapest.out.total_cost_dollars, "$");
    metric(&format!("cheapest.n{n}.sim_makespan_s"), cheapest.out.makespan_s, "s");
    runs.push(json_run(n, "cheapest", &cheapest));
    assert!(
        cheapest.out.total_cost_dollars <= all_cloud.out.total_cost_dollars + 1e-9,
        "acceptance: CheapestFirst (${:.2}) must not exceed all-cloud (${:.2})",
        cheapest.out.total_cost_dollars,
        all_cloud.out.total_cost_dollars
    );

    // --- DeadlineAware: bursting never ends later than all-HPC ---
    let deadline_s = all_hpc.out.makespan_s * 0.6;
    let deadline = run(&jobs, &fleet, PlacementPolicy::DeadlineAware { deadline_s }, &cfg);
    metric(&format!("deadline.n{n}.dollars"), deadline.out.total_cost_dollars, "$");
    metric(&format!("deadline.n{n}.sim_makespan_s"), deadline.out.makespan_s, "s");
    runs.push(json_run(n, "deadline-0.6hpc", &deadline));
    assert!(
        deadline.out.makespan_s <= all_hpc.out.makespan_s + 1e-6,
        "acceptance: DeadlineAware makespan ({:.0} s) must not exceed all-HPC ({:.0} s)",
        deadline.out.makespan_s,
        all_hpc.out.makespan_s
    );
    let used = deadline.out.per_backend.iter().filter(|u| u.jobs > 0).count();
    assert!(used >= 2, "a 0.6×-makespan deadline must force a burst: {used} backend(s) used");
    let completed = deadline.out.staged.timings.iter().filter(|t| t.completed).count();
    assert_eq!(completed, n, "clean deadline run completes everything");

    // --- zero-fault determinism: same seed, identical records ---
    let replay = run(&jobs, &fleet, PlacementPolicy::DeadlineAware { deadline_s }, &cfg);
    assert_eq!(
        deadline.out.staged.timings, replay.out.staged.timings,
        "acceptance: zero-fault placement must replay f64-exactly"
    );
    assert_eq!(deadline.out.total_cost_dollars, replay.out.total_cost_dollars);
    assert_eq!(deadline.out.plan.assignment, replay.out.plan.assignment);
    println!("determinism OK at n={n}: deadline placement replays bit-identically");

    // --- fault injection across the fleet: conservation under harsh ---
    {
        let mut faulty_fleet = fleet.clone();
        for backend in &mut faulty_fleet {
            backend.faults = Some(FaultModel::harsh());
        }
        let fcfg = PlacementConfig {
            transfer_faults: Some(FaultModel::harsh()),
            ..cfg
        };
        let harsh = run(&jobs, &faulty_fleet, PlacementPolicy::DeadlineAware { deadline_s }, &fcfg);
        let done = harsh.out.staged.timings.iter().filter(|t| t.completed).count();
        assert_eq!(done as u64 + harsh.out.aborted, n as u64, "harsh run conserves jobs");
        assert!(!harsh.out.compute_events.is_empty(), "harsh rates must fail attempts");
        assert!(
            harsh.out.total_cost_dollars > deadline.out.total_cost_dollars,
            "wasted attempts must be billed: harsh ${:.2} vs clean ${:.2}",
            harsh.out.total_cost_dollars,
            deadline.out.total_cost_dollars
        );
        metric(&format!("deadline-harsh.n{n}.dollars"), harsh.out.total_cost_dollars, "$");
        metric(
            &format!("deadline-harsh.n{n}.failed_attempts"),
            (harsh.out.compute_events.len() + harsh.out.transfer_events.len()) as f64,
            "",
        );
        runs.push(json_run(n, "deadline-harsh", &harsh));
    }

    // --- the frontier: full cost-vs-makespan curve, no dominated point ---
    let steps = if test_mode { 2 } else { 6 };
    let t0 = Instant::now();
    let frontier = frontier_sweep(&jobs, &fleet, &cfg, steps);
    let frontier_wall_s = t0.elapsed().as_secs_f64();
    metric(&format!("frontier.n{n}.points"), frontier.len() as f64, "");
    metric(&format!("frontier.n{n}.wall_s"), frontier_wall_s, "s");
    print!("{}", format_frontier(&frontier));
    assert!(frontier.len() >= 2, "anchors alone span ≥ 2 undominated points");
    for (i, p) in frontier.iter().enumerate() {
        for q in &frontier[i + 1..] {
            let q_dominates = q.cost_dollars <= p.cost_dollars
                && q.makespan_s <= p.makespan_s
                && (q.cost_dollars < p.cost_dollars || q.makespan_s < p.makespan_s);
            let p_dominates = p.cost_dollars <= q.cost_dollars
                && p.makespan_s <= q.makespan_s
                && (p.cost_dollars < q.cost_dollars || p.makespan_s < q.makespan_s);
            assert!(
                !q_dominates && !p_dominates,
                "acceptance: frontier holds a dominated pair: {} vs {}",
                p.label,
                q.label
            );
        }
    }
    println!("frontier OK: {} undominated points from {} sweeps", frontier.len(), 3 + steps);

    // --- regression gate vs the committed baseline, then (full mode)
    // refresh the trajectory file ---
    gate_against_baseline(&runs);
    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("placement_frontier"))
            .set(
                "scenario",
                Json::str(
                    "synthetic campaign split across hpc (64×8-core nodes) / cloud (2048 \
                     lanes) / local (32 lanes) on one shared staging path, seed 42 (see \
                     benches/placement_frontier.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_placement_frontier.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("placement_frontier OK");
}
