// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Bench: chaos-resilience sweep — the DESIGN.md §15 tentpole numbers.
//! The shared synthetic campaign runs through `placement::execute_chaos`
//! under seeded infrastructure-fault schedules, swept over outage
//! severity (none / mild / harsh) × fleet size, asserting in **both**
//! modes:
//!
//! * **empty-schedule parity** — `execute_chaos` with no outages is
//!   f64-record-identical to `placement::execute`;
//! * **conservation** — with no fault model armed, every job completes
//!   under every severity: outages delay work, never lose it;
//! * **determinism** — the harshest swept scenario replays to identical
//!   timings and outage stats;
//! * **monotonicity** — on a single-backend fleet with nowhere to flee,
//!   growing the outage window never shortens the makespan.
//!
//! Run: `cargo bench --bench chaos_resilience` — full mode sweeps 2·10³
//! jobs per scenario and writes `BENCH_chaos_resilience.json`;
//! `-- --test` is the reduced CI sweep. `--check-baseline <path>` gates
//! this run's wall clocks against a committed baseline.

use std::time::Instant;

use medflow::coordinator::placement::{
    execute, execute_chaos, BackendKind, BackendSpec, PlacementOutcome, PlacementPolicy,
};
use medflow::coordinator::staged::synthetic_fault_campaign;
use medflow::coordinator::tenancy::TenancyConfig;
use medflow::faults::outage::{ComputeOutage, OutageMode, OutageSchedule, OutageSeverity};
use medflow::netsim::Env;
use medflow::slurm::ClusterSpec;
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;

const SEED: u64 = 42;

/// The placement trio at a swept scale: `scale` multiplies the Slurm
/// concurrency and both lane pools.
fn fleet(scale: usize) -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(8 * scale as u32, 8, 64),
                max_concurrent: 64 * scale as u32,
            },
            faults: None,
            transfer_streams: 8,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 256 * scale },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 8 * scale },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

fn config() -> medflow::coordinator::placement::PlacementConfig {
    TenancyConfig {
        seed: SEED,
        ..Default::default()
    }
    .placement()
}

fn json_run(severity: &str, fleet_name: &str, jobs: usize, wall_s: f64, out: &PlacementOutcome) -> Json {
    let completed = out.staged.timings.iter().filter(|t| t.completed).count();
    let o_stats = out.outage.unwrap_or_default();
    let mut o = Json::obj();
    o.set("scenario", Json::str(severity))
        .set("fleet", Json::str(fleet_name))
        .set("jobs", Json::num(jobs as f64))
        .set("wall_s", Json::num(wall_s))
        .set("sim_makespan_s", Json::num(out.makespan_s))
        .set("total_dollars", Json::num(out.total_cost_dollars))
        .set("completed", Json::num(completed as f64))
        .set("killed", Json::num(o_stats.killed as f64))
        .set("orphaned", Json::num(o_stats.orphaned as f64))
        .set("re_placed", Json::num(o_stats.re_placed as f64));
    Json::Obj(o)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Chaos-resilience sweep (DESIGN.md §15) ===");
    let n = if test_mode { 150 } else { 2_000 };
    let jobs = synthetic_fault_campaign(n, SEED);
    let cfg = config();
    let mut runs: Vec<Json> = Vec::new();

    // --- empty-schedule parity: the chaos path costs nothing ---
    {
        let fleet = fleet(1);
        let base = execute(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg);
        let chaos = execute_chaos(
            &jobs,
            &fleet,
            PlacementPolicy::CheapestFirst,
            &cfg,
            &OutageSchedule::empty(),
        );
        assert_eq!(
            chaos.staged.timings, base.staged.timings,
            "acceptance: empty schedule must replay execute f64-record-identically"
        );
        assert_eq!(chaos.per_backend, base.per_backend);
        assert_eq!(chaos.total_cost_dollars, base.total_cost_dollars);
        assert_eq!(chaos.makespan_s, base.makespan_s);
        println!("parity OK at n={n}: empty-schedule chaos ≡ execute, f64-exact");
    }

    // --- the sweep: severity × fleet size. The outage horizon is each
    // fleet's own fault-free makespan, so the synthetic windows always
    // land mid-campaign regardless of job count or fleet scale ---
    let mut harshest: Option<(PlacementOutcome, f64)> = None;
    for (fleet_name, scale) in [("trio-x1", 1usize), ("trio-x4", 4usize)] {
        let fleet = fleet(scale);
        let mut horizon_s = 1.0; // severity none ignores it; set by that run
        for severity in [OutageSeverity::None, OutageSeverity::Mild, OutageSeverity::Harsh] {
            let schedule = OutageSchedule::synthetic(severity, fleet.len(), horizon_s, SEED);
            let t0 = Instant::now();
            let out = execute_chaos(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
            let wall_s = t0.elapsed().as_secs_f64();
            let label = severity.label();
            let completed = out.staged.timings.iter().filter(|t| t.completed).count();
            assert_eq!(
                completed, n,
                "acceptance: {label}/{fleet_name} must conserve jobs — delayed, never lost"
            );
            assert_eq!(out.aborted, 0, "no fault model ⇒ nothing aborts");
            let o = out.outage.expect("chaos runs report outage stats");
            if severity == OutageSeverity::None {
                horizon_s = (out.makespan_s * 0.8).max(60.0);
            }
            if severity == OutageSeverity::Harsh {
                assert!(o.killed > 0, "harsh Down windows must kill work ({fleet_name}): {o:?}");
                if scale == 1 {
                    // the contended fleet queues deep behind 64 slots —
                    // onsets must find queued work to orphan there
                    assert!(o.orphaned > 0, "harsh onset must orphan the queue: {o:?}");
                }
            }
            metric(&format!("chaos.{label}.{fleet_name}.wall_s"), wall_s, "s");
            metric(
                &format!("chaos.{label}.{fleet_name}.sim_makespan_s"),
                out.makespan_s,
                "s",
            );
            metric(&format!("chaos.{label}.{fleet_name}.killed"), o.killed as f64, "");
            metric(&format!("chaos.{label}.{fleet_name}.orphaned"), o.orphaned as f64, "");
            runs.push(json_run(label, fleet_name, n, wall_s, &out));
            if severity == OutageSeverity::Harsh && scale == 4 {
                harshest = Some((out, horizon_s));
            }
        }
    }

    // --- determinism: the harshest scenario replays identically ---
    {
        let fleet = fleet(4);
        let (first, horizon_s) = harshest.expect("sweep ran");
        let schedule = OutageSchedule::synthetic(OutageSeverity::Harsh, fleet.len(), horizon_s, SEED);
        let replay = execute_chaos(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
        assert_eq!(
            replay.staged.timings, first.staged.timings,
            "acceptance: same seed must replay identical timings under harsh chaos"
        );
        assert_eq!(replay.outage, first.outage);
        assert_eq!(replay.total_cost_dollars, first.total_cost_dollars);
        println!("determinism OK: harsh/trio-x4 replays f64-identically");
    }

    // --- monotonicity: one backend, growing Down window ---
    {
        let solo = vec![BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Lanes { workers: 8 },
            faults: None,
            transfer_streams: 8,
        }];
        let small = if test_mode { 60 } else { 200 };
        let js = synthetic_fault_campaign(small, SEED);
        let mut last = execute(&js, &solo, PlacementPolicy::CheapestFirst, &cfg).makespan_s;
        for len_s in [0.0, 300.0, 1_500.0] {
            let mut schedule = OutageSchedule::empty();
            if len_s > 0.0 {
                schedule.compute.push(ComputeOutage {
                    backend: 0,
                    mode: OutageMode::Down,
                    start_s: 100.0,
                    end_s: 100.0 + len_s,
                });
            }
            let out = execute_chaos(&js, &solo, PlacementPolicy::CheapestFirst, &cfg, &schedule);
            assert!(
                out.makespan_s >= last - 1e-9,
                "acceptance: a longer outage may not finish earlier ({len_s} s window: {} < {last})",
                out.makespan_s
            );
            last = out.makespan_s;
        }
        println!("monotonicity OK: single-backend makespan is monotone in the window");
    }

    // --- regression gate vs the committed baseline, then (full mode)
    // refresh the trajectory file ---
    gate_against_baseline(&runs);
    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("chaos_resilience"))
            .set(
                "scenario",
                Json::str(
                    "2·10³-job campaign under seeded outage schedules (none/mild/harsh) on the \
                     hpc/cloud/local trio at two fleet scales, seed 42 (see \
                     benches/chaos_resilience.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_chaos_resilience.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("chaos_resilience OK");
}
