//! Bench: full-scan vs sharded-indexed vs incremental query discovery —
//! the ISSUE 1 tentpole numbers. Generates a Table 4–scale synthetic
//! catalog (structure only, stub bytes) and times the three query paths
//! of `medflow::query` over its largest dataset, then the whole catalog.
//!
//! Run: `cargo bench --bench query_index`

use medflow::archive::{EntityIndex, ProcessedIndex, SessionKey};
use medflow::pipeline::by_name;
use medflow::query::{find_runnable, find_runnable_sharded, IncrementalEngine};
use medflow::util::bench::{bench, metric};
use medflow::workload::{ingest_catalog_lite, ingest_cohort_lite, SynthCohort};

fn main() -> anyhow::Result<()> {
    println!("=== Indexed / incremental query vs full scan ===");
    let root = std::env::temp_dir().join(format!("medflow_bench_qidx_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let fs = by_name("freesurfer").unwrap();

    // --- single large dataset (ADNI-shaped at reduced scale) ---
    let cohort = SynthCohort {
        name: "ADNISCALE".into(),
        participants: 400,
        sessions: 1700,
        tier: medflow::archive::SecurityTier::General,
    };
    let t0 = std::time::Instant::now();
    let ds = ingest_cohort_lite(&root.join("bids"), &cohort, 7)?;
    metric("ingest_lite_seconds", t0.elapsed().as_secs_f64(), "s for 1700 sessions");

    let index = EntityIndex::load(&ds.index_dir().join("index"))?;
    metric("index.sessions", index.len() as f64, "");
    metric("index.shards", index.n_shards() as f64, "");
    let processed = ProcessedIndex::default();

    let full = bench("full_scan_find_runnable", 1, 10, || {
        find_runnable(&ds, &fs).unwrap().runnable.len()
    });
    let sharded = bench("sharded_indexed_query_w4", 1, 10, || {
        find_runnable_sharded(&ds, &fs, &index, &processed, 4)
            .unwrap()
            .0
            .runnable
            .len()
    });
    metric("speedup.sharded_vs_full", full.mean_s / sharded.mean_s, "x");

    // --- incremental re-query over an unchanged, fully processed archive ---
    let mut engine = IncrementalEngine::open(&ds)?;
    let (r1, s1) = engine.query(&ds, &fs, 4)?;
    metric("first_query.examined", s1.sessions_examined as f64, "");
    for job in &r1.runnable {
        let key = SessionKey::new(&job.subject, job.session.as_deref());
        engine.record_completion("freesurfer", &key);
    }
    engine.save(&ds)?;
    let incremental = bench("incremental_requery_unchanged", 1, 20, || {
        let (r, stats) = engine.query(&ds, &fs, 4).unwrap();
        assert_eq!(stats.sessions_examined, 0, "unchanged archive must not rescan");
        r.skipped.len()
    });
    metric("speedup.incremental_vs_full", full.mean_s / incremental.mean_s, "x");

    // --- the whole 20-dataset catalog at reduced scale ---
    let cat_root = root.join("catalog");
    std::fs::create_dir_all(&cat_root)?;
    let t1 = std::time::Instant::now();
    let sets = ingest_catalog_lite(&cat_root, 0.02, 11)?;
    let total_sessions: usize = sets
        .iter()
        .map(|d| EntityIndex::load(&d.index_dir().join("index")).map(|i| i.len()).unwrap_or(0))
        .sum();
    metric("catalog.datasets", sets.len() as f64, "");
    metric("catalog.sessions", total_sessions as f64, "");
    metric("catalog.ingest_seconds", t1.elapsed().as_secs_f64(), "s");

    bench("catalog_full_scan_all20", 1, 3, || {
        sets.iter()
            .map(|d| find_runnable(d, &fs).unwrap().runnable.len())
            .sum::<usize>()
    });
    let indexes: Vec<EntityIndex> = sets
        .iter()
        .map(|d| EntityIndex::load(&d.index_dir().join("index")).unwrap())
        .collect();
    bench("catalog_sharded_all20_w4", 1, 3, || {
        sets.iter()
            .zip(&indexes)
            .map(|(d, idx)| {
                find_runnable_sharded(d, &fs, idx, &processed, 4)
                    .unwrap()
                    .0
                    .runnable
                    .len()
            })
            .sum::<usize>()
    });

    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
