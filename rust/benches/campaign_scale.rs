// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Bench: event-engine scaling — the ISSUE 3 tentpole numbers. Sweeps
//! staged-campaign sizes 10³→10⁶ through the rewritten engines
//! (`coordinator::staged` + `netsim::scheduler` + `slurm`) and, on the
//! retained `--legacy` path (`medflow::sim_legacy`, the frozen pre-PR
//! engines), measures the before/after wall-clock head to head:
//!
//! * **parity** — at every A/B point the two generations must produce
//!   *identical* `StagedTiming`/`TransferStats` (deterministic seeds
//!   make exact equality the right bar; the full battery lives in
//!   `rust/tests/engine_parity.rs`);
//! * **perf smoke** — 10⁵ staged jobs must simulate under a generous
//!   wall-clock bound so an accidental O(n²) regression fails CI
//!   loudly, not silently;
//! * **speedup** — full mode runs the legacy path at 10⁵ too and
//!   asserts the rewrite is ≥10× faster, then records the whole
//!   trajectory in `BENCH_campaign_scale.json` at the repo root;
//! * **thread parity + scaling** — every mode asserts `threads=4` is
//!   record-identical to `threads=1` on a multi-backend fleet (the
//!   `coordinator::sync` window drivers, DESIGN.md §16); full mode
//!   sweeps threads ∈ {1, 2, 4, 8} at 10⁶ and runs the 10⁷ frontier
//!   at the host's available parallelism.
//!
//! Run: `cargo bench --bench campaign_scale` — or with `-- --test` for
//! the reduced sweep CI runs (parity at 10³/10⁴ + the 10⁵ smoke).

use std::time::Instant;

use medflow::coordinator::staged::{
    run_multi_threaded, run_staged, ComputeSim, LanePool, SlurmSim, StagedJob, StagedOutcome,
};
use medflow::netsim::scheduler::TransferScheduler;
use medflow::netsim::Env;
use medflow::sim_legacy;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;
use medflow::util::rng::Rng;

/// Stream cap on the campaign staging host: wide enough to be a real
/// fair-share problem, narrow enough that per-event work stays O(k).
const STREAM_CAP: usize = 16;
const WORKERS: usize = 512;
const SEED: u64 = 42;

/// Generous CI bound for the 10⁵-job smoke (expected: ~2 s release).
const SMOKE_BOUND_S: f64 = 120.0;

fn campaign(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1,
            ram_gb: 4,
            compute_s: 60.0 + rng.next_f64() * 540.0,
            bytes_in: 10_000_000 + rng.below(40_000_000),
            bytes_out: 2_000_000 + rng.below(8_000_000),
        })
        .collect()
}

struct Timed {
    wall_s: f64,
    out: StagedOutcome,
}

fn run_live_lanes(jobs: &[StagedJob]) -> Timed {
    let mut lanes = LanePool::new(WORKERS);
    let mut transfers = TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
    let t0 = Instant::now();
    let out = run_staged(jobs, &mut lanes, &mut transfers);
    Timed {
        wall_s: t0.elapsed().as_secs_f64(),
        out,
    }
}

fn run_legacy_lanes(jobs: &[StagedJob]) -> Timed {
    let mut lanes = sim_legacy::LanePool::new(WORKERS);
    let mut transfers = sim_legacy::TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
    let t0 = Instant::now();
    let out = sim_legacy::run_staged(jobs, &mut lanes, &mut transfers);
    Timed {
        wall_s: t0.elapsed().as_secs_f64(),
        out,
    }
}

/// A `fleet`-way lane-pool fleet through the window drivers
/// (`coordinator::sync`): `threads = 1` takes the inline sequential
/// driver, `threads > 1` shards the backends across worker threads.
/// Jobs round-robin across the fleet so every backend stays busy.
fn run_mt_lanes(jobs: &[StagedJob], fleet: usize, threads: usize) -> Timed {
    let mut pools: Vec<LanePool> = (0..fleet).map(|_| LanePool::new(WORKERS / fleet)).collect();
    let mut backends: Vec<&mut dyn ComputeSim> =
        pools.iter_mut().map(|p| p as &mut dyn ComputeSim).collect();
    let assignment: Vec<usize> = (0..jobs.len()).map(|i| i % fleet).collect();
    let mut transfers = TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
    let t0 = Instant::now();
    let out = run_multi_threaded(jobs, &assignment, &mut backends, &mut transfers, threads);
    Timed {
        wall_s: t0.elapsed().as_secs_f64(),
        out,
    }
}

fn run_live_slurm(jobs: &[StagedJob]) -> Timed {
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 2_000,
    };
    let mut sim = SlurmSim::new(Scheduler::new(ClusterSpec::accre()), "medflow", Some(handle));
    let mut transfers = TransferScheduler::for_env(Env::Hpc, STREAM_CAP, SEED);
    let t0 = Instant::now();
    let out = run_staged(jobs, &mut sim, &mut transfers);
    Timed {
        wall_s: t0.elapsed().as_secs_f64(),
        out,
    }
}

fn assert_complete(tag: &str, n: usize, out: &StagedOutcome) {
    assert_eq!(out.timings.len(), n, "{tag}: timing per job");
    assert!(
        out.timings.iter().all(|t| t.completed),
        "{tag}: every job must finish its verified copy-back"
    );
    assert_eq!(out.transfer.transfers, 2 * n, "{tag}: stage-in + copy-back per job");
}

fn json_run(jobs: usize, engine: &str, path: &str, t: &Timed) -> Json {
    let mut o = Json::obj();
    o.set("jobs", Json::num(jobs as f64))
        .set("engine", Json::str(engine))
        .set("path", Json::str(path))
        .set("wall_s", Json::num(t.wall_s))
        .set("sim_makespan_s", Json::num(t.out.makespan_s))
        .set("transfers", Json::num(t.out.transfer.transfers as f64));
    Json::Obj(o)
}

/// One A/B point: run the same campaign through both generations,
/// demand record-for-record parity, report the wall-clock ratio.
fn ab_point(n: usize, runs: &mut Vec<Json>) -> f64 {
    let jobs = campaign(n, SEED);
    let live = run_live_lanes(&jobs);
    let legacy = run_legacy_lanes(&jobs);
    assert_complete("live", n, &live.out);
    assert_eq!(
        live.out.timings, legacy.out.timings,
        "n={n}: rewritten engines must be record-for-record identical to sim_legacy"
    );
    assert_eq!(live.out.transfer, legacy.out.transfer, "n={n}: transfer stats");
    let speedup = legacy.wall_s / live.wall_s.max(1e-9);
    metric(&format!("lanes.n{n}.live_wall_s"), live.wall_s, "s");
    metric(&format!("lanes.n{n}.legacy_wall_s"), legacy.wall_s, "s");
    metric(&format!("lanes.n{n}.speedup"), speedup, "x");
    runs.push(json_run(n, "lanepool", "event-heap", &live));
    runs.push(json_run(n, "lanepool", "legacy", &legacy));
    speedup
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Campaign-scale event-engine sweep (DESIGN.md §10) ===");
    let mut runs: Vec<Json> = Vec::new();

    // --- A/B parity + speedup on the lane-pool campaign ---
    let ab_points: &[usize] = if test_mode {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    let mut last_speedup = 0.0;
    for &n in ab_points {
        last_speedup = ab_point(n, &mut runs);
    }
    if !test_mode {
        assert!(
            last_speedup >= 10.0,
            "acceptance: ≥10× speedup at 10⁵ staged jobs (got {last_speedup:.1}×)"
        );
    }

    // --- perf smoke: 10⁵ jobs through the live path under a hard bound ---
    {
        let n = 100_000;
        let jobs = campaign(n, SEED + 1);
        let live = run_live_lanes(&jobs);
        assert_complete("smoke", n, &live.out);
        metric("smoke.n100000.live_wall_s", live.wall_s, "s");
        assert!(
            live.wall_s < SMOKE_BOUND_S,
            "perf smoke: 10⁵ staged jobs took {:.1} s (bound {SMOKE_BOUND_S} s) — \
             an event-engine regression reintroduced superlinear cost",
            live.wall_s
        );
        runs.push(json_run(n, "lanepool", "event-heap-smoke", &live));
    }

    // --- SLURM co-simulation at ACCRE scale ---
    let slurm_points: &[usize] = if test_mode { &[10_000] } else { &[10_000, 100_000] };
    for &n in slurm_points {
        let jobs = campaign(n, SEED + 2);
        let live = run_live_slurm(&jobs);
        assert_complete("slurm", n, &live.out);
        metric(&format!("slurm.n{n}.live_wall_s"), live.wall_s, "s");
        runs.push(json_run(n, "slurm-accre", "event-heap", &live));
    }

    // --- full mode: the 10⁶ frontier + recorded trajectory ---
    if !test_mode {
        let n = 1_000_000;
        let jobs = campaign(n, SEED + 3);
        let live = run_live_lanes(&jobs);
        assert_complete("frontier", n, &live.out);
        metric("lanes.n1000000.live_wall_s", live.wall_s, "s");
        runs.push(json_run(n, "lanepool", "event-heap", &live));
    }

    // --- thread parity: the sharded window driver must be f64-exact ---
    // (ISSUE 9 acceptance: `--threads 4` record-identical to
    // `--threads 1` at 10⁵ jobs, asserted in --test mode too)
    {
        let n = 100_000;
        let jobs = campaign(n, SEED + 4);
        let seq = run_mt_lanes(&jobs, 4, 1);
        let par = run_mt_lanes(&jobs, 4, 4);
        assert_complete("mt-parity", n, &seq.out);
        assert_eq!(
            seq.out.timings, par.out.timings,
            "n={n}: --threads 4 must be record-identical to --threads 1"
        );
        assert_eq!(seq.out.transfer, par.out.transfer, "n={n}: mt transfer stats");
        assert_eq!(
            seq.out.makespan_s.to_bits(),
            par.out.makespan_s.to_bits(),
            "n={n}: mt makespan must match to the bit"
        );
        metric("mt.n100000.t1_wall_s", seq.wall_s, "s");
        metric("mt.n100000.t4_wall_s", par.wall_s, "s");
        runs.push(json_run(n, "lanepool-x4", "threads-1", &seq));
        runs.push(json_run(n, "lanepool-x4", "threads-4", &par));
    }

    // --- full mode: thread-scaling sweep at 10⁶ + the 10⁷ frontier ---
    if !test_mode {
        let n = 1_000_000;
        let jobs = campaign(n, SEED + 5);
        let mut first: Option<Timed> = None;
        for &threads in &[1usize, 2, 4, 8] {
            let run = run_mt_lanes(&jobs, 8, threads);
            assert_complete(&format!("sweep-t{threads}"), n, &run.out);
            metric(&format!("sweep.n1000000.t{threads}_wall_s"), run.wall_s, "s");
            runs.push(json_run(n, "lanepool-x8", &format!("threads-{threads}"), &run));
            match &first {
                Some(f) => {
                    assert_eq!(
                        f.out.timings, run.out.timings,
                        "threads={threads} must be record-identical to threads=1 at 10⁶"
                    );
                    metric(
                        &format!("sweep.n1000000.t{threads}_speedup"),
                        f.wall_s / run.wall_s.max(1e-9),
                        "x",
                    );
                }
                None => first = Some(run),
            }
        }

        let n = 10_000_000;
        let jobs = campaign(n, SEED + 6);
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let run = run_mt_lanes(&jobs, 8, threads);
        assert_complete("frontier-1e7", n, &run.out);
        metric("mt.n10000000.wall_s", run.wall_s, "s");
        metric("mt.n10000000.threads", threads as f64, "threads");
        runs.push(json_run(n, "lanepool-x8", "threads-native", &run));
    }

    // regression gate against the committed baseline (checked before
    // full mode overwrites it below)
    gate_against_baseline(&runs);

    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("campaign_scale"))
            .set(
                "scenario",
                Json::str(
                    "staged campaign on Env::Hpc, stream cap 16, 512 lanes / ACCRE, seed 42 \
                     (see benches/campaign_scale.rs)",
                ),
            )
            .set("speedup_1e5_legacy_over_live", Json::num(last_speedup))
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_campaign_scale.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("campaign_scale OK");
}
