// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Bench: multi-tenant co-simulation sweep — the ISSUE 6 tentpole
//! numbers. N independent tenant campaigns share ONE heterogeneous
//! fleet and ONE staging path (`coordinator::tenancy`, DESIGN.md §13),
//! swept from 1 tenant to 10³ tenants, asserting in **both** modes:
//!
//! * **N=1 parity** — a single unbounded tenant is f64-record-identical
//!   to `coordinator::placement::execute` on the same fleet and seed;
//! * **no starvation** — every tenant in a clean run completes every
//!   job it submitted, at every swept scale;
//! * **conservation under harsh faults** — completed + aborted equals
//!   submitted, tenant-by-tenant totals included;
//! * **determinism** — the largest swept scale replays to an identical
//!   `TenancyReport` (PartialEq over every f64 field).
//!
//! Run: `cargo bench --bench tenancy_sweep` — full mode sweeps up to
//! 1000 tenants and writes `BENCH_tenancy_sweep.json`; `-- --test` is
//! the reduced CI sweep. `--check-baseline <path>` gates this run's
//! wall clocks against a committed baseline.

use std::time::Instant;

use medflow::coordinator::placement::{execute, BackendKind, BackendSpec, PlacementPolicy};
use medflow::coordinator::staged::synthetic_fault_campaign;
use medflow::coordinator::tenancy::{
    run_tenants, synthetic_tenants, TenancyConfig, TenancyOutcome, TenantSpec,
};
use medflow::faults::FaultModel;
use medflow::netsim::Env;
use medflow::slurm::ClusterSpec;
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;

const SEED: u64 = 42;

/// The placement-frontier trio: a constrained HPC cluster, a wide
/// cloud lane pool, and a few local workstations on one staging path.
fn fleet() -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(64, 8, 64),
                max_concurrent: 512,
            },
            faults: None,
            transfer_streams: 8,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 2_048 },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 32 },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

fn config(queue_depth: Option<usize>) -> TenancyConfig {
    TenancyConfig {
        seed: SEED,
        transfer_faults: None,
        max_retries: 3,
        retry_backoff_s: 60.0,
        queue_depth,
    }
}

struct Timed {
    wall_s: f64,
    out: TenancyOutcome,
}

fn json_run(label: &str, n_tenants: usize, jobs: usize, t: &Timed) -> Json {
    let completed: usize = t.out.report.tenants.iter().map(|u| u.completed).sum();
    let mut o = Json::obj();
    o.set("tenants", Json::str(&format!("{n_tenants}")))
        .set("scenario", Json::str(label))
        .set("jobs", Json::num(jobs as f64))
        .set("wall_s", Json::num(t.wall_s))
        .set("sim_makespan_s", Json::num(t.out.report.makespan_s))
        .set("total_dollars", Json::num(t.out.report.total_cost_dollars))
        .set("completed", Json::num(completed as f64));
    Json::Obj(o)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Multi-tenant fleet co-simulation sweep (DESIGN.md §13) ===");
    let fleet = fleet();
    let jobs_per = if test_mode { 10 } else { 20 };
    let counts: &[usize] = if test_mode { &[1, 10, 100] } else { &[1, 10, 100, 1_000] };
    let mut runs: Vec<Json> = Vec::new();

    // --- N=1 parity: one unbounded tenant IS the placement engine ---
    {
        let n = if test_mode { 500 } else { 5_000 };
        let jobs = synthetic_fault_campaign(n, SEED);
        let cfg = config(None);
        let base = execute(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg.placement());
        let solo = vec![TenantSpec::new("solo", jobs.clone())];
        let one = run_tenants(&solo, &fleet, &cfg);
        assert_eq!(
            one.staged.timings, base.staged.timings,
            "acceptance: N=1 tenancy must replay placement f64-record-identically"
        );
        assert_eq!(one.report.total_cost_dollars, base.total_cost_dollars);
        assert_eq!(one.report.makespan_s, base.makespan_s);
        assert_eq!(one.assignment, base.plan.assignment);
        println!("parity OK at n={n}: N=1 tenancy ≡ placement, f64-exact");
    }

    // --- the sweep: 1 → 10³ tenants on one shared fleet ---
    let mut largest: Option<Timed> = None;
    for &n_tenants in counts {
        let mut tenants = synthetic_tenants(n_tenants, jobs_per, SEED);
        for (k, t) in tenants.iter_mut().enumerate() {
            t.weight = [1.0, 2.0, 4.0][k % 3];
        }
        let depth = if n_tenants > 1 { Some(256) } else { None };
        let cfg = config(depth);
        let t0 = Instant::now();
        let out = run_tenants(&tenants, &fleet, &cfg);
        let timed = Timed {
            wall_s: t0.elapsed().as_secs_f64(),
            out,
        };
        let total_jobs = n_tenants * jobs_per;
        metric(&format!("tenancy.t{n_tenants}.wall_s"), timed.wall_s, "s");
        metric(
            &format!("tenancy.t{n_tenants}.sim_makespan_s"),
            timed.out.report.makespan_s,
            "s",
        );
        metric(
            &format!("tenancy.t{n_tenants}.dollars"),
            timed.out.report.total_cost_dollars,
            "$",
        );
        for u in &timed.out.report.tenants {
            assert_eq!(
                u.completed, u.jobs,
                "acceptance: clean run must not starve tenant '{}' ({} of {} jobs done)",
                u.name, u.completed, u.jobs
            );
        }
        assert_eq!(timed.out.report.aborted, 0, "clean run aborts nothing");
        runs.push(json_run("clean-w124", n_tenants, total_jobs, &timed));
        largest = Some(timed);
    }

    // --- determinism: the largest scale replays report-identically ---
    {
        let n_tenants = *counts.last().unwrap();
        let mut tenants = synthetic_tenants(n_tenants, jobs_per, SEED);
        for (k, t) in tenants.iter_mut().enumerate() {
            t.weight = [1.0, 2.0, 4.0][k % 3];
        }
        let replay = run_tenants(&tenants, &fleet, &config(Some(256)));
        let first = largest.expect("sweep ran");
        assert_eq!(
            replay.report, first.out.report,
            "acceptance: same seed must replay an identical TenancyReport"
        );
        println!("determinism OK at {n_tenants} tenants: report replays identically");
    }

    // --- conservation under harsh faults on every backend ---
    {
        let n_tenants = if test_mode { 10 } else { 100 };
        let mut faulty_fleet = fleet.clone();
        for backend in &mut faulty_fleet {
            backend.faults = Some(FaultModel::harsh());
        }
        let mut cfg = config(Some(128));
        cfg.transfer_faults = Some(FaultModel::harsh());
        let tenants = synthetic_tenants(n_tenants, jobs_per, SEED);
        let t0 = Instant::now();
        let out = run_tenants(&tenants, &faulty_fleet, &cfg);
        let timed = Timed {
            wall_s: t0.elapsed().as_secs_f64(),
            out,
        };
        let total_jobs = n_tenants * jobs_per;
        let done: usize = timed.out.report.tenants.iter().map(|u| u.completed).sum();
        assert_eq!(
            done as u64 + timed.out.report.aborted,
            total_jobs as u64,
            "harsh run conserves jobs across tenants"
        );
        assert!(!timed.out.compute_events.is_empty(), "harsh rates must fail attempts");
        metric(&format!("tenancy-harsh.t{n_tenants}.wall_s"), timed.wall_s, "s");
        metric(&format!("tenancy-harsh.t{n_tenants}.aborted"), timed.out.report.aborted as f64, "");
        runs.push(json_run("harsh-depth128", n_tenants, total_jobs, &timed));
    }

    // --- regression gate vs the committed baseline, then (full mode)
    // refresh the trajectory file ---
    gate_against_baseline(&runs);
    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("tenancy_sweep"))
            .set(
                "scenario",
                Json::str(
                    "1 → 10³ synthetic tenants (weights cycled 1/2/4, depth cap 256) sharing \
                     the hpc/cloud/local trio on one staging path, seed 42 (see \
                     benches/tenancy_sweep.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_tenancy_sweep.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("tenancy_sweep OK");
}
