//! Bench: regenerates **Table 1** (compute-environment comparison) and
//! checks the reproduction shape against the paper's numbers.
//!
//! Run: `cargo bench --bench table1_compute_envs`

use medflow::compute::load_runtime;
use medflow::report::{format_table1, paper, table1};
use medflow::util::bench::{bench, metric};

fn main() -> anyhow::Result<()> {
    println!("=== Table 1: compute environments (paper §2.4 / §3) ===");
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    if runtime.is_none() {
        println!("(artifacts/ not built: duration-model only, no PJRT timing)");
    }

    let cols = table1(runtime.as_ref(), 42, 100, 100)?;
    println!("{}", format_table1(&cols));

    // paper-vs-measured metrics
    for (col, (bw, lat, rate, mins, cost)) in
        cols.iter().zip([paper::HPC, paper::CLOUD, paper::LOCAL])
    {
        let tag = col.env.name().replace(' ', "_");
        metric(&format!("{tag}.throughput_gbps"), col.throughput_gbps.0, "Gb/s");
        metric(&format!("{tag}.throughput_paper"), bw, "Gb/s");
        metric(&format!("{tag}.latency_ms"), col.latency_ms.0, "ms");
        metric(&format!("{tag}.latency_paper"), lat, "ms");
        metric(&format!("{tag}.rate_per_hr"), col.dollars_per_hour, "$");
        metric(&format!("{tag}.rate_paper"), rate, "$");
        metric(&format!("{tag}.freesurfer_mins"), col.freesurfer_minutes.0, "min");
        metric(&format!("{tag}.freesurfer_paper"), mins, "min");
        metric(&format!("{tag}.total_cost"), col.total_cost_dollars, "$");
        metric(&format!("{tag}.total_cost_paper"), cost, "$");
    }
    metric(
        "cloud_over_hpc_cost_ratio",
        cols[1].total_cost_dollars / cols[0].total_cost_dollars,
        "x (paper ~18.3)",
    );

    // wall-clock of the whole experiment harness
    bench("table1_full_experiment", 1, 5, || {
        table1(None, 7, 100, 100).unwrap()
    });
    if let Some(rt) = runtime.as_ref() {
        let vol = medflow::compute::default_volume(&mut medflow::util::rng::Rng::new(1));
        bench("pjrt_seg_pipeline_64cubed", 2, 10, || rt.run_seg(&vol).unwrap());
    }
    Ok(())
}
