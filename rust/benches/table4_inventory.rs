//! Bench: regenerates **Table 4** (dataset inventory) over real ingested
//! synthetic cohorts, checks catalog ground truth, and times
//! ingest/query/inventory at increasing cohort sizes.
//!
//! Run: `cargo bench --bench table4_inventory`

use medflow::archive::Archive;
use medflow::pipeline::by_name;
use medflow::query::find_runnable;
use medflow::report::{format_table4, table4};
use medflow::util::bench::{bench, metric};
use medflow::workload::{catalog, catalog_totals, ingest_cohort, scale_entry, SynthCohort};

fn main() -> anyhow::Result<()> {
    println!("=== Table 4: dataset inventory ===");

    // catalog ground truth (paper scale)
    let (participants, sessions, tb, raw, files) = catalog_totals();
    metric("paper.participants", participants as f64, "");
    metric("paper.sessions", sessions as f64, "");
    metric("paper.terabytes", tb, "TB");
    metric("paper.raw_images", raw as f64, "");
    metric("paper.total_files", files as f64, "");

    // ingest all 20 datasets at small scale and regenerate the table
    let root = std::env::temp_dir().join(format!("medflow_bench_t4_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let bids_parent = root.join("bids");
    let mut archive = Archive::at(&root.join("store"))?;
    for entry in catalog() {
        let cohort = scale_entry(&entry, 0.001);
        ingest_cohort(&mut archive, &bids_parent, &cohort, 8, 5)?;
    }
    let rows = table4(&archive, &bids_parent)?;
    println!("{}", format_table4(&rows));
    metric("ingested.datasets", rows.len() as f64, "");
    metric(
        "ingested.sessions",
        rows.iter().map(|r| r.sessions).sum::<u64>() as f64,
        "",
    );

    bench("table4_inventory_walk_20_datasets", 1, 10, || {
        table4(&archive, &bids_parent).unwrap()
    });

    // ingest + query scaling
    for (tag, participants) in [("small", 5u64), ("medium", 20), ("large", 80)] {
        let r2 = root.join(format!("scale_{tag}"));
        std::fs::create_dir_all(&r2)?;
        let mut a2 = Archive::at(&r2.join("store"))?;
        let cohort = SynthCohort {
            name: format!("SCALE{tag}").to_uppercase(),
            participants,
            sessions: participants * 2,
            tier: medflow::archive::SecurityTier::General,
        };
        let t0 = std::time::Instant::now();
        let ds = ingest_cohort(&mut a2, &r2.join("bids"), &cohort, 8, 2)?;
        metric(
            &format!("ingest_seconds.{tag}"),
            t0.elapsed().as_secs_f64(),
            &format!("s for {participants} participants"),
        );
        let fs = by_name("freesurfer").unwrap();
        bench(&format!("query_runnable_{tag}"), 2, 20, || {
            find_runnable(&ds, &fs).unwrap().runnable.len()
        });
        std::fs::remove_dir_all(&r2).ok();
    }

    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
