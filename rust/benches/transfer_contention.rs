//! Bench: shared-link transfer contention — the ISSUE 2 tentpole
//! numbers. Sweeps concurrent stream counts 1→64 per environment through
//! `netsim::scheduler` and checks, with assertions that run in both
//! modes, that
//!
//! * a single stream reproduces the Table 1 calibration (HPC 0.60,
//!   cloud 0.33, local 0.81 Gb/s within the netsim test tolerance),
//! * aggregate observed throughput never exceeds the bottleneck link
//!   capacity, and
//! * every stream's throughput is monotonically non-increasing in the
//!   stream count — max-min fair share is population-monotone, and
//!   per-transfer sampling is keyed by transfer id, so stream i sees
//!   identical draws at every sweep point and the comparison is
//!   pointwise.
//!
//! Run: `cargo bench --bench transfer_contention` — or with `-- --test`
//! for the reduced sweep CI runs so the assertions cannot bit-rot.
//! Full mode records the sweep in `BENCH_transfer_contention.json`;
//! `--check-baseline <path>` gates this run's wall clocks against a
//! committed baseline (`util::bench::check_baseline`).

use std::time::Instant;

use medflow::netsim::scheduler::{scheduler_bandwidth_experiment, Topology, TransferScheduler};
use medflow::netsim::Env;
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;
use medflow::util::units::mean_std;

const GB: u64 = 1_000_000_000;

/// Simulate `n` concurrent 1 GB streams; returns (per-stream observed
/// Gb/s ordered by id, aggregate Gb/s, link utilization, wall seconds).
fn contended(env: Env, n: usize, seed: u64) -> (Vec<f64>, f64, f64, f64) {
    let mut sim = TransferScheduler::for_env(env, n.max(1), seed);
    for i in 0..n {
        sim.submit_at(i as u64, 0, GB, 0.0);
    }
    let t0 = Instant::now();
    sim.run_to_completion();
    let wall_s = t0.elapsed().as_secs_f64();
    let mut recs = sim.records().to_vec();
    recs.sort_by_key(|r| r.id);
    let per_stream: Vec<f64> = recs.iter().map(|r| r.observed_gbps()).collect();
    let stats = sim.stats();
    (per_stream, stats.aggregate_gbps, stats.link_utilization, wall_s)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let counts: &[usize] = if test_mode {
        &[1, 2, 4, 8, 16]
    } else {
        &[1, 2, 4, 8, 16, 32, 64]
    };
    let k = if test_mode { 40 } else { 100 };

    println!("=== Shared-link transfer contention (netsim::scheduler) ===");
    let mut runs: Vec<Json> = Vec::new();
    for (env, want) in [(Env::Hpc, 0.60), (Env::Cloud, 0.33), (Env::Local, 0.81)] {
        let cap = Topology::of(env).bottleneck_gbps();
        println!("--- {} (bottleneck {cap:.3} Gb/s) ---", env.name());

        // 1-stream calibration must match the paper's Table 1 column
        let mean = mean_std(&scheduler_bandwidth_experiment(env, k, 42)).0;
        metric(&format!("{env:?}.single_stream_gbps"), mean, "Gb/s");
        assert!(
            (mean - want).abs() < 0.05,
            "{env:?}: single-stream mean {mean} drifted from Table 1 {want}"
        );

        let mut prev: Vec<f64> = Vec::new();
        for &n in counts {
            let (per_stream, aggregate, util, wall_s) = contended(env, n, 42);
            metric(
                &format!("{env:?}.n{n}.per_stream_gbps"),
                mean_std(&per_stream).0,
                "Gb/s mean",
            );
            metric(&format!("{env:?}.n{n}.aggregate_gbps"), aggregate, "Gb/s");
            metric(&format!("{env:?}.n{n}.link_utilization"), util, "");
            assert!(
                aggregate <= cap * (1.0 + 1e-9),
                "{env:?} n={n}: aggregate {aggregate} exceeds link capacity {cap}"
            );
            // pointwise per-id comparison against the previous sweep point
            for (id, (&now, &before)) in per_stream.iter().zip(&prev).enumerate() {
                assert!(
                    now <= before + 1e-6,
                    "{env:?} n={n} stream {id}: throughput rose ({now} > {before})"
                );
            }
            prev = per_stream;
            let mut o = Json::obj();
            o.set("env", Json::str(format!("{env:?}")))
                .set("streams", Json::num(n as f64))
                .set("wall_s", Json::num(wall_s))
                .set("per_stream_gbps", Json::num(mean_std(&prev).0))
                .set("aggregate_gbps", Json::num(aggregate))
                .set("link_utilization", Json::num(util));
            runs.push(Json::Obj(o));
        }
    }

    // regression gate against the committed baseline (checked before
    // full mode overwrites it below)
    gate_against_baseline(&runs);
    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("transfer_contention"))
            .set(
                "scenario",
                Json::str(
                    "n × 1 GB concurrent streams per environment through the \
                     contention-aware scheduler, seed 42 (see benches/transfer_contention.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_transfer_contention.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }
    println!("transfer_contention OK");
}
