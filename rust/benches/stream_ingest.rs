//! Bench: streaming-ingest year trace (DESIGN.md §17). A 10⁶-session
//! longitudinal campaign arrives steadily over 365 simulated days and
//! is drained by the epoch re-planning loop (`coordinator::stream`)
//! with weekly planning epochs, asserting in **both** modes:
//!
//! * **t=0 parity** — an `AtStart` trace degenerates to one epoch that
//!   is f64-record-identical to the one-shot `RunSpec` run, at any
//!   `--threads N`;
//! * **replay determinism** — the same `(config, seed)` reproduces the
//!   full `StreamReport`, every epoch row, and every latency sample;
//! * **bounded backlog** — with a fleet sized to the arrival rate, no
//!   epoch's admitted batch exceeds a small multiple of the expected
//!   per-epoch arrivals, and the stream drains (`backlog_final == 0`);
//! * **conservation** — arrived = processed + aborted + backlog.
//!
//! Run: `cargo bench --bench stream_ingest` — full mode drains the
//! 10⁶-session year and writes `BENCH_stream_ingest.json`; `-- --test`
//! is the reduced CI sweep at 10⁴ sessions. `--check-baseline <path>`
//! gates this run's wall clocks against a committed baseline.

use std::time::Instant;

use medflow::coordinator::placement::{default_fleet, BackendSpec, PlacementConfig};
use medflow::coordinator::stream::{
    run_stream, stream_campaign, ArrivalPattern, StreamConfig, StreamOutcome, DAY_S,
};
use medflow::coordinator::RunSpec;
use medflow::slurm::ClusterSpec;
use medflow::util::bench::{gate_against_baseline, metric};
use medflow::util::json::Json;

const SEED: u64 = 42;

/// The default heterogeneous fleet, scaled so the weekly arrival mass
/// (~330 core-seconds per session) drains well inside one epoch.
fn fleet() -> Vec<BackendSpec> {
    default_fleet(ClusterSpec::accre(), 2_000, 256, 16)
}

fn pcfg() -> PlacementConfig {
    PlacementConfig {
        seed: SEED,
        ..Default::default()
    }
}

fn json_run(scenario: &str, wall_s: f64, out: &StreamOutcome) -> Json {
    let r = &out.report;
    let mut o = Json::obj();
    o.set("scenario", Json::str(scenario))
        .set("sessions", Json::num(r.sessions as f64))
        .set("wall_s", Json::num(wall_s))
        .set("epochs", Json::num(r.epochs as f64))
        .set("processed", Json::num(r.processed as f64))
        .set("latency_p50_s", Json::num(r.latency_p50_s))
        .set("latency_p95_s", Json::num(r.latency_p95_s))
        .set("backlog_peak", Json::num(r.backlog_peak as f64))
        .set("cost_per_session_dollars", Json::num(r.cost_per_session_dollars))
        .set("total_dollars", Json::num(r.total_cost_dollars));
    Json::Obj(o)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("=== Streaming-ingest year trace (DESIGN.md §17) ===");
    let sessions = if test_mode { 10_000 } else { 1_000_000 };
    let fleet = fleet();
    let pcfg = pcfg();
    let mut runs: Vec<Json> = Vec::new();

    // --- t=0 parity: the stream loop is a composition of the one-shot
    // engines, not a new engine. Epoch 0 runs under the unsalted base
    // seed, so an AtStart trace must reproduce RunSpec::execute
    // record-for-record at any thread count ---
    let parity_threads: &[usize] = if test_mode { &[1, 4] } else { &[8] };
    for &threads in parity_threads {
        let cfg = StreamConfig {
            sessions,
            horizon_s: 7.0 * DAY_S,
            pattern: ArrivalPattern::AtStart,
            seed: SEED,
            ..Default::default()
        };
        let spec = RunSpec::new().threads(threads);
        let streamed = run_stream(&cfg, &fleet, &pcfg, &spec);
        let one_shot = spec.execute(&stream_campaign(&cfg), &fleet, &pcfg);
        assert_eq!(streamed.report.epochs, 1, "t=0 arrivals are one epoch");
        let one_shot_done: Vec<f64> = one_shot
            .staged
            .timings
            .iter()
            .filter(|t| t.completed)
            .map(|t| t.done_s)
            .collect();
        assert_eq!(
            streamed.latencies_s, one_shot_done,
            "acceptance: t=0 stream must replay the one-shot run f64-record-identically \
             (threads={threads})"
        );
        assert_eq!(streamed.report.total_cost_dollars, one_shot.total_cost_dollars);
        assert_eq!(streamed.epochs[0].makespan_s, one_shot.makespan_s);
        println!("parity OK at n={sessions}, threads={threads}: t=0 stream ≡ one-shot RunSpec");
    }

    // --- the trace: steady arrivals over a year (test mode: a quarter),
    // weekly planning epochs ---
    let cfg = StreamConfig {
        sessions,
        horizon_s: if test_mode { 91.0 * DAY_S } else { 365.0 * DAY_S },
        epoch_s: 7.0 * DAY_S,
        pattern: ArrivalPattern::Steady,
        seed: SEED,
        ..Default::default()
    };
    let spec = RunSpec::new().threads(if test_mode { 2 } else { 8 });
    let t0 = Instant::now();
    let out = run_stream(&cfg, &fleet, &pcfg, &spec);
    let wall_s = t0.elapsed().as_secs_f64();
    let r = &out.report;

    assert_eq!(
        r.processed + r.aborted + r.backlog_final,
        r.sessions,
        "acceptance: arrived = processed + aborted + backlog"
    );
    assert_eq!(r.backlog_final, 0, "a fleet sized to the rate must drain the stream");
    assert!(r.epochs > 10, "weekly epochs over the horizon must re-plan many times");
    let expected_per_epoch = sessions as f64 * cfg.epoch_s / cfg.horizon_s;
    assert!(
        (r.backlog_peak as f64) <= 3.0 * expected_per_epoch.ceil(),
        "acceptance: bounded backlog — peak admitted batch {} vs expected/epoch {:.0}",
        r.backlog_peak,
        expected_per_epoch
    );
    assert!(r.latency_p95_s >= r.latency_p50_s && r.latency_p50_s > 0.0);

    metric("stream.year.wall_s", wall_s, "s");
    metric("stream.year.latency_p50_s", r.latency_p50_s, "s");
    metric("stream.year.latency_p95_s", r.latency_p95_s, "s");
    metric("stream.year.cost_per_session", r.cost_per_session_dollars, "$");
    metric("stream.year.backlog_peak", r.backlog_peak as f64, "");
    metric("stream.year.epochs", r.epochs as f64, "");
    runs.push(json_run(if test_mode { "quarter-10e4" } else { "year-10e6" }, wall_s, &out));
    println!(
        "trace OK: {} sessions, {} epochs, p50 {:.0} s, p95 {:.0} s, ${:.4}/session",
        r.sessions, r.epochs, r.latency_p50_s, r.latency_p95_s, r.cost_per_session_dollars
    );

    // --- replay determinism: the full trace reproduces from the seed ---
    {
        let replay = run_stream(&cfg, &fleet, &pcfg, &spec);
        assert_eq!(
            replay.report, out.report,
            "acceptance: same (config, seed) must replay the report exactly"
        );
        assert_eq!(replay.epochs, out.epochs);
        assert_eq!(replay.latencies_s, out.latencies_s);
        println!("determinism OK: the trace replays f64-identically");
    }

    // --- regression gate vs the committed baseline, then (full mode)
    // refresh the trajectory file ---
    gate_against_baseline(&runs);
    if !test_mode {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("stream_ingest"))
            .set(
                "scenario",
                Json::str(
                    "10⁶-session year-long steady trace drained by weekly planning epochs on \
                     the default heterogeneous fleet, seed 42 (see benches/stream_ingest.rs)",
                ),
            )
            .set("runs", Json::Arr(runs));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_stream_ingest.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).expect("write bench trajectory");
        println!("trajectory written to {path}");
    }

    println!("stream_ingest OK");
}
