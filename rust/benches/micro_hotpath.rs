//! Micro-benchmarks of L3 hot paths — the §Perf optimization targets:
//! query engine, scheduler event loop, integrity hashing, transfer
//! sampling, JSON parsing, and the PJRT artifact execution itself.
//!
//! Run: `cargo bench --bench micro_hotpath`

use medflow::archive::{Archive, SecurityTier};
use medflow::compute::{default_volume, load_runtime};
use medflow::integrity::{crc32, sha256_hex, Manifest};
use medflow::netsim::{Env, NetProfile};
use medflow::pipeline::by_name;
use medflow::query::find_runnable;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler, SimJob};
use medflow::util::bench::{bench, metric};
use medflow::util::json::Json;
use medflow::util::rng::Rng;
use medflow::workload::{ingest_cohort, SynthCohort};

fn bench_scheduler(jobs: usize) -> f64 {
    let mut s = Scheduler::new(ClusterSpec::accre());
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 500,
    };
    let mut rng = Rng::new(1);
    for i in 0..jobs {
        s.submit(SimJob {
            id: i as u64,
            user: format!("u{}", i % 7),
            cores: 1 + (i % 4) as u32,
            ram_gb: 8,
            duration_s: 600.0 + rng.next_f64() * 3600.0,
            submit_s: (i / 100) as f64,
            array: Some(handle),
        });
    }
    let t0 = std::time::Instant::now();
    s.run_to_completion();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(s.records().len(), jobs);
    dt
}

fn main() -> anyhow::Result<()> {
    println!("=== L3 hot-path micro benches ===");

    // --- query engine over a real ingested tree ---
    let root = std::env::temp_dir().join(format!("medflow_bench_micro_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let mut archive = Archive::at(&root.join("store"))?;
    let cohort = SynthCohort {
        name: "MICRO".into(),
        participants: 50,
        sessions: 100,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 13)?;
    let fs = by_name("freesurfer").unwrap();
    let q = bench("query_100_sessions", 2, 30, || {
        find_runnable(&ds, &fs).unwrap().runnable.len()
    });
    metric("query_sessions_per_sec", 100.0 * q.per_sec(), "sessions/s");

    // --- scheduler throughput ---
    for jobs in [1_000usize, 5_000] {
        let dt = bench_scheduler(jobs);
        metric(
            &format!("scheduler_jobs_per_sec_{jobs}"),
            jobs as f64 / dt,
            "jobs/s",
        );
    }

    // --- integrity hashing ---
    let mb = vec![7u8; 1_000_000];
    let r = bench("sha256_1MB", 3, 50, || sha256_hex(&mb));
    metric("sha256_MBps", r.per_sec(), "MB/s");
    let r = bench("crc32_1MB", 3, 50, || crc32(&mb));
    metric("crc32_MBps", r.per_sec(), "MB/s");
    bench("manifest_of_tree", 2, 10, || {
        Manifest::of_tree(&root.join("store")).unwrap().len()
    });

    // --- transfer sampling (the netsim inner loop) ---
    let p = NetProfile::of(Env::Hpc);
    let mut rng = Rng::new(5);
    let r = bench("netsim_transfer_sample", 10, 10_000, || {
        p.transfer_time(&mut rng, 1_000_000_000)
    });
    metric("netsim_samples_per_sec", r.per_sec(), "samples/s");

    // --- JSON sidecar parsing ---
    let sidecar = r#"{"Modality":"MR","ProtocolName":"T1w_MPRAGE","EchoTime":2.95,
        "RepetitionTime":2300,"MagneticFieldStrength":3,"SliceCount":64,
        "Tags":["a","b","c"],"Nested":{"x":1,"y":[1,2,3]}}"#;
    let r = bench("json_parse_sidecar", 10, 10_000, || Json::parse(sidecar).unwrap());
    metric("json_parses_per_sec", r.per_sec(), "docs/s");

    // --- PJRT artifact execution (the real compute hot path) ---
    if let Some(rt) = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR"))) {
        let vol = default_volume(&mut Rng::new(1));
        let r = bench("pjrt_seg_64cubed", 2, 10, || rt.run_seg(&vol).unwrap());
        metric("pjrt_seg_vols_per_sec", r.per_sec(), "vols/s");
        let (dwi, bvals) = medflow::compute::default_dwi(&mut Rng::new(2));
        let r = bench("pjrt_dwi_7x64cubed", 2, 10, || rt.run_dwi(&dwi, &bvals).unwrap());
        metric("pjrt_dwi_shells_per_sec", r.per_sec(), "shells/s");
    } else {
        println!("(artifacts/ not built: skipping PJRT benches)");
    }

    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
