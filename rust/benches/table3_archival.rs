//! Bench: regenerates **Table 3** (archival solutions) and times the CLI
//! archive's own operations (store, usage walk, symlinked BIDS access) —
//! the quantitative counterpart to "flexibility" in the paper's argument.
//!
//! Run: `cargo bench --bench table3_archival`

use medflow::archive::solutions::{design_criteria_score, solutions};
use medflow::archive::{Archive, SecurityTier};
use medflow::report::format_table3;
use medflow::util::bench::{bench, metric};

fn main() -> anyhow::Result<()> {
    println!("=== Table 3: data archival solutions ===");
    println!("{}", format_table3());

    for s in solutions() {
        metric(
            &format!("criteria_score.{}", s.name.replace(' ', "_")),
            design_criteria_score(&s) as f64,
            "violations (lower=better)",
        );
    }

    // CLI-archive mechanics
    let root = std::env::temp_dir().join(format!("medflow_bench_t3_{}", std::process::id()));
    std::fs::create_dir_all(&root)?;
    let mut archive = Archive::at(&root)?;
    archive.register_dataset("BENCH", SecurityTier::General)?;
    let payload = vec![0u8; 100_000];
    let mut n = 0u64;
    bench("archive_store_100kb_file", 5, 200, || {
        n += 1;
        archive
            .store_raw("BENCH", &format!("sub-{n:05}/scan.nii.gz"), &payload)
            .unwrap()
    });
    bench("archive_usage_walk", 2, 20, || archive.usage("BENCH").unwrap());
    let usage = archive.usage("BENCH")?;
    metric("archive_files_after_bench", usage.file_count as f64, "files");
    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
