//! Bench: regenerates **Fig. 1** (the compute/bandwidth/cost/complexity
//! tradeoff quadrants) from the quantitative models and emits the CSV
//! series for plotting.
//!
//! Run: `cargo bench --bench fig1_tradeoff`

use medflow::report::{fig1, fig1_csv, format_fig1};
use medflow::util::bench::{bench, metric};

fn main() {
    println!("=== Fig 1: tradeoff quadrants ===");
    let points = fig1(42);
    println!("{}", format_fig1(&points));
    println!("--- CSV series ---\n{}", fig1_csv(&points));

    for p in &points {
        let tag = p.option.replace([' ', '(', ')'], "_");
        metric(&format!("{tag}.efficiency"), p.compute_efficiency, "/10");
        metric(&format!("{tag}.bandwidth"), p.bandwidth, "/10");
        metric(&format!("{tag}.cost"), p.cost, "/10 (lower better)");
        metric(&format!("{tag}.complexity"), p.complexity, "/10 (lower better)");
    }

    // the paper's Fig-1 claim, asserted quantitatively
    let adaptive = points.iter().find(|p| p.option.contains("Adaptive")).unwrap();
    let cloud = points.iter().find(|p| p.option == "Cloud").unwrap();
    let local = points.iter().find(|p| p.option.contains("Local")).unwrap();
    assert!(adaptive.compute_efficiency > local.compute_efficiency);
    assert!(adaptive.cost < cloud.cost && adaptive.complexity < cloud.complexity);
    metric("fig1_claim_holds", 1.0, "bool");

    bench("fig1_recompute", 2, 50, || fig1(7));
}
