//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Scheduler policy** — fairshare+backfill (ACCRE's setup) vs FIFO
//!    and vs no-backfill: makespan + mean queue wait on a mixed workload.
//! 2. **Failure/retry economics** — the §4 warning ("actual costs would
//!    likely be much greater due to processing errors … resubmitting
//!    failed jobs") quantified: cost-overrun factor per fault regime.
//! 3. **Checksum overhead** — what the §2.3 integrity policy costs on the
//!    staging path (sha256 vs crc32 vs none at realistic file sizes).
//!
//! Run: `cargo bench --bench ablations`

use medflow::faults::{expected_overrun, FaultModel};
use medflow::integrity::{crc32, sha256_hex};
use medflow::slurm::{ArrayHandle, ClusterSpec, Policy, Scheduler, SimJob};
use medflow::util::bench::{bench, metric};
use medflow::util::rng::Rng;
use medflow::util::units::mean_std;

/// Mixed workload: many short jobs from several users + a stream of long
/// wide jobs (the shape where backfill/fairshare matter).
fn workload(seed: u64) -> Vec<SimJob> {
    let mut rng = Rng::new(seed);
    let mut jobs = Vec::new();
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 64,
    };
    for i in 0..600u64 {
        let long = rng.next_f64() < 0.15;
        jobs.push(SimJob {
            id: i,
            user: format!("u{}", rng.below(5)),
            cores: if long { 8 } else { 1 + rng.below(2) as u32 },
            ram_gb: if long { 32 } else { 8 },
            duration_s: if long {
                rng.range_f64(4.0, 10.0) * 3600.0
            } else {
                rng.range_f64(0.2, 1.5) * 3600.0
            },
            submit_s: rng.next_f64() * 7200.0,
            array: if rng.below(2) == 0 { Some(handle) } else { None },
        });
    }
    jobs
}

fn run_policy(policy: Policy, seed: u64) -> (f64, f64) {
    let mut sched = Scheduler::with_policy(ClusterSpec::small(16, 16, 128), policy);
    for job in workload(seed) {
        sched.submit(job);
    }
    sched.run_to_completion();
    let waits: Vec<f64> = sched.records().iter().map(|r| r.queue_wait_s()).collect();
    let (mean_wait, _) = mean_std(&waits);
    (sched.makespan(), mean_wait)
}

fn main() {
    println!("=== Ablation 1: scheduler policy (600-job mixed workload) ===");
    let configs = [
        ("fairshare+backfill", Policy { fairshare: true, backfill: true }),
        ("fifo+backfill", Policy { fairshare: false, backfill: true }),
        ("fairshare_no_backfill", Policy { fairshare: true, backfill: false }),
        ("fifo_no_backfill", Policy { fairshare: false, backfill: false }),
    ];
    let mut baseline_wait = None;
    for (name, policy) in configs {
        let mut makespans = Vec::new();
        let mut waits = Vec::new();
        for seed in 0..5 {
            let (m, w) = run_policy(policy, seed);
            makespans.push(m / 3600.0);
            waits.push(w / 3600.0);
        }
        let (mk, _) = mean_std(&makespans);
        let (wt, _) = mean_std(&waits);
        metric(&format!("{name}.makespan_hours"), mk, "h");
        metric(&format!("{name}.mean_queue_wait_hours"), wt, "h");
        if name == "fairshare+backfill" {
            baseline_wait = Some(wt);
        } else if let Some(b) = baseline_wait {
            metric(&format!("{name}.wait_vs_baseline"), wt / b, "x");
        }
    }

    println!("\n=== Ablation 2: failure/retry cost overrun (paper §4) ===");
    for (name, model) in [
        ("fault_free", FaultModel::none()),
        ("typical", FaultModel::typical()),
        ("harsh", FaultModel::harsh()),
    ] {
        for retries in [0u32, 3] {
            let overrun = expected_overrun(&model, retries, 50_000, 11);
            metric(
                &format!("overrun.{name}.retries{retries}"),
                overrun,
                "x naive cost",
            );
        }
    }

    println!("\n=== Ablation 3: checksum overhead on staging (per 100 MB) ===");
    let payload = vec![0x5Au8; 10_000_000]; // 10 MB, scaled ×10 in metric
    let sha = bench("sha256_10MB", 2, 20, || sha256_hex(&payload));
    let crc = bench("crc32_10MB", 2, 20, || crc32(&payload));
    metric("sha256_seconds_per_100MB", sha.mean_s * 10.0, "s");
    metric("crc32_seconds_per_100MB", crc.mean_s * 10.0, "s");
    metric("sha_over_crc", sha.mean_s / crc.mean_s, "x");
    // context: staging 100 MB over the HPC path takes ~1.3 s (0.60 Gb/s),
    // so end-to-end sha256 adds a small, bounded fraction — the paper's
    // integrity-always policy is cheap insurance.
    metric("hpc_transfer_seconds_per_100MB", 100e6 * 8.0 / 0.60e9, "s");
}
