// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Parallel-engine parity battery (DESIGN.md §16): the conservative
//! time-window driver (`coordinator::sync`) must make every thread
//! count **f64-record-identical** to the sequential loop — not close,
//! not statistically equal, identical to the bit. The cases here aim
//! at the places a windowed parallel run could diverge:
//!
//! * **zero-length windows** — identical jobs land simultaneous events
//!   on every backend, so consecutive window bounds coincide;
//! * **simultaneous cross-backend events** — completions at the exact
//!   same instant on different backends must merge in backend index
//!   order, never thread-arrival order;
//! * **outage onset exactly at a window edge** — a chaos window whose
//!   start is bit-equal to a record instant from a clean run;
//! * **harsh faults + outages at 10³ jobs** — the full chaos surface
//!   replayed seed-identically at 1 vs N threads;
//! * **tenancy admission** — queue-depth admission control through the
//!   sharded drivers.

use medflow::coordinator::placement::{
    execute, execute_chaos, execute_chaos_threaded, execute_threaded, BackendKind, BackendSpec,
    PlacementConfig, PlacementPolicy,
};
use medflow::coordinator::staged::{
    run_multi, run_multi_threaded, ComputeSim, LanePool, SlurmSim, StagedJob, StagedOutcome,
};
use medflow::coordinator::tenancy::{
    run_tenants, run_tenants_chaos, run_tenants_chaos_threaded, run_tenants_threaded,
    TenancyConfig, TenantSpec,
};
use medflow::faults::outage::{ComputeOutage, OutageMode, OutageSchedule, OutageSeverity};
use medflow::faults::FaultModel;
use medflow::netsim::scheduler::TransferScheduler;
use medflow::netsim::Env;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::rng::Rng;

fn staged_jobs(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1 + rng.below(3) as u32,
            ram_gb: 1 + rng.below(8) as u32,
            compute_s: 20.0 + rng.next_f64() * 400.0,
            bytes_in: 10_000_000 + rng.below(150_000_000),
            bytes_out: 1_000_000 + rng.below(50_000_000),
        })
        .collect()
}

/// Run a lane-pool fleet (worker counts per pool) over a shared
/// transfer scheduler, jobs assigned round-robin across the pools.
fn run_lanes(jobs: &[StagedJob], pools: &[usize], threads: usize, cap: usize) -> StagedOutcome {
    let mut fleet: Vec<LanePool> = pools.iter().map(|&w| LanePool::new(w)).collect();
    let mut backends: Vec<&mut dyn ComputeSim> =
        fleet.iter_mut().map(|p| p as &mut dyn ComputeSim).collect();
    let assignment: Vec<usize> = (0..jobs.len()).map(|i| i % pools.len()).collect();
    let mut transfers = TransferScheduler::for_env(Env::Hpc, cap, 7);
    if threads == 0 {
        run_multi(jobs, &assignment, &mut backends, &mut transfers)
    } else {
        run_multi_threaded(jobs, &assignment, &mut backends, &mut transfers, threads)
    }
}

fn assert_same(tag: &str, a: &StagedOutcome, b: &StagedOutcome) {
    assert_eq!(a.timings, b.timings, "{tag}: timings");
    assert_eq!(a.transfer, b.transfer, "{tag}: transfer stats");
    assert_eq!(
        a.makespan_s.to_bits(),
        b.makespan_s.to_bits(),
        "{tag}: makespan must match to the bit"
    );
}

/// Identical jobs on identical backends: every stage-in admits at t=0,
/// shares the link rate equally, and lands at the same instant — so
/// the compute backends see simultaneous submissions, simultaneous
/// completions, and runs of zero-length windows between coinciding
/// event times. Any thread count must reproduce the sequential records.
#[test]
fn zero_length_windows_from_identical_jobs_stay_exact() {
    let jobs = vec![
        StagedJob {
            cores: 1,
            ram_gb: 2,
            compute_s: 300.0,
            bytes_in: 50_000_000,
            bytes_out: 5_000_000,
        };
        16
    ];
    // cap 64 ≥ all 32 transfers: nothing queues, everything overlaps
    let seq = run_lanes(&jobs, &[8, 8], 0, 64);

    // the scenario must actually produce simultaneous cross-backend
    // events, or this test gates nothing
    let t0 = seq.timings[0];
    assert!(
        seq.timings[1..].iter().all(|t| {
            t.compute_start_s.to_bits() == t0.compute_start_s.to_bits()
                && t.compute_end_s.to_bits() == t0.compute_end_s.to_bits()
        }),
        "identical jobs on symmetric backends must complete simultaneously"
    );

    for threads in [1, 2, 4, 8] {
        let par = run_lanes(&jobs, &[8, 8], threads, 64);
        assert_same(&format!("threads={threads}"), &seq, &par);
    }
}

/// A mixed campaign over a heterogeneous fleet — two uneven lane pools
/// plus a constrained SLURM cluster — where backends genuinely race:
/// simultaneous cross-backend events must merge in backend index
/// order. `threads = 8` on 3 backends also exercises the
/// more-workers-than-backends clamp.
#[test]
fn heterogeneous_fleet_parity_across_thread_counts() {
    let jobs = staged_jobs(240, 17);
    let assignment: Vec<usize> = (0..jobs.len()).map(|i| i % 3).collect();
    let run = |threads: usize| -> StagedOutcome {
        let mut lanes_a = LanePool::new(6);
        let mut lanes_b = LanePool::new(2);
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: 16,
        };
        let mut slurm =
            SlurmSim::new(Scheduler::new(ClusterSpec::small(6, 8, 64)), "medflow", Some(handle));
        let mut backends: Vec<&mut dyn ComputeSim> = vec![&mut lanes_a, &mut lanes_b, &mut slurm];
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 8, 17);
        if threads == 0 {
            run_multi(&jobs, &assignment, &mut backends, &mut transfers)
        } else {
            run_multi_threaded(&jobs, &assignment, &mut backends, &mut transfers, threads)
        }
    };
    let seq = run(0);
    assert!(seq.timings.iter().all(|t| t.completed));
    for threads in [1, 2, 3, 8] {
        assert_same(&format!("threads={threads}"), &seq, &run(threads));
    }
}

fn trio_fleet() -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(6, 8, 64),
                max_concurrent: 24,
            },
            faults: None,
            transfer_streams: 6,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 16 },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 2 },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

fn clean_cfg(seed: u64) -> PlacementConfig {
    PlacementConfig {
        seed,
        transfer_faults: None,
        max_retries: 3,
        retry_backoff_s: 30.0,
    }
}

/// An outage whose onset is **bit-equal** to a record instant from a
/// clean run — a compute completion and a stage-in landing, each of
/// which is a window bound in the windowed loop. The conservative
/// protocol must place the onset in the same window at every thread
/// count, or kills/orphans shift between runs.
#[test]
fn outage_onset_exactly_at_a_window_edge_is_thread_invariant() {
    let js = staged_jobs(120, 41);
    let fleet = trio_fleet();
    let cfg = clean_cfg(41);
    let clean = execute(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg);
    let mid = &clean.staged.timings[js.len() / 2];
    for onset in [mid.compute_end_s, mid.compute_start_s] {
        let mut schedule = OutageSchedule::empty();
        schedule.compute.push(ComputeOutage {
            backend: clean.plan.assignment[js.len() / 2],
            mode: OutageMode::Down,
            start_s: onset,
            end_s: onset + 400.0,
        });
        let seq = execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
        for threads in [2, 4] {
            let par = execute_chaos_threaded(
                &js,
                &fleet,
                PlacementPolicy::CheapestFirst,
                &cfg,
                &schedule,
                threads,
            );
            let tag = format!("onset={onset} threads={threads}");
            assert_same(&tag, &seq.staged, &par.staged);
            assert_eq!(seq.plan.assignment, par.plan.assignment, "{tag}");
            assert_eq!(seq.per_backend, par.per_backend, "{tag}");
            assert_eq!(seq.total_cost_dollars, par.total_cost_dollars, "{tag}");
            assert_eq!(seq.outage, par.outage, "{tag}");
            assert_eq!(seq.aborted, par.aborted, "{tag}");
        }
        assert!(seq.outage.expect("chaos run reports stats").windows > 0);
    }
}

/// The full chaos surface at campaign scale: harsh synthetic outages
/// *and* harsh transfer-checksum faults over 10³ jobs. One thread and
/// many threads must replay seed-identically, and the damage must
/// actually bite so the gate is not vacuous.
#[test]
fn harsh_chaos_with_transfer_faults_replays_identically_at_one_vs_many_threads() {
    let n = 1_000;
    let js = staged_jobs(n, 73);
    let fleet = trio_fleet();
    let schedule = OutageSchedule::synthetic(OutageSeverity::Harsh, fleet.len(), 20_000.0, 73);
    let cfg = PlacementConfig {
        seed: 73,
        transfer_faults: Some(FaultModel::harsh()),
        max_retries: 3,
        retry_backoff_s: 30.0,
    };
    let policy = PlacementPolicy::CheapestFirst;
    let run =
        |threads: usize| execute_chaos_threaded(&js, &fleet, policy, &cfg, &schedule, threads);
    let seq = run(1);
    for threads in [2, 4] {
        let par = run(threads);
        let tag = format!("threads={threads}");
        assert_same(&tag, &seq.staged, &par.staged);
        assert_eq!(seq.per_backend, par.per_backend, "{tag}");
        assert_eq!(seq.total_cost_dollars, par.total_cost_dollars, "{tag}");
        assert_eq!(seq.compute_events, par.compute_events, "{tag}");
        assert_eq!(seq.transfer_events, par.transfer_events, "{tag}");
        assert_eq!(seq.outage, par.outage, "{tag}");
        assert_eq!(seq.aborted, par.aborted, "{tag}");
    }
    // replay determinism at a fixed thread count, run to run
    let again = run(4);
    assert_same("replay", &seq.staged, &again.staged);
    let o = seq.outage.expect("chaos run reports outage stats");
    assert!(o.killed > 0 && o.orphaned > 0, "harsh schedule must bite: {o:?}");
    assert!(!seq.transfer_events.is_empty(), "harsh faults must bite");
}

/// Fault-free placement parity for every policy — the threaded entry
/// point is what `medflow place --threads N` calls.
#[test]
fn every_placement_policy_is_thread_invariant() {
    let js = staged_jobs(90, 53);
    let fleet = trio_fleet();
    let cfg = clean_cfg(53);
    for policy in [
        PlacementPolicy::CheapestFirst,
        PlacementPolicy::DeadlineAware { deadline_s: 2_000.0 },
        PlacementPolicy::BudgetCapped { budget_dollars: 5.0 },
        PlacementPolicy::Pinned(1),
    ] {
        let seq = execute(&js, &fleet, policy, &cfg);
        let par = execute_threaded(&js, &fleet, policy, &cfg, 4);
        assert_same(&format!("{policy:?}"), &seq.staged, &par.staged);
        assert_eq!(seq.plan.assignment, par.plan.assignment, "{policy:?}");
        assert_eq!(seq.total_cost_dollars, par.total_cost_dollars, "{policy:?}");
    }
}

/// Queue-depth admission control and SLO enforcement through the
/// sharded drivers: the tenancy layer frees admission slots off
/// per-window abort deltas, so a window-boundary slip would re-order
/// every later admission grant.
#[test]
fn tenancy_admission_and_chaos_are_thread_invariant() {
    let tenants = vec![
        TenantSpec {
            weight: 1.0,
            ..TenantSpec::new("a", staged_jobs(40, 11))
        },
        TenantSpec {
            weight: 2.0,
            ..TenantSpec::new("b", staged_jobs(40, 12))
        },
        TenantSpec {
            priority: 1,
            ..TenantSpec::new("c", staged_jobs(40, 13))
        },
    ];
    let fleet = trio_fleet();
    let cfg = TenancyConfig {
        seed: 91,
        queue_depth: Some(6),
        ..Default::default()
    };
    let seq = run_tenants(&tenants, &fleet, &cfg);
    let par = run_tenants_threaded(&tenants, &fleet, &cfg, 4);
    assert_same("tenants", &seq.staged, &par.staged);
    assert_eq!(seq.admit_s, par.admit_s, "admission grant instants");
    assert_eq!(seq.assignment, par.assignment);
    assert_eq!(seq.report.tenants, par.report.tenants);
    assert_eq!(seq.report.per_backend, par.report.per_backend);

    let schedule = OutageSchedule::synthetic(OutageSeverity::Harsh, fleet.len(), 20_000.0, 91);
    let seq = run_tenants_chaos(&tenants, &fleet, &cfg, &schedule, true);
    let par = run_tenants_chaos_threaded(&tenants, &fleet, &cfg, &schedule, true, 4);
    assert_same("tenants-chaos", &seq.staged, &par.staged);
    assert_eq!(seq.admit_s, par.admit_s, "chaos admission grant instants");
    assert_eq!(seq.report.tenants, par.report.tenants);
    assert_eq!(seq.report.outage, par.report.outage);
    assert_eq!(seq.report.aborted, par.report.aborted);
}
