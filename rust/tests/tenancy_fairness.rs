// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Tenancy fairness gates (DESIGN.md §13): the ISSUE 6 test battery
//! over `coordinator::tenancy` — starvation freedom, weighted-share
//! convergence, priority dominance, seed determinism, and
//! admission-cap monotonicity, at 10²–10³ concurrent tenants.
//!
//! Fairness is asserted on `TenantUsage::contended_share` — the share
//! of admitted service granted while *every* tenant still had pending
//! work — against `entitlement = weight / Σ weights`, within the ±10%
//! relative tolerance DESIGN.md §13 derives from one-job admission
//! granularity.

use medflow::coordinator::placement::{BackendKind, BackendSpec};
use medflow::coordinator::staged::StagedJob;
use medflow::coordinator::tenancy::{run_tenants, synthetic_tenants, TenancyConfig};
use medflow::faults::FaultModel;
use medflow::netsim::Env;
use medflow::slurm::ClusterSpec;

fn uniform_jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
    (0..n)
        .map(|_| StagedJob {
            cores: 1,
            ram_gb: 1,
            compute_s,
            bytes_in: 20_000_000,
            bytes_out: 5_000_000,
        })
        .collect()
}

/// A single Hpc-env lane pool: speed factor 1.0, so uniform jobs admit
/// uniform effective service — fair shares reduce to admission counts.
fn lanes_fleet(workers: usize, streams: usize) -> Vec<BackendSpec> {
    vec![BackendSpec {
        name: "hpc".into(),
        env: Env::Hpc,
        kind: BackendKind::Lanes { workers },
        faults: None,
        transfer_streams: streams,
    }]
}

fn config(seed: u64, queue_depth: Option<usize>) -> TenancyConfig {
    TenancyConfig {
        seed,
        queue_depth,
        ..Default::default()
    }
}

/// Acceptance: 10³ concurrent tenants behind a binding admission cap —
/// nobody starves. Every tenant's every job is admitted (finite
/// `admit_s`) and completes; the clean run aborts nothing.
#[test]
fn no_tenant_starved_at_1000_tenants() {
    let tenants = synthetic_tenants(1_000, 4, 42);
    let fleet = lanes_fleet(64, 16);
    let out = run_tenants(&tenants, &fleet, &config(42, Some(256)));
    assert_eq!(out.report.aborted, 0, "clean run must abort nothing");
    assert_eq!(out.report.tenants.len(), 1_000);
    for u in &out.report.tenants {
        assert_eq!(u.jobs, 4);
        assert_eq!(
            u.completed, u.jobs,
            "tenant '{}' starved: {} of {} jobs completed",
            u.name, u.completed, u.jobs
        );
    }
    assert!(
        out.admit_s.iter().all(|t| t.is_finite()),
        "every job must eventually be admitted"
    );
}

/// Acceptance: 10² tenants with weights cycled 1/2/4 behind a binding
/// cap — each tenant's contended-window share lands within ±10%
/// (relative) of its weight entitlement. Uniform jobs make service
/// proportional to admissions, so this is a pure arbiter property.
#[test]
fn weighted_shares_track_entitlement_at_100_tenants() {
    let weights = [1.0, 2.0, 4.0];
    let mut tenants = synthetic_tenants(100, 1, 7);
    for (k, t) in tenants.iter_mut().enumerate() {
        t.weight = weights[k % 3];
        t.jobs = uniform_jobs(120, 100.0);
    }
    let fleet = lanes_fleet(16, 8);
    let out = run_tenants(&tenants, &fleet, &config(7, Some(32)));
    let total_w: f64 = tenants.iter().map(|t| t.weight).sum();
    for (k, u) in out.report.tenants.iter().enumerate() {
        let ent = weights[k % 3] / total_w;
        assert_eq!(u.entitlement, ent, "tenant '{}' entitlement", u.name);
        assert!(
            (u.contended_share - ent).abs() <= 0.10 * ent,
            "tenant '{}' (weight {}): contended share {:.5} vs entitlement {:.5} (> ±10%)",
            u.name,
            u.weight,
            u.contended_share,
            ent
        );
    }
}

/// Equal weights at 10³ tenants degenerate to round-robin: every
/// tenant's contended share sits within ±10% of 1/1000 (the deviation
/// is exactly the one-quantum edge effect at the window boundary).
#[test]
fn equal_weights_round_robin_at_1000_tenants() {
    let mut tenants = synthetic_tenants(1_000, 1, 9);
    for t in tenants.iter_mut() {
        t.jobs = uniform_jobs(12, 50.0);
    }
    let fleet = lanes_fleet(64, 16);
    let out = run_tenants(&tenants, &fleet, &config(9, Some(100)));
    for u in &out.report.tenants {
        assert_eq!(u.entitlement, 1.0 / 1_000.0);
        assert!(
            (u.contended_share - u.entitlement).abs() <= 0.10 * u.entitlement,
            "tenant '{}': contended share {:.6} vs 0.001 (> ±10%)",
            u.name,
            u.contended_share
        );
    }
}

/// Promoting one tenant to a higher priority tier never makes *its*
/// makespan worse: strict-priority admission puts all of its pending
/// jobs ahead of every priority-0 tenant.
#[test]
fn promoted_tenant_finishes_no_later_than_demoted() {
    let run = |promoted_priority: u32| {
        let mut tenants = synthetic_tenants(20, 1, 11);
        for t in tenants.iter_mut() {
            t.jobs = uniform_jobs(30, 80.0);
        }
        tenants[7].priority = promoted_priority;
        let fleet = lanes_fleet(8, 4);
        run_tenants(&tenants, &fleet, &config(11, Some(8)))
    };
    let demoted = run(0);
    let promoted = run(1);
    let d = &demoted.report.tenants[7];
    let p = &promoted.report.tenants[7];
    assert_eq!(p.completed, p.jobs);
    assert!(
        p.makespan_s <= d.makespan_s + 1e-9,
        "promotion must not slow tenant 7: promoted {:.1} s vs demoted {:.1} s",
        p.makespan_s,
        d.makespan_s
    );
    // and the promotion is not vacuous — it strictly helps here
    assert!(p.makespan_s < d.makespan_s, "a binding cap must make priority matter");
    // everyone still finishes in both runs
    for out in [&demoted, &promoted] {
        assert!(out.report.tenants.iter().all(|u| u.completed == u.jobs));
    }
}

/// Seed determinism under harsh faults on a mixed Slurm + lanes fleet:
/// the same seed replays an identical `TenancyReport` — every f64 of
/// cost, waits, shares, and makespans — plus identical record streams.
#[test]
fn same_seed_replays_identical_report_under_harsh_faults() {
    let tenants = synthetic_tenants(50, 20, 13);
    let mut fleet = vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(8, 8, 64),
                max_concurrent: 48,
            },
            faults: None,
            transfer_streams: 6,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 24 },
            faults: None,
            transfer_streams: 4,
        },
    ];
    for backend in &mut fleet {
        backend.faults = Some(FaultModel::harsh());
    }
    let mut cfg = config(13, Some(64));
    cfg.transfer_faults = Some(FaultModel::harsh());
    let a = run_tenants(&tenants, &fleet, &cfg);
    let b = run_tenants(&tenants, &fleet, &cfg);
    assert_eq!(a.report, b.report, "same seed must replay the report f64-identically");
    assert_eq!(a.staged.timings, b.staged.timings);
    assert_eq!(a.admit_s, b.admit_s);
    assert_eq!(a.compute_events, b.compute_events);
    assert_eq!(a.transfer_events, b.transfer_events);
    assert!(!a.compute_events.is_empty(), "harsh rates over 1000 jobs must fail attempts");
    // faults bite, but conservation still holds tenant-by-tenant
    let done: usize = a.report.tenants.iter().map(|u| u.completed).sum();
    assert_eq!(done as u64 + a.report.aborted, 1_000);
}

/// Admission-cap monotonicity: raising the depth cap never increases
/// the number of jobs whose *admission* wait violates a fixed bound.
/// Uniform clean lanes-only runs keep the admission sequence
/// cap-independent, so a larger cap admits every job weakly earlier.
#[test]
fn raising_depth_cap_never_increases_wait_bound_violations() {
    let mut tenants = synthetic_tenants(30, 1, 17);
    for t in tenants.iter_mut() {
        t.jobs = uniform_jobs(40, 50.0);
    }
    let fleet = lanes_fleet(16, 8);
    const BOUND_S: f64 = 1_000.0;
    let mut violations = Vec::new();
    for cap in [8usize, 64, 1_200] {
        let out = run_tenants(&tenants, &fleet, &config(17, Some(cap)));
        assert!(out.report.tenants.iter().all(|u| u.completed == u.jobs));
        let v = out.admit_s.iter().filter(|&&t| t > BOUND_S).count();
        violations.push((cap, v));
    }
    for w in violations.windows(2) {
        assert!(
            w[1].1 <= w[0].1,
            "raising the cap {} → {} must not add violations ({} → {})",
            w[0].0,
            w[1].0,
            w[0].1,
            w[1].1
        );
    }
    // the bound actually discriminates at the tight cap — not vacuous
    assert!(violations[0].1 > 0, "cap 8 must violate the {BOUND_S} s bound somewhere");
    // cap 1200 covers every job: the whole campaign admits at t=0
    assert_eq!(violations[2].1, 0, "a cap ≥ total jobs admits everything immediately");
}
