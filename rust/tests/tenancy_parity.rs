// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Tenancy parity gates (DESIGN.md §13): a single tenant with no
//! admission cap IS the placement engine. `run_tenants` over one
//! `TenantSpec` must be **f64-record-identical** to
//! `coordinator::placement::execute` on the same fleet, seed, and
//! policy — timings, transfer stats, per-backend usage, dollars, and
//! fault-event streams — for every policy, clean and under harsh
//! faults. The multi-tenant machinery must cost exactly nothing in
//! bit-drift when there is nothing to arbitrate.

use medflow::coordinator::placement::{execute, BackendKind, BackendSpec, PlacementPolicy};
use medflow::coordinator::staged::StagedJob;
use medflow::coordinator::tenancy::{run_tenants, TenancyConfig, TenantSpec};
use medflow::faults::FaultModel;
use medflow::netsim::Env;
use medflow::slurm::ClusterSpec;
use medflow::util::rng::Rng;

fn staged_jobs(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1 + rng.below(3) as u32,
            ram_gb: 1 + rng.below(8) as u32,
            compute_s: 20.0 + rng.next_f64() * 400.0,
            bytes_in: 10_000_000 + rng.below(150_000_000),
            bytes_out: 1_000_000 + rng.below(50_000_000),
        })
        .collect()
}

/// The heterogeneous trio — a constrained Slurm cluster plus two lane
/// pools — so parity crosses every engine kind in one run.
fn trio_fleet() -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(6, 8, 64),
                max_concurrent: 24,
            },
            faults: None,
            transfer_streams: 6,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 16 },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 2 },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

fn solo(policy: PlacementPolicy, jobs: Vec<StagedJob>) -> Vec<TenantSpec> {
    vec![TenantSpec {
        policy,
        ..TenantSpec::new("solo", jobs)
    }]
}

fn every_policy() -> [PlacementPolicy; 6] {
    [
        PlacementPolicy::CheapestFirst,
        PlacementPolicy::DeadlineAware { deadline_s: 2_000.0 },
        PlacementPolicy::BudgetCapped { budget_dollars: 5.0 },
        PlacementPolicy::Pinned(0),
        PlacementPolicy::Pinned(1),
        PlacementPolicy::Pinned(2),
    ]
}

/// Acceptance: clean N=1 parity across every policy — the whole
/// record surface matches f64-exactly, and the tenancy-only telemetry
/// is coherent with it (all jobs admitted at t=0, all completed).
#[test]
fn single_unbounded_tenant_is_record_identical_to_placement() {
    let js = staged_jobs(120, 61);
    let fleet = trio_fleet();
    for policy in every_policy() {
        let cfg = TenancyConfig {
            seed: 61,
            ..Default::default()
        };
        let base = execute(&js, &fleet, policy, &cfg.placement());
        let one = run_tenants(&solo(policy, js.clone()), &fleet, &cfg);
        assert_eq!(one.staged.timings, base.staged.timings, "{policy:?}");
        assert_eq!(one.staged.makespan_s, base.staged.makespan_s, "{policy:?}");
        assert_eq!(one.staged.transfer, base.staged.transfer, "{policy:?}");
        assert_eq!(one.assignment, base.plan.assignment, "{policy:?}");
        assert_eq!(one.report.per_backend, base.per_backend, "{policy:?}");
        assert_eq!(one.report.total_cost_dollars, base.total_cost_dollars, "{policy:?}");
        assert_eq!(one.report.makespan_s, base.makespan_s, "{policy:?}");
        assert_eq!(one.report.aborted, base.aborted, "{policy:?}");
        assert!(one.compute_events.is_empty() && base.compute_events.is_empty());
        assert!(one.transfer_events.is_empty() && base.transfer_events.is_empty());

        let u = &one.report.tenants[0];
        assert_eq!(u.completed, js.len(), "{policy:?}");
        assert!(one.admit_s.iter().all(|&t| t == 0.0), "unbounded: all admitted at t=0");
        assert_eq!(u.entitlement, 1.0, "a lone tenant is entitled to the whole fleet");
        assert!(
            (u.cost_dollars - base.total_cost_dollars).abs() < 1e-6,
            "{policy:?}: tenant fold ${} vs placement fold ${}",
            u.cost_dollars,
            base.total_cost_dollars
        );
    }
}

/// The same parity under harsh compute + transfer faults: retry
/// traces, wasted-minute billing, aborts, and both fault-event streams
/// replay identically through the tenancy path.
#[test]
fn single_tenant_parity_holds_under_harsh_faults() {
    let js = staged_jobs(90, 67);
    let mut fleet = trio_fleet();
    for backend in &mut fleet {
        backend.faults = Some(FaultModel::harsh());
    }
    for policy in every_policy() {
        let cfg = TenancyConfig {
            seed: 67,
            transfer_faults: Some(FaultModel::harsh()),
            ..Default::default()
        };
        let base = execute(&js, &fleet, policy, &cfg.placement());
        let one = run_tenants(&solo(policy, js.clone()), &fleet, &cfg);
        assert_eq!(one.staged.timings, base.staged.timings, "{policy:?}");
        assert_eq!(one.staged.transfer, base.staged.transfer, "{policy:?}");
        assert_eq!(one.report.per_backend, base.per_backend, "{policy:?}");
        assert_eq!(one.report.total_cost_dollars, base.total_cost_dollars, "{policy:?}");
        assert_eq!(one.report.aborted, base.aborted, "{policy:?}");
        assert_eq!(one.compute_events, base.compute_events, "{policy:?}");
        assert_eq!(one.transfer_events, base.transfer_events, "{policy:?}");
    }
    // harsh rates over 90 jobs × 6 policies must actually exercise the
    // fault path somewhere, or the parity above is vacuous
    let cfg = TenancyConfig {
        seed: 67,
        transfer_faults: Some(FaultModel::harsh()),
        ..Default::default()
    };
    let one = run_tenants(&solo(PlacementPolicy::CheapestFirst, js), &fleet, &cfg);
    assert!(!one.compute_events.is_empty() || !one.transfer_events.is_empty());
}
