//! Integration tests for the sharded/incremental query subsystem (ISSUE 1
//! satellite): skip-reason CSV output, `AlreadyProcessed` served from the
//! persistent processed index, and `MissingPrior` unblocking when a
//! prerequisite pipeline completes — all through the public API and the
//! coordinator campaign path.

use std::path::PathBuf;

use medflow::archive::{Archive, EntityIndex, ProcessedIndex, SecurityTier, SessionKey};
use medflow::bids::{BidsDataset, BidsName, Modality};
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::pipeline::by_name;
use medflow::query::{find_runnable, find_runnable_sharded, IncrementalEngine, SkipReason};
use medflow::workload::{ingest_cohort, ingest_cohort_lite, SynthCohort};

fn tmproot(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("medflow_itq_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn stub_image(ds: &BidsDataset, sub: &str, ses: Option<&str>, m: Modality) {
    let name = BidsName::new(sub, ses, m);
    let p = ds.raw_path(&name, "nii.gz");
    std::fs::create_dir_all(p.parent().unwrap()).unwrap();
    std::fs::write(&p, b"img").unwrap();
}

#[test]
fn skip_csv_identical_across_all_three_query_paths() {
    let root = tmproot("csv");
    let ds = BidsDataset::create(&root, "CSVDS").unwrap();
    stub_image(&ds, "01", Some("a"), Modality::T1w);
    stub_image(&ds, "02", Some("a"), Modality::Dwi); // NoT1w for freesurfer
    let name = BidsName::new("03", Some("a"), Modality::T1w);
    std::fs::create_dir_all(ds.raw_dir(&name).parent().unwrap()).unwrap(); // empty session
    let fs = by_name("freesurfer").unwrap();

    let full = find_runnable(&ds, &fs).unwrap();
    let index = EntityIndex::build(&ds, 4).unwrap();
    let (sharded, _) =
        find_runnable_sharded(&ds, &fs, &index, &ProcessedIndex::default(), 2).unwrap();
    let mut engine = IncrementalEngine::open(&ds).unwrap();
    let (incremental, _) = engine.query(&ds, &fs, 2).unwrap();

    let csv = full.skip_csv();
    assert_eq!(csv, sharded.skip_csv());
    assert_eq!(csv, incremental.skip_csv());
    assert!(csv.starts_with("subject,session,skip_reason"));
    assert!(csv.contains("sub-02,ses-a,no available T1w image in session"));
    assert!(csv.contains("sub-03,ses-a,no available T1w image in session"));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn already_processed_served_from_persistent_index_across_processes() {
    let root = tmproot("procidx");
    let ds = BidsDataset::create(&root, "PROCDS").unwrap();
    for i in 1..=4 {
        stub_image(&ds, &format!("{i:02}"), None, Modality::T1w);
    }
    let fs = by_name("freesurfer").unwrap();
    {
        // "process" every runnable session, then persist the engine state
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r, _) = engine.query(&ds, &fs, 2).unwrap();
        assert_eq!(r.runnable.len(), 4);
        for job in &r.runnable {
            let key = SessionKey::new(&job.subject, job.session.as_deref());
            engine.record_completion("freesurfer", &key);
        }
        engine.save(&ds).unwrap();
    }
    // a fresh engine (≈ a fresh control-node process) replays everything
    // from the processed index: no derivatives exist on disk at all, so a
    // filesystem probe could not answer this — only the index can
    let mut engine = IncrementalEngine::open(&ds).unwrap();
    let (r, stats) = engine.query(&ds, &fs, 2).unwrap();
    assert!(r.runnable.is_empty());
    assert_eq!(r.skipped.len(), 4);
    assert!(r.skipped.iter().all(|s| s.reason == SkipReason::AlreadyProcessed));
    assert_eq!(stats.sessions_examined, 0);
    assert_eq!(stats.sessions_replayed, 4);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn missing_prior_unblocks_through_coordinator_campaigns() {
    let root = tmproot("unblock");
    // deterministic dataset: 3 DWI sessions (blocked on prequal), 1
    // T1w-only session (skipped for NoDwi either way)
    let ds = BidsDataset::create(&root.join("bids"), "UNBLOCK").unwrap();
    for sub in ["01", "02", "03"] {
        stub_image(&ds, sub, Some("a"), Modality::Dwi);
    }
    stub_image(&ds, "04", Some("a"), Modality::T1w);
    let archive = Archive::at(&root.join("store")).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    let cfg = CampaignConfig::default();

    // tractseg needs prequal first: everything with DWI is blocked
    let r0 = coord.run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg).unwrap();
    assert_eq!(r0.completed, 0);
    assert!(r0.skip_csv.contains("prerequisite pipeline 'prequal' not yet run"), "{}", r0.skip_csv);

    // prequal completes → its processed-set version bumps → exactly the
    // blocked sessions are re-examined on the next tractseg campaign
    let rp = coord.run_campaign(&ds, "prequal", SubmitTarget::Hpc, &cfg).unwrap();
    assert_eq!(rp.completed, 3);
    let r1 = coord.run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg).unwrap();
    assert_eq!(r1.completed, rp.completed, "every prequal'd session unblocks");
    assert_eq!(
        r1.query_stats.sessions_examined, rp.completed,
        "only the unblocked sessions were re-evaluated: {:?}",
        r1.query_stats
    );
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn sharded_query_scales_across_workers_consistently() {
    let root = tmproot("workers");
    let cohort = SynthCohort {
        name: "WORKERS".into(),
        participants: 24,
        sessions: 48,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort_lite(&root, &cohort, 5).unwrap();
    let fs = by_name("freesurfer").unwrap();
    let index = EntityIndex::load(&ds.index_dir().join("index")).unwrap();
    let processed = ProcessedIndex::default();
    let (r1, _) = find_runnable_sharded(&ds, &fs, &index, &processed, 1).unwrap();
    for workers in [2, 4, 8] {
        let (r, _) = find_runnable_sharded(&ds, &fs, &index, &processed, workers).unwrap();
        assert_eq!(r.runnable, r1.runnable, "workers={workers}");
        assert_eq!(r.skipped, r1.skipped, "workers={workers}");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn campaign_query_stats_reported_in_report() {
    let root = tmproot("stats");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let cohort = SynthCohort {
        name: "STATS".into(),
        participants: 2,
        sessions: 3,
        tier: SecurityTier::General,
    };
    let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 13).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    let cfg = CampaignConfig::default();
    let r = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg).unwrap();
    assert!(!r.query_stats.full_scan);
    assert_eq!(r.query_stats.sessions_examined, r.queried, "first campaign evaluates everything");
    assert_eq!(r.query_stats.sessions_replayed, 0);
    std::fs::remove_dir_all(&root).unwrap();
}
