//! Property tests over the data-format substrates: NIfTI, DICOM,
//! conversion, container archive, faults, and the growth model.

use medflow::container::{ContainerArchive, ImageDef};
use medflow::convert::convert_series;
use medflow::dicom::synth::{synth_series, SeriesSpec};
use medflow::dicom::DicomObject;
use medflow::faults::{run_with_retries, FaultModel};
use medflow::nifti::NiftiImage;
use medflow::util::prop::forall;
use medflow::util::rng::Rng;

fn rand_dims(rng: &mut Rng) -> [u16; 3] {
    [
        2 + rng.below(14) as u16,
        2 + rng.below(14) as u16,
        2 + rng.below(14) as u16,
    ]
}

#[test]
fn prop_nifti_roundtrip() {
    forall("nifti roundtrip", 100, |rng| {
        let dims = rand_dims(rng);
        let n: usize = dims.iter().map(|&d| d as usize).product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let vox = [
            rng.range_f64(0.5, 3.0) as f32,
            rng.range_f64(0.5, 3.0) as f32,
            rng.range_f64(0.5, 3.0) as f32,
        ];
        let img = NiftiImage::new(dims, vox, data.clone()).unwrap();
        let back = NiftiImage::from_nii_bytes(&img.to_nii_bytes().unwrap()).unwrap();
        assert_eq!(back.header.dims(), dims);
        assert_eq!(back.data, data);
        for (a, b) in back.header.voxel_mm().iter().zip(vox.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    });
}

#[test]
fn prop_nifti_rejects_truncation() {
    forall("nifti truncation rejected", 50, |rng| {
        let dims = rand_dims(rng);
        let n: usize = dims.iter().map(|&d| d as usize).product();
        let img = NiftiImage::new(dims, [1.0; 3], vec![0.5; n]).unwrap();
        let bytes = img.to_nii_bytes().unwrap();
        let cut = 352 + rng.below((bytes.len() - 352) as u64) as usize;
        assert!(NiftiImage::from_nii_bytes(&bytes[..cut]).is_err(), "cut={cut}");
    });
}

#[test]
fn prop_dicom_roundtrip_any_series() {
    forall("dicom series roundtrip", 40, |rng| {
        let dim = 2 + rng.below(10) as u16;
        let sub = rng.token(6);
        let spec = if rng.below(2) == 0 {
            SeriesSpec::t1w(&sub, "20240101", dim)
        } else {
            SeriesSpec::dwi(&sub, "20240101", dim, 500.0 + rng.next_f64() * 2000.0)
        };
        let objs = synth_series(&spec, rng.next_u64());
        for o in &objs {
            let back = DicomObject::from_bytes(&o.to_bytes()).unwrap();
            assert_eq!(&back, o);
        }
    });
}

#[test]
fn prop_convert_preserves_voxel_count_and_order_independence() {
    forall("convert invariants", 30, |rng| {
        let dim = 2 + rng.below(10) as u16;
        let spec = SeriesSpec::t1w(&rng.token(5), "20240102", dim);
        let mut objs = synth_series(&spec, rng.next_u64());
        let a = convert_series(&objs).unwrap();
        assert_eq!(a.image.data.len(), (dim as usize).pow(3));
        rng.shuffle(&mut objs);
        let b = convert_series(&objs).unwrap();
        assert_eq!(a.image.data, b.image.data, "slice order must not matter");
    });
}

#[test]
fn prop_container_hash_is_content_addressed() {
    forall("container content addressing", 20, |rng| {
        let dir = std::env::temp_dir().join(format!(
            "medflow_prop_cont_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut archive = ContainerArchive::open(&dir).unwrap();
        let version = format!("{}.{}", rng.below(9), rng.below(9));
        let def = ImageDef {
            pipeline: "freesurfer".into(),
            version: version.clone(),
            base_env: "ubuntu22.04+xla0.5.1".into(),
            artifact: Some("seg_pipeline".into()),
        };
        let img = archive.build(def.clone()).unwrap();
        // same def in a fresh archive → same sha
        let dir2 = dir.join("twin");
        std::fs::create_dir_all(&dir2).unwrap();
        let img2 = ContainerArchive::open(&dir2).unwrap().build(def).unwrap();
        assert_eq!(img.sha256, img2.sha256);
        assert!(archive.fsck().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn prop_fault_traces_consistent() {
    forall("fault trace consistency", 200, |rng| {
        let model = match rng.below(3) {
            0 => FaultModel::none(),
            1 => FaultModel::typical(),
            _ => FaultModel::harsh(),
        };
        let retries = rng.below(5) as u32;
        let t = run_with_retries(&model, retries, rng);
        // attempts ≤ retries + 1; completed ⇔ failures < attempts budget
        assert!(t.failures.len() <= retries as usize + 1);
        if t.completed {
            assert!(t.failures.len() <= retries as usize);
            assert!(t.effective_duration_factor >= 1.0);
        } else {
            assert_eq!(t.failures.len(), retries as usize + 1);
        }
        // wasted work bounded by one full duration per attempt
        assert!(t.effective_duration_factor <= retries as f64 + 2.0);
    });
}

#[test]
fn prop_growth_monotone_and_tier_conserving() {
    use medflow::archive::growth::{default_models, forecast};
    forall("growth monotonicity", 50, |rng| {
        let models = default_models();
        let y1 = rng.range_f64(0.0, 20.0);
        let y2 = y1 + rng.range_f64(0.0, 20.0);
        let a = forecast(&models, y1);
        let b = forecast(&models, y2);
        assert!(b.general_bytes >= a.general_bytes);
        assert!(b.gdpr_bytes >= a.gdpr_bytes);
        // capacity constants never drift
        assert_eq!(a.general_capacity, 407 * 1_000_000_000_000);
        assert_eq!(a.gdpr_capacity, 266 * 1_000_000_000_000);
    });
}
