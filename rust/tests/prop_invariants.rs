//! Property tests on coordinator/substrate invariants (DESIGN.md §5),
//! driven by the hand-rolled `util::prop` harness (proptest is not in the
//! offline crate cache).

use medflow::bids::{BidsName, Modality};
use medflow::integrity::{crc32, sha256_hex};
use medflow::netsim::{Env, NetProfile};
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler, SimJob};
use medflow::util::csv::{parse_csv, write_csv};
use medflow::util::json::Json;
use medflow::util::prop::forall;
use medflow::util::rng::Rng;
use medflow::util::units::{bytes_per_sec_to_gbps, gbps_to_bytes_per_sec, mean_std, percentile};

fn rand_label(rng: &mut Rng) -> String {
    { let n = 1 + rng.below(8) as usize; rng.token(n) }
}

#[test]
fn prop_bids_name_roundtrip() {
    // parse ∘ format = id for every legal entity combination
    forall("bids name roundtrip", 300, |rng| {
        let modality = if rng.below(2) == 0 { Modality::T1w } else { Modality::Dwi };
        let mut name = BidsName::new(&rand_label(rng), None, modality);
        if rng.below(2) == 0 {
            name.session = Some(rand_label(rng));
        }
        if rng.below(2) == 0 {
            name = name.with_acq(&rand_label(rng));
        }
        if rng.below(2) == 0 {
            name = name.with_run(rng.below(99) as u32 + 1);
        }
        let parsed = BidsName::parse(&name.format()).unwrap();
        assert_eq!(parsed, name);
    });
}

#[test]
fn prop_json_roundtrip() {
    // parse(to_string(v)) == v for random JSON trees
    fn gen(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 4.0),
            3 => Json::Str({ let n = rng.below(12) as usize; rng.token(n) }),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for _ in 0..rng.below(5) {
                    let n = 1 + rng.below(6) as usize;
                    let key = rng.token(n);
                    o.set(&key, gen(rng, depth - 1));
                }
                Json::Obj(o)
            }
        }
    }
    forall("json roundtrip", 300, |rng| {
        let v = gen(rng, 3);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    });
}

#[test]
fn prop_csv_roundtrip() {
    forall("csv roundtrip", 200, |rng| {
        let cols = 1 + rng.below(5) as usize;
        let rows: Vec<Vec<String>> = (0..rng.below(6))
            .map(|_| {
                (0..cols)
                    .map(|_| {
                        let mut s = { let n = rng.below(8) as usize; rng.token(n) };
                        if rng.below(4) == 0 {
                            s.push(',');
                        }
                        if rng.below(4) == 0 {
                            s.push('"');
                        }
                        if rng.below(6) == 0 {
                            s.push('\n');
                        }
                        s
                    })
                    .collect()
            })
            .collect();
        let header: Vec<&str> = (0..cols).map(|_| "h").collect();
        let text = write_csv(&header, &rows);
        let parsed = parse_csv(&text);
        assert_eq!(parsed.len(), rows.len() + 1);
        for (got, want) in parsed[1..].iter().zip(&rows) {
            assert_eq!(got, want);
        }
    });
}

#[test]
fn prop_scheduler_conservation() {
    // every submitted job runs exactly once; no node over-commits; array
    // throttles hold; no job starts before submit
    forall("scheduler conservation", 60, |rng| {
        let nodes = 1 + rng.below(4) as usize;
        let cores = 2 + rng.below(7) as u32;
        let mut sched = Scheduler::new(ClusterSpec::small(nodes, cores, 64));
        let n_jobs = 1 + rng.below(40);
        let throttle = 1 + rng.below(5) as u32;
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: throttle,
        };
        for id in 0..n_jobs {
            sched.submit(SimJob {
                id,
                user: format!("u{}", rng.below(3)),
                cores: 1 + rng.below(cores as u64) as u32,
                ram_gb: 1,
                duration_s: 1.0 + rng.next_f64() * 100.0,
                submit_s: rng.next_f64() * 50.0,
                array: if rng.below(2) == 0 { Some(handle) } else { None },
            });
        }
        let records = sched.run_to_completion().to_vec();
        // conservation: all jobs completed exactly once
        assert_eq!(records.len() as u64, n_jobs);
        let mut ids: Vec<u64> = records.iter().map(|r| r.job.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, n_jobs);
        // causality + duration
        for r in &records {
            assert!(r.start_s >= r.job.submit_s - 1e-9, "job {} started early", r.job.id);
            assert!((r.end_s - r.start_s - r.job.duration_s).abs() < 1e-6);
        }
        // node capacity: sweep events on each node
        for node in 0..nodes {
            let mut events: Vec<(f64, i64)> = Vec::new();
            for r in records.iter().filter(|r| r.node == node) {
                events.push((r.start_s, r.job.cores as i64));
                events.push((r.end_s, -(r.job.cores as i64)));
            }
            events.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
            });
            let mut used = 0i64;
            for (_, delta) in events {
                used += delta;
                assert!(used <= cores as i64, "node {node} over-committed");
            }
        }
        // array throttle: concurrent array jobs never exceed max_concurrent
        let mut events: Vec<(f64, i64)> = Vec::new();
        for r in records.iter().filter(|r| r.job.array.is_some()) {
            events.push((r.start_s, 1));
            events.push((r.end_s, -1));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut inflight = 0i64;
        for (_, delta) in events {
            inflight += delta;
            assert!(inflight <= throttle as i64, "array throttle violated");
        }
    });
}

#[test]
fn prop_checksums_detect_single_bit_flips() {
    forall("checksum bit flip", 150, |rng| {
        let len = 1 + rng.below(4096) as usize;
        let mut data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let h1 = sha256_hex(&data);
        let c1 = crc32(&data);
        let byte = rng.below(len as u64) as usize;
        let bit = rng.below(8) as u8;
        data[byte] ^= 1 << bit;
        assert_ne!(sha256_hex(&data), h1, "sha256 must catch bit flips");
        assert_ne!(crc32(&data), c1, "crc32 must catch single-bit flips");
    });
}

#[test]
fn prop_transfer_time_monotone_in_size() {
    // with the same rng stream position, bigger payload ⇒ ≥ time
    forall("transfer monotone", 100, |rng| {
        let env = *rng.choose(&[Env::Hpc, Env::Cloud, Env::Local]);
        let p = NetProfile::of(env);
        let seed = rng.next_u64();
        let small = rng.below(1_000_000) + 1;
        let big = small + rng.below(1_000_000_000);
        let t_small = p.transfer_time(&mut Rng::new(seed), small);
        let t_big = p.transfer_time(&mut Rng::new(seed), big);
        assert!(t_big >= t_small, "{env:?}: {t_big} < {t_small}");
    });
}

#[test]
fn prop_units_roundtrip_and_stats() {
    forall("units invariants", 200, |rng| {
        let gbps = rng.next_f64() * 100.0 + 0.001;
        let back = bytes_per_sec_to_gbps(gbps_to_bytes_per_sec(gbps));
        assert!((back - gbps).abs() < 1e-9);

        let n = 1 + rng.below(50) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_ms(5.0, 2.0)).collect();
        let (mean, std) = mean_std(&xs);
        assert!(std >= 0.0);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(mean >= lo - 1e-9 && mean <= hi + 1e-9);
        // percentiles are monotone and bounded
        let p10 = percentile(&xs, 10.0);
        let p90 = percentile(&xs, 90.0);
        assert!(p10 <= p90 + 1e-12);
        assert!(p10 >= lo - 1e-9 && p90 <= hi + 1e-9);
    });
}

#[test]
fn prop_gaussian_band_rows_normalized() {
    // mirror of the python-side property, on the rust cost of constants:
    // any banded blur operator in the manifest preserves constants — here
    // we assert the *runtime artifacts* are hash-pinned instead.
    forall("manifest hash pins", 20, |rng| {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let manifest = medflow::runtime::ArtifactManifest::load(&dir).unwrap();
        let art = rng.choose(&manifest.artifacts);
        let text = std::fs::read_to_string(dir.join(&art.file)).unwrap();
        assert_eq!(sha256_hex(text.as_bytes()), art.sha256);
        assert!(!text.contains("{...}"), "elided constants would zero out");
    });
}
