//! The reproduction gate as an integration test: every headline number of
//! the paper must hold, with the real PJRT artifacts when built.

use medflow::compute::load_runtime;
use medflow::report::gate::{run_gate, summarize};

#[test]
fn paper_reproduction_gate() {
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let checks = run_gate(runtime.as_ref(), 42).unwrap();
    match summarize(&checks) {
        Ok(report) => println!("{report}"),
        Err(failures) => panic!("{failures}"),
    }
    // with artifacts built, real compute must have run
    if runtime.is_some() {
        // (artifact timing is in Table1Column; assert via a fresh gate run)
        let cols = medflow::report::table1(runtime.as_ref(), 7, 10, 10).unwrap();
        assert!(cols.iter().all(|c| c.artifact_exec_s > 0.0));
    }
}

#[test]
fn gate_stable_across_seeds() {
    for seed in [1u64, 99, 12345] {
        let checks = run_gate(None, seed).unwrap();
        assert!(
            summarize(&checks).is_ok(),
            "gate must not be seed-sensitive (seed {seed})"
        );
    }
}
