//! Streaming-coordinator gates (DESIGN.md §17): the epoch loop must be
//! a *composition* of the one-shot engines, not a new engine — t=0
//! arrivals reproduce a one-shot `RunSpec` run f64-record-identically
//! (at any thread count), multi-epoch harsh-fault traces replay
//! bit-for-bit from the seed, sessions are conserved through cutoffs
//! and tenancy, and a link brownout can only push ingest-to-processed
//! latency up.

use medflow::coordinator::placement::{default_fleet, BackendSpec, PlacementConfig};
use medflow::coordinator::stream::{
    run_stream, stream_campaign, ArrivalPattern, StreamConfig, DAY_S,
};
use medflow::coordinator::RunSpec;
use medflow::faults::outage::{Brownout, OutageSchedule, OutageSeverity};
use medflow::faults::FaultModel;
use medflow::slurm::ClusterSpec;

fn fleet() -> Vec<BackendSpec> {
    default_fleet(ClusterSpec::accre(), 64, 8, 4)
}

fn pcfg(seed: u64) -> PlacementConfig {
    PlacementConfig {
        seed,
        ..Default::default()
    }
}

/// t=0 arrivals degenerate to one planning epoch whose engines run
/// under the unsalted base seed — the stream loop must reproduce the
/// one-shot RunSpec run record-for-record: same completion set, same
/// `done_s` per session (= the stream latencies), same cost and
/// makespan. Holds at `--threads 1` and at a sharded thread count.
#[test]
fn t0_arrivals_match_one_shot_runspec_at_any_thread_count() {
    let cfg = StreamConfig {
        sessions: 250,
        horizon_s: 2.0 * DAY_S,
        pattern: ArrivalPattern::AtStart,
        seed: 17,
        ..Default::default()
    };
    let fleet = fleet();
    let pcfg = pcfg(17);
    for threads in [1usize, 4] {
        let spec = RunSpec::new().threads(threads);
        let streamed = run_stream(&cfg, &fleet, &pcfg, &spec);
        assert_eq!(streamed.report.epochs, 1, "t=0 arrivals are one epoch");
        assert_eq!(streamed.report.backlog_final, 0);

        let one_shot = spec.execute(&stream_campaign(&cfg), &fleet, &pcfg);
        let one_shot_done: Vec<f64> = one_shot
            .staged
            .timings
            .iter()
            .filter(|t| t.completed)
            .map(|t| t.done_s)
            .collect();
        // arrivals are all 0.0, so latency ≡ done_s: record-identical
        assert_eq!(streamed.latencies_s, one_shot_done, "threads={threads}");
        assert_eq!(streamed.report.total_cost_dollars, one_shot.total_cost_dollars);
        assert_eq!(streamed.epochs[0].makespan_s, one_shot.makespan_s);
        assert_eq!(
            streamed.report.processed,
            one_shot.staged.timings.iter().filter(|t| t.completed).count()
        );
    }
}

/// The replay contract extends across planning epochs: a steady trace
/// under a harsh outage schedule plus in-engine fault injection must
/// reproduce every report field, every epoch row, and every latency
/// sample from `(config, seed)` alone.
#[test]
fn multi_epoch_harsh_fault_trace_replays_from_the_seed() {
    let cfg = StreamConfig {
        sessions: 200,
        horizon_s: 5.0 * DAY_S,
        epoch_s: DAY_S,
        pattern: ArrivalPattern::Waves { count: 3 },
        seed: 23,
        ..Default::default()
    };
    let fleet = fleet();
    let pcfg = PlacementConfig {
        seed: 23,
        transfer_faults: Some(FaultModel::typical()),
        ..Default::default()
    };
    let schedule = OutageSchedule::synthetic(
        OutageSeverity::Harsh,
        fleet.len(),
        cfg.horizon_s,
        23,
    );
    let spec = RunSpec::new().outages(schedule).threads(2);
    let a = run_stream(&cfg, &fleet, &pcfg, &spec);
    let b = run_stream(&cfg, &fleet, &pcfg, &spec);
    assert_eq!(a.report, b.report);
    assert_eq!(a.epochs, b.epochs);
    assert_eq!(a.latencies_s, b.latencies_s);
    assert!(a.report.epochs > 1, "waves over 5 days must re-plan");
    assert!(a.report.outage.is_some(), "harsh schedule must report outage stats");
    assert_eq!(
        a.report.processed + a.report.aborted + a.report.backlog_final,
        a.report.sessions
    );
}

/// Conservation: every arrival is exactly one of processed, aborted,
/// or stranded backlog. A cutoff strands the tail; without one the
/// stream drains. Holds through the tenancy path too.
#[test]
fn backlog_conservation_under_cutoff_and_tenancy() {
    let base = StreamConfig {
        sessions: 160,
        horizon_s: 8.0 * DAY_S,
        epoch_s: DAY_S,
        pattern: ArrivalPattern::Steady,
        seed: 31,
        ..Default::default()
    };
    let fleet = fleet();

    let cut = StreamConfig {
        cutoff_s: Some(3.0 * DAY_S),
        ..base.clone()
    };
    let out = run_stream(&cut, &fleet, &pcfg(31), &RunSpec::new());
    assert!(out.report.backlog_final > 0, "post-cutoff arrivals must strand");
    assert_eq!(
        out.report.processed + out.report.aborted + out.report.backlog_final,
        out.report.sessions
    );
    // the stranded tail is exactly the sessions arriving past the last
    // admitted epoch — nothing double-counted across epochs
    assert_eq!(
        out.epochs.iter().map(|e| e.admitted).sum::<usize>() + out.report.backlog_final,
        out.report.sessions
    );

    let tenanted = StreamConfig {
        tenants: 4,
        ..base
    };
    let out = run_stream(&tenanted, &fleet, &pcfg(31), &RunSpec::new());
    assert_eq!(out.report.backlog_final, 0, "cutoff-free streams drain");
    assert_eq!(
        out.report.processed + out.report.aborted,
        out.report.sessions
    );
    assert_eq!(out.report.processed, out.latencies_s.len());
}

/// Throttling the shared link can only slow verified copy-back:
/// against the same t=0 batch, a half-capacity brownout covering the
/// run must leave every latency quantile at or above the clean run's.
#[test]
fn brownout_pushes_ingest_latency_monotonically_up() {
    let cfg = StreamConfig {
        sessions: 180,
        horizon_s: 2.0 * DAY_S,
        pattern: ArrivalPattern::AtStart,
        seed: 41,
        ..Default::default()
    };
    let fleet = fleet();
    let pcfg = pcfg(41);
    let clean = run_stream(&cfg, &fleet, &pcfg, &RunSpec::new());

    let mut schedule = OutageSchedule::empty();
    schedule.brownouts.push(Brownout {
        start_s: 0.0,
        end_s: 30.0 * DAY_S,
        factor: 0.5,
    });
    let browned = run_stream(&cfg, &fleet, &pcfg, &RunSpec::new().outages(schedule));

    assert_eq!(clean.report.processed, browned.report.processed);
    assert!(
        browned.report.latency_p50_s >= clean.report.latency_p50_s,
        "brownout p50 {} must not beat clean {}",
        browned.report.latency_p50_s,
        clean.report.latency_p50_s
    );
    assert!(
        browned.report.latency_p95_s >= clean.report.latency_p95_s,
        "brownout p95 {} must not beat clean {}",
        browned.report.latency_p95_s,
        clean.report.latency_p95_s
    );
    assert!(
        browned.report.latency_mean_s > clean.report.latency_mean_s,
        "a half-capacity link must measurably slow the mean"
    );
    let o = browned.report.outage.expect("armed schedule reports outage stats");
    assert!(o.brownouts >= 1);
}
