// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Chaos co-simulation gates (DESIGN.md §15): the infrastructure-fault
//! layer must cost exactly nothing when the schedule is empty — every
//! chaos entry point is **f64-record-identical** to its plain sibling —
//! and under real outages it must degrade gracefully (orphans conserved,
//! no job silently lost), replay seed-identically at campaign scale, and
//! never make a constrained fleet *faster*.

use medflow::coordinator::placement::{
    execute, execute_chaos, BackendKind, BackendSpec, PlacementPolicy,
};
use medflow::coordinator::staged::StagedJob;
use medflow::coordinator::tenancy::{
    run_tenants, run_tenants_chaos, TenancyConfig, TenantSpec,
};
use medflow::faults::outage::{
    ComputeOutage, OutageMode, OutageSchedule, OutageSeverity, OutageStats,
};
use medflow::netsim::Env;
use medflow::slurm::ClusterSpec;
use medflow::util::rng::Rng;

fn staged_jobs(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1 + rng.below(3) as u32,
            ram_gb: 1 + rng.below(8) as u32,
            compute_s: 20.0 + rng.next_f64() * 400.0,
            bytes_in: 10_000_000 + rng.below(150_000_000),
            bytes_out: 1_000_000 + rng.below(50_000_000),
        })
        .collect()
}

/// The heterogeneous trio — a constrained Slurm cluster plus two lane
/// pools — so every engine kind crosses the chaos path in one run.
fn trio_fleet() -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(6, 8, 64),
                max_concurrent: 24,
            },
            faults: None,
            transfer_streams: 6,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes { workers: 16 },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes { workers: 2 },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

fn every_policy() -> [PlacementPolicy; 6] {
    [
        PlacementPolicy::CheapestFirst,
        PlacementPolicy::DeadlineAware { deadline_s: 2_000.0 },
        PlacementPolicy::BudgetCapped { budget_dollars: 5.0 },
        PlacementPolicy::Pinned(0),
        PlacementPolicy::Pinned(1),
        PlacementPolicy::Pinned(2),
    ]
}

/// Acceptance: an empty outage schedule is a no-op at the record level
/// for every placement policy — the chaos plumbing (owned job copies,
/// engine outage hooks, brownout-aware scheduler) must not perturb a
/// single f64.
#[test]
fn empty_schedule_is_record_identical_to_execute_for_every_policy() {
    let js = staged_jobs(120, 61);
    let fleet = trio_fleet();
    let empty = OutageSchedule::empty();
    let cfg = TenancyConfig {
        seed: 61,
        ..Default::default()
    }
    .placement();
    for policy in every_policy() {
        let base = execute(&js, &fleet, policy, &cfg);
        let chaos = execute_chaos(&js, &fleet, policy, &cfg, &empty);
        assert_eq!(chaos.staged.timings, base.staged.timings, "{policy:?}");
        assert_eq!(chaos.staged.transfer, base.staged.transfer, "{policy:?}");
        assert_eq!(chaos.plan.assignment, base.plan.assignment, "{policy:?}");
        assert_eq!(chaos.per_backend, base.per_backend, "{policy:?}");
        assert_eq!(chaos.total_cost_dollars, base.total_cost_dollars, "{policy:?}");
        assert_eq!(chaos.makespan_s, base.makespan_s, "{policy:?}");
        assert_eq!(chaos.aborted, base.aborted, "{policy:?}");
        assert!(base.outage.is_none(), "plain runs carry no outage stats");
        assert_eq!(chaos.outage, Some(OutageStats::default()), "{policy:?}");
    }
}

fn three_tenants() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            weight: 1.0,
            ..TenantSpec::new("a", staged_jobs(40, 11))
        },
        TenantSpec {
            weight: 2.0,
            ..TenantSpec::new("b", staged_jobs(40, 12))
        },
        TenantSpec {
            priority: 1,
            ..TenantSpec::new("c", staged_jobs(40, 13))
        },
    ]
}

/// The same no-op guarantee through the tenancy layer: empty schedule +
/// enforcement off reproduces `run_tenants` exactly, under contention.
#[test]
fn empty_schedule_tenancy_is_record_identical_to_run_tenants() {
    let tenants = three_tenants();
    let fleet = trio_fleet();
    let cfg = TenancyConfig {
        seed: 91,
        queue_depth: Some(6),
        ..Default::default()
    };
    let plain = run_tenants(&tenants, &fleet, &cfg);
    let chaos = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), false);
    assert_eq!(plain.staged.timings, chaos.staged.timings);
    assert_eq!(plain.admit_s, chaos.admit_s);
    assert_eq!(plain.assignment, chaos.assignment);
    assert_eq!(plain.report.tenants, chaos.report.tenants);
    assert_eq!(plain.report.per_backend, chaos.report.per_backend);
    assert_eq!(plain.report.total_cost_dollars, chaos.report.total_cost_dollars);
    assert_eq!(plain.report.makespan_s, chaos.report.makespan_s);
    assert_eq!(plain.report.transfer, chaos.report.transfer);
    assert_eq!(plain.report.aborted, chaos.report.aborted);
    assert!(plain.report.outage.is_none() && !plain.report.enforced);
    assert_eq!(chaos.report.outage, Some(OutageStats::default()));
    assert!(!chaos.report.enforced);
}

/// Acceptance: a harsh synthetic schedule over a ~10³-job campaign
/// replays **seed-identically** — the chaos layer stays inside the
/// replay contract — and the damage is conserved: kills and orphans
/// happen, every orphan is re-placed or waits out its window, and no
/// job is silently lost (no fault model ⇒ nothing may abort).
#[test]
fn harsh_chaos_replays_seed_identically_at_campaign_scale() {
    let n = 1_000;
    let js = staged_jobs(n, 73);
    let fleet = trio_fleet();
    let schedule = OutageSchedule::synthetic(OutageSeverity::Harsh, fleet.len(), 20_000.0, 73);
    let cfg = TenancyConfig {
        seed: 73,
        ..Default::default()
    }
    .placement();
    let a = execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
    let b = execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
    assert_eq!(a.staged.timings, b.staged.timings);
    assert_eq!(a.staged.transfer, b.staged.transfer);
    assert_eq!(a.per_backend, b.per_backend);
    assert_eq!(a.total_cost_dollars, b.total_cost_dollars);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.outage, b.outage);

    // the schedule must actually bite, or the replay gate is vacuous
    let o = a.outage.expect("chaos run reports outage stats");
    assert!(o.windows > 0 && o.brownouts > 0, "{o:?}");
    assert!(o.killed > 0, "harsh Down windows must kill running work: {o:?}");
    assert!(o.orphaned > 0, "drains must orphan queued work: {o:?}");
    assert!(o.re_placed <= o.orphaned, "{o:?}");
    assert!(o.killed_wasted_s > 0.0, "{o:?}");

    // conservation: every window ends before the campaign does, no
    // fault model is armed — all n jobs must still complete
    let completed = a.staged.timings.iter().filter(|t| t.completed).count();
    assert_eq!(completed, n, "graceful degradation may delay, never lose");
    assert_eq!(a.aborted, 0);
}

/// On a fleet with nowhere to flee, an outage can only delay work:
/// makespan is monotone in the window length.
#[test]
fn outages_never_shorten_a_single_backend_campaign() {
    let js = staged_jobs(60, 29);
    let fleet = vec![BackendSpec {
        name: "hpc".into(),
        env: Env::Hpc,
        kind: BackendKind::Lanes { workers: 4 },
        faults: None,
        transfer_streams: 4,
    }];
    let cfg = TenancyConfig {
        seed: 29,
        ..Default::default()
    }
    .placement();
    let base = execute(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg);
    let mut last = base.makespan_s;
    for (mode, len_s) in [
        (OutageMode::Drain, 200.0),
        (OutageMode::Down, 200.0),
        (OutageMode::Down, 900.0),
    ] {
        let mut schedule = OutageSchedule::empty();
        schedule.compute.push(ComputeOutage {
            backend: 0,
            mode,
            start_s: 120.0,
            end_s: 120.0 + len_s,
        });
        let out = execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
        assert!(
            out.makespan_s >= base.makespan_s - 1e-9,
            "{mode:?} {len_s}: {} < baseline {}",
            out.makespan_s,
            base.makespan_s
        );
        if mode == OutageMode::Down {
            assert!(
                out.makespan_s >= last - 1e-9,
                "longer window may not finish earlier: {} < {last}",
                out.makespan_s
            );
            last = out.makespan_s;
        }
        let completed = out.staged.timings.iter().filter(|t| t.completed).count();
        assert_eq!(completed, js.len(), "the window ends; everything drains through");
    }
}

/// Satellite SLO gate at integration scale: under budget enforcement a
/// tenant's billed spend never exceeds its budget by more than one
/// job's billing quantum, stranded jobs bill $0, and unconstrained
/// co-tenants are untouched.
#[test]
fn budget_enforcement_bounds_spend_within_one_job_quantum() {
    let tiny = |n: usize, seed: u64| -> Vec<StagedJob> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s: 200.0 + rng.next_f64() * 100.0,
                bytes_in: 1_000,
                bytes_out: 1_000,
            })
            .collect()
    };
    let fleet = trio_fleet();
    let cfg = TenancyConfig {
        seed: 37,
        ..Default::default()
    };
    let mut tenants = vec![
        TenantSpec::new("capped", tiny(24, 5)),
        TenantSpec::new("free", tiny(24, 6)),
    ];
    let baseline = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), true);
    let total = baseline.report.tenants[0].cost_dollars;
    assert!(total > 0.0);
    assert_eq!(baseline.report.tenants[0].slo_aborted, 0, "no budget ⇒ nothing stranded");

    let budget = total * 0.5;
    tenants[0].budget_dollars = Some(budget);
    let out = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), true);
    let capped = &out.report.tenants[0];
    assert!(capped.slo_aborted > 0, "half the budget must strand jobs");
    assert_eq!(capped.completed + capped.slo_aborted, 24, "stranded jobs drain, not vanish");
    let quantum = total / 24.0;
    assert!(
        capped.cost_dollars <= budget + quantum + 1e-9,
        "billed {} vs budget {budget} + quantum {quantum}",
        capped.cost_dollars
    );
    let free = &out.report.tenants[1];
    assert_eq!(free.slo_aborted, 0);
    assert_eq!(free.completed, 24, "co-tenants keep their full service");
}
