// This battery deliberately drives the deprecated pre-RunSpec entry
// points: it pins that every legacy name delegates to the builder
// f64-record-identically (see coordinator::spec).
#![allow(deprecated)]

//! Placement gates (DESIGN.md §12): single-backend parity with the
//! staged path, per-(job, backend, attempt) determinism, and Pareto
//! frontier properties.
//!
//! The parity bar mirrors `rust/tests/engine_parity.rs`: placement
//! pinned to one backend drives the *same* engines through the same
//! hand-offs, so the right comparison is **f64-exact record equality**
//! with `coordinator::staged::run_staged` — and, transitively, with the
//! frozen `sim_legacy` reference the staged path is itself pinned to.

use medflow::coordinator::placement::{
    execute, execute_pinned, frontier_sweep, pareto, plan, shared_topology, BackendKind,
    BackendSpec, FrontierPoint, PlacementConfig, PlacementPolicy, PLACEMENT_TRANSFER_SALT,
};
use medflow::coordinator::staged::{run_staged, LanePool, SlurmSim, StagedJob};
use medflow::faults::FaultModel;
use medflow::netsim::scheduler::TransferScheduler;
use medflow::netsim::Env;
use medflow::sim_legacy;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::prop::forall;
use medflow::util::rng::Rng;

fn staged_jobs(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1 + rng.below(3) as u32,
            ram_gb: 1 + rng.below(8) as u32,
            compute_s: 20.0 + rng.next_f64() * 400.0,
            bytes_in: 10_000_000 + rng.below(150_000_000),
            bytes_out: 1_000_000 + rng.below(50_000_000),
        })
        .collect()
}

fn lanes_backend(name: &str, env: Env, workers: usize, streams: usize) -> BackendSpec {
    BackendSpec {
        name: name.into(),
        env,
        kind: BackendKind::Lanes { workers },
        faults: None,
        transfer_streams: streams,
    }
}

/// Single-backend placement must be f64-record-identical to the
/// existing staged path: same lane pool, same transfer scheduler
/// (placement's shared topology + salt), same records.
#[test]
fn pinned_lane_placement_identical_to_staged_path() {
    for (n, workers, streams, seed) in [(12usize, 3usize, 2usize, 41u64), (150, 16, 8, 47)] {
        let js = staged_jobs(n, seed);
        // the HPC-env backend: speed factor 1.0, so effective == input
        let fleet = vec![lanes_backend("hpc", Env::Hpc, workers, streams)];
        let cfg = PlacementConfig {
            seed,
            ..Default::default()
        };
        let placed = execute_pinned(&js, &fleet, 0, &cfg);

        let mut lanes = LanePool::new(workers);
        let mut transfers =
            TransferScheduler::new(shared_topology(&fleet), seed ^ PLACEMENT_TRANSFER_SALT);
        let reference = run_staged(&js, &mut lanes, &mut transfers);

        assert_eq!(placed.staged.timings, reference.timings, "n={n}");
        assert_eq!(placed.staged.makespan_s, reference.makespan_s);
        assert_eq!(placed.staged.transfer, reference.transfer);
        assert!(placed.staged.timings.iter().all(|t| t.completed));

        // transitively: the frozen pre-PR engines agree record for record
        let mut frozen_lanes = sim_legacy::LanePool::new(workers);
        let mut frozen_transfers = sim_legacy::TransferScheduler::new(
            shared_topology(&fleet),
            seed ^ PLACEMENT_TRANSFER_SALT,
        );
        let frozen = sim_legacy::run_staged(&js, &mut frozen_lanes, &mut frozen_transfers);
        assert_eq!(placed.staged.timings, frozen.timings, "n={n} vs sim_legacy");
        assert_eq!(placed.staged.transfer, frozen.transfer);
    }
}

/// The same parity through the SLURM backend: a pinned single-Slurm
/// fleet reproduces `run_staged` over `SlurmSim` exactly, job records
/// included.
#[test]
fn pinned_slurm_placement_identical_to_staged_path() {
    let js = staged_jobs(80, 53);
    let cluster = ClusterSpec::small(6, 8, 64);
    let handle = ArrayHandle {
        array_id: 1, // placement numbers arrays 1 + backend index; backend 0 → 1
        max_concurrent: 24,
    };
    let fleet = vec![BackendSpec {
        name: "hpc".into(),
        env: Env::Hpc,
        kind: BackendKind::Slurm {
            cluster: cluster.clone(),
            max_concurrent: handle.max_concurrent,
        },
        faults: None,
        transfer_streams: 6,
    }];
    let cfg = PlacementConfig {
        seed: 59,
        ..Default::default()
    };
    let placed = execute_pinned(&js, &fleet, 0, &cfg);

    let mut sim = SlurmSim::new(Scheduler::new(cluster), "medflow", Some(handle));
    let mut transfers =
        TransferScheduler::new(shared_topology(&fleet), 59 ^ PLACEMENT_TRANSFER_SALT);
    let reference = run_staged(&js, &mut sim, &mut transfers);

    assert_eq!(placed.staged.timings, reference.timings);
    assert_eq!(placed.staged.makespan_s, reference.makespan_s);
    assert_eq!(placed.staged.transfer, reference.transfer);
}

/// Per-(job, backend, attempt) determinism: the same seed replays a
/// faulty multi-backend placement bit-for-bit — timings, retry traces,
/// assignments, dollars.
#[test]
fn faulty_multi_backend_placement_replays_exactly() {
    let js = staged_jobs(60, 71);
    let mut fleet = vec![
        lanes_backend("hpc", Env::Hpc, 4, 4),
        lanes_backend("cloud", Env::Cloud, 8, 4),
        lanes_backend("local", Env::Local, 2, 2),
    ];
    for backend in &mut fleet {
        backend.faults = Some(FaultModel::harsh());
    }
    let cfg = PlacementConfig {
        seed: 73,
        transfer_faults: Some(FaultModel::harsh()),
        max_retries: 3,
        retry_backoff_s: 5.0,
    };
    let policy = PlacementPolicy::DeadlineAware { deadline_s: 900.0 };
    let a = execute(&js, &fleet, policy, &cfg);
    let b = execute(&js, &fleet, policy, &cfg);
    assert_eq!(a.plan.assignment, b.plan.assignment);
    assert_eq!(a.staged.timings, b.staged.timings);
    assert_eq!(a.compute_events, b.compute_events);
    assert_eq!(a.transfer_events, b.transfer_events);
    assert_eq!(a.total_cost_dollars, b.total_cost_dollars);
    assert!(!a.compute_events.is_empty(), "harsh rates over 60 jobs must fail attempts");
    // and the verdict stream is per-backend: the same jobs pinned to a
    // different backend index draw a different retry trace
    let pinned_a = execute_pinned(&js, &fleet, 0, &cfg);
    let pinned_b = execute_pinned(&js, &fleet, 1, &cfg);
    assert!(
        pinned_a.compute_events != pinned_b.compute_events,
        "backends must not replay each other's verdicts"
    );
}

/// Frontier monotonicity: emitted points are strictly increasing in
/// cost and strictly decreasing in makespan, with no dominated pair —
/// over random fleets and campaigns, not one curated scenario.
#[test]
fn prop_frontier_never_emits_dominated_points() {
    forall("pareto frontier is undominated", 15, |rng| {
        let n = 10 + rng.below(30) as usize;
        let js = staged_jobs(n, rng.next_u64());
        let fleet = vec![
            lanes_backend("hpc", Env::Hpc, 1 + rng.below(4) as usize, 4),
            lanes_backend("cloud", Env::Cloud, 4 + rng.below(12) as usize, 4),
            lanes_backend("local", Env::Local, 1 + rng.below(2) as usize, 2),
        ];
        let cfg = PlacementConfig {
            seed: rng.next_u64(),
            ..Default::default()
        };
        let frontier = frontier_sweep(&js, &fleet, &cfg, 1 + rng.below(3) as usize);
        assert!(!frontier.is_empty());
        for w in frontier.windows(2) {
            assert!(w[0].cost_dollars < w[1].cost_dollars, "{w:?}");
            assert!(w[0].makespan_s > w[1].makespan_s, "{w:?}");
        }
        for p in &frontier {
            assert_eq!(p.jobs_per_backend.iter().sum::<usize>(), n, "{}", p.label);
        }
    });
}

/// `pareto` itself on adversarial hand-built inputs.
#[test]
fn pareto_handles_ties_and_degenerate_inputs() {
    let p = |cost: f64, mk: f64| FrontierPoint {
        label: format!("{cost}/{mk}"),
        cost_dollars: cost,
        makespan_s: mk,
        jobs_per_backend: vec![],
    };
    // all identical → exactly one survives
    let same = pareto(vec![p(1.0, 1.0), p(1.0, 1.0), p(1.0, 1.0)]);
    assert_eq!(same.len(), 1);
    // a single point is its own frontier
    assert_eq!(pareto(vec![p(2.0, 3.0)]).len(), 1);
    // strictly worse on one axis with equal other axis is dominated
    let kept = pareto(vec![p(1.0, 5.0), p(1.0, 9.0), p(2.0, 5.0), p(2.0, 4.0)]);
    let labels: Vec<&str> = kept.iter().map(|q| q.label.as_str()).collect();
    assert_eq!(labels, ["1/5", "2/4"]);
}

/// The planner never assigns to a backend outside the fleet and every
/// policy covers every job.
#[test]
fn prop_plans_are_total_and_in_range() {
    forall("plans cover all jobs in range", 20, |rng| {
        let n = 1 + rng.below(40) as usize;
        let js = staged_jobs(n, rng.next_u64());
        let fleet = vec![
            lanes_backend("a", Env::Hpc, 1 + rng.below(8) as usize, 2),
            lanes_backend("b", Env::Cloud, 1 + rng.below(8) as usize, 2),
        ];
        let policies = [
            PlacementPolicy::CheapestFirst,
            PlacementPolicy::DeadlineAware {
                deadline_s: rng.next_f64() * 5_000.0,
            },
            PlacementPolicy::BudgetCapped {
                budget_dollars: rng.next_f64() * 2.0,
            },
            PlacementPolicy::Pinned(rng.below(2) as usize),
        ];
        for policy in policies {
            let p = plan(&js, &fleet, policy);
            assert_eq!(p.assignment.len(), n, "{policy:?}");
            assert!(p.assignment.iter().all(|&k| k < fleet.len()), "{policy:?}");
            assert_eq!(p.effective.len(), n);
            assert!(p.projected_cost_dollars >= 0.0);
            assert!(p.projected_makespan_s >= 0.0);
        }
    });
}
