//! End-to-end integration tests: DICOM → NIfTI → BIDS → archive → query →
//! scripts → campaign (SLURM sim or local burst, PJRT artifacts when
//! built) → provenance → reports. Plus failure injection (paper §2.3:
//! checksum mismatch terminates the job).

use std::path::PathBuf;

use medflow::archive::{Archive, SecurityTier};
use medflow::bids::{validate_dataset, BidsDataset, BidsName, Modality, Severity};
use medflow::compute::load_runtime;
use medflow::container::ContainerArchive;
use medflow::coordinator::{CampaignConfig, Coordinator, SubmitTarget};
use medflow::integrity::{verified_copy, Manifest};
use medflow::pipeline::{by_name, registry};
use medflow::provenance::Provenance;
use medflow::query::find_runnable;
use medflow::report::{table4, Table4Row};
use medflow::scripts::{slurm_array_script, SlurmOptions};
use medflow::slurm::Maintenance;
use medflow::workload::{ingest_cohort, SynthCohort};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("medflow_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn mini_cohort(name: &str, participants: u64, sessions: u64) -> SynthCohort {
    SynthCohort {
        name: name.into(),
        participants,
        sessions,
        tier: SecurityTier::General,
    }
}

#[test]
fn full_flow_ingest_to_reports() {
    let root = tmp("full");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("E2E", 4, 8), 8, 21).unwrap();

    // BIDS validation clean
    let errors = validate_dataset(&ds.root)
        .into_iter()
        .filter(|i| i.severity == Severity::Error)
        .count();
    assert_eq!(errors, 0);

    // query → scripts
    let fs = by_name("freesurfer").unwrap();
    let q = find_runnable(&ds, &fs).unwrap();
    assert!(!q.runnable.is_empty());
    let script = slurm_array_script(&q.runnable, &SlurmOptions::default());
    assert!(script.contains("#SBATCH --array=0-"));

    // campaign on simulated HPC
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    let r = coord
        .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &CampaignConfig::default())
        .unwrap();
    assert_eq!(r.completed, q.runnable.len());
    assert_eq!(r.failed, 0);
    assert!(r.total_cost_dollars > 0.0);

    // Table 4 over the archive includes our dataset with real counts
    let rows: Vec<Table4Row> = table4(&coord.archive, &root.join("bids")).unwrap();
    let row = rows.iter().find(|r| r.dataset == "E2E").unwrap();
    assert_eq!(row.participants, 4);
    assert!(row.raw_images > 0);

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn pjrt_campaign_writes_real_qa_stats() {
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let Some(rt) = load_runtime(&repo) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let root = tmp("pjrt");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("PJ", 2, 2), 8, 5).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, Some(&rt));
    let r = coord
        .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &CampaignConfig::default())
        .unwrap();
    assert!(r.completed > 0);
    assert!(r.artifact_exec_s > 0.0, "real PJRT compute must be measured");
    // the derivative stats contain EM tissue volumes from the artifact
    let mut saw_stats = false;
    for sub in ds.subjects().unwrap() {
        for ses in ds.sessions(&sub).unwrap() {
            let name = BidsName::new(&sub, ses.as_deref(), Modality::T1w);
            let stats = ds.derivative_dir("freesurfer", &name).join("stats.tsv");
            if stats.exists() {
                let text = std::fs::read_to_string(&stats).unwrap();
                assert!(text.contains("gm_voxels"), "{text}");
                let gm: f64 = text
                    .lines()
                    .find(|l| l.starts_with("gm_voxels"))
                    .and_then(|l| l.split('\t').nth(1))
                    .unwrap()
                    .parse()
                    .unwrap();
                assert!(gm > 0.0);
                saw_stats = true;
            }
        }
    }
    assert!(saw_stats);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn corrupted_transfer_aborts_job() {
    // paper §2.3: "any non-match resulting in the termination of the job
    // script with an error notification"
    let root = tmp("corrupt");
    let src = root.join("input.nii.gz");
    std::fs::write(&src, vec![9u8; 10_000]).unwrap();

    // normal verified copy succeeds
    let dst = root.join("scratch/input.nii.gz");
    assert!(verified_copy(&src, &dst).is_ok());

    // manifest-verified tree catches tampering mid-job
    let tree = root.join("outputs");
    std::fs::create_dir_all(&tree).unwrap();
    std::fs::write(tree.join("seg.nii.gz"), b"result-a").unwrap();
    std::fs::write(tree.join("stats.tsv"), b"gm\t1\n").unwrap();
    let manifest = Manifest::of_tree(&tree).unwrap();
    // ... bit rot happens between compute and copy-back ...
    std::fs::write(tree.join("seg.nii.gz"), b"result-X").unwrap();
    let err = manifest.verify_tree(&tree).unwrap_err();
    assert!(err.to_string().contains("checksum mismatch"));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn gdpr_and_general_data_never_mix() {
    let root = tmp("gdpr");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("OPEN", 2, 2), 8, 1).unwrap();
    let gdpr_cohort = SynthCohort {
        name: "UKBBMINI".into(),
        participants: 2,
        sessions: 2,
        tier: SecurityTier::Gdpr,
    };
    ingest_cohort(&mut archive, &root.join("bids"), &gdpr_cohort, 8, 2).unwrap();

    // physical separation on disk
    let open_root = archive.dataset_root("OPEN").unwrap();
    let ukbb_root = archive.dataset_root("UKBBMINI").unwrap();
    assert!(open_root.starts_with(root.join("store/general")));
    assert!(ukbb_root.starts_with(root.join("store/gdpr")));
    // usage accounting separated per tier
    assert!(archive.tier_usage(SecurityTier::General).unwrap() > 0);
    assert!(archive.tier_usage(SecurityTier::Gdpr).unwrap() > 0);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn multi_pipeline_dependency_chain() {
    // freesurfer → brain_age chain (T1wAndPrior) + prequal → tractseg
    let root = tmp("chain");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("CHAIN", 3, 3), 8, 9).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    let cfg = CampaignConfig::default();

    // dependents blocked initially
    for dep in ["brain_age", "tractseg"] {
        let r = coord.run_campaign(&ds, dep, SubmitTarget::Hpc, &cfg).unwrap();
        assert_eq!(r.completed, 0, "{dep} must wait for its prior");
    }
    // run the priors
    let fs = coord.run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg).unwrap();
    let pq = coord.run_campaign(&ds, "prequal", SubmitTarget::Hpc, &cfg).unwrap();
    // dependents now proceed for the sessions whose priors completed
    let ba = coord.run_campaign(&ds, "brain_age", SubmitTarget::Hpc, &cfg).unwrap();
    let ts = coord.run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg).unwrap();
    assert_eq!(ba.completed, fs.completed);
    assert_eq!(ts.completed, pq.completed);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn maintenance_burst_end_to_end() {
    let root = tmp("maint");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("MB", 2, 4), 8, 3).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    coord.add_maintenance(Maintenance { start_s: 0.0, end_s: 86_400.0 });

    let target = coord.choose_target(0.0, 3);
    assert!(matches!(target, SubmitTarget::LocalBurst { workers: 3 }));
    let r = coord
        .run_campaign(&ds, "lesion_seg", target, &CampaignConfig::default())
        .unwrap();
    assert!(r.completed > 0);
    // provenance records the local environment
    let mut found = false;
    for sub in ds.subjects().unwrap() {
        for ses in ds.sessions(&sub).unwrap() {
            let name = BidsName::new(&sub, ses.as_deref(), Modality::T1w);
            let p = ds.derivative_dir("lesion_seg", &name).join("provenance.json");
            if p.exists() {
                assert_eq!(Provenance::load(&p).unwrap().compute_env, "Local");
                found = true;
            }
        }
    }
    assert!(found);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn every_registered_pipeline_can_run_a_campaign() {
    // smoke the whole 16-pipeline registry end-to-end (model durations;
    // priors run first so dependents unlock)
    let root = tmp("allpipes");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("ALL", 2, 2), 8, 17).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    let cfg = CampaignConfig::default();

    // two passes: first run prior-free pipelines, then dependents
    let mut completed_total = 0;
    for pass in 0..2 {
        for p in registry() {
            let has_prior = matches!(
                p.input,
                medflow::pipeline::InputReq::T1wAndPrior(_)
                    | medflow::pipeline::InputReq::DwiAndPrior(_)
            );
            if (pass == 0) == has_prior {
                continue;
            }
            let r = coord.run_campaign(&ds, p.name, SubmitTarget::Hpc, &cfg).unwrap();
            assert_eq!(r.failed, 0, "{}", p.name);
            completed_total += r.completed;
        }
    }
    assert!(completed_total > 0);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn dataset_reopen_after_campaigns_is_consistent() {
    let root = tmp("reopen");
    let mut archive = Archive::at(&root.join("store")).unwrap();
    let ds =
        ingest_cohort(&mut archive, &root.join("bids"), &mini_cohort("RO", 2, 2), 8, 23).unwrap();
    let containers = ContainerArchive::open(&root.join("containers")).unwrap();
    let mut coord = Coordinator::new(archive, containers, None);
    coord
        .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &CampaignConfig::default())
        .unwrap();

    // a fresh process opening the same tree sees processed state
    let ds2 = BidsDataset::open(&ds.root).unwrap();
    let fs = by_name("freesurfer").unwrap();
    let q = find_runnable(&ds2, &fs).unwrap();
    assert!(q.runnable.is_empty(), "state must persist across opens");
    std::fs::remove_dir_all(&root).unwrap();
}
