//! Golden parity gates for the event-engine overhaul (DESIGN.md §10).
//!
//! The rewritten engines (`netsim::scheduler::TransferScheduler`,
//! `slurm::Scheduler`, `coordinator::staged::{LanePool, run_staged}`)
//! must be **record-for-record identical** to the frozen pre-PR
//! implementations in `medflow::sim_legacy`: both generations are
//! deterministic given a seed, so the right bar is *exact* equality of
//! every `TransferRecord`/`JobRecord`/`StagedTiming` — every f64 bit —
//! not approximate agreement. The legacy engines are the recorded seed
//! traces: they are frozen in-tree, so any semantic drift in the live
//! engines (ordering, sampling, fair-share arithmetic, backfill
//! decisions) fails these tests loudly.
//!
//! Batteries cover storm submissions, staggered/out-of-order arrivals,
//! multi-host queues, interleaved `advance_to` checkpoints, all three
//! scheduler policies, maintenance windows, array throttles, the staged
//! co-simulation through both compute backends, randomized
//! property-style scenarios — and the Table 1 calibration cases.

use medflow::coordinator::staged::{run_staged, LanePool, SlurmSim, StagedJob};
use medflow::faults::{FaultModel, Injection};
use medflow::netsim::scheduler::{scheduler_bandwidth_experiment, TransferScheduler};
use medflow::netsim::Env;
use medflow::sim_legacy;
use medflow::slurm::trace::{generate_trace, TraceSpec};
use medflow::slurm::{ArrayHandle, ClusterSpec, Maintenance, Policy, Scheduler, SimJob};
use medflow::util::prop::forall;
use medflow::util::rng::Rng;
use medflow::util::units::mean_std;

/// A transfer submission plan both engines replay identically.
#[derive(Clone)]
struct Submission {
    id: u64,
    host: u64,
    bytes: u64,
    submit_s: f64,
}

fn run_both_transfers(
    env: Env,
    cap: usize,
    seed: u64,
    subs: &[Submission],
) -> (
    Vec<medflow::netsim::scheduler::TransferRecord>,
    Vec<medflow::netsim::scheduler::TransferRecord>,
) {
    let mut live = TransferScheduler::for_env(env, cap, seed);
    let mut frozen = sim_legacy::TransferScheduler::for_env(env, cap, seed);
    for s in subs {
        live.submit_at(s.id, s.host, s.bytes, s.submit_s);
        frozen.submit_at(s.id, s.host, s.bytes, s.submit_s);
    }
    live.run_to_completion();
    frozen.run_to_completion();
    (live.records().to_vec(), frozen.records().to_vec())
}

#[test]
fn transfer_storm_records_identical() {
    for env in Env::all() {
        for (n, cap, seed) in [(1usize, 1usize, 7u64), (8, 2, 11), (64, 8, 13), (200, 8, 17)] {
            let subs: Vec<Submission> = (0..n)
                .map(|i| Submission {
                    id: i as u64,
                    host: 0,
                    bytes: 40_000_000 + (i as u64 % 5) * 7_000_000,
                    submit_s: 0.0,
                })
                .collect();
            let (live, frozen) = run_both_transfers(env, cap, seed, &subs);
            assert_eq!(live.len(), n);
            assert_eq!(live, frozen, "{env:?} n={n} cap={cap} seed={seed}");
        }
    }
}

#[test]
fn transfer_staggered_multi_host_records_identical() {
    // out-of-order ids, mixed hosts, due and future submissions — the
    // admission-order edge cases the per-host queues must replay exactly
    for env in Env::all() {
        let subs = vec![
            Submission { id: 5, host: 0, bytes: 80_000_000, submit_s: 0.0 },
            Submission { id: 3, host: 1, bytes: 120_000_000, submit_s: 0.0 },
            Submission { id: 9, host: 0, bytes: 40_000_000, submit_s: 0.0 },
            Submission { id: 1, host: 2, bytes: 60_000_000, submit_s: 0.5 },
            Submission { id: 2, host: 0, bytes: 90_000_000, submit_s: 1.5 },
            Submission { id: 8, host: 1, bytes: 30_000_000, submit_s: 2.25 },
            Submission { id: 7, host: 0, bytes: 50_000_000, submit_s: 30.0 },
            Submission { id: 6, host: 2, bytes: 10_000_000, submit_s: 30.0 },
        ];
        let (live, frozen) = run_both_transfers(env, 2, 23, &subs);
        assert_eq!(live.len(), subs.len());
        assert_eq!(live, frozen, "{env:?}");
    }
}

#[test]
fn transfer_advance_checkpoints_identical() {
    // step both engines through the same irregular time grid, comparing
    // clock + records at every checkpoint (not just at completion)
    let mut live = TransferScheduler::for_env(Env::Cloud, 2, 31);
    let mut frozen = sim_legacy::TransferScheduler::for_env(Env::Cloud, 2, 31);
    for i in 0..12u64 {
        let submit = (i % 4) as f64 * 7.5;
        live.submit_at(i, i % 2, 200_000_000, submit);
        frozen.submit_at(i, i % 2, 200_000_000, submit);
    }
    for t in [0.1, 3.0, 7.5, 11.2, 30.0, 60.0, 600.0, 3_600.0, 36_000.0] {
        live.advance_to(t);
        frozen.advance_to(t);
        assert_eq!(live.clock(), frozen.clock(), "clock at t={t}");
        assert_eq!(live.records(), frozen.records(), "records at t={t}");
    }
    live.run_to_completion();
    frozen.run_to_completion();
    assert_eq!(live.records(), frozen.records());
    assert_eq!(live.stats(), frozen.stats());
}

#[test]
fn transfer_table1_calibration_identical() {
    // the Table 1 calibration cases: the §2.4 bandwidth experiment must
    // be sample-for-sample identical across generations AND still match
    // the paper's means
    for (env, want) in [(Env::Hpc, 0.60), (Env::Cloud, 0.33), (Env::Local, 0.81)] {
        let live = scheduler_bandwidth_experiment(env, 100, 42);
        let frozen = sim_legacy::scheduler_bandwidth_experiment(env, 100, 42);
        assert_eq!(live, frozen, "{env:?}: calibration samples must match bit-for-bit");
        let (mean, _) = mean_std(&live);
        assert!((mean - want).abs() < 0.05, "{env:?}: mean {mean} want {want}");
    }
}

#[test]
fn prop_transfer_engines_identical() {
    forall("transfer engines agree on random scenarios", 40, |rng| {
        let env = *rng.choose(&Env::all());
        let cap = 1 + rng.below(6) as usize;
        let seed = rng.next_u64();
        let n = 1 + rng.below(30);
        let subs: Vec<Submission> = (0..n)
            .map(|i| Submission {
                id: i,
                host: rng.below(3),
                bytes: 1_000 + rng.below(300_000_000),
                submit_s: if rng.below(2) == 0 { 0.0 } else { rng.next_f64() * 50.0 },
            })
            .collect();
        let (live, frozen) = run_both_transfers(env, cap, seed, &subs);
        assert_eq!(live.len(), n as usize);
        assert_eq!(live, frozen, "{env:?} cap={cap} seed={seed}");
    });
}

fn run_both_slurm(
    cluster: ClusterSpec,
    policy: Policy,
    maintenance: &[Maintenance],
    jobs: &[SimJob],
) -> (Vec<medflow::slurm::JobRecord>, Vec<medflow::slurm::JobRecord>) {
    let mut live = Scheduler::with_policy(cluster.clone(), policy);
    let mut frozen = sim_legacy::Scheduler::with_policy(cluster, policy);
    for w in maintenance {
        live.add_maintenance(*w);
        frozen.add_maintenance(*w);
    }
    for j in jobs {
        live.submit(j.clone());
        frozen.submit(j.clone());
    }
    live.run_to_completion();
    frozen.run_to_completion();
    assert_eq!(live.makespan(), frozen.makespan());
    assert_eq!(live.utilization(), frozen.utilization());
    assert_eq!(live.pending_count(), frozen.pending_count());
    (live.records().to_vec(), frozen.records().to_vec())
}

#[test]
fn slurm_trace_records_identical_across_policies() {
    let spec = TraceSpec {
        jobs: 400,
        users: 5,
        mean_interarrival_s: 10.0,
        ..Default::default()
    };
    let policies = [
        Policy { fairshare: true, backfill: true },
        Policy { fairshare: true, backfill: false },
        Policy { fairshare: false, backfill: true },
        Policy { fairshare: false, backfill: false },
    ];
    for (i, policy) in policies.into_iter().enumerate() {
        let jobs = generate_trace(&spec, 7 + i as u64);
        let (live, frozen) = run_both_slurm(ClusterSpec::small(6, 8, 64), policy, &[], &jobs);
        assert_eq!(live.len(), 400, "{policy:?}");
        assert_eq!(live, frozen, "{policy:?}");
    }
}

#[test]
fn slurm_maintenance_and_throttle_records_identical() {
    let spec = TraceSpec {
        jobs: 250,
        users: 3,
        mean_interarrival_s: 15.0,
        array_throttle: 8,
        ..Default::default()
    };
    let jobs = generate_trace(&spec, 99);
    let windows = [
        Maintenance { start_s: 0.0, end_s: 600.0 },
        Maintenance { start_s: 5_000.0, end_s: 9_000.0 },
    ];
    let (live, frozen) =
        run_both_slurm(ClusterSpec::small(4, 8, 64), Policy::default(), &windows, &jobs);
    assert_eq!(live.len(), 250);
    assert_eq!(live, frozen);
}

#[test]
fn slurm_advance_checkpoints_identical() {
    let jobs = generate_trace(
        &TraceSpec {
            jobs: 120,
            mean_interarrival_s: 30.0,
            ..Default::default()
        },
        3,
    );
    let mut live = Scheduler::new(ClusterSpec::small(3, 8, 64));
    let mut frozen = sim_legacy::Scheduler::new(ClusterSpec::small(3, 8, 64));
    for j in &jobs {
        live.submit(j.clone());
        frozen.submit(j.clone());
    }
    let mut t = 0.0;
    for step in [13.0, 100.0, 1.0, 450.0, 3_600.0, 7_200.0, 86_400.0] {
        t += step;
        live.advance_to(t);
        frozen.advance_to(t);
        assert_eq!(live.clock(), frozen.clock(), "clock at t={t}");
        assert_eq!(live.records(), frozen.records(), "records at t={t}");
        assert_eq!(live.running_count(), frozen.running_count(), "running at t={t}");
        assert_eq!(live.next_event_time(), frozen.next_event_time(), "next at t={t}");
    }
    live.run_to_completion();
    frozen.run_to_completion();
    assert_eq!(live.records(), frozen.records());
}

#[test]
fn prop_slurm_engines_identical() {
    forall("slurm engines agree on random scenarios", 30, |rng| {
        let nodes = 1 + rng.below(4) as usize;
        let cores = 2 + rng.below(7) as u32;
        let cluster = ClusterSpec::small(nodes, cores, 64);
        let policy = Policy {
            fairshare: rng.below(2) == 0,
            backfill: rng.below(2) == 0,
        };
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: 1 + rng.below(5) as u32,
        };
        let n_jobs = 1 + rng.below(50);
        let jobs: Vec<SimJob> = (0..n_jobs)
            .map(|id| SimJob {
                id,
                user: format!("u{}", rng.below(3)),
                cores: 1 + rng.below(cores as u64) as u32,
                ram_gb: 1 + rng.below(16) as u32,
                duration_s: 1.0 + rng.next_f64() * 500.0,
                submit_s: rng.next_f64() * 100.0,
                array: if rng.below(2) == 0 { Some(handle) } else { None },
            })
            .collect();
        let windows = if rng.below(3) == 0 {
            vec![Maintenance { start_s: 0.0, end_s: 50.0 + rng.next_f64() * 200.0 }]
        } else {
            vec![]
        };
        let (live, frozen) = run_both_slurm(cluster, policy, &windows, &jobs);
        assert_eq!(live, frozen);
    });
}

fn staged_jobs(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1 + rng.below(3) as u32,
            ram_gb: 1 + rng.below(8) as u32,
            compute_s: 20.0 + rng.next_f64() * 400.0,
            bytes_in: 10_000_000 + rng.below(150_000_000),
            bytes_out: 1_000_000 + rng.below(50_000_000),
        })
        .collect()
}

#[test]
fn staged_cosim_identical_through_lane_pool() {
    for (n, workers, cap, env, seed) in [
        (12usize, 3usize, 2usize, Env::Local, 41u64),
        (60, 8, 4, Env::Hpc, 43),
        (150, 16, 8, Env::Cloud, 47),
    ] {
        let js = staged_jobs(n, seed);
        let mut lanes = LanePool::new(workers);
        let mut transfers = TransferScheduler::for_env(env, cap, seed);
        let live = run_staged(&js, &mut lanes, &mut transfers);

        let mut frozen_lanes = sim_legacy::LanePool::new(workers);
        let mut frozen_transfers = sim_legacy::TransferScheduler::for_env(env, cap, seed);
        let frozen = sim_legacy::run_staged(&js, &mut frozen_lanes, &mut frozen_transfers);

        assert_eq!(live.timings, frozen.timings, "n={n} {env:?}");
        assert_eq!(live.makespan_s, frozen.makespan_s);
        assert_eq!(live.transfer, frozen.transfer);
        assert!(live.timings.iter().all(|t| t.completed));
    }
}

#[test]
fn staged_cosim_identical_through_slurm() {
    let js = staged_jobs(80, 53);
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 24,
    };
    let mut live_sim = SlurmSim::new(Scheduler::new(ClusterSpec::small(6, 8, 64)), "medflow", Some(handle));
    let mut live_transfers = TransferScheduler::for_env(Env::Hpc, 6, 59);
    let live = run_staged(&js, &mut live_sim, &mut live_transfers);

    let mut frozen_sim = sim_legacy::SlurmSim::new(
        sim_legacy::Scheduler::new(ClusterSpec::small(6, 8, 64)),
        "medflow",
        Some(handle),
    );
    let mut frozen_transfers = sim_legacy::TransferScheduler::for_env(Env::Hpc, 6, 59);
    let frozen = sim_legacy::run_staged(&js, &mut frozen_sim, &mut frozen_transfers);

    assert_eq!(live.timings, frozen.timings);
    assert_eq!(live.makespan_s, frozen.makespan_s);
    assert_eq!(live.transfer, frozen.transfer);
    assert!(live.timings.iter().all(|t| t.completed));
    assert_eq!(
        live_sim.scheduler().records(),
        frozen_sim.scheduler().records(),
        "the compute backends must agree job-record-for-job-record too"
    );
}

/// Zero-rate injection wired into every live engine: the fault machinery
/// present but sampling no failures must leave every record — every f64
/// bit — identical to the frozen pre-injection engines (the ISSUE 4
/// acceptance bar: with `FaultModel::none()` the co-simulated path
/// reproduces the existing staged engine's records exactly).
#[test]
fn zero_rate_injection_keeps_transfer_parity() {
    for env in Env::all() {
        let mut live = TransferScheduler::for_env(env, 4, 71);
        live.set_faults(Injection::new(FaultModel::none(), 3, 1234));
        let mut frozen = sim_legacy::TransferScheduler::for_env(env, 4, 71);
        for i in 0..60u64 {
            let submit = (i % 6) as f64 * 3.5;
            live.submit_at(i, i % 3, 30_000_000 + i * 1_000_000, submit);
            frozen.submit_at(i, i % 3, 30_000_000 + i * 1_000_000, submit);
        }
        live.run_to_completion();
        frozen.run_to_completion();
        assert_eq!(live.records(), frozen.records(), "{env:?}");
        assert_eq!(live.stats(), frozen.stats(), "{env:?}");
        assert!(live.fault_events().is_empty() && live.aborted_ids().is_empty());
    }
}

#[test]
fn zero_rate_injection_keeps_slurm_parity() {
    let jobs = generate_trace(
        &TraceSpec {
            jobs: 300,
            users: 4,
            mean_interarrival_s: 12.0,
            array_throttle: 16,
            ..Default::default()
        },
        31,
    );
    let mut live = Scheduler::new(ClusterSpec::small(5, 8, 64));
    live.set_faults(
        Injection::new(FaultModel::none(), 3, 77)
            .with_backoff(30.0)
            .with_parked_timeouts(),
    );
    let mut frozen = sim_legacy::Scheduler::new(ClusterSpec::small(5, 8, 64));
    for j in &jobs {
        live.submit(j.clone());
        frozen.submit(j.clone());
    }
    live.run_to_completion();
    frozen.run_to_completion();
    assert_eq!(live.records(), frozen.records());
    assert_eq!(live.makespan(), frozen.makespan());
    assert_eq!(live.utilization(), frozen.utilization());
    assert!(live.fault_events().is_empty() && live.take_parked().is_empty());
}

#[test]
fn zero_rate_injection_keeps_staged_cosim_parity() {
    // both hand-off directions, both compute backends, injectors armed
    // everywhere — the fault-free co-simulated path must reproduce the
    // frozen staged engine's StagedTiming records f64-exactly
    let js = staged_jobs(90, 83);

    let mut live_lanes = LanePool::new(8);
    live_lanes.set_faults(
        Injection::new(FaultModel::none(), 3, 11)
            .with_backoff(60.0)
            .with_parked_timeouts(),
    );
    let mut live_transfers = TransferScheduler::for_env(Env::Hpc, 4, 89);
    live_transfers.set_faults(Injection::new(FaultModel::none(), 3, 13));
    let live = run_staged(&js, &mut live_lanes, &mut live_transfers);

    let mut frozen_lanes = sim_legacy::LanePool::new(8);
    let mut frozen_transfers = sim_legacy::TransferScheduler::for_env(Env::Hpc, 4, 89);
    let frozen = sim_legacy::run_staged(&js, &mut frozen_lanes, &mut frozen_transfers);

    assert_eq!(live.timings, frozen.timings);
    assert_eq!(live.makespan_s, frozen.makespan_s);
    assert_eq!(live.transfer, frozen.transfer);

    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 32,
    };
    let mut sched = Scheduler::new(ClusterSpec::small(6, 8, 64));
    sched.set_faults(
        Injection::new(FaultModel::none(), 3, 17)
            .with_backoff(60.0)
            .with_parked_timeouts(),
    );
    let mut live_sim = SlurmSim::new(sched, "medflow", Some(handle));
    let mut live_transfers = TransferScheduler::for_env(Env::Hpc, 6, 97);
    live_transfers.set_faults(Injection::new(FaultModel::none(), 3, 19));
    let live = run_staged(&js, &mut live_sim, &mut live_transfers);

    let mut frozen_sim = sim_legacy::SlurmSim::new(
        sim_legacy::Scheduler::new(ClusterSpec::small(6, 8, 64)),
        "medflow",
        Some(handle),
    );
    let mut frozen_transfers = sim_legacy::TransferScheduler::for_env(Env::Hpc, 6, 97);
    let frozen = sim_legacy::run_staged(&js, &mut frozen_sim, &mut frozen_transfers);

    assert_eq!(live.timings, frozen.timings);
    assert_eq!(live.makespan_s, frozen.makespan_s);
    assert_eq!(live.transfer, frozen.transfer);
    assert_eq!(live_sim.scheduler().records(), frozen_sim.scheduler().records());
}

#[test]
fn staged_cosim_identical_with_dropped_jobs() {
    // oversized jobs the cluster can never place: the drop/completion
    // bookkeeping must match across generations as well
    let mut js = staged_jobs(10, 61);
    js[3].cores = 99; // larger than any node
    js[7].cores = 99;
    let mut live_sim = SlurmSim::new(Scheduler::new(ClusterSpec::small(2, 4, 32)), "medflow", None);
    let mut live_transfers = TransferScheduler::for_env(Env::Hpc, 4, 67);
    let live = run_staged(&js, &mut live_sim, &mut live_transfers);

    let mut frozen_sim =
        sim_legacy::SlurmSim::new(sim_legacy::Scheduler::new(ClusterSpec::small(2, 4, 32)), "medflow", None);
    let mut frozen_transfers = sim_legacy::TransferScheduler::for_env(Env::Hpc, 4, 67);
    let frozen = sim_legacy::run_staged(&js, &mut frozen_sim, &mut frozen_transfers);

    assert_eq!(live.timings, frozen.timings);
    assert_eq!(live.timings.iter().filter(|t| !t.completed).count(), 2);
}
