//! CLI smoke tests: drive the `medflow` binary end-to-end the way a
//! curation-team member would (paper Fig. 3's control-node workflow).

use std::path::PathBuf;
use std::process::Command;

fn medflow() -> Command {
    // cargo builds the binary next to the test executable's deps dir
    let mut path = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    path.push("target/release/medflow");
    assert!(path.exists(), "build the binary first: cargo build --release");
    Command::new(path)
}

fn run_ok(args: &[&str]) -> String {
    let out = medflow().args(args).output().expect("spawn medflow");
    assert!(
        out.status.success(),
        "medflow {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn full_cli_workflow() {
    let root = std::env::temp_dir().join(format!("medflow_cli_{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let rootstr = root.to_string_lossy().to_string();

    // ingest → validate → query → campaign → status
    let out = run_ok(&[
        "ingest", "--root", &rootstr, "--dataset", "CLIDS", "--participants", "3",
        "--sessions", "4", "--dim", "8",
    ]);
    assert!(out.contains("ingested 'CLIDS'"), "{out}");

    let out = run_ok(&["validate", "--root", &rootstr, "--dataset", "CLIDS"]);
    assert!(out.contains("0 errors"), "{out}");

    let out = run_ok(&[
        "query", "--root", &rootstr, "--dataset", "CLIDS", "--pipeline", "freesurfer",
    ]);
    assert!(out.contains("runnable:"), "{out}");

    let out = run_ok(&[
        "campaign", "--root", &rootstr, "--dataset", "CLIDS", "--pipeline", "freesurfer",
    ]);
    assert!(out.contains("campaign CLIDS/freesurfer"), "{out}");
    assert!(out.contains("cost $"), "{out}");

    let out = run_ok(&["status", "--root", &rootstr]);
    assert!(out.contains("CLIDS"), "{out}");

    // re-query: idempotency visible through the CLI
    let out = run_ok(&[
        "query", "--root", &rootstr, "--dataset", "CLIDS", "--pipeline", "freesurfer",
    ]);
    assert!(out.contains("runnable: 0"), "{out}");

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn report_commands_print_tables() {
    let out = run_ok(&["table2"]);
    assert!(out.contains("Singularity"));
    let out = run_ok(&["table3"]);
    assert!(out.contains("Datalad"));
    let out = run_ok(&["fig1"]);
    assert!(out.contains("Adaptive"));
    let out = run_ok(&["pipelines"]);
    assert!(out.contains("freesurfer") && out.contains("prequal"));
    let out = run_ok(&["project"]);
    assert!(out.contains("TOTAL"));
    let out = run_ok(&["growth"]);
    assert!(out.contains("glacier"));
}

#[test]
fn transfer_sim_reports_contention() {
    let out = run_ok(&[
        "transfer-sim", "--env", "hpc", "--streams", "4", "--gb", "0.1", "--seed", "7",
    ]);
    assert!(out.contains("bottleneck"), "{out}");
    assert!(out.contains("observed Gb/s"), "{out}");
    assert!(out.contains("link utilization"), "{out}");
    // 4 streams → 4 record rows (the only lines starting with a digit)
    let record_rows = out
        .lines()
        .filter(|l| l.trim_start().starts_with(|c: char| c.is_ascii_digit()))
        .count();
    assert_eq!(record_rows, 4, "{out}");

    let out = medflow().args(["transfer-sim", "--env", "mars"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown env"));
}

#[test]
fn faults_cli_reports_cosimulation() {
    let out = run_ok(&[
        "faults", "--model", "harsh", "--jobs", "300", "--retries", "3", "--seed", "11",
    ]);
    assert!(out.contains("fault co-simulation"), "{out}");
    assert!(out.contains("fault-free"), "{out}");
    assert!(out.contains("failed attempts"), "{out}");
    assert!(out.contains("closed-form overrun"), "{out}");

    let out = medflow().args(["faults", "--model", "mars"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown fault model"));
}

#[test]
fn place_cli_reports_fleet_and_frontier() {
    // a small deadline-aware run over the default heterogeneous fleet
    let out = run_ok(&[
        "place", "--jobs", "300", "--policy", "deadline", "--deadline", "1200", "--seed", "7",
    ]);
    assert!(out.contains("placement co-simulation"), "{out}");
    assert!(out.contains("deadline-aware"), "{out}");
    assert!(out.contains("hpc") && out.contains("cloud") && out.contains("local"), "{out}");
    assert!(out.contains("TOTAL"), "{out}");
    assert!(out.contains("completed 300/300"), "{out}");

    // the frontier sweep prints the Pareto rows
    let out = run_ok(&[
        "place", "--jobs", "120", "--policy", "cheapest", "--frontier", "2", "--seed", "7",
        "--cloud-lanes", "32", "--local-lanes", "4",
    ]);
    assert!(out.contains("Pareto"), "{out}");
    assert!(out.contains("all-"), "anchors must appear: {out}");

    let out = medflow().args(["place", "--policy", "mars"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown placement policy"));
}

#[test]
fn campaign_placement_reports_backend_usage() {
    let root = std::env::temp_dir().join(format!("medflow_cli_place_{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let rootstr = root.to_string_lossy().to_string();
    run_ok(&[
        "ingest", "--root", &rootstr, "--dataset", "PLDS", "--participants", "2",
        "--sessions", "3", "--dim", "8",
    ]);
    let out = run_ok(&[
        "campaign", "--root", &rootstr, "--dataset", "PLDS", "--pipeline", "freesurfer",
        "--placement", "cheapest",
    ]);
    assert!(out.contains("campaign PLDS/freesurfer"), "{out}");
    assert!(out.contains("placement [cheapest-first]"), "{out}");
    assert!(out.contains("TOTAL"), "{out}");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn tenants_cli_reports_fairness_table() {
    // happy path: a small weighted, prioritized co-simulation over the
    // default fleet, with a binding admission cap
    let out = run_ok(&[
        "tenants", "--tenants", "6", "--jobs-per", "20", "--depth", "16", "--weights", "1,2",
        "--priorities", "1,0", "--faults", "typical", "--seed", "7",
    ]);
    assert!(out.contains("tenancy co-simulation"), "{out}");
    assert!(out.contains("tenant-0000") && out.contains("tenant-0005"), "{out}");
    assert!(out.contains("wait p95") && out.contains("entl%"), "{out}");
    assert!(out.contains("TOTAL"), "{out}");
    assert!(out.contains("SLO violations"), "{out}");
    assert!(out.contains("failed compute attempts"), "{out}");

    // rejected knobs fail cleanly, naming the offending value
    for (args, needle) in [
        (vec!["tenants", "--weights", "0"], "invalid tenant weight"),
        (vec!["tenants", "--weights", "1,nope"], "invalid tenant weight"),
        (vec!["tenants", "--priorities", "nope"], "invalid tenant priority"),
        (vec!["tenants", "--priorities", "-1"], "invalid tenant priority"),
        (vec!["tenants", "--depth", "0"], "invalid queue depth"),
    ] {
        let out = medflow().args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        let stderr = String::from_utf8_lossy(&out.stderr).to_string();
        assert!(stderr.contains(needle), "{args:?}: {stderr}");
    }

    // --help prints the usage block instead of running a simulation
    let out = run_ok(&["tenants", "--help"]);
    assert!(out.contains("medflow tenants"), "{out}");
    assert!(out.contains("--weights"), "{out}");
}

#[test]
fn chaos_cli_reports_outage_degradation() {
    // happy path: a small harsh run over the default fleet — the outage
    // damage line renders next to the usual placement telemetry
    let out = run_ok(&[
        "chaos", "--jobs", "200", "--severity", "harsh", "--horizon", "4000", "--seed", "7",
        "--cloud-lanes", "32", "--local-lanes", "4",
    ]);
    assert!(out.contains("chaos co-simulation"), "{out}");
    assert!(out.contains("'harsh' outages"), "{out}");
    assert!(out.contains("chaos:") && out.contains("outage windows"), "{out}");
    assert!(out.contains("killed") && out.contains("re-placed"), "{out}");
    assert!(out.contains("completed 200/200"), "{out}");
    assert!(out.contains("TOTAL"), "{out}");

    // explicit windows stack on the preset and show up in the counts
    let out = run_ok(&[
        "chaos", "--jobs", "60", "--severity", "none", "--window", "0:drain:100:400",
        "--brownout", "50:150:0.5", "--seed", "7", "--cloud-lanes", "8", "--local-lanes", "2",
    ]);
    assert!(out.contains("'none' outages (1 windows, 1 brownouts"), "{out}");

    // rejected knobs fail cleanly, naming the offending value
    for (args, needle) in [
        (vec!["chaos", "--severity", "mars"], "unknown outage severity"),
        (vec!["chaos", "--window", "0:drain:400"], "invalid outage window"),
        (vec!["chaos", "--window", "0:nope:100:400"], "invalid outage window"),
        (vec!["chaos", "--window", "99:down:100:400"], "invalid outage window"),
        (vec!["chaos", "--window", "0:down:400:100"], "invalid outage window"),
        (vec!["chaos", "--brownout", "50:150:7"], "factor"),
        (vec!["chaos", "--brownout", "nope"], "invalid brownout window"),
    ] {
        let out = medflow().args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        let stderr = String::from_utf8_lossy(&out.stderr).to_string();
        assert!(stderr.contains(needle), "{args:?}: {stderr}");
    }

    // --help prints the usage block instead of running a simulation
    let out = run_ok(&["chaos", "--help"]);
    assert!(out.contains("medflow chaos"), "{out}");
    assert!(out.contains("--severity"), "{out}");
}

#[test]
fn lint_cli_reports_and_gates() {
    // happy path: the committed tree is lint-clean, so --deny passes
    let out = run_ok(&["lint", "--deny"]);
    assert!(out.contains("determinism lint"), "{out}");
    assert!(out.contains("0 malformed directive(s)"), "{out}");

    // the rule table names every rule with its code and scope
    let out = run_ok(&["lint", "--list"]);
    assert!(out.contains("map-iter") && out.contains("DL001"), "{out}");
    assert!(out.contains("lossy-cast") && out.contains("billing"), "{out}");

    // a rule filter narrows the pass; unknown rules fail cleanly
    let out = run_ok(&["lint", "--rules", "float-ord,wall-clock"]);
    assert!(out.contains("determinism lint"), "{out}");
    let out = medflow().args(["lint", "--rules", "nope"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown lint rule"));

    // --help prints the usage block instead of linting
    let out = run_ok(&["lint", "--help"]);
    assert!(out.contains("medflow lint"), "{out}");
}

#[test]
fn stream_cli_reports_epoch_telemetry() {
    // happy path: a small two-day steady trace with half-day epochs
    let out = run_ok(&[
        "stream", "--sessions", "60", "--horizon-days", "2", "--epoch-hours", "12",
        "--seed", "7", "--cloud-lanes", "16", "--local-lanes", "2",
    ]);
    assert!(out.contains("stream co-simulation"), "{out}");
    assert!(out.contains("ingest→processed latency"), "{out}");
    assert!(out.contains("stranded backlog"), "{out}");
    assert!(out.contains("/session"), "{out}");
    assert!(out.contains("plan at") && out.contains("makespan"), "{out}");

    // every arrival pattern resolves and labels its report
    for (flags, label) in [
        (vec!["--pattern", "t0"], "t0"),
        (vec!["--pattern", "waves", "--waves", "2"], "waves"),
        (vec!["--pattern", "daynight"], "daynight"),
        (vec!["--pattern", "backfill", "--burst", "0.5"], "backfill"),
    ] {
        let mut args = vec![
            "stream", "--sessions", "40", "--horizon-days", "2", "--epoch-hours", "12",
            "--seed", "7", "--cloud-lanes", "8", "--local-lanes", "2",
        ];
        args.extend(flags.iter());
        let out = run_ok(&args);
        assert!(out.contains(&format!("'{label}' arrivals")), "{label}: {out}");
    }

    // rejected knobs fail cleanly, naming the offending value
    for (args, needle) in [
        (vec!["stream", "--sessions", "0"], "invalid --sessions"),
        (vec!["stream", "--epoch-hours", "0"], "invalid --epoch-hours"),
        (vec!["stream", "--tenants", "0"], "invalid --tenants"),
        (vec!["stream", "--pattern", "mars"], "unknown arrival pattern"),
        (vec!["stream", "--pattern", "backfill", "--burst", "2.0"], "invalid --burst"),
        (vec!["stream", "--cutoff-days", "nope"], "invalid --cutoff-days"),
        (vec!["stream", "--severity", "mars"], "unknown outage severity"),
    ] {
        let out = medflow().args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        let stderr = String::from_utf8_lossy(&out.stderr).to_string();
        assert!(stderr.contains(needle), "{args:?}: {stderr}");
    }

    // --help prints the usage block instead of running a simulation
    let out = run_ok(&["stream", "--help"]);
    assert!(out.contains("medflow stream"), "{out}");
    assert!(out.contains("--pattern"), "{out}");
}

/// The pre-RunSpec entry points survive as deprecated shims: a caller
/// that has not migrated yet gets the exact run the builder produces.
#[test]
#[allow(deprecated)]
fn deprecated_entry_points_still_delegate() {
    use medflow::coordinator::placement::{
        self, default_fleet, PlacementConfig, PlacementPolicy,
    };
    use medflow::coordinator::RunSpec;
    use medflow::coordinator::staged::synthetic_fault_campaign;
    use medflow::slurm::ClusterSpec;

    let jobs = synthetic_fault_campaign(40, 7);
    let fleet = default_fleet(ClusterSpec::accre(), 32, 8, 2);
    let cfg = PlacementConfig { seed: 7, ..Default::default() };
    let old = placement::execute(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg);
    let new = RunSpec::new().policy(PlacementPolicy::CheapestFirst).execute(&jobs, &fleet, &cfg);
    assert_eq!(old.total_cost_dollars, new.total_cost_dollars);
    assert_eq!(old.makespan_s, new.makespan_s);
    assert_eq!(old.staged.timings, new.staged.timings);
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = medflow().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}
