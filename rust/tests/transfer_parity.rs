//! Single-stream parity and contention invariants for the
//! contention-aware transfer scheduler (ISSUE 2 satellite): with one
//! stream, `netsim::scheduler` must reproduce the
//! `NetProfile::transfer_time` calibration — the sampling API is the
//! single-stream special case of the shared-link model (DESIGN.md §9).

use medflow::netsim::scheduler::{scheduler_bandwidth_experiment, Topology, TransferScheduler};
use medflow::netsim::{bandwidth_experiment, Env};
use medflow::util::prop::forall;
use medflow::util::units::{gbps_to_bytes_per_sec, mean_std};

/// Mean observed Gb/s over `k` serialized 1 GB copies through the
/// scheduler (stream cap 1) — the paper's §2.4 bandwidth experiment.
fn scheduler_bandwidth_mean(env: Env, k: usize, seed: u64) -> f64 {
    mean_std(&scheduler_bandwidth_experiment(env, k, seed)).0
}

#[test]
fn single_stream_reproduces_table1_calibration() {
    // same tolerance as netsim's bandwidth_matches_paper_calibration
    for (env, want) in [(Env::Hpc, 0.60), (Env::Cloud, 0.33), (Env::Local, 0.81)] {
        let mean = scheduler_bandwidth_mean(env, 100, 42);
        assert!(
            (mean - want).abs() < 0.05,
            "{env:?}: scheduler mean {mean} want {want}"
        );
    }
}

#[test]
fn single_stream_tracks_the_sampling_api_mean() {
    // the two models are calibrated to the same distribution, so their
    // experiment means must agree (independent RNG streams → compare
    // means, not samples)
    for env in Env::all() {
        let sampled = mean_std(&bandwidth_experiment(env, 200, 7)).0;
        let scheduled = scheduler_bandwidth_mean(env, 200, 8);
        assert!(
            (sampled - scheduled).abs() < 0.05,
            "{env:?}: sampling {sampled} vs scheduler {scheduled}"
        );
    }
}

#[test]
fn prop_single_stream_is_latency_plus_bytes_over_rate() {
    forall("scheduler single stream = sampling special case", 100, |rng| {
        let env = *rng.choose(&Env::all());
        let bytes = 1_000 + rng.below(2_000_000_000);
        let mut sim = TransferScheduler::for_env(env, 1, rng.next_u64());
        sim.submit_at(0, 0, bytes, 0.0);
        sim.run_to_completion();
        let r = &sim.records()[0];
        // exactly the sampling API's shape: sampled first-byte latency,
        // then bytes at the sampled per-stream rate — no contention terms
        let expect = r.latency_s + bytes as f64 / gbps_to_bytes_per_sec(r.stream_gbps);
        let got = r.transfer_s();
        assert!(
            (got - expect).abs() < 1e-6 * expect.max(1.0),
            "{env:?}: got {got} expect {expect}"
        );
        assert!(r.stream_gbps >= 0.01, "same floor as the sampling API");
        assert_eq!(r.queue_wait_s(), 0.0);
    });
}

#[test]
fn prop_aggregate_bounded_and_utilization_sane() {
    forall("aggregate ≤ bottleneck capacity", 40, |rng| {
        let env = *rng.choose(&Env::all());
        let n = 1 + rng.below(12) as usize;
        let bytes = 50_000_000 + rng.below(200_000_000);
        let cap = Topology::of(env).bottleneck_gbps();
        let mut sim = TransferScheduler::for_env(env, n, rng.next_u64());
        for i in 0..n {
            sim.submit_at(i as u64, 0, bytes, 0.0);
        }
        sim.run_to_completion();
        let stats = sim.stats();
        assert_eq!(stats.transfers, n);
        assert!(
            stats.aggregate_gbps <= cap * (1.0 + 1e-9),
            "{env:?} n={n}: {} > {cap}",
            stats.aggregate_gbps
        );
        assert!(stats.link_utilization > 0.0 && stats.link_utilization <= 1.0 + 1e-9);
        assert!(stats.peak_streams <= n);
    });
}
