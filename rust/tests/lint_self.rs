//! Self-run integration gate: the committed tree must be clean under
//! the determinism lint (DESIGN.md §14).
//!
//! CI re-checks the same property through the binary (`medflow lint
//! --deny` in the `lint-determinism` job); this test pins it at the
//! library level so plain `cargo test` catches a freshly introduced
//! hazard — or a suppression without an auditable reason — before a
//! parity battery ever has the chance to.

use std::path::PathBuf;

use medflow::analysis::lint_tree;

#[test]
fn committed_tree_is_lint_clean_under_deny() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&src, None).expect("lint tree");
    assert!(report.files >= 50, "walked the real tree, not a stub: {}", report.files);
    assert_eq!(
        report.deny_count(),
        0,
        "determinism hazards in the committed tree:\n{}",
        report.render()
    );
    // intentional exceptions exist (the frozen sim_legacy comparators,
    // the measured PJRT artifact timing) and each carries a reason
    assert!(report.suppressed_count() >= 1, "{}", report.render());
    for f in &report.findings {
        if let Some(reason) = &f.suppressed {
            assert!(!reason.trim().is_empty(), "{}:{} allowed without reason", f.path, f.line);
        }
    }
    assert!(report.unused_allows.is_empty(), "stale allows:\n{}", report.render());
}

#[test]
fn self_run_report_is_deterministic() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let a = lint_tree(&src, None).expect("lint tree").render();
    let b = lint_tree(&src, None).expect("lint tree").render();
    assert_eq!(a, b, "the report must be byte-identical across runs");
    // findings arrive path-sorted, lines ascending within a path
    let report = lint_tree(&src, None).expect("lint tree");
    let keys: Vec<_> = report.findings.iter().map(|f| (f.path.clone(), f.line)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "findings must be path/line sorted");
}
