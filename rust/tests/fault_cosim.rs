//! Integration gates for in-engine failure injection (DESIGN.md §11):
//! the co-simulated retry path must be deterministic, conserve jobs
//! (completed + aborted = submitted), and make retried work visibly
//! re-contend for shared resources — the modeling bug this replaces
//! scaled outcomes *after* the simulation, so retries never queued.

use medflow::coordinator::staged::{
    run_staged, synthetic_fault_campaign as campaign, LanePool, SlurmSim, StagedJob,
};
use medflow::faults::{FaultAction, FaultModel, Injection};
use medflow::netsim::scheduler::TransferScheduler;
use medflow::netsim::Env;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::prop::forall;
use medflow::util::units::percentiles;

struct FaultRun {
    timings: Vec<medflow::coordinator::staged::StagedTiming>,
    makespan_s: f64,
    transfer_waits: Vec<f64>,
    compute_events: Vec<medflow::faults::FaultEvent>,
    transfer_events: Vec<medflow::faults::FaultEvent>,
    aborted: usize,
}

fn run_slurm_cosim(
    jobs: &[StagedJob],
    model: Option<FaultModel>,
    retries: u32,
    seed: u64,
) -> FaultRun {
    let mut sched = Scheduler::new(ClusterSpec::small(64, 8, 64));
    if let Some(m) = model {
        sched.set_faults(
            Injection::new(m.compute_only(), retries, seed ^ 0xc0)
                .with_backoff(30.0)
                .with_parked_timeouts(),
        );
    }
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: 4_000,
    };
    let mut sim = SlurmSim::new(sched, "medflow", Some(handle));
    let mut transfers = TransferScheduler::for_env(Env::Hpc, 8, seed ^ 0x7f);
    if let Some(m) = model {
        transfers.set_faults(Injection::new(m.transfer_only(), retries, seed ^ 0xf0));
    }
    let out = run_staged(jobs, &mut sim, &mut transfers);
    FaultRun {
        timings: out.timings,
        makespan_s: out.makespan_s,
        transfer_waits: transfers.records().iter().map(|r| r.queue_wait_s()).collect(),
        compute_events: sim.scheduler().fault_events().to_vec(),
        transfer_events: transfers.fault_events().to_vec(),
        aborted: sim.scheduler().aborted_ids().len() + transfers.aborted_ids().len(),
    }
}

#[test]
fn jobs_are_conserved_under_harsh_faults() {
    let jobs = campaign(400, 3);
    let run = run_slurm_cosim(&jobs, Some(FaultModel::harsh()), 5, 17);
    let completed = run.timings.iter().filter(|t| t.completed).count();
    // every job either reached a verified copy-back or aborted in one of
    // the two engines — nothing silently vanishes
    assert_eq!(completed + run.aborted, 400, "{} aborted", run.aborted);
    assert!(
        !run.compute_events.is_empty(),
        "harsh rates over 400 jobs must fail some compute attempts"
    );
    // failure instants are recorded in simulation order per engine
    for events in [&run.compute_events, &run.transfer_events] {
        for w in events.windows(2) {
            assert!(w[1].fail_s + 1e-9 >= w[0].fail_s, "{:?}", w);
        }
    }
    // every failed attempt consumed real simulated time
    assert!(run.compute_events.iter().all(|e| e.wasted_s > 0.0));
}

#[test]
fn retried_work_recontends_visibly() {
    // same campaign with and without harsh faults: retries add transfer
    // and compute load to the *same* shared resources, so the campaign
    // runs strictly longer, and queue waits do not improve
    let jobs = campaign(1_000, 5);
    let free = run_slurm_cosim(&jobs, None, 3, 23);
    let harsh = run_slurm_cosim(&jobs, Some(FaultModel::harsh()), 3, 23);
    assert!(free.compute_events.is_empty() && free.aborted == 0);
    assert!(
        harsh.makespan_s > free.makespan_s,
        "retries must extend the makespan: {} vs {}",
        harsh.makespan_s,
        free.makespan_s
    );
    let p95 = |xs: &[f64]| percentiles(xs, &[95.0])[0];
    assert!(
        p95(&harsh.transfer_waits) + 1e-9 >= p95(&free.transfer_waits),
        "extra retry transfers cannot shorten queue waits: {} vs {}",
        p95(&harsh.transfer_waits),
        p95(&free.transfer_waits)
    );
}

#[test]
fn fault_cosim_replays_exactly_from_the_seed() {
    let jobs = campaign(300, 7);
    let a = run_slurm_cosim(&jobs, Some(FaultModel::harsh()), 4, 29);
    let b = run_slurm_cosim(&jobs, Some(FaultModel::harsh()), 4, 29);
    assert_eq!(a.timings, b.timings);
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.compute_events, b.compute_events);
    assert_eq!(a.transfer_events, b.transfer_events);
    // a different fault seed perturbs the retry trace
    let c = run_slurm_cosim(&jobs, Some(FaultModel::harsh()), 4, 31);
    assert_ne!(
        (a.compute_events, a.transfer_events),
        (c.compute_events, c.transfer_events),
        "fault sampling must be keyed by the seed"
    );
}

#[test]
fn prop_random_models_conserve_jobs_through_lane_pool() {
    forall("random valid fault models conserve jobs", 25, |rng| {
        let model = FaultModel {
            p_checksum: rng.next_f64() * 0.1,
            p_pipeline: rng.next_f64() * 0.3,
            p_node: rng.next_f64() * 0.1,
            p_timeout: rng.next_f64() * 0.1,
        };
        assert!(model.validate().is_ok());
        let n = 20 + rng.below(60) as usize;
        let retries = rng.below(4) as u32;
        let jobs = campaign(n, rng.next_u64());
        let mut lanes = LanePool::new(1 + rng.below(8) as usize);
        lanes.set_faults(
            Injection::new(model.compute_only(), retries, rng.next_u64())
                .with_backoff(rng.next_f64() * 60.0)
                .with_parked_timeouts(),
        );
        let mut transfers = TransferScheduler::for_env(Env::Local, 4, rng.next_u64());
        transfers.set_faults(Injection::new(model.transfer_only(), retries, rng.next_u64()));
        let out = run_staged(&jobs, &mut lanes, &mut transfers);
        let completed = out.timings.iter().filter(|t| t.completed).count();
        let aborted = lanes.aborted_ids().len() + transfers.aborted_ids().len();
        assert_eq!(completed + aborted, n, "jobs must not vanish or duplicate");
        // parked attempts always come back as restage stage-ins: every
        // park has a matching later event or abort for the same id
        let parks = lanes
            .fault_events()
            .iter()
            .filter(|e| e.action == FaultAction::Parked)
            .count();
        let restage_ins = transfers
            .records()
            .iter()
            .filter(|r| r.id >= 2 * n as u64)
            .count()
            + transfers.aborted_ids().iter().filter(|&&id| id >= 2 * n as u64).count();
        assert_eq!(parks, restage_ins, "each park triggers exactly one re-stage");
    });
}
