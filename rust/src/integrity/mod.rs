//! Data-integrity layer (paper §2.3): every transfer is checksum-verified;
//! a mismatch terminates the job with an error notification.
//!
//! SHA-256 manifests over file trees, plus a fast CRC32 path for the
//! in-simulator transfer verification where cryptographic strength is not
//! needed but per-chunk checking is.

use std::collections::BTreeMap;
use std::path::Path;
#[cfg(test)]
use std::path::PathBuf;

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};

/// Hex SHA-256 of a byte slice.
pub fn sha256_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    hex(&h.finalize())
}

/// Hex SHA-256 of a file (streamed).
pub fn sha256_file(path: &Path) -> Result<String> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut h = Sha256::new();
    std::io::copy(&mut f, &mut h)?;
    Ok(hex(&h.finalize()))
}

/// CRC32 of a byte slice (fast per-chunk transfer check).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = crc32fast::Hasher::new();
    h.update(bytes);
    h.finalize()
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// Checksum manifest over a set of files (relative path → sha256).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    pub entries: BTreeMap<String, String>,
}

/// A verification mismatch (the paper's abort condition).
///
/// Manual `Display`/`Error` impls: the crate is offline-first with
/// `anyhow` as its only dependency (rust/Cargo.toml), so no derive
/// macro crate is available here.
#[derive(Debug)]
pub enum IntegrityError {
    Mismatch {
        path: String,
        expected: String,
        actual: String,
    },
    Missing(String),
}

impl std::fmt::Display for IntegrityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IntegrityError::Mismatch {
                path,
                expected,
                actual,
            } => write!(
                f,
                "checksum mismatch for '{path}': manifest {expected}, found {actual}"
            ),
            IntegrityError::Missing(path) => {
                write!(f, "file in manifest missing from tree: '{path}'")
            }
        }
    }
}

impl std::error::Error for IntegrityError {}

impl Manifest {
    /// Hash every file under `root` (recursive), keyed by relative path.
    pub fn of_tree(root: &Path) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut stack = vec![root.to_path_buf()];
        while let Some(dir) = stack.pop() {
            for entry in std::fs::read_dir(&dir).with_context(|| format!("read {dir:?}"))? {
                let entry = entry?;
                let path = entry.path();
                if entry.file_type()?.is_dir() {
                    stack.push(path);
                } else {
                    let rel = path
                        .strip_prefix(root)
                        .unwrap()
                        .to_string_lossy()
                        .to_string();
                    entries.insert(rel, sha256_file(&path)?);
                }
            }
        }
        Ok(Self { entries })
    }

    /// Verify a tree against this manifest. First failure aborts (paper:
    /// "any non-match results in termination of the job script").
    pub fn verify_tree(&self, root: &Path) -> Result<(), IntegrityError> {
        for (rel, expected) in &self.entries {
            let path = root.join(rel);
            let actual = match sha256_file(&path) {
                Ok(h) => h,
                Err(_) => return Err(IntegrityError::Missing(rel.clone())),
            };
            if &actual != expected {
                return Err(IntegrityError::Mismatch {
                    path: rel.clone(),
                    expected: expected.clone(),
                    actual,
                });
            }
        }
        Ok(())
    }

    /// Serialize as `<sha256>  <path>` lines (sha256sum format).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (path, digest) in &self.entries {
            out.push_str(digest);
            out.push_str("  ");
            out.push_str(path);
            out.push('\n');
        }
        out
    }

    /// Parse the sha256sum format.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some((digest, path)) = line.split_once("  ") else {
                bail!("bad manifest line: '{line}'");
            };
            if digest.len() != 64 || !digest.chars().all(|c| c.is_ascii_hexdigit()) {
                bail!("bad digest in line: '{line}'");
            }
            entries.insert(path.to_string(), digest.to_string());
        }
        Ok(Self { entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Copy a file with end-to-end checksum verification; returns bytes copied.
/// Mirrors the paper's transfer pattern: hash at source, copy, hash at
/// destination, abort on mismatch.
pub fn verified_copy(src: &Path, dst: &Path) -> Result<u64> {
    let before = sha256_file(src)?;
    if let Some(parent) = dst.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let n = std::fs::copy(src, dst).with_context(|| format!("copy {src:?} -> {dst:?}"))?;
    let after = sha256_file(dst)?;
    if before != after {
        std::fs::remove_file(dst).ok();
        bail!("verified_copy: checksum mismatch copying {src:?} (expected {before}, got {after})");
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("medflow_int_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn sha256_known_vector() {
        // NIST: sha256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn manifest_roundtrip_and_verify() {
        let root = tmp("manifest");
        std::fs::create_dir_all(root.join("a/b")).unwrap();
        std::fs::write(root.join("x.txt"), b"hello").unwrap();
        std::fs::write(root.join("a/b/y.bin"), [0u8, 1, 2]).unwrap();
        let m = Manifest::of_tree(&root).unwrap();
        assert_eq!(m.len(), 2);
        m.verify_tree(&root).unwrap();
        let parsed = Manifest::from_text(&m.to_text()).unwrap();
        assert_eq!(parsed, m);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let root = tmp("corrupt");
        std::fs::write(root.join("f"), b"payload").unwrap();
        let m = Manifest::of_tree(&root).unwrap();
        std::fs::write(root.join("f"), b"tampered").unwrap();
        match m.verify_tree(&root) {
            Err(IntegrityError::Mismatch { path, .. }) => assert_eq!(path, "f"),
            other => panic!("expected mismatch, got {other:?}"),
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn missing_file_detected() {
        let root = tmp("missing");
        std::fs::write(root.join("f"), b"payload").unwrap();
        let m = Manifest::of_tree(&root).unwrap();
        std::fs::remove_file(root.join("f")).unwrap();
        assert!(matches!(m.verify_tree(&root), Err(IntegrityError::Missing(_))));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn verified_copy_roundtrip() {
        let root = tmp("copy");
        let src = root.join("src.bin");
        std::fs::write(&src, vec![7u8; 4096]).unwrap();
        let dst = root.join("sub/dst.bin");
        let n = verified_copy(&src, &dst).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(std::fs::read(&dst).unwrap(), vec![7u8; 4096]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn manifest_text_rejects_garbage() {
        assert!(Manifest::from_text("nothash  path").is_err());
        assert!(Manifest::from_text("deadbeef\n").is_err());
    }

    #[test]
    fn crc32_differs_on_change() {
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
        assert_eq!(crc32(b"abc"), crc32(b"abc"));
    }
}
