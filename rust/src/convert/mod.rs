//! DICOM→NIfTI conversion (the paper's dcm2niix step, §2.1): stack a
//! series' slices into a volume, build the NIfTI header from DICOM geometry
//! tags, and emit the JSON metadata sidecar.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dicom::{tags, DicomObject, Value};
use crate::nifti::NiftiImage;
use crate::util::json::{Json, JsonObj};

/// A converted series: volume + sidecar (what dcm2niix writes as
/// `<name>.nii.gz` + `<name>.json`).
#[derive(Debug, Clone)]
pub struct Converted {
    pub image: NiftiImage,
    pub sidecar: Json,
    pub protocol: String,
    pub patient_id: String,
    pub study_date: String,
}

/// Convert one series (slices in any order; sorted by InstanceNumber).
pub fn convert_series(slices: &[DicomObject]) -> Result<Converted> {
    if slices.is_empty() {
        bail!("empty series");
    }
    let first = &slices[0];
    let rows = first
        .get(tags::ROWS)
        .and_then(Value::as_u16)
        .context("missing Rows")?;
    let cols = first
        .get(tags::COLS)
        .and_then(Value::as_u16)
        .context("missing Columns")?;
    let series_uid = first.str_of(tags::SERIES_UID).unwrap_or_default().to_string();

    // Order slices by instance number; reject mixed series / duplicates.
    let mut by_instance: BTreeMap<u16, &DicomObject> = BTreeMap::new();
    for s in slices {
        if s.str_of(tags::SERIES_UID).unwrap_or_default() != series_uid {
            bail!("mixed SeriesInstanceUID in conversion input");
        }
        if s.get(tags::ROWS).and_then(Value::as_u16) != Some(rows)
            || s.get(tags::COLS).and_then(Value::as_u16) != Some(cols)
        {
            bail!("inconsistent slice matrix in series");
        }
        let inst = s
            .get(tags::INSTANCE_NUMBER)
            .and_then(Value::as_u16)
            .context("missing InstanceNumber")?;
        if by_instance.insert(inst, s).is_some() {
            bail!("duplicate InstanceNumber {inst}");
        }
    }

    let nslices = by_instance.len() as u16;
    let mut data = Vec::with_capacity(rows as usize * cols as usize * nslices as usize);
    for (_, s) in &by_instance {
        match s.get(tags::PIXEL_DATA) {
            Some(Value::Pixels(px)) => {
                if px.len() != rows as usize * cols as usize {
                    bail!("pixel payload size mismatch");
                }
                data.extend(px.iter().map(|&v| v as f32));
            }
            _ => bail!("slice missing PixelData"),
        }
    }

    let spacing = first
        .str_of(tags::PIXEL_SPACING)
        .unwrap_or("1.0\\1.0")
        .split('\\')
        .filter_map(|s| s.trim().parse::<f32>().ok())
        .collect::<Vec<_>>();
    let thickness = first
        .get(tags::SLICE_THICKNESS)
        .and_then(Value::as_f64)
        .unwrap_or(1.0) as f32;
    let vox = [
        spacing.first().copied().unwrap_or(1.0),
        spacing.get(1).copied().unwrap_or(1.0),
        thickness,
    ];

    let image = NiftiImage::new([rows, cols, nslices], vox, data)?;
    let sidecar = build_sidecar(first, nslices);
    Ok(Converted {
        image,
        sidecar,
        protocol: first.str_of(tags::PROTOCOL_NAME).unwrap_or("unknown").to_string(),
        patient_id: first.str_of(tags::PATIENT_ID).unwrap_or("unknown").to_string(),
        study_date: first.str_of(tags::STUDY_DATE).unwrap_or("unknown").to_string(),
    })
}

fn build_sidecar(first: &DicomObject, nslices: u16) -> Json {
    let mut o = JsonObj::new();
    let put_str = |o: &mut JsonObj, key: &str, tag| {
        if let Some(v) = first.str_of(tag) {
            o.set(key, Json::str(v));
        }
    };
    put_str(&mut o, "Modality", tags::MODALITY);
    put_str(&mut o, "ProtocolName", tags::PROTOCOL_NAME);
    put_str(&mut o, "SeriesDescription", tags::SERIES_DESC);
    put_str(&mut o, "Manufacturer", tags::MANUFACTURER);
    put_str(&mut o, "StudyDate", tags::STUDY_DATE);
    for (key, tag) in [
        ("EchoTime", tags::ECHO_TIME),
        ("RepetitionTime", tags::REPETITION_TIME),
        ("MagneticFieldStrength", tags::MAGNETIC_FIELD),
        ("DiffusionBValue", tags::B_VALUE),
    ] {
        if let Some(v) = first.get(tag).and_then(Value::as_f64) {
            o.set(key, Json::num(v));
        }
    }
    o.set("SliceCount", Json::num(nslices as f64));
    o.set("ConversionSoftware", Json::str("medflow-convert"));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dicom::synth::{synth_series, SeriesSpec};

    #[test]
    fn converts_t1_series() {
        let objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 16), 1);
        let c = convert_series(&objs).unwrap();
        assert_eq!(c.image.header.dims(), [16, 16, 16]);
        assert_eq!(c.protocol, "T1w_MPRAGE");
        assert_eq!(c.sidecar.get_path("Modality").unwrap().as_str(), Some("MR"));
        assert_eq!(c.sidecar.get_path("SliceCount").unwrap().as_f64(), Some(16.0));
    }

    #[test]
    fn slice_order_independent() {
        let mut objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 8), 2);
        let a = convert_series(&objs).unwrap();
        objs.reverse();
        let b = convert_series(&objs).unwrap();
        assert_eq!(a.image.data, b.image.data);
    }

    #[test]
    fn rejects_mixed_series() {
        let mut objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 4), 1);
        let other = synth_series(&SeriesSpec::t1w("sub02", "20240101", 4), 1);
        objs.push(other[0].clone());
        assert!(convert_series(&objs).is_err());
    }

    #[test]
    fn rejects_duplicate_instance() {
        let mut objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 4), 1);
        let dup = objs[1].clone();
        objs.push(dup);
        assert!(convert_series(&objs).is_err());
    }

    #[test]
    fn rejects_missing_pixels() {
        let mut objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 4), 1);
        objs[2].elements.remove(&tags::PIXEL_DATA);
        assert!(convert_series(&objs).is_err());
    }

    #[test]
    fn dwi_sidecar_has_bvalue() {
        let objs = synth_series(&SeriesSpec::dwi("sub01", "20240101", 8, 1000.0), 1);
        let c = convert_series(&objs).unwrap();
        assert_eq!(c.sidecar.get_path("DiffusionBValue").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn voxel_geometry_from_tags() {
        let objs = synth_series(&SeriesSpec::t1w("sub01", "20240101", 4), 1);
        let c = convert_series(&objs).unwrap();
        assert_eq!(c.image.header.voxel_mm(), [1.0, 1.0, 1.0]);
    }
}
