//! BIDS (Brain Imaging Data Structure, v1.9) organization layer — paper
//! §2.1 and Fig. 2.
//!
//! Covers what medflow needs: entity-based file naming
//! (`sub-X[_ses-Y]_modality.ext`), dataset tree construction with
//! `dataset_description.json`, a validator mirroring the checks the Python
//! bids-validator performs on this subset, and the paper's customization:
//! derivatives live in flat per-pipeline directories (no anat/dwi subdirs)
//! and raw files are symlinks into the out-of-tree data store.

mod entities;
pub mod participants;
mod validator;

pub use entities::{BidsName, Modality};
pub use validator::{validate_dataset, Severity, ValidationIssue};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{Json, JsonObj};

/// A BIDS dataset rooted at `<store>/<name>/` (paper: each dataset is a
/// separate directory in one parent folder).
#[derive(Debug, Clone)]
pub struct BidsDataset {
    pub root: PathBuf,
    pub name: String,
}

impl BidsDataset {
    /// Create the skeleton: root, `dataset_description.json`, derivatives/.
    pub fn create(parent: &Path, name: &str) -> Result<Self> {
        let root = parent.join(name);
        std::fs::create_dir_all(root.join("derivatives"))?;
        let mut desc = JsonObj::new();
        desc.set("Name", Json::str(name));
        desc.set("BIDSVersion", Json::str("1.9.0"));
        desc.set("DatasetType", Json::str("raw"));
        desc.set("GeneratedBy", {
            let mut g = JsonObj::new();
            g.set("Name", Json::str("medflow"));
            Json::Arr(vec![Json::Obj(g)])
        });
        std::fs::write(
            root.join("dataset_description.json"),
            Json::Obj(desc).to_string_pretty(),
        )?;
        Ok(Self {
            root,
            name: name.to_string(),
        })
    }

    /// Open an existing dataset directory.
    pub fn open(root: &Path) -> Result<Self> {
        let desc = root.join("dataset_description.json");
        let text = std::fs::read_to_string(&desc).with_context(|| format!("open {desc:?}"))?;
        let json = Json::parse(&text)?;
        let name = json
            .get_path("Name")
            .and_then(Json::as_str)
            .unwrap_or("unnamed")
            .to_string();
        Ok(Self {
            root: root.to_path_buf(),
            name,
        })
    }

    /// Directory for a subject/session's raw files of one modality
    /// (`sub-X/ses-Y/anat/`). Raw data keeps modality subdirs (Fig. 2).
    pub fn raw_dir(&self, name: &BidsName) -> PathBuf {
        let mut p = self.root.join(format!("sub-{}", name.subject));
        if let Some(ses) = &name.session {
            p = p.join(format!("ses-{ses}"));
        }
        p.join(name.modality.raw_dir())
    }

    /// Derivatives dir for one pipeline run on one subject/session. The
    /// paper intentionally drops modality subdirs here (Fig. 2): pipelines
    /// are often multimodal.
    pub fn derivative_dir(&self, pipeline: &str, name: &BidsName) -> PathBuf {
        let mut p = self
            .root
            .join("derivatives")
            .join(pipeline)
            .join(format!("sub-{}", name.subject));
        if let Some(ses) = &name.session {
            p = p.join(format!("ses-{ses}"));
        }
        p
    }

    /// Full path of a raw image file for `name` with `ext` (e.g. "nii.gz").
    pub fn raw_path(&self, name: &BidsName, ext: &str) -> PathBuf {
        self.raw_dir(name).join(format!("{}.{ext}", name.format()))
    }

    /// Place a data file as a **symlink** into the tree (paper §2.1: the
    /// BIDS tree links to raw files living outside it, as a security and
    /// storage-management measure). Falls back to copy on filesystems
    /// without symlink support.
    pub fn link_raw(&self, name: &BidsName, ext: &str, target: &Path) -> Result<PathBuf> {
        let dest = self.raw_path(name, ext);
        if let Some(parent) = dest.parent() {
            std::fs::create_dir_all(parent)?;
        }
        if dest.exists() || dest.symlink_metadata().is_ok() {
            std::fs::remove_file(&dest).ok();
        }
        #[cfg(unix)]
        std::os::unix::fs::symlink(target, &dest)
            .with_context(|| format!("symlink {dest:?} -> {target:?}"))?;
        #[cfg(not(unix))]
        std::fs::copy(target, &dest)?;
        Ok(dest)
    }

    /// Enumerate subjects (`sub-*` directories).
    pub fn subjects(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            let fname = entry.file_name().to_string_lossy().to_string();
            if let Some(s) = fname.strip_prefix("sub-") {
                if entry.file_type()?.is_dir() {
                    out.push(s.to_string());
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Enumerate sessions of a subject (None if the subject has no ses-*
    /// level, which BIDS allows).
    pub fn sessions(&self, subject: &str) -> Result<Vec<Option<String>>> {
        let subdir = self.root.join(format!("sub-{subject}"));
        let mut sessions = Vec::new();
        let mut has_session_dirs = false;
        for entry in std::fs::read_dir(&subdir)? {
            let entry = entry?;
            let fname = entry.file_name().to_string_lossy().to_string();
            if let Some(s) = fname.strip_prefix("ses-") {
                has_session_dirs = true;
                sessions.push(Some(s.to_string()));
            }
        }
        if !has_session_dirs {
            sessions.push(None);
        }
        sessions.sort();
        Ok(sessions)
    }

    /// All raw image files (`.nii` / `.nii.gz`) of a modality in a session.
    pub fn raw_images(&self, name: &BidsName) -> Vec<PathBuf> {
        let dir = self.raw_dir(name);
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for entry in rd.flatten() {
                let p = entry.path();
                let s = p.to_string_lossy();
                if s.ends_with(".nii") || s.ends_with(".nii.gz") {
                    out.push(p);
                }
            }
        }
        out.sort();
        out
    }

    /// Directory for medflow's own dataset-local metadata (the sharded
    /// entity index, processed-set index and query caches of
    /// [`crate::archive::index`]). Lives inside the dataset so the state
    /// travels with it; the validator treats `.medflow` like `.bidsignore`.
    pub fn index_dir(&self) -> PathBuf {
        self.root.join(".medflow")
    }

    /// Whether a derivative directory exists and is non-empty (the query
    /// engine's "already processed" signal, paper §2.3).
    pub fn has_derivative(&self, pipeline: &str, name: &BidsName) -> bool {
        let dir = self.derivative_dir(pipeline, name);
        std::fs::read_dir(&dir)
            .map(|mut it| it.next().is_some())
            .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("medflow_bids_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn create_and_open() {
        let parent = tmpdir("create");
        let ds = BidsDataset::create(&parent, "TESTDS").unwrap();
        assert!(ds.root.join("dataset_description.json").exists());
        assert!(ds.root.join("derivatives").exists());
        let again = BidsDataset::open(&ds.root).unwrap();
        assert_eq!(again.name, "TESTDS");
        std::fs::remove_dir_all(&parent).unwrap();
    }

    #[test]
    fn raw_and_derivative_paths_follow_fig2() {
        let parent = tmpdir("paths");
        let ds = BidsDataset::create(&parent, "DS").unwrap();
        let name = BidsName::new("01", Some("baseline"), Modality::T1w);
        assert!(ds
            .raw_path(&name, "nii.gz")
            .ends_with("DS/sub-01/ses-baseline/anat/sub-01_ses-baseline_T1w.nii.gz"));
        // derivatives: flat per-pipeline, NO anat/ level
        assert!(ds
            .derivative_dir("prequal", &name)
            .ends_with("DS/derivatives/prequal/sub-01/ses-baseline"));
        std::fs::remove_dir_all(&parent).unwrap();
    }

    #[test]
    fn link_raw_creates_symlink_to_store() {
        let parent = tmpdir("link");
        let store = parent.join("store");
        std::fs::create_dir_all(&store).unwrap();
        let raw = store.join("scan001.nii.gz");
        std::fs::write(&raw, b"fake").unwrap();
        let ds = BidsDataset::create(&parent, "DS").unwrap();
        let name = BidsName::new("01", None, Modality::T1w);
        let link = ds.link_raw(&name, "nii.gz", &raw).unwrap();
        assert!(link.symlink_metadata().unwrap().file_type().is_symlink());
        assert_eq!(std::fs::read(&link).unwrap(), b"fake");
        std::fs::remove_dir_all(&parent).unwrap();
    }

    #[test]
    fn subject_session_enumeration() {
        let parent = tmpdir("enum");
        let ds = BidsDataset::create(&parent, "DS").unwrap();
        for (sub, ses) in [("01", Some("a")), ("01", Some("b")), ("02", None)] {
            let name = BidsName::new(sub, ses, Modality::T1w);
            std::fs::create_dir_all(ds.raw_dir(&name)).unwrap();
        }
        assert_eq!(ds.subjects().unwrap(), vec!["01", "02"]);
        assert_eq!(
            ds.sessions("01").unwrap(),
            vec![Some("a".to_string()), Some("b".to_string())]
        );
        assert_eq!(ds.sessions("02").unwrap(), vec![None]);
        std::fs::remove_dir_all(&parent).unwrap();
    }

    #[test]
    fn has_derivative_detects_outputs() {
        let parent = tmpdir("deriv");
        let ds = BidsDataset::create(&parent, "DS").unwrap();
        let name = BidsName::new("01", None, Modality::T1w);
        assert!(!ds.has_derivative("freesurfer", &name));
        let d = ds.derivative_dir("freesurfer", &name);
        std::fs::create_dir_all(&d).unwrap();
        assert!(!ds.has_derivative("freesurfer", &name)); // empty dir ≠ processed
        std::fs::write(d.join("aseg.stats"), b"ok").unwrap();
        assert!(ds.has_derivative("freesurfer", &name));
        std::fs::remove_dir_all(&parent).unwrap();
    }
}
