//! `participants.tsv` support (BIDS top-level demographics table): written
//! at ingest, read back for cohort summaries; kept consistent with the
//! sub-* directories by the validator-adjacent check here.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bids::BidsDataset;
use crate::util::rng::Rng;

/// One participants.tsv row.
#[derive(Debug, Clone, PartialEq)]
pub struct Participant {
    pub id: String,
    pub age: u32,
    pub sex: char,
    pub group: String,
}

/// Deterministic synthetic demographics for a subject label.
pub fn synth_participant(subject: &str, rng: &mut Rng) -> Participant {
    Participant {
        id: format!("sub-{subject}"),
        age: 45 + rng.below(45) as u32,
        sex: if rng.below(2) == 0 { 'F' } else { 'M' },
        group: if rng.next_f64() < 0.3 { "patient" } else { "control" }.into(),
    }
}

/// Serialize rows as BIDS participants.tsv.
pub fn to_tsv(rows: &[Participant]) -> String {
    let mut s = String::from("participant_id\tage\tsex\tgroup\n");
    for r in rows {
        s.push_str(&format!("{}\t{}\t{}\t{}\n", r.id, r.age, r.sex, r.group));
    }
    s
}

/// Parse participants.tsv.
pub fn from_tsv(text: &str) -> Result<Vec<Participant>> {
    let mut lines = text.lines();
    let header = lines.next().context("empty participants.tsv")?;
    if header != "participant_id\tage\tsex\tgroup" {
        bail!("unexpected participants.tsv header: '{header}'");
    }
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            bail!("participants.tsv line {} has {} columns", i + 2, cols.len());
        }
        rows.push(Participant {
            id: cols[0].to_string(),
            age: cols[1].parse().with_context(|| format!("bad age '{}'", cols[1]))?,
            sex: cols[2].chars().next().context("empty sex column")?,
            group: cols[3].to_string(),
        });
    }
    Ok(rows)
}

/// Write participants.tsv for every subject directory in the dataset.
pub fn write_for_dataset(ds: &BidsDataset, seed: u64) -> Result<Vec<Participant>> {
    let mut rng = Rng::new(seed);
    let rows: Vec<Participant> = ds
        .subjects()?
        .iter()
        .map(|s| synth_participant(s, &mut rng))
        .collect();
    std::fs::write(ds.root.join("participants.tsv"), to_tsv(&rows))?;
    Ok(rows)
}

/// Cross-check participants.tsv against the sub-* tree; returns subjects
/// missing from the TSV and TSV rows without a directory.
pub fn check_consistency(ds: &BidsDataset) -> Result<(Vec<String>, Vec<String>)> {
    let path = ds.root.join("participants.tsv");
    let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
    let rows = from_tsv(&text)?;
    let tsv_ids: BTreeMap<String, ()> = rows.iter().map(|r| (r.id.clone(), ())).collect();
    let subjects = ds.subjects()?;
    let missing_from_tsv: Vec<String> = subjects
        .iter()
        .filter(|s| !tsv_ids.contains_key(&format!("sub-{s}")))
        .cloned()
        .collect();
    let missing_dirs: Vec<String> = rows
        .iter()
        .filter(|r| {
            r.id.strip_prefix("sub-")
                .map(|s| !subjects.contains(&s.to_string()))
                .unwrap_or(true)
        })
        .map(|r| r.id.clone())
        .collect();
    Ok((missing_from_tsv, missing_dirs))
}

/// Check if `path` is listed in the dataset's `.bidsignore` (glob-free
/// exact-suffix matching, which covers the paper's usage).
pub fn bidsignored(ds: &BidsDataset, rel: &str) -> bool {
    let Ok(text) = std::fs::read_to_string(ds.root.join(".bidsignore")) else {
        return false;
    };
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .any(|pat| rel == pat || rel.ends_with(pat.trim_start_matches('*')))
}

/// Helper for tests: `Path` reexport guard.
pub fn _exists(p: &Path) -> bool {
    p.exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpds(tag: &str) -> BidsDataset {
        let parent =
            std::env::temp_dir().join(format!("medflow_ptsv_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        let ds = BidsDataset::create(&parent, "DS").unwrap();
        for sub in ["01", "02", "03"] {
            std::fs::create_dir_all(ds.root.join(format!("sub-{sub}/anat"))).unwrap();
        }
        ds
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    #[test]
    fn tsv_roundtrip() {
        let mut rng = Rng::new(1);
        let rows: Vec<Participant> = ["01", "02"]
            .iter()
            .map(|s| synth_participant(s, &mut rng))
            .collect();
        let parsed = from_tsv(&to_tsv(&rows)).unwrap();
        assert_eq!(parsed, rows);
    }

    #[test]
    fn write_and_check_consistent() {
        let ds = tmpds("ok");
        write_for_dataset(&ds, 7).unwrap();
        let (missing_tsv, missing_dir) = check_consistency(&ds).unwrap();
        assert!(missing_tsv.is_empty() && missing_dir.is_empty());
        cleanup(&ds);
    }

    #[test]
    fn detects_drift() {
        let ds = tmpds("drift");
        write_for_dataset(&ds, 7).unwrap();
        // add a subject dir not in the TSV + remove one that is
        std::fs::create_dir_all(ds.root.join("sub-99/anat")).unwrap();
        std::fs::remove_dir_all(ds.root.join("sub-01")).unwrap();
        let (missing_tsv, missing_dir) = check_consistency(&ds).unwrap();
        assert_eq!(missing_tsv, vec!["99".to_string()]);
        assert_eq!(missing_dir, vec!["sub-01".to_string()]);
        cleanup(&ds);
    }

    #[test]
    fn rejects_malformed_tsv() {
        assert!(from_tsv("").is_err());
        assert!(from_tsv("wrong\theader\n").is_err());
        assert!(from_tsv("participant_id\tage\tsex\tgroup\nsub-01\tnotanage\tF\tx\n").is_err());
        assert!(from_tsv("participant_id\tage\tsex\tgroup\nsub-01\t44\n").is_err());
    }

    #[test]
    fn bidsignore_matching() {
        let ds = tmpds("ignore");
        std::fs::write(ds.root.join(".bidsignore"), "# comment\nderivatives_wip\n*.log\n").unwrap();
        assert!(bidsignored(&ds, "derivatives_wip"));
        assert!(bidsignored(&ds, "run_2024.log"));
        assert!(!bidsignored(&ds, "sub-01/anat/sub-01_T1w.nii.gz"));
        let _ = PathBuf::new();
        cleanup(&ds);
    }

    #[test]
    fn demographics_deterministic() {
        let a = synth_participant("01", &mut Rng::new(3));
        let b = synth_participant("01", &mut Rng::new(3));
        assert_eq!(a, b);
        assert!((45..90).contains(&a.age));
    }
}
