//! BIDS entity model and filename grammar.
//!
//! `sub-<label>[_ses-<label>][_acq-<label>][_run-<index>]_<suffix>` with
//! alphanumeric labels. Parsing and formatting are exact inverses
//! (property-tested in `rust/tests/prop_dataformats.rs`).

use anyhow::{bail, Result};

/// Image modality (the suffix). The paper curates T1w and DWI only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Modality {
    T1w,
    Dwi,
}

impl Modality {
    pub fn suffix(self) -> &'static str {
        match self {
            Modality::T1w => "T1w",
            Modality::Dwi => "dwi",
        }
    }

    /// Raw-data subdirectory per BIDS ("anat" / "dwi").
    pub fn raw_dir(self) -> &'static str {
        match self {
            Modality::T1w => "anat",
            Modality::Dwi => "dwi",
        }
    }

    pub fn from_suffix(s: &str) -> Result<Self> {
        Ok(match s {
            "T1w" => Modality::T1w,
            "dwi" => Modality::Dwi,
            other => bail!("unknown modality suffix '{other}'"),
        })
    }
}

/// A parsed BIDS file name (without extension).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BidsName {
    pub subject: String,
    pub session: Option<String>,
    pub acquisition: Option<String>,
    pub run: Option<u32>,
    pub modality: Modality,
}

fn valid_label(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric())
}

impl BidsName {
    pub fn new(subject: &str, session: Option<&str>, modality: Modality) -> Self {
        Self {
            subject: subject.to_string(),
            session: session.map(|s| s.to_string()),
            acquisition: None,
            run: None,
            modality,
        }
    }

    pub fn with_acq(mut self, acq: &str) -> Self {
        self.acquisition = Some(acq.to_string());
        self
    }

    pub fn with_run(mut self, run: u32) -> Self {
        self.run = Some(run);
        self
    }

    /// Check labels are BIDS-legal (alphanumeric).
    pub fn is_valid(&self) -> bool {
        valid_label(&self.subject)
            && self.session.as_deref().map_or(true, valid_label)
            && self.acquisition.as_deref().map_or(true, valid_label)
    }

    /// Format `sub-..._ses-..._acq-..._run-..._<suffix>`.
    pub fn format(&self) -> String {
        let mut s = format!("sub-{}", self.subject);
        if let Some(ses) = &self.session {
            s.push_str(&format!("_ses-{ses}"));
        }
        if let Some(acq) = &self.acquisition {
            s.push_str(&format!("_acq-{acq}"));
        }
        if let Some(run) = self.run {
            s.push_str(&format!("_run-{run:02}"));
        }
        s.push_str(&format!("_{}", self.modality.suffix()));
        s
    }

    /// Parse a name (extension already stripped). Inverse of [`Self::format`].
    pub fn parse(name: &str) -> Result<Self> {
        let parts: Vec<&str> = name.split('_').collect();
        if parts.len() < 2 {
            bail!("bids name '{name}' needs at least sub-X_suffix");
        }
        let suffix = parts[parts.len() - 1];
        let modality = Modality::from_suffix(suffix)?;
        let mut subject = None;
        let mut session = None;
        let mut acquisition = None;
        let mut run = None;
        for (i, part) in parts[..parts.len() - 1].iter().enumerate() {
            let (key, value) = part
                .split_once('-')
                .ok_or_else(|| anyhow::anyhow!("bad entity '{part}' in '{name}'"))?;
            if !valid_label(value) {
                bail!("illegal label '{value}' in '{name}'");
            }
            match key {
                "sub" if i == 0 => subject = Some(value.to_string()),
                "sub" => bail!("sub- entity must come first in '{name}'"),
                "ses" => session = Some(value.to_string()),
                "acq" => acquisition = Some(value.to_string()),
                "run" => run = Some(value.parse::<u32>()?),
                other => bail!("unknown entity key '{other}' in '{name}'"),
            }
        }
        Ok(Self {
            subject: subject.ok_or_else(|| anyhow::anyhow!("missing sub- in '{name}'"))?,
            session,
            acquisition,
            run,
            modality,
        })
    }

    /// Strip `.nii`/`.nii.gz`/`.json` and parse.
    pub fn parse_filename(filename: &str) -> Result<Self> {
        let stem = filename
            .trim_end_matches(".gz")
            .trim_end_matches(".nii")
            .trim_end_matches(".json");
        Self::parse(stem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_minimal() {
        assert_eq!(BidsName::new("01", None, Modality::T1w).format(), "sub-01_T1w");
    }

    #[test]
    fn format_full() {
        let n = BidsName::new("ADNI002", Some("m12"), Modality::Dwi)
            .with_acq("98dir")
            .with_run(3);
        assert_eq!(n.format(), "sub-ADNI002_ses-m12_acq-98dir_run-03_dwi");
    }

    #[test]
    fn parse_inverts_format() {
        for n in [
            BidsName::new("01", None, Modality::T1w),
            BidsName::new("x9", Some("a"), Modality::Dwi).with_run(12),
            BidsName::new("ABC", Some("baseline"), Modality::T1w).with_acq("mprage"),
        ] {
            assert_eq!(BidsName::parse(&n.format()).unwrap(), n);
        }
    }

    #[test]
    fn parse_filename_strips_extensions() {
        let n = BidsName::parse_filename("sub-01_ses-2_T1w.nii.gz").unwrap();
        assert_eq!(n.subject, "01");
        assert_eq!(n.session.as_deref(), Some("2"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "T1w",                      // no subject
            "ses-1_sub-01_T1w",         // sub not first
            "sub-01_T2w",               // unknown suffix
            "sub-01_foo-bar_T1w",       // unknown entity
            "sub-0!1_T1w",              // illegal label char
            "sub-01_run-x_dwi",         // non-numeric run
        ] {
            assert!(BidsName::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn is_valid_checks_labels() {
        assert!(BidsName::new("01", Some("base"), Modality::T1w).is_valid());
        assert!(!BidsName::new("0_1", None, Modality::T1w).is_valid());
    }
}
