//! BIDS dataset validator (the paper validates with the Python
//! bids-validator, §2.1; this is the equivalent check for medflow's
//! subset).
//!
//! Checks: dataset_description.json present and well-formed; every file
//! under sub-*/ parses as a BIDS name; name entities match their directory
//! (sub/ses consistency, modality in the right subdir); every image has a
//! JSON sidecar; derivatives tree structure (flat pipeline dirs).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::entities::BidsName;

/// Issue severity: errors fail validation, warnings do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// One validation finding.
#[derive(Debug, Clone)]
pub struct ValidationIssue {
    pub severity: Severity,
    pub path: PathBuf,
    pub message: String,
}

impl ValidationIssue {
    fn error(path: &Path, message: impl Into<String>) -> Self {
        Self {
            severity: Severity::Error,
            path: path.to_path_buf(),
            message: message.into(),
        }
    }

    fn warning(path: &Path, message: impl Into<String>) -> Self {
        Self {
            severity: Severity::Warning,
            path: path.to_path_buf(),
            message: message.into(),
        }
    }
}

/// Validate a dataset tree; returns all issues found (empty = fully valid).
pub fn validate_dataset(root: &Path) -> Vec<ValidationIssue> {
    let mut issues = Vec::new();

    // 1. dataset_description.json
    let desc_path = root.join("dataset_description.json");
    match std::fs::read_to_string(&desc_path) {
        Err(_) => {
            issues.push(ValidationIssue::error(&desc_path, "missing dataset_description.json"))
        }
        Ok(text) => match Json::parse(&text) {
            Err(e) => issues.push(ValidationIssue::error(&desc_path, format!("invalid JSON: {e}"))),
            Ok(json) => {
                for key in ["Name", "BIDSVersion"] {
                    if json.get_path(key).is_none() {
                        issues.push(ValidationIssue::error(&desc_path, format!("missing '{key}'")));
                    }
                }
            }
        },
    }

    // 2. subject trees
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => {
            issues.push(ValidationIssue::error(root, "cannot read dataset root"));
            return issues;
        }
    };
    for entry in entries.flatten() {
        let fname = entry.file_name().to_string_lossy().to_string();
        let path = entry.path();
        if let Some(sub) = fname.strip_prefix("sub-") {
            if path.is_dir() {
                walk_subject(&path, sub, &mut issues);
            } else {
                issues.push(ValidationIssue::error(&path, "sub-* must be a directory"));
            }
        } else if fname == "derivatives" {
            walk_derivatives(&path, &mut issues);
        } else if !matches!(
            fname.as_str(),
            "dataset_description.json" | "participants.tsv" | "README" | "CHANGES" | ".bidsignore"
                | ".medflow"
        ) {
            issues.push(ValidationIssue::warning(&path, "unexpected top-level entry"));
        }
    }
    issues
}

fn walk_subject(subdir: &Path, subject: &str, issues: &mut Vec<ValidationIssue>) {
    for entry in std::fs::read_dir(subdir).into_iter().flatten().flatten() {
        let fname = entry.file_name().to_string_lossy().to_string();
        let path = entry.path();
        if let Some(ses) = fname.strip_prefix("ses-") {
            walk_modalities(&path, subject, Some(ses), issues);
        } else if matches!(fname.as_str(), "anat" | "dwi") {
            check_modality_dir(&path, subject, None, &fname, issues);
        } else {
            issues.push(ValidationIssue::warning(&path, "unexpected entry in subject dir"));
        }
    }
}

fn walk_modalities(
    sesdir: &Path,
    subject: &str,
    session: Option<&str>,
    issues: &mut Vec<ValidationIssue>,
) {
    for entry in std::fs::read_dir(sesdir).into_iter().flatten().flatten() {
        let fname = entry.file_name().to_string_lossy().to_string();
        let path = entry.path();
        if matches!(fname.as_str(), "anat" | "dwi") {
            check_modality_dir(&path, subject, session, &fname, issues);
        } else {
            issues.push(ValidationIssue::warning(&path, "unexpected entry in session dir"));
        }
    }
}

fn check_modality_dir(
    dir: &Path,
    subject: &str,
    session: Option<&str>,
    dirname: &str,
    issues: &mut Vec<ValidationIssue>,
) {
    for entry in std::fs::read_dir(dir).into_iter().flatten().flatten() {
        let fname = entry.file_name().to_string_lossy().to_string();
        let path = entry.path();
        let is_image = fname.ends_with(".nii") || fname.ends_with(".nii.gz");
        let is_sidecar = fname.ends_with(".json");
        if !is_image && !is_sidecar {
            issues.push(ValidationIssue::warning(&path, "non-BIDS file in modality dir"));
            continue;
        }
        match BidsName::parse_filename(&fname) {
            Err(e) => issues.push(ValidationIssue::error(&path, format!("unparseable name: {e}"))),
            Ok(name) => {
                if name.subject != subject {
                    issues.push(ValidationIssue::error(
                        &path,
                        format!(
                            "subject mismatch: file says '{}', dir says '{subject}'",
                            name.subject
                        ),
                    ));
                }
                if name.session.as_deref() != session {
                    issues.push(ValidationIssue::error(
                        &path,
                        format!(
                            "session mismatch: file says {:?}, dir says {session:?}",
                            name.session
                        ),
                    ));
                }
                if name.modality.raw_dir() != dirname {
                    issues.push(ValidationIssue::error(
                        &path,
                        format!(
                            "modality {} belongs in {}/",
                            name.modality.suffix(),
                            name.modality.raw_dir()
                        ),
                    ));
                }
                if is_image {
                    let sidecar = sidecar_path(&path);
                    if !sidecar.exists() {
                        issues.push(ValidationIssue::warning(&path, "image has no JSON sidecar"));
                    }
                }
            }
        }
    }
}

fn sidecar_path(image: &Path) -> PathBuf {
    let s = image.to_string_lossy();
    let stem = s.trim_end_matches(".gz").trim_end_matches(".nii");
    PathBuf::from(format!("{stem}.json"))
}

fn walk_derivatives(dir: &Path, issues: &mut Vec<ValidationIssue>) {
    for entry in std::fs::read_dir(dir).into_iter().flatten().flatten() {
        let path = entry.path();
        if !path.is_dir() {
            issues.push(ValidationIssue::warning(&path, "loose file in derivatives/"));
        }
        // per-pipeline content is free-form (paper keeps each pipeline's
        // native output layout), so no deeper checks here.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bids::{BidsDataset, Modality};

    fn tmpds(tag: &str) -> BidsDataset {
        let parent = std::env::temp_dir().join(format!("medflow_val_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        BidsDataset::create(&parent, "DS").unwrap()
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    fn errors(issues: &[ValidationIssue]) -> Vec<String> {
        issues
            .iter()
            .filter(|i| i.severity == Severity::Error)
            .map(|i| i.message.clone())
            .collect()
    }

    #[test]
    fn fresh_dataset_validates() {
        let ds = tmpds("fresh");
        assert!(errors(&validate_dataset(&ds.root)).is_empty());
        cleanup(&ds);
    }

    #[test]
    fn good_file_passes_warning_only_for_missing_sidecar() {
        let ds = tmpds("good");
        let name = BidsName::new("01", Some("a"), Modality::T1w);
        let p = ds.raw_path(&name, "nii");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"x").unwrap();
        let issues = validate_dataset(&ds.root);
        assert!(errors(&issues).is_empty(), "{issues:?}");
        assert!(issues.iter().any(|i| i.message.contains("sidecar")));
        cleanup(&ds);
    }

    #[test]
    fn subject_mismatch_is_error() {
        let ds = tmpds("mismatch");
        let dir = ds.root.join("sub-01/anat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("sub-02_T1w.nii"), b"x").unwrap();
        let issues = validate_dataset(&ds.root);
        assert!(errors(&issues).iter().any(|m| m.contains("subject mismatch")));
        cleanup(&ds);
    }

    #[test]
    fn wrong_modality_dir_is_error() {
        let ds = tmpds("wrongdir");
        let dir = ds.root.join("sub-01/dwi");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("sub-01_T1w.nii"), b"x").unwrap();
        let issues = validate_dataset(&ds.root);
        assert!(errors(&issues).iter().any(|m| m.contains("belongs in anat/")));
        cleanup(&ds);
    }

    #[test]
    fn missing_description_is_error() {
        let ds = tmpds("nodesc");
        std::fs::remove_file(ds.root.join("dataset_description.json")).unwrap();
        let issues = validate_dataset(&ds.root);
        assert!(errors(&issues).iter().any(|m| m.contains("dataset_description")));
        cleanup(&ds);
    }

    #[test]
    fn unparseable_name_is_error() {
        let ds = tmpds("badname");
        let dir = ds.root.join("sub-01/anat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("garbage.nii"), b"x").unwrap();
        let issues = validate_dataset(&ds.root);
        assert!(errors(&issues).iter().any(|m| m.contains("unparseable")));
        cleanup(&ds);
    }
}
