//! Frozen pre-PR reference engines (the "before" of the event-engine
//! overhaul, DESIGN.md §10).
//!
//! This module preserves, verbatim, the discrete-event implementations
//! that shipped before the 10⁶-job event-engine rewrite:
//!
//! * [`TransferScheduler`] — the contention-aware transfer scheduler
//!   with a single globally sorted queue that `admit`/`next_event_time`
//!   re-scan per event (O(n) per event, O(n²) per campaign);
//! * [`Scheduler`] — the SLURM simulator that re-sorts every pending
//!   job on every scheduling pass, finds the next completion with a
//!   linear scan over running jobs, and re-clones the node array inside
//!   `earliest_start_estimate`;
//! * [`LanePool`] / [`SlurmSim`] / [`run_staged`] — the staged
//!   co-simulation loop that polls both engines' O(n)
//!   `next_event_time` on every iteration.
//!
//! They exist for two reasons, both load-bearing:
//!
//! 1. **Golden parity.** Both engine generations are deterministic given
//!    a seed, so `rust/tests/engine_parity.rs` demands *exact* equality
//!    — every [`TransferRecord`]/[`crate::slurm::JobRecord`] field,
//!    every f64 bit — between the rewritten engines and these
//!    references across seeded scenario batteries (including the
//!    Table 1 calibration cases). Any semantic drift in the rewrite
//!    fails loudly.
//! 2. **The `--legacy` benchmark path.** `benches/campaign_scale.rs`
//!    runs the same staged campaigns through both generations and
//!    records the before/after trajectory in
//!    `BENCH_campaign_scale.json`; the ≥10× speedup claim at 10⁵ jobs
//!    is measured, not asserted from memory.
//!
//! Do not "fix" or optimize this module: its value is that it does not
//! change. It shares the public data types (records, stats, topologies,
//! job specs) with the live engines so comparisons are type-identical.

// lint:allow-file(float-ord) — frozen pre-rewrite golden reference: these are
// the exact comparators the parity batteries diff against; changing them
// defeats the module's purpose

use std::collections::BTreeMap;

use crate::coordinator::staged::{ComputeSim, StagedJob, StagedOutcome, StagedTiming};
use crate::netsim::scheduler::{fair_share, Topology, TransferRecord, TransferStats};
use crate::netsim::{Env, NetProfile};
use crate::slurm::{
    ArrayHandle, ClusterSpec, JobRecord, Maintenance, Policy, SimJob,
};
use crate::util::rng::Rng;
use crate::util::units::gbps_to_bytes_per_sec;

/// Comparison slack for event times (seconds) — transfers are O(ms..h).
const EPS: f64 = 1e-9;

/// Remaining-byte threshold below which a stream counts as drained.
const DONE_BYTES: f64 = 0.5;

#[derive(Debug, Clone)]
struct QueuedTransfer {
    id: u64,
    host: u64,
    bytes: u64,
    submit_s: f64,
}

#[derive(Debug, Clone)]
struct ActiveStream {
    id: u64,
    host: u64,
    bytes: u64,
    submit_s: f64,
    start_s: f64,
    latency_s: f64,
    stream_gbps: f64,
    bytes_left: f64,
}

impl ActiveStream {
    fn flow_start_s(&self) -> f64 {
        self.start_s + self.latency_s
    }
}

/// The pre-PR discrete-event transfer scheduler: one globally sorted
/// `Vec<QueuedTransfer>` whose due-but-blocked prefix is re-scanned by
/// `admit`/`next_event_time` on every event, and a fair-share
/// allocation recomputed from scratch inside both `next_event_time`
/// and `integrate` — O(n) per event, fine up to ~10⁴ transfers.
#[derive(Debug)]
pub struct TransferScheduler {
    topo: Topology,
    profile: NetProfile,
    bottleneck_gbps: f64,
    seed: u64,
    clock: f64,
    queue: Vec<QueuedTransfer>,
    active: Vec<ActiveStream>,
    records: Vec<TransferRecord>,
    busy_s: f64,
    bytes_done: u64,
    peak_streams: usize,
}

impl TransferScheduler {
    pub fn new(topo: Topology, seed: u64) -> Self {
        let profile = NetProfile::of(topo.env);
        let bottleneck_gbps = topo.bottleneck_gbps();
        Self {
            topo,
            profile,
            bottleneck_gbps,
            seed,
            clock: 0.0,
            queue: Vec::new(),
            active: Vec::new(),
            records: Vec::new(),
            busy_s: 0.0,
            bytes_done: 0,
            peak_streams: 0,
        }
    }

    /// Convenience: environment topology with an explicit stream cap.
    pub fn for_env(env: Env, max_streams_per_host: usize, seed: u64) -> Self {
        Self::new(Topology::of(env).with_stream_cap(max_streams_per_host), seed)
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn records(&self) -> &[TransferRecord] {
        &self.records
    }

    /// Submit a transfer of `bytes` from `host` at absolute time
    /// `submit_s` (must not be in the scheduler's past).
    pub fn submit_at(&mut self, id: u64, host: u64, bytes: u64, submit_s: f64) {
        assert!(
            submit_s + EPS >= self.clock,
            "transfer {id}: cannot submit in the past (submit {submit_s}, clock {})",
            self.clock
        );
        debug_assert!(
            !self.queue.iter().any(|q| q.id == id)
                && !self.active.iter().any(|a| a.id == id)
                && !self.records.iter().any(|r| r.id == id),
            "transfer id {id} reused"
        );
        let submit_s = submit_s.max(self.clock);
        // keep the queue sorted by (submit_s, id): binary-search insertion
        // here keeps admit() a plain scan instead of a per-event sort
        let pos = self
            .queue
            .partition_point(|q| (q.submit_s, q.id) <= (submit_s, id));
        self.queue.insert(
            pos,
            QueuedTransfer {
                id,
                host,
                bytes,
                submit_s,
            },
        );
        if submit_s <= self.clock + EPS {
            self.admit();
        }
    }

    /// Deterministic per-transfer sampling stream (identical to the live
    /// engine's keyed sampling).
    fn transfer_rng(&self, id: u64) -> Rng {
        Rng::new(self.seed.wrapping_add(id.wrapping_mul(0x9E3779B97F4A7C15)))
    }

    /// Admit queued transfers due at the current clock, FIFO per host,
    /// while the host is under its stream cap.
    fn admit(&mut self) {
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].submit_s > self.clock + EPS {
                break; // sorted queue: everything after is future too
            }
            let host = self.queue[i].host;
            let host_active = self.active.iter().filter(|a| a.host == host).count();
            if host_active >= self.topo.max_streams_per_host {
                i += 1;
                continue;
            }
            let q = self.queue.remove(i);
            let mut rng = self.transfer_rng(q.id);
            let stream_gbps = rng
                .normal_ms(self.profile.throughput_gbps.0, self.profile.throughput_gbps.1)
                .max(0.01);
            let latency_s = rng
                .normal_ms(self.profile.latency_ms.0, self.profile.latency_ms.1)
                .max(0.01)
                / 1e3;
            self.active.push(ActiveStream {
                id: q.id,
                host: q.host,
                bytes: q.bytes,
                submit_s: q.submit_s,
                start_s: self.clock,
                latency_s,
                stream_gbps,
                bytes_left: q.bytes as f64,
            });
            self.peak_streams = self.peak_streams.max(self.active.len());
        }
    }

    /// Per-active-stream rate (Gb/s) under the current composition;
    /// recomputed from scratch on every call.
    fn current_rates(&self) -> Vec<f64> {
        let flowing: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, a)| self.clock + EPS >= a.flow_start_s())
            .map(|(i, _)| i)
            .collect();
        let caps: Vec<f64> = flowing.iter().map(|&i| self.active[i].stream_gbps).collect();
        let shares = fair_share(&caps, self.bottleneck_gbps);
        let mut rates = vec![0.0; self.active.len()];
        for (k, &i) in flowing.iter().enumerate() {
            rates[i] = shares[k];
        }
        rates
    }

    /// Time of the next state change (scans the whole blocked prefix).
    pub fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if let Some(q) = self.queue.iter().find(|q| q.submit_s > self.clock + EPS) {
            t = t.min(q.submit_s);
        }
        let rates = self.current_rates();
        for (a, &r) in self.active.iter().zip(&rates) {
            if self.clock + EPS < a.flow_start_s() {
                t = t.min(a.flow_start_s());
            } else if r > 0.0 {
                t = t.min(self.clock + a.bytes_left.max(0.0) / gbps_to_bytes_per_sec(r));
            }
        }
        t.is_finite().then_some(t)
    }

    /// Move bytes at the current allocation from `clock` to `target`.
    fn integrate(&mut self, target: f64) {
        let dt = target - self.clock;
        if dt <= 0.0 {
            return;
        }
        if !self.active.is_empty() {
            self.busy_s += dt;
        }
        let rates = self.current_rates();
        for (a, r) in self.active.iter_mut().zip(rates) {
            if r > 0.0 {
                a.bytes_left -= gbps_to_bytes_per_sec(r) * dt;
            }
        }
    }

    fn complete_finished(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            if self.clock + EPS >= a.flow_start_s() && a.bytes_left <= DONE_BYTES {
                let a = self.active.swap_remove(i);
                self.bytes_done += a.bytes;
                self.records.push(TransferRecord {
                    id: a.id,
                    host: a.host,
                    bytes: a.bytes,
                    submit_s: a.submit_s,
                    start_s: a.start_s,
                    end_s: self.clock,
                    latency_s: a.latency_s,
                    stream_gbps: a.stream_gbps,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Advance to absolute time `t`, processing every event up to and
    /// including `t`.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t + EPS >= self.clock,
            "cannot advance backwards (to {t}, clock {})",
            self.clock
        );
        loop {
            self.admit();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.integrate(target);
            self.clock = self.clock.max(target);
            self.complete_finished();
            if target + EPS >= t {
                self.admit();
                return;
            }
        }
    }

    /// Run until every submitted transfer has completed.
    pub fn run_to_completion(&mut self) -> &[TransferRecord] {
        while let Some(t) = self.next_event_time() {
            self.advance_to(t);
        }
        &self.records
    }

    /// Aggregate telemetry over everything completed so far.
    pub fn stats(&self) -> TransferStats {
        let makespan_s = self.records.iter().map(|r| r.end_s).fold(0.0, f64::max);
        let gbits = self.bytes_done as f64 * 8.0 / 1e9;
        let waits: f64 = self.records.iter().map(|r| r.queue_wait_s()).sum();
        TransferStats {
            transfers: self.records.len(),
            bytes: self.bytes_done,
            makespan_s,
            busy_s: self.busy_s,
            peak_streams: self.peak_streams,
            mean_queue_wait_s: if self.records.is_empty() {
                0.0
            } else {
                waits / self.records.len() as f64
            },
            link_utilization: if self.busy_s > 0.0 {
                gbits / (self.bottleneck_gbps * self.busy_s)
            } else {
                0.0
            },
            aggregate_gbps: if makespan_s > 0.0 {
                gbits / makespan_s
            } else {
                0.0
            },
        }
    }
}

/// The §2.4 bandwidth experiment through the pre-PR scheduler — the
/// Table 1 calibration case for the golden parity tests.
pub fn scheduler_bandwidth_experiment(env: Env, n: usize, seed: u64) -> Vec<f64> {
    let mut sim = TransferScheduler::for_env(env, 1, seed);
    let gb = 1_000_000_000u64;
    for i in 0..n {
        sim.submit_at(i as u64, 0, gb, 0.0);
    }
    sim.run_to_completion();
    sim.records().iter().map(|r| r.observed_gbps()).collect()
}

// ---------------------------------------------------------------------
// SLURM cluster simulator (pre-PR)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct NodeState {
    free_cores: u32,
    free_ram_gb: u32,
}

#[derive(Debug, Clone)]
struct Running {
    job: SimJob,
    node: usize,
    start_s: f64,
    end_s: f64,
}

/// The pre-PR SLURM discrete-event scheduler: every scheduling pass
/// rescans and re-sorts the whole pending vector, `next_event_time`
/// linearly scans running jobs, and `earliest_start_estimate` clones
/// the node array and rescans all nodes per release.
#[derive(Debug)]
pub struct Scheduler {
    pub spec: ClusterSpec,
    nodes: Vec<NodeState>,
    clock: f64,
    pending: Vec<SimJob>,
    running: Vec<Running>,
    records: Vec<JobRecord>,
    usage: BTreeMap<String, f64>,
    maintenance: Vec<Maintenance>,
    array_running: BTreeMap<u64, u32>,
    core_seconds_capacity: f64,
    core_seconds_used: f64,
    needs_schedule: bool,
    pub policy: Policy,
}

impl Scheduler {
    pub fn new(spec: ClusterSpec) -> Self {
        Self::with_policy(spec, Policy::default())
    }

    pub fn with_policy(spec: ClusterSpec, policy: Policy) -> Self {
        let nodes = spec
            .nodes
            .iter()
            .map(|n| NodeState {
                free_cores: n.cores,
                free_ram_gb: n.ram_gb,
            })
            .collect();
        Self {
            nodes,
            clock: 0.0,
            pending: Vec::new(),
            running: Vec::new(),
            records: Vec::new(),
            usage: BTreeMap::new(),
            maintenance: Vec::new(),
            array_running: BTreeMap::new(),
            core_seconds_capacity: 0.0,
            core_seconds_used: 0.0,
            needs_schedule: false,
            policy,
            spec,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn add_maintenance(&mut self, w: Maintenance) {
        self.maintenance.push(w);
    }

    /// True if `t` falls in a maintenance window (no job starts).
    pub fn in_maintenance(&self, t: f64) -> bool {
        self.maintenance.iter().any(|w| t >= w.start_s && t < w.end_s)
    }

    pub fn submit(&mut self, job: SimJob) {
        assert!(
            job.submit_s >= self.clock,
            "cannot submit in the past (job {} at {}, clock {})",
            job.id,
            job.submit_s,
            self.clock
        );
        self.pending.push(job);
        self.needs_schedule = true;
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn records(&self) -> &[JobRecord] {
        &self.records
    }

    /// Cluster-wide core utilization over simulated time so far (0..1).
    pub fn utilization(&self) -> f64 {
        if self.core_seconds_capacity <= 0.0 {
            return 0.0;
        }
        self.core_seconds_used / self.core_seconds_capacity
    }

    fn priority(&self, job: &SimJob) -> (f64, f64, u64) {
        let usage = if self.policy.fairshare {
            self.usage.get(&job.user).copied().unwrap_or(0.0)
        } else {
            0.0
        };
        (usage, job.submit_s, job.id)
    }

    fn fits_on(&self, node: usize, job: &SimJob) -> bool {
        self.nodes[node].free_cores >= job.cores && self.nodes[node].free_ram_gb >= job.ram_gb
    }

    fn first_fit(&self, job: &SimJob) -> Option<usize> {
        (0..self.nodes.len()).find(|&n| self.fits_on(n, job))
    }

    fn array_ok(&self, job: &SimJob) -> bool {
        match &job.array {
            None => true,
            Some(h) => self.array_running.get(&h.array_id).copied().unwrap_or(0) < h.max_concurrent,
        }
    }

    fn start_job(&mut self, job: SimJob, node: usize) {
        self.nodes[node].free_cores -= job.cores;
        self.nodes[node].free_ram_gb -= job.ram_gb;
        if let Some(h) = &job.array {
            *self.array_running.entry(h.array_id).or_insert(0) += 1;
        }
        *self.usage.entry(job.user.clone()).or_insert(0.0) +=
            job.cores as f64 * job.duration_s;
        self.core_seconds_used += job.cores as f64 * job.duration_s;
        let end_s = self.clock + job.duration_s;
        self.running.push(Running {
            job,
            node,
            start_s: self.clock,
            end_s,
        });
    }

    /// Priority order + EASY backfill over the full pending vector.
    fn schedule(&mut self) {
        if self.in_maintenance(self.clock) {
            return;
        }
        self.needs_schedule = false;
        let mut arrived: Vec<(usize, (f64, f64, u64))> = (0..self.pending.len())
            .filter(|&i| self.pending[i].submit_s <= self.clock)
            .map(|i| (i, self.priority(&self.pending[i])))
            .collect();
        arrived.sort_unstable_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let arrived: Vec<usize> = arrived.into_iter().map(|(i, _)| i).collect();

        let mut started: Vec<usize> = Vec::new();
        let mut shadow: Option<f64> = None; // head job's reserved start
        let mut failed_reqs: Vec<(u32, u32)> = Vec::new();
        for &idx in &arrived {
            let job = self.pending[idx].clone();
            if !self.array_ok(&job) {
                continue;
            }
            if let Some(sh) = shadow {
                if !self.policy.backfill || self.clock + job.duration_s > sh {
                    continue;
                }
            }
            if failed_reqs
                .iter()
                .any(|&(c, r)| job.cores >= c && job.ram_gb >= r)
            {
                if shadow.is_none() {
                    shadow = Some(self.earliest_start_estimate(&job));
                }
                continue;
            }
            match self.first_fit(&job) {
                Some(node) => {
                    self.start_job(job, node);
                    started.push(idx);
                }
                None => {
                    failed_reqs.push((job.cores, job.ram_gb));
                    if shadow.is_none() {
                        shadow = Some(self.earliest_start_estimate(&job));
                    }
                }
            }
        }
        started.sort_unstable_by(|a, b| b.cmp(a));
        for idx in started {
            self.pending.remove(idx);
        }
    }

    /// Earliest time the blocked job could start (clones the node array,
    /// rescans every node per release — the pre-PR cost).
    fn earliest_start_estimate(&self, job: &SimJob) -> f64 {
        let mut frees: Vec<(f64, usize, u32, u32)> = self
            .running
            .iter()
            .map(|r| (r.end_s, r.node, r.job.cores, r.job.ram_gb))
            .collect();
        frees.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut nodes = self.nodes.clone();
        for (end, node, cores, ram) in frees {
            nodes[node].free_cores += cores;
            nodes[node].free_ram_gb += ram;
            if nodes
                .iter()
                .any(|n| n.free_cores >= job.cores && n.free_ram_gb >= job.ram_gb)
            {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Time of the next event (linear scans over running + pending).
    pub fn next_event_time(&self) -> Option<f64> {
        if self.needs_schedule
            && !self.in_maintenance(self.clock)
            && self.pending.iter().any(|j| j.submit_s <= self.clock)
        {
            return Some(self.clock);
        }
        let next_end = self
            .running
            .iter()
            .map(|r| r.end_s)
            .fold(f64::INFINITY, f64::min);
        let next_arrival = self
            .pending
            .iter()
            .map(|j| j.submit_s)
            .filter(|&t| t > self.clock)
            .fold(f64::INFINITY, f64::min);
        let next_maint_end = self
            .maintenance
            .iter()
            .filter(|w| w.end_s > self.clock && w.start_s <= self.clock)
            .map(|w| w.end_s)
            .fold(f64::INFINITY, f64::min);
        let next_t = next_end.min(next_arrival).min(next_maint_end);
        next_t.is_finite().then_some(next_t)
    }

    /// Release resources of every running job whose end time has passed.
    fn complete_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].end_s <= self.clock {
                let r = self.running.swap_remove(i);
                self.nodes[r.node].free_cores += r.job.cores;
                self.nodes[r.node].free_ram_gb += r.job.ram_gb;
                if let Some(h) = &r.job.array {
                    if let Some(c) = self.array_running.get_mut(&h.array_id) {
                        *c -= 1;
                    }
                }
                self.records.push(JobRecord {
                    start_s: r.start_s,
                    end_s: r.end_s,
                    node: r.node,
                    job: r.job,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Advance to the next event; returns false when nothing remains.
    pub fn step(&mut self) -> bool {
        self.schedule();
        let Some(next_t) = self.next_event_time() else {
            return false;
        };
        let dt = next_t - self.clock;
        self.core_seconds_capacity += self.spec.total_cores() as f64 * dt.max(0.0);
        self.clock = next_t;
        self.complete_finished();
        true
    }

    /// Advance the simulation to absolute time `t` without overshooting.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t + 1e-9 >= self.clock,
            "cannot advance backwards (to {t}, clock {})",
            self.clock
        );
        loop {
            self.schedule();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            let dt = (target - self.clock).max(0.0);
            self.core_seconds_capacity += self.spec.total_cores() as f64 * dt;
            self.clock = self.clock.max(target);
            self.complete_finished();
            if target + 1e-9 >= t {
                self.schedule();
                return;
            }
        }
    }

    /// Run until all submitted jobs have completed (or deadlock).
    pub fn run_to_completion(&mut self) -> &[JobRecord] {
        while !self.pending.is_empty() || !self.running.is_empty() {
            if !self.step() {
                break;
            }
        }
        &self.records
    }

    /// Makespan of everything completed so far.
    pub fn makespan(&self) -> f64 {
        self.records.iter().map(|r| r.end_s).fold(0.0, f64::max)
    }
}

// ---------------------------------------------------------------------
// Staged co-simulation (pre-PR)
// ---------------------------------------------------------------------

/// Host id used for a campaign's staging path (one shared gateway).
const STAGE_HOST: u64 = 0;

/// The pre-PR SLURM compute backend wrapper.
pub struct SlurmSim {
    sched: Scheduler,
    user: String,
    array: Option<ArrayHandle>,
    cursor: usize,
}

impl SlurmSim {
    pub fn new(sched: Scheduler, user: &str, array: Option<ArrayHandle>) -> Self {
        Self {
            sched,
            user: user.to_string(),
            array,
            cursor: 0,
        }
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }
}

impl ComputeSim for SlurmSim {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        self.sched.submit(SimJob {
            id,
            user: self.user.clone(),
            cores: job.cores,
            ram_gb: job.ram_gb,
            duration_s: job.compute_s,
            submit_s: ready_s.max(self.sched.clock()),
            array: self.array,
        });
    }

    fn next_event_time(&self) -> Option<f64> {
        self.sched.next_event_time()
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        self.sched.advance_to(t);
        let recs = self.sched.records();
        let done = recs[self.cursor..]
            .iter()
            .map(|r| (r.job.id, r.end_s))
            .collect();
        self.cursor = recs.len();
        done
    }
}

/// The pre-PR bounded worker-lane pool: job selection linearly scans
/// the whole queue per start, `next_event_time` per event.
pub struct LanePool {
    lanes: Vec<f64>,
    queue: Vec<(u64, f64, f64)>,
    running: Vec<(u64, f64)>,
    clock: f64,
}

impl LanePool {
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "lane pool needs at least one worker");
        Self {
            lanes: vec![0.0; workers],
            queue: Vec::new(),
            running: Vec::new(),
            clock: 0.0,
        }
    }

    /// Start queued-and-ready jobs on free lanes, FIFO by (ready, id).
    fn start_ready(&mut self) {
        loop {
            let Some(lane) = self.lanes.iter().position(|&f| f <= self.clock + EPS) else {
                return;
            };
            let next = self
                .queue
                .iter()
                .enumerate()
                .filter(|(_, &(_, ready, _))| ready <= self.clock + EPS)
                .min_by(|(_, a), (_, b)| {
                    (a.1, a.0).partial_cmp(&(b.1, b.0)).expect("finite times")
                })
                .map(|(k, _)| k);
            let Some(k) = next else { return };
            let (id, _ready, dur) = self.queue.remove(k);
            self.lanes[lane] = self.clock + dur;
            self.running.push((id, self.clock + dur));
        }
    }
}

impl ComputeSim for LanePool {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        let ready = ready_s.max(self.clock);
        self.queue.push((id, ready, job.compute_s));
        if ready <= self.clock + EPS {
            self.start_ready();
        }
    }

    fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for &(_, end) in &self.running {
            t = t.min(end);
        }
        for &(_, ready, _) in &self.queue {
            if ready > self.clock + EPS {
                t = t.min(ready);
            }
        }
        t.is_finite().then_some(t)
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        assert!(t + EPS >= self.clock, "cannot advance backwards");
        let mut done = Vec::new();
        loop {
            self.start_ready();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.clock = self.clock.max(target);
            let mut i = 0;
            while i < self.running.len() {
                if self.running[i].1 <= self.clock + EPS {
                    done.push(self.running.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            if target + EPS >= t {
                self.start_ready();
                return done;
            }
        }
    }
}

const fn stage_in_id(i: usize) -> u64 {
    (i as u64) * 2
}

const fn stage_out_id(i: usize) -> u64 {
    (i as u64) * 2 + 1
}

/// The pre-PR staged campaign loop: polls both engines'
/// `next_event_time` on every iteration and advances both to the
/// globally earliest event. Byte-identical hand-off semantics to
/// [`crate::coordinator::staged::run_staged`], at pre-PR cost.
pub fn run_staged(
    jobs: &[StagedJob],
    compute: &mut dyn ComputeSim,
    transfers: &mut TransferScheduler,
) -> StagedOutcome {
    let mut timings = vec![StagedTiming::default(); jobs.len()];
    for (i, j) in jobs.iter().enumerate() {
        transfers.submit_at(stage_in_id(i), STAGE_HOST, j.bytes_in, 0.0);
    }
    let mut seen = 0usize;
    loop {
        let t = match (transfers.next_event_time(), compute.next_event_time()) {
            (None, None) => break,
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) => a,
            (None, Some(b)) => b,
        };
        transfers.advance_to(t);
        let new_records = transfers.records()[seen..].to_vec();
        seen = transfers.records().len();
        for r in &new_records {
            let i = (r.id / 2) as usize;
            if r.id % 2 == 0 {
                timings[i].stage_in_wait_s = r.queue_wait_s();
                timings[i].stage_in_s = r.transfer_s();
                compute.submit(i as u64, r.end_s, &jobs[i]);
            } else {
                timings[i].stage_out_wait_s = r.queue_wait_s();
                timings[i].stage_out_s = r.transfer_s();
                timings[i].done_s = r.end_s;
                timings[i].completed = true;
            }
        }
        for (id, end_s) in compute.advance_to(t) {
            let i = id as usize;
            timings[i].compute_end_s = end_s;
            timings[i].compute_start_s = end_s - jobs[i].compute_s;
            transfers.submit_at(stage_out_id(i), STAGE_HOST, jobs[i].bytes_out, end_s);
        }
    }
    let makespan_s = timings
        .iter()
        .map(|x| x.compute_end_s)
        .fold(transfers.stats().makespan_s, f64::max);
    StagedOutcome {
        makespan_s,
        transfer: transfers.stats(),
        timings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The real coverage for this module is rust/tests/engine_parity.rs,
    // which pins the live engines to these references record-for-record.
    // Here: just prove the frozen copies still run end to end.

    #[test]
    fn frozen_transfer_engine_runs() {
        let mut sim = TransferScheduler::for_env(Env::Local, 2, 7);
        for i in 0..4 {
            sim.submit_at(i, 0, 100_000_000, 0.0);
        }
        assert_eq!(sim.run_to_completion().len(), 4);
    }

    #[test]
    fn frozen_slurm_engine_runs() {
        let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
        for id in 0..4 {
            s.submit(SimJob {
                id,
                user: "u".into(),
                cores: 2,
                ram_gb: 1,
                duration_s: 50.0,
                submit_s: 0.0,
                array: None,
            });
        }
        assert_eq!(s.run_to_completion().len(), 4);
        assert_eq!(s.makespan(), 100.0);
    }

    #[test]
    fn frozen_staged_loop_runs() {
        let jobs: Vec<StagedJob> = (0..3)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s: 60.0,
                bytes_in: 50_000_000,
                bytes_out: 10_000_000,
            })
            .collect();
        let mut lanes = LanePool::new(2);
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 4, 3);
        let out = run_staged(&jobs, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        assert_eq!(out.transfer.transfers, 6);
    }
}
