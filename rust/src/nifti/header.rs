//! The 348-byte NIfTI-1 header (https://nifti.nimh.nih.gov/nifti-1).
//! Only the fields medflow reads/writes are modeled; the rest are zeroed on
//! write and ignored on read (which real tools also tolerate).

use anyhow::{bail, Result};

/// Supported on-disk datatypes (NIfTI codes 2, 4, 16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datatype {
    Uint8,
    Int16,
    Float32,
}

impl Datatype {
    pub fn code(self) -> i16 {
        match self {
            Datatype::Uint8 => 2,
            Datatype::Int16 => 4,
            Datatype::Float32 => 16,
        }
    }

    pub fn bitpix(self) -> i16 {
        (self.size() * 8) as i16
    }

    pub fn size(self) -> usize {
        match self {
            Datatype::Uint8 => 1,
            Datatype::Int16 => 2,
            Datatype::Float32 => 4,
        }
    }

    pub fn from_code(code: i16) -> Result<Self> {
        Ok(match code {
            2 => Datatype::Uint8,
            4 => Datatype::Int16,
            16 => Datatype::Float32,
            c => bail!("unsupported nifti datatype code {c}"),
        })
    }
}

/// Parsed NIfTI-1 header (3-D images).
#[derive(Debug, Clone)]
pub struct NiftiHeader {
    pub dim: [i16; 8],
    pub pixdim: [f32; 8],
    pub datatype: Datatype,
    pub vox_offset: f32,
    pub scl_slope: f32,
    pub scl_inter: f32,
    pub descrip: String,
}

impl NiftiHeader {
    pub fn for_dims(dims: [u16; 3], voxel_mm: [f32; 3], datatype: Datatype) -> Self {
        let mut dim = [1i16; 8];
        dim[0] = 3;
        for i in 0..3 {
            dim[i + 1] = dims[i] as i16;
        }
        let mut pixdim = [1.0f32; 8];
        for i in 0..3 {
            pixdim[i + 1] = voxel_mm[i];
        }
        Self {
            dim,
            pixdim,
            datatype,
            vox_offset: 352.0,
            scl_slope: 1.0,
            scl_inter: 0.0,
            descrip: "medflow".to_string(),
        }
    }

    pub fn for_dims_4d(dims: [u16; 4], voxel_mm: [f32; 3], datatype: Datatype) -> Self {
        let mut h = Self::for_dims([dims[0], dims[1], dims[2]], voxel_mm, datatype);
        h.dim[0] = 4;
        h.dim[4] = dims[3] as i16;
        h
    }

    pub fn dims(&self) -> [u16; 3] {
        [self.dim[1] as u16, self.dim[2] as u16, self.dim[3] as u16]
    }

    pub fn voxel_mm(&self) -> [f32; 3] {
        [self.pixdim[1], self.pixdim[2], self.pixdim[3]]
    }

    pub fn nvox(&self) -> usize {
        (1..=self.dim[0] as usize)
            .map(|i| self.dim[i].max(1) as usize)
            .product()
    }

    /// Serialize the canonical 348 bytes.
    pub fn to_bytes(&self) -> Result<[u8; 348]> {
        let mut b = [0u8; 348];
        put_i32(&mut b, 0, 348); // sizeof_hdr
        put_i16(&mut b, 40, self.dim[0]);
        for i in 1..8 {
            put_i16(&mut b, 40 + 2 * i, self.dim[i]);
        }
        put_i16(&mut b, 70, self.datatype.code());
        put_i16(&mut b, 72, self.datatype.bitpix());
        for i in 0..8 {
            put_f32(&mut b, 76 + 4 * i, self.pixdim[i]);
        }
        put_f32(&mut b, 108, self.vox_offset);
        put_f32(&mut b, 112, self.scl_slope);
        put_f32(&mut b, 116, self.scl_inter);
        let desc = self.descrip.as_bytes();
        let n = desc.len().min(79);
        b[148..148 + n].copy_from_slice(&desc[..n]);
        // sform/qform codes 0 (unoriented synthetic data)
        b[344..348].copy_from_slice(b"n+1\0"); // magic: single-file
        Ok(b)
    }

    /// Parse 348 header bytes (little-endian only — we never emit BE).
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        if b.len() < 348 {
            bail!("header too short");
        }
        if get_i32(b, 0) != 348 {
            bail!("bad sizeof_hdr (big-endian or not nifti-1?)");
        }
        if &b[344..347] != b"n+1" {
            bail!("bad magic: {:?}", &b[344..348]);
        }
        let mut dim = [0i16; 8];
        for i in 0..8 {
            dim[i] = get_i16(b, 40 + 2 * i);
        }
        if !(1..=7).contains(&dim[0]) {
            bail!("bad ndim {}", dim[0]);
        }
        let mut pixdim = [0f32; 8];
        for i in 0..8 {
            pixdim[i] = get_f32(b, 76 + 4 * i);
        }
        let descrip = String::from_utf8_lossy(&b[148..227])
            .trim_end_matches('\0')
            .to_string();
        Ok(Self {
            dim,
            pixdim,
            datatype: Datatype::from_code(get_i16(b, 70))?,
            vox_offset: get_f32(b, 108),
            scl_slope: get_f32(b, 112),
            scl_inter: get_f32(b, 116),
            descrip,
        })
    }
}

fn put_i32(b: &mut [u8], off: usize, v: i32) {
    b[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

fn put_i16(b: &mut [u8], off: usize, v: i16) {
    b[off..off + 2].copy_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut [u8], off: usize, v: f32) {
    b[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

fn get_i32(b: &[u8], off: usize) -> i32 {
    i32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn get_i16(b: &[u8], off: usize) -> i16 {
    i16::from_le_bytes([b[off], b[off + 1]])
}

fn get_f32(b: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = NiftiHeader::for_dims([64, 64, 48], [1.0, 1.0, 1.5], Datatype::Float32);
        let back = NiftiHeader::from_bytes(&h.to_bytes().unwrap()).unwrap();
        assert_eq!(back.dims(), [64, 64, 48]);
        assert_eq!(back.voxel_mm(), [1.0, 1.0, 1.5]);
        assert_eq!(back.datatype, Datatype::Float32);
        assert_eq!(back.nvox(), 64 * 64 * 48);
        assert_eq!(back.descrip, "medflow");
    }

    #[test]
    fn datatype_codes_match_standard() {
        assert_eq!(Datatype::Uint8.code(), 2);
        assert_eq!(Datatype::Int16.code(), 4);
        assert_eq!(Datatype::Float32.code(), 16);
        assert_eq!(Datatype::Float32.bitpix(), 32);
        assert!(Datatype::from_code(64).is_err()); // f64 unsupported
    }

    #[test]
    fn rejects_wrong_sizeof_hdr() {
        let h = NiftiHeader::for_dims([4, 4, 4], [1.0; 3], Datatype::Uint8);
        let mut b = h.to_bytes().unwrap();
        b[0] = 0;
        assert!(NiftiHeader::from_bytes(&b).is_err());
    }

    #[test]
    fn long_description_truncated_safely() {
        let mut h = NiftiHeader::for_dims([2, 2, 2], [1.0; 3], Datatype::Uint8);
        h.descrip = "x".repeat(200);
        let back = NiftiHeader::from_bytes(&h.to_bytes().unwrap()).unwrap();
        assert_eq!(back.descrip.len(), 79);
    }
}
