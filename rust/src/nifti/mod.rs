//! NIfTI-1 reader/writer built from scratch (paper §2.1: images are stored
//! as NIfTI after dcm2niix conversion).
//!
//! Implements the 348-byte NIfTI-1 header (single-file `.nii` layout, vox
//! offset 352), f32/i16/u8 data types, and transparent gzip (`.nii.gz`) via
//! flate2. That subset covers everything the pipelines produce or consume.

mod header;

pub use header::{Datatype, NiftiHeader};

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;

/// An in-memory NIfTI-1 image: header + f32 voxels (whatever the on-disk
/// datatype, voxels are widened to f32 on read; `scl_slope/inter` applied).
#[derive(Debug, Clone)]
pub struct NiftiImage {
    pub header: NiftiHeader,
    pub data: Vec<f32>,
}

impl NiftiImage {
    /// Build an image from dims + voxel data (row-major x-fastest, the
    /// NIfTI on-disk order).
    pub fn new(dims: [u16; 3], voxel_mm: [f32; 3], data: Vec<f32>) -> Result<Self> {
        let n = dims.iter().map(|&d| d as usize).product::<usize>();
        if data.len() != n {
            bail!("data length {} != dims product {}", data.len(), n);
        }
        Ok(Self {
            header: NiftiHeader::for_dims(dims, voxel_mm, Datatype::Float32),
            data,
        })
    }

    /// Build a 4-D image (e.g. a DWI series: x, y, z, volumes).
    pub fn new_4d(dims: [u16; 4], voxel_mm: [f32; 3], data: Vec<f32>) -> Result<Self> {
        let n = dims.iter().map(|&d| d as usize).product::<usize>();
        if data.len() != n {
            bail!("data length {} != dims product {}", data.len(), n);
        }
        Ok(Self {
            header: NiftiHeader::for_dims_4d(dims, voxel_mm, Datatype::Float32),
            data,
        })
    }

    /// Extract 3-D volume `t` from a 4-D image.
    pub fn volume(&self, t: usize) -> Result<Vec<f32>> {
        let dim = &self.header.dim;
        if dim[0] != 4 {
            bail!("volume() needs a 4-D image (ndim={})", dim[0]);
        }
        let vol_len = (dim[1] as usize) * (dim[2] as usize) * (dim[3] as usize);
        let nt = dim[4] as usize;
        if t >= nt {
            bail!("volume {t} out of range (nt={nt})");
        }
        Ok(self.data[t * vol_len..(t + 1) * vol_len].to_vec())
    }

    pub fn nvox(&self) -> usize {
        self.data.len()
    }

    /// Serialize as single-file `.nii` bytes (348-byte header + pad + data).
    pub fn to_nii_bytes(&self) -> Result<Vec<u8>> {
        let mut out = self.header.to_bytes()?.to_vec();
        out.extend_from_slice(&[0u8; 4]); // extension flag: none
        debug_assert_eq!(out.len(), 352);
        match self.header.datatype {
            Datatype::Float32 => {
                for v in &self.data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Datatype::Int16 => {
                for v in &self.data {
                    let q = v.round().clamp(-32768.0, 32767.0) as i16;
                    out.extend_from_slice(&q.to_le_bytes());
                }
            }
            Datatype::Uint8 => {
                for v in &self.data {
                    out.push(v.round().clamp(0.0, 255.0) as u8);
                }
            }
        }
        Ok(out)
    }

    /// Parse single-file `.nii` bytes.
    pub fn from_nii_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 352 {
            bail!("nii too short: {} bytes", bytes.len());
        }
        let header = NiftiHeader::from_bytes(&bytes[..348])?;
        let off = header.vox_offset.max(352.0) as usize;
        let n = header.nvox();
        let dt = header.datatype;
        let need = off + n * dt.size();
        if bytes.len() < need {
            bail!("nii truncated: have {}, need {}", bytes.len(), need);
        }
        let raw = &bytes[off..need];
        let mut data = Vec::with_capacity(n);
        match dt {
            Datatype::Float32 => {
                for c in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            Datatype::Int16 => {
                for c in raw.chunks_exact(2) {
                    data.push(i16::from_le_bytes([c[0], c[1]]) as f32);
                }
            }
            Datatype::Uint8 => data.extend(raw.iter().map(|&b| b as f32)),
        }
        // apply scaling if set
        if header.scl_slope != 0.0 && (header.scl_slope != 1.0 || header.scl_inter != 0.0) {
            for v in &mut data {
                *v = *v * header.scl_slope + header.scl_inter;
            }
        }
        Ok(Self { header, data })
    }

    /// Write to `.nii` or `.nii.gz` (gzip decided by extension).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_nii_bytes()?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        if path.extension().map(|e| e == "gz").unwrap_or(false) {
            let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
            let mut enc = GzEncoder::new(f, Compression::fast());
            enc.write_all(&bytes)?;
            enc.finish()?;
        } else {
            std::fs::write(path, &bytes)?;
        }
        Ok(())
    }

    /// Read from `.nii` or `.nii.gz`.
    pub fn load(path: &Path) -> Result<Self> {
        let raw = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        let bytes = if path.extension().map(|e| e == "gz").unwrap_or(false) {
            let mut dec = GzDecoder::new(&raw[..]);
            let mut out = Vec::new();
            dec.read_to_end(&mut out).context("gunzip")?;
            out
        } else {
            raw
        };
        Self::from_nii_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(dims: [u16; 3]) -> NiftiImage {
        let n: usize = dims.iter().map(|&d| d as usize).product();
        let data: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.5).collect();
        NiftiImage::new(dims, [1.0, 1.0, 1.2], data).unwrap()
    }

    #[test]
    fn roundtrip_bytes() {
        let img = sample([8, 7, 6]);
        let back = NiftiImage::from_nii_bytes(&img.to_nii_bytes().unwrap()).unwrap();
        assert_eq!(back.header.dims(), [8, 7, 6]);
        assert_eq!(back.data, img.data);
        assert_eq!(back.header.voxel_mm(), [1.0, 1.0, 1.2]);
    }

    #[test]
    fn roundtrip_file_nii_and_gz(){
        let dir = std::env::temp_dir().join(format!("medflow_nifti_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let img = sample([16, 16, 16]);
        for name in ["a.nii", "b.nii.gz"] {
            let p = dir.join(name);
            img.save(&p).unwrap();
            let back = NiftiImage::load(&p).unwrap();
            assert_eq!(back.data, img.data, "{name}");
        }
        // gz must actually be smaller than raw for this compressible data
        let raw = std::fs::metadata(dir.join("a.nii")).unwrap().len();
        let gz = std::fs::metadata(dir.join("b.nii.gz")).unwrap().len();
        assert!(gz < raw, "gz {gz} raw {raw}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn four_d_roundtrip_and_volume_extraction() {
        let nt = 3;
        let vol_len = 4 * 4 * 4;
        let data: Vec<f32> = (0..vol_len * nt).map(|i| i as f32).collect();
        let img = NiftiImage::new_4d([4, 4, 4, nt as u16], [1.0; 3], data.clone()).unwrap();
        let back = NiftiImage::from_nii_bytes(&img.to_nii_bytes().unwrap()).unwrap();
        assert_eq!(back.header.dim[0], 4);
        assert_eq!(back.header.dim[4], nt as i16);
        assert_eq!(back.data, data);
        let v1 = back.volume(1).unwrap();
        assert_eq!(v1, data[vol_len..2 * vol_len]);
        assert!(back.volume(3).is_err());
        // 3-D images refuse volume()
        assert!(sample([4, 4, 4]).volume(0).is_err());
    }

    #[test]
    fn int16_roundtrip_with_scaling() {
        let mut img = sample([4, 4, 4]);
        img.header.datatype = Datatype::Int16;
        img.header.scl_slope = 2.0;
        img.header.scl_inter = 1.0;
        let back = NiftiImage::from_nii_bytes(&img.to_nii_bytes().unwrap()).unwrap();
        // stored value round(v) then scaled by slope/inter on read
        assert_eq!(back.data[3], (img.data[3].round()) * 2.0 + 1.0);
    }

    #[test]
    fn dim_mismatch_rejected() {
        assert!(NiftiImage::new([2, 2, 2], [1.0; 3], vec![0.0; 7]).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let img = sample([4, 4, 4]);
        let bytes = img.to_nii_bytes().unwrap();
        assert!(NiftiImage::from_nii_bytes(&bytes[..400]).is_err());
        assert!(NiftiImage::from_nii_bytes(&bytes[..100]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let img = sample([4, 4, 4]);
        let mut bytes = img.to_nii_bytes().unwrap();
        bytes[344] = b'X';
        assert!(NiftiImage::from_nii_bytes(&bytes).is_err());
    }
}
