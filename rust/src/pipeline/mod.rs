//! The processing-pipeline registry: the paper's 16 computationally
//! intensive pipelines (§1, §2), each containerized, with input criteria,
//! resource requirements, and a calibrated duration model.
//!
//! Two pipelines (`freesurfer`-like structural seg and `prequal`-like DWI
//! preprocessing) execute *real* compute through the PJRT runtime
//! artifacts; the rest share the same job lifecycle with duration/resource
//! models only (their numeric cores are out of the paper's evaluation
//! scope, but the coordinator must schedule them — the paper's experiments
//! are about coordination, not segmentation quality).

use crate::util::rng::Rng;

/// What a pipeline needs from a scanning session to be runnable (§2.3's
/// query criteria; sessions failing these land in the skip CSV).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InputReq {
    /// At least one T1w image.
    T1w,
    /// At least one DWI image.
    Dwi,
    /// Both a T1w and a DWI image in the same session.
    T1wAndDwi,
    /// A T1w plus the outputs of a prior pipeline.
    T1wAndPrior(&'static str),
    /// A DWI plus the outputs of a prior pipeline.
    DwiAndPrior(&'static str),
}

/// Resource request for one job instance (feeds the SLURM sim).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceSpec {
    pub cores: u32,
    pub ram_gb: u32,
    /// Expected wall-clock minutes at paper scale (mean, std) — calibrated
    /// to the paper where reported (Freesurfer: 375.5 ± 15.5 on HPC).
    pub minutes_mean: f64,
    pub minutes_std: f64,
}

/// One registered pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    pub name: &'static str,
    pub version: &'static str,
    pub input: InputReq,
    pub resources: ResourceSpec,
    /// HLO artifact executed by the runtime (None = duration model only).
    pub artifact: Option<&'static str>,
    /// Approximate output size per session (bytes) — drives storage and
    /// copy-back transfer modeling.
    pub output_bytes: u64,
}

impl PipelineSpec {
    /// Sample a wall-clock duration (minutes) for one instance.
    pub fn sample_minutes(&self, rng: &mut Rng) -> f64 {
        rng.normal_ms(self.resources.minutes_mean, self.resources.minutes_std)
            .max(1.0)
    }
}

/// The 16-pipeline registry (paper §1: "16 separate pipelines").
/// Names follow the paper's cited tools where given (Freesurfer, SLANT,
/// UNesT, PreQual) and the Vanderbilt lab's published pipeline suite for
/// the remainder.
pub fn registry() -> Vec<PipelineSpec> {
    use InputReq::*;
    let mb = |n: u64| n * 1_000_000;
    vec![
        PipelineSpec {
            name: "freesurfer",
            version: "7.2.0",
            input: T1w,
            resources: ResourceSpec { cores: 1, ram_gb: 8, minutes_mean: 375.5, minutes_std: 15.5 },
            artifact: Some("seg_pipeline"),
            output_bytes: mb(300),
        },
        PipelineSpec {
            name: "prequal",
            version: "1.0.0",
            input: Dwi,
            resources: ResourceSpec {
                cores: 4,
                ram_gb: 16,
                minutes_mean: 180.0,
                minutes_std: 30.0,
            },
            artifact: Some("dwi_preproc"),
            output_bytes: mb(800),
        },
        PipelineSpec {
            name: "slant",
            version: "1.1.0",
            input: T1w,
            resources: ResourceSpec { cores: 2, ram_gb: 12, minutes_mean: 90.0, minutes_std: 10.0 },
            artifact: Some("seg_pipeline"),
            output_bytes: mb(150),
        },
        PipelineSpec {
            name: "unest",
            version: "0.9.0",
            input: T1w,
            resources: ResourceSpec { cores: 2, ram_gb: 16, minutes_mean: 45.0, minutes_std: 8.0 },
            artifact: Some("seg_pipeline"),
            output_bytes: mb(120),
        },
        PipelineSpec {
            name: "tractseg",
            version: "2.9",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec {
                cores: 4,
                ram_gb: 24,
                minutes_mean: 120.0,
                minutes_std: 20.0,
            },
            artifact: None,
            output_bytes: mb(500),
        },
        PipelineSpec {
            name: "macruise",
            version: "3.2.0",
            input: T1wAndPrior("slant"),
            resources: ResourceSpec { cores: 2, ram_gb: 8, minutes_mean: 150.0, minutes_std: 25.0 },
            artifact: None,
            output_bytes: mb(200),
        },
        PipelineSpec {
            name: "biscuit",
            version: "1.3.0",
            input: T1w,
            resources: ResourceSpec { cores: 1, ram_gb: 8, minutes_mean: 60.0, minutes_std: 10.0 },
            artifact: None,
            output_bytes: mb(80),
        },
        PipelineSpec {
            name: "eve_registration",
            version: "2.0",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec { cores: 2, ram_gb: 12, minutes_mean: 75.0, minutes_std: 12.0 },
            artifact: Some("atlas_register"),
            output_bytes: mb(250),
        },
        PipelineSpec {
            name: "wm_atlas",
            version: "1.5",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec {
                cores: 2,
                ram_gb: 16,
                minutes_mean: 200.0,
                minutes_std: 40.0,
            },
            artifact: None,
            output_bytes: mb(600),
        },
        PipelineSpec {
            name: "connectome_special",
            version: "1.0",
            input: T1wAndDwi,
            resources: ResourceSpec {
                cores: 8,
                ram_gb: 32,
                minutes_mean: 300.0,
                minutes_std: 50.0,
            },
            artifact: None,
            output_bytes: mb(1_200),
        },
        PipelineSpec {
            name: "francois_special",
            version: "1.2",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec {
                cores: 8,
                ram_gb: 48,
                minutes_mean: 480.0,
                minutes_std: 80.0,
            },
            artifact: None,
            output_bytes: mb(2_500),
        },
        PipelineSpec {
            name: "noddi",
            version: "1.1",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec {
                cores: 4,
                ram_gb: 24,
                minutes_mean: 240.0,
                minutes_std: 35.0,
            },
            artifact: None,
            output_bytes: mb(400),
        },
        PipelineSpec {
            name: "bedpostx",
            version: "6.0",
            input: DwiAndPrior("prequal"),
            resources: ResourceSpec {
                cores: 8,
                ram_gb: 32,
                minutes_mean: 600.0,
                minutes_std: 90.0,
            },
            artifact: None,
            output_bytes: mb(1_500),
        },
        PipelineSpec {
            name: "lesion_seg",
            version: "0.8",
            input: T1w,
            resources: ResourceSpec { cores: 2, ram_gb: 16, minutes_mean: 30.0, minutes_std: 5.0 },
            artifact: None,
            output_bytes: mb(60),
        },
        PipelineSpec {
            name: "brain_age",
            version: "1.0",
            input: T1wAndPrior("freesurfer"),
            resources: ResourceSpec { cores: 1, ram_gb: 4, minutes_mean: 10.0, minutes_std: 2.0 },
            artifact: None,
            output_bytes: mb(1),
        },
        PipelineSpec {
            name: "qa_report",
            version: "1.0",
            input: T1w,
            resources: ResourceSpec { cores: 1, ram_gb: 4, minutes_mean: 5.0, minutes_std: 1.0 },
            artifact: Some("seg_pipeline"),
            output_bytes: mb(5),
        },
    ]
}

/// Find a pipeline by name.
pub fn by_name(name: &str) -> Option<PipelineSpec> {
    registry().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_pipelines() {
        assert_eq!(registry().len(), 16);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = registry().iter().map(|p| p.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn freesurfer_matches_paper_calibration() {
        let fs = by_name("freesurfer").unwrap();
        assert_eq!(fs.resources.minutes_mean, 375.5);
        assert_eq!(fs.resources.minutes_std, 15.5);
        assert_eq!(fs.artifact, Some("seg_pipeline"));
    }

    #[test]
    fn priors_reference_registered_pipelines() {
        let names: Vec<&str> = registry().iter().map(|p| p.name).collect();
        for p in registry() {
            match p.input {
                InputReq::T1wAndPrior(d) | InputReq::DwiAndPrior(d) => {
                    assert!(names.contains(&d), "{} depends on unknown '{d}'", p.name);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn no_dependency_cycles() {
        // With priors one level deep and every prior itself prior-free,
        // acyclicity reduces to: a dependency target has no dependency.
        for p in registry() {
            if let InputReq::T1wAndPrior(d) | InputReq::DwiAndPrior(d) = p.input {
                let dep = by_name(d).unwrap();
                assert!(
                    matches!(dep.input, InputReq::T1w | InputReq::Dwi | InputReq::T1wAndDwi),
                    "{} -> {} forms a chain",
                    p.name,
                    d
                );
            }
        }
    }

    #[test]
    fn sampled_durations_positive_and_near_mean() {
        let mut rng = Rng::new(1);
        let fs = by_name("freesurfer").unwrap();
        let n = 1000;
        let mean = (0..n).map(|_| fs.sample_minutes(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 375.5).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn artifact_pipelines_reference_known_artifacts() {
        for p in registry() {
            if let Some(a) = p.artifact {
                assert!(
                    matches!(a, "seg_pipeline" | "dwi_preproc" | "atlas_register"),
                    "{}",
                    p.name
                );
            }
        }
    }
}
