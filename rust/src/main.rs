//! medflow CLI — the leader entrypoint (paper Fig. 3's "local control
//! node"). Hand-rolled arg parsing (no clap in the offline cache).
//!
//! ```text
//! medflow ingest    --root DIR --dataset NAME --participants N --sessions M [--gdpr]
//! medflow validate  --root DIR --dataset NAME
//! medflow query     --root DIR --dataset NAME --pipeline P
//! medflow campaign  --root DIR --dataset NAME --pipeline P [--local N]
//! medflow status    --root DIR
//! medflow transfer-sim [--env E] [--streams N] [--gb X] [--cap N]
//! medflow pipelines
//! medflow table1 | table2 | table3 | fig1
//! ```

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use medflow::analysis;
use medflow::archive::{Archive, SecurityTier};
use medflow::bids::{validate_dataset, BidsDataset, Severity};
use medflow::compute::load_runtime;
use medflow::container::ContainerArchive;
use medflow::coordinator::placement::{self, PlacementConfig, PlacementPolicy};
use medflow::coordinator::staged::{run_staged, synthetic_fault_campaign, SlurmSim};
use medflow::coordinator::stream::{self, ArrivalPattern, StreamConfig};
use medflow::coordinator::tenancy;
use medflow::coordinator::{CampaignConfig, Coordinator, RunSpec, SubmitTarget};
use medflow::faults::outage::{Brownout, ComputeOutage, OutageMode, OutageSchedule, OutageSeverity};
use medflow::faults::{FaultModel, FaultTelemetry, Injection};
use medflow::netsim::scheduler::{Topology, TransferScheduler};
use medflow::netsim::Env;
use medflow::pipeline::{by_name, registry};
use medflow::query::{find_runnable, IncrementalEngine};
use medflow::report;
use medflow::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use medflow::util::units::{fmt_duration, percentiles};
use medflow::workload::{ingest_cohort, SynthCohort};

fn main() {
    if let Err(e) = run() {
        eprintln!("medflow error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs + `--flag` booleans.
struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    values.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { values, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing --{key}"))
    }

    fn num(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);

    match cmd.as_str() {
        "ingest" => cmd_ingest(&args),
        "validate" => cmd_validate(&args),
        "query" => cmd_query(&args),
        "index" => cmd_index(&args),
        "campaign" => cmd_campaign(&args),
        "status" => cmd_status(&args),
        "pipelines" => {
            println!(
                "{:<22}{:<10}{:>8}{:>8}{:>12}",
                "pipeline", "version", "cores", "ram", "minutes"
            );
            for p in registry() {
                println!(
                    "{:<22}{:<10}{:>8}{:>8}{:>12.1}",
                    p.name,
                    p.version,
                    p.resources.cores,
                    p.resources.ram_gb,
                    p.resources.minutes_mean
                );
            }
            Ok(())
        }
        "table1" => {
            let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
            let cols = report::table1(runtime.as_ref(), 42, 100, 100)?;
            println!("{}", report::format_table1(&cols));
            Ok(())
        }
        "sweep" => cmd_sweep(&args),
        "transfer-sim" => cmd_transfer_sim(&args),
        "faults" => cmd_faults(&args),
        "place" => cmd_place(&args),
        "tenants" => cmd_tenants(&args),
        "chaos" => cmd_chaos(&args),
        "stream" => cmd_stream(&args),
        "lint" => cmd_lint(&args),
        "growth" => {
            let models = medflow::archive::growth::default_models();
            for years in [0.0, 1.0, 3.0, 5.0] {
                let f = medflow::archive::growth::forecast(&models, years);
                println!(
                    "t+{years:>3.0}y  general {:>6.1} TB ({:>4.0}% free)  gdpr {:>6.1} TB ({:>4.0}% free)  glacier ${:>7.0}/mo",
                    f.general_bytes as f64 / 1e12,
                    f.general_headroom() * 100.0,
                    f.gdpr_bytes as f64 / 1e12,
                    f.gdpr_headroom() * 100.0,
                    f.glacier_dollars_per_month
                );
            }
            match medflow::archive::growth::years_until_exhaustion(&models) {
                Some(y) => println!("capacity exhausted in ~{y:.1} years — plan expansion"),
                None => println!("no exhaustion within 100 years"),
            }
            Ok(())
        }
        "project" => {
            let faults = if args.has("faults") {
                Some(medflow::faults::FaultModel::typical())
            } else {
                None
            };
            println!("{}", medflow::cost::planner::project_campaign(faults, 3).format());
            Ok(())
        }
        "table2" => {
            println!("{}", report::format_table2());
            Ok(())
        }
        "table3" => {
            println!("{}", report::format_table3());
            Ok(())
        }
        "fig1" => {
            let pts = report::fig1(42);
            println!("{}", report::format_fig1(&pts));
            print!("{}", report::fig1_csv(&pts));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: medflow help)"),
    }
}

fn root_of(args: &Args) -> Result<PathBuf> {
    Ok(PathBuf::from(args.require("root")?))
}

fn cmd_ingest(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let name = args.require("dataset")?;
    let cohort = SynthCohort {
        name: name.to_string(),
        participants: args.num("participants", 4),
        sessions: args.num("sessions", 6),
        tier: if args.has("gdpr") {
            SecurityTier::Gdpr
        } else {
            SecurityTier::General
        },
    };
    let mut archive = Archive::at(&root.join("store"))?;
    let ds = ingest_cohort(
        &mut archive,
        &root.join("bids"),
        &cohort,
        args.num("dim", 16) as u16,
        args.num("seed", 42),
    )?;
    let usage = archive.usage(name)?;
    println!(
        "ingested '{}': {} subjects, {} files, {} bytes (tier {:?})",
        ds.name,
        ds.subjects()?.len(),
        usage.file_count,
        usage.bytes,
        cohort.tier
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let ds_root = root.join("bids").join(args.require("dataset")?);
    let issues = validate_dataset(&ds_root);
    for issue in &issues {
        println!(
            "{}: {} ({})",
            if issue.severity == Severity::Error { "ERROR" } else { "warn" },
            issue.message,
            issue.path.display()
        );
    }
    let errors = issues.iter().filter(|i| i.severity == Severity::Error).count();
    println!("{} issues, {} errors", issues.len(), errors);
    if errors > 0 {
        bail!("validation failed");
    }
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let ds = BidsDataset::open(&root.join("bids").join(args.require("dataset")?))?;
    let pipeline = by_name(args.require("pipeline")?)
        .with_context(|| "unknown pipeline (see `medflow pipelines`)")?;
    // incremental indexed query by default; --full forces the baseline
    // scan, and a dataset we cannot write .medflow/ state into (e.g. a
    // read-only mount) degrades to the full scan instead of erroring
    let q = if args.has("full") {
        find_runnable(&ds, &pipeline)?
    } else {
        match IncrementalEngine::open(&ds) {
            Ok(mut engine) => {
                let (q, stats) = engine.query(&ds, &pipeline, args.num("workers", 4) as usize)?;
                if let Err(e) = engine.save(&ds) {
                    eprintln!("note: query state not persisted ({e:#}); next query re-evaluates");
                }
                println!(
                    "query: {} shards, {} evaluated, {} replayed, {} new",
                    stats.shards_scanned,
                    stats.sessions_examined,
                    stats.sessions_replayed,
                    stats.new_sessions
                );
                q
            }
            Err(e) => {
                eprintln!("note: index unavailable ({e:#}); falling back to full scan");
                find_runnable(&ds, &pipeline)?
            }
        }
    };
    println!("runnable: {}", q.runnable.len());
    for j in &q.runnable {
        println!("  {}", j.instance_id());
    }
    println!("skipped: {}", q.skipped.len());
    print!("{}", q.skip_csv());
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let ds = BidsDataset::open(&root.join("bids").join(args.require("dataset")?))?;
    if args.has("rebuild") {
        // full re-walk; also clears every cached skip verdict (stale
        // generations from before the rebuild must not survive it). The
        // rebuild must work even when the existing state is corrupt —
        // that is exactly what it recovers from — so a failed open falls
        // back to a fresh engine instead of erroring out.
        let mut engine = match IncrementalEngine::open(&ds) {
            Ok(engine) => engine,
            Err(e) => {
                eprintln!("note: existing query state unreadable ({e:#}); rebuilding from scratch");
                IncrementalEngine::fresh()
            }
        };
        engine.rebuild(&ds)?;
        println!(
            "rebuilt index: {} sessions in {} shards (skip caches cleared)",
            engine.index.len(),
            engine.index.n_shards()
        );
        return Ok(());
    }
    let mut engine = IncrementalEngine::open(&ds)?;
    if let Some(pipeline) = args.get("invalidate") {
        // recovery hook after out-of-band derivative writes/deletions:
        // forgets the pipeline's processed set + cached verdicts; the next
        // query re-probes derivatives/ and re-absorbs what exists
        engine.invalidate_pipeline(pipeline);
        engine.save(&ds)?;
        println!("invalidated '{pipeline}': processed set and cached verdicts dropped");
        return Ok(());
    }
    let added = engine.index.refresh(&ds)?;
    engine.save(&ds)?;
    println!(
        "index: {} sessions in {} shards ({} newly discovered)",
        engine.index.len(),
        engine.index.n_shards(),
        added.len()
    );
    for p in registry() {
        let n = engine.processed.count(p.name);
        if n > 0 {
            println!(
                "  processed {:<20} {:>6} sessions (v{})",
                p.name,
                n,
                engine.processed.version(p.name)
            );
        }
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let ds = BidsDataset::open(&root.join("bids").join(args.require("dataset")?))?;
    let pipeline = args.require("pipeline")?;
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let archive = Archive::at(&root.join("store"))?;
    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, runtime.as_ref());
    // --placement [cheapest|deadline|budget] splits the campaign across
    // the heterogeneous fleet (DESIGN.md §12) instead of one target
    let placement = match args.get("placement") {
        Some(name) => Some(parse_placement_policy(name, args)?),
        None if args.has("placement") => Some(PlacementPolicy::CheapestFirst),
        None => None,
    };
    let target = if placement.is_some() {
        SubmitTarget::Placement
    } else {
        match args.get("local") {
            Some(w) => SubmitTarget::LocalBurst {
                workers: w.parse().unwrap_or(4),
            },
            None => SubmitTarget::Hpc,
        }
    };
    // --faults [none|typical|harsh] switches on in-engine injection
    // (bare flag = typical); --retries bounds resubmissions per job
    let faults = match args.get("faults") {
        Some(name) => Some(parse_fault_model(name)?),
        None if args.has("faults") => Some(FaultModel::typical()),
        None => None,
    };
    let cfg = CampaignConfig {
        user: args.get("user").unwrap_or("medflow").to_string(),
        seed: args.num("seed", 42),
        faults,
        max_retries: args.num("retries", 3) as u32,
        placement,
        threads: threads_arg(args)?,
        ..Default::default()
    };
    let r = coord.run_campaign(&ds, pipeline, target, &cfg)?;
    println!(
        "campaign {}/{}: queried {} completed {} skipped {} failed {}",
        r.dataset, r.pipeline, r.queried, r.completed, r.skipped, r.failed
    );
    println!(
        "makespan {:.2} h, compute {:.1} ± {:.1} min/job, cost ${:.2}",
        r.makespan_s / 3600.0,
        r.compute_minutes.0,
        r.compute_minutes.1,
        r.total_cost_dollars
    );
    if r.transfer.transfers > 0 {
        print!("{}", report::format_transfer_stats(&r.transfer));
    }
    if cfg.faults.is_some() {
        print!("{}", report::format_fault_stats(&r.faults));
    }
    if let Some(usage) = &r.placement {
        let label = cfg.placement.unwrap_or(PlacementPolicy::CheapestFirst).label();
        print!("{}", report::format_placement(&label, usage));
    }
    Ok(())
}

/// `--threads N` for the parallel co-sim engines (`coordinator::sync`).
/// Defaults to the machine's available parallelism; explicit values
/// must be ≥ 1. `--threads 1` is byte-identical to the sequential
/// engine (the replay contract's parity gate).
fn threads_arg(args: &Args) -> Result<usize> {
    match args.get("threads") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => bail!("invalid --threads '{v}' (must be an integer ≥ 1)"),
        },
        None => Ok(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
    }
}

fn parse_fault_model(name: &str) -> Result<FaultModel> {
    match name {
        "none" => Ok(FaultModel::none()),
        "typical" => Ok(FaultModel::typical()),
        "harsh" => Ok(FaultModel::harsh()),
        other => bail!("unknown fault model '{other}' (none | typical | harsh)"),
    }
}

fn parse_placement_policy(name: &str, args: &Args) -> Result<PlacementPolicy> {
    Ok(match name {
        "cheapest" => PlacementPolicy::CheapestFirst,
        // --deadline SECS (default: one simulated day)
        "deadline" => PlacementPolicy::DeadlineAware {
            deadline_s: args.num("deadline", 86_400) as f64,
        },
        // --budget DOLLARS (default $100)
        "budget" => PlacementPolicy::BudgetCapped {
            budget_dollars: args
                .get("budget")
                .and_then(|v| v.parse().ok())
                .unwrap_or(100.0),
        },
        other => bail!("unknown placement policy '{other}' (cheapest | deadline | budget)"),
    })
}

/// `medflow place`: run the shared synthetic campaign
/// ([`synthetic_fault_campaign`]) through the heterogeneous placement
/// optimizer (DESIGN.md §12) — ACCRE slots + a cloud lane pool + local
/// workstations co-simulated against one shared staging path — and
/// print the per-backend usage; `--frontier [STEPS]` sweeps and prints
/// the cost-vs-makespan Pareto set.
fn cmd_place(args: &Args) -> Result<()> {
    let n = args.num("jobs", 2_000) as usize;
    let seed = args.num("seed", 42);
    let retries = args.num("retries", 3) as u32;
    let policy = parse_placement_policy(args.get("policy").unwrap_or("cheapest"), args)?;
    let model = match args.get("faults") {
        Some(name) => Some(parse_fault_model(name)?),
        None if args.has("faults") => Some(FaultModel::typical()),
        None => None,
    };
    if let Some(m) = &model {
        m.validate().map_err(anyhow::Error::msg)?;
    }
    let jobs = synthetic_fault_campaign(n, seed);
    let mut fleet = placement::default_fleet(
        ClusterSpec::accre(),
        args.num("concurrent", 2_000) as u32,
        args.num("cloud-lanes", 64).max(1) as usize,
        args.num("local-lanes", 8).max(1) as usize,
    );
    if let Some(m) = model {
        for backend in &mut fleet {
            backend.faults = Some(m);
        }
    }
    let cfg = PlacementConfig {
        seed,
        transfer_faults: model,
        max_retries: retries,
        retry_backoff_s: args.num("backoff", 60) as f64,
    };
    println!(
        "placement co-simulation: {n} jobs across {} backends (retries {retries}, seed {seed})",
        fleet.len()
    );
    let out = RunSpec::new()
        .policy(policy)
        .threads(threads_arg(args)?)
        .execute(&jobs, &fleet, &cfg);
    let completed = out.staged.timings.iter().filter(|t| t.completed).count();
    println!(
        "completed {completed}/{n}   cost ${:.2}   makespan {}\n",
        out.total_cost_dollars,
        fmt_duration(out.makespan_s)
    );
    print!("{}", report::format_placement(&policy.label(), &out.per_backend));
    print!("{}", report::format_transfer_stats(&out.transfer));
    if model.is_some() {
        println!(
            "faults: {} failed compute attempts, {} checksum retries, {} aborted",
            out.compute_events.len(),
            out.transfer_events.len(),
            out.aborted
        );
    }
    if args.has("frontier") || args.get("frontier").is_some() {
        let steps = args.num("frontier", 5) as usize;
        let frontier = placement::frontier_sweep(&jobs, &fleet, &cfg, steps);
        print!("\n{}", report::format_frontier(&frontier));
    }
    Ok(())
}

/// `medflow tenants`: co-simulate N independent tenant campaigns
/// against ONE shared heterogeneous fleet and staging path
/// (DESIGN.md §13) — weighted fair-share + priority arbitration at
/// admission time, optional queue-depth backpressure — and print the
/// per-tenant telemetry table plus shared-fleet usage.
fn cmd_tenants(args: &Args) -> Result<()> {
    if args.has("help") {
        print_usage();
        return Ok(());
    }
    let n_tenants = args.num("tenants", 8).max(1) as usize;
    let jobs_per = args.num("jobs-per", 50).max(1) as usize;
    let seed = args.num("seed", 42);
    let retries = args.num("retries", 3) as u32;
    let policy = parse_placement_policy(args.get("policy").unwrap_or("cheapest"), args)?;
    let weights: Vec<f64> = args
        .get("weights")
        .unwrap_or("1")
        .split(',')
        .map(|w| {
            let w = w.trim();
            match w.parse::<f64>() {
                Ok(v) if v.is_finite() && v > 0.0 => Ok(v),
                _ => bail!("invalid tenant weight '{w}' (must be a finite number > 0)"),
            }
        })
        .collect::<Result<_>>()?;
    let priorities: Vec<u32> = args
        .get("priorities")
        .unwrap_or("0")
        .split(',')
        .map(|p| {
            let p = p.trim();
            match p.parse::<u32>() {
                Ok(v) => Ok(v),
                _ => bail!("invalid tenant priority '{p}' (must be a non-negative integer)"),
            }
        })
        .collect::<Result<_>>()?;
    let queue_depth = match args.get("depth") {
        Some(d) => match d.parse::<usize>() {
            Ok(v) if v >= 1 => Some(v),
            _ => bail!("invalid queue depth '{d}' (must be an integer ≥ 1)"),
        },
        None => None,
    };
    let model = match args.get("faults") {
        Some(name) => Some(parse_fault_model(name)?),
        None if args.has("faults") => Some(FaultModel::typical()),
        None => None,
    };
    if let Some(m) = &model {
        m.validate().map_err(anyhow::Error::msg)?;
    }
    let mut fleet = placement::default_fleet(
        ClusterSpec::accre(),
        args.num("concurrent", 2_000) as u32,
        args.num("cloud-lanes", 64).max(1) as usize,
        args.num("local-lanes", 8).max(1) as usize,
    );
    if let Some(m) = model {
        for backend in &mut fleet {
            backend.faults = Some(m);
        }
    }
    let mut tenants = tenancy::synthetic_tenants(n_tenants, jobs_per, seed);
    for (k, t) in tenants.iter_mut().enumerate() {
        t.weight = weights[k % weights.len()];
        t.priority = priorities[k % priorities.len()];
        t.policy = policy;
    }
    let cfg = tenancy::TenancyConfig {
        seed,
        transfer_faults: model,
        max_retries: retries,
        retry_backoff_s: args.num("backoff", 60) as f64,
        queue_depth,
    };
    println!(
        "tenancy co-simulation: {n_tenants} tenants × {jobs_per} jobs across {} backends (retries {retries}, seed {seed})",
        fleet.len()
    );
    let out = RunSpec::new()
        .threads(threads_arg(args)?)
        .run_tenants(&tenants, &fleet, &cfg);
    print!("{}", report::format_tenancy(&out.report));
    println!();
    print!("{}", report::format_placement(&policy.label(), &out.report.per_backend));
    print!("{}", report::format_transfer_stats(&out.report.transfer));
    if model.is_some() {
        println!(
            "faults: {} failed compute attempts, {} checksum retries, {} aborted",
            out.compute_events.len(),
            out.transfer_events.len(),
            out.report.aborted
        );
    }
    Ok(())
}

/// `medflow chaos`: run the shared synthetic campaign through the
/// heterogeneous fleet under a seeded infrastructure-fault schedule
/// (DESIGN.md §15) — per-backend Down/Drain windows plus link
/// brownouts — and print the outage damage report next to the usual
/// placement telemetry. `--severity` picks the synthetic preset;
/// explicit `--window`/`--brownout` events stack on top of it.
fn cmd_chaos(args: &Args) -> Result<()> {
    if args.has("help") {
        print_usage();
        return Ok(());
    }
    let n = args.num("jobs", 500) as usize;
    let seed = args.num("seed", 42);
    let retries = args.num("retries", 3) as u32;
    let policy = parse_placement_policy(args.get("policy").unwrap_or("cheapest"), args)?;
    let horizon_s = args.num("horizon", 14_400).max(1) as f64;
    let severity = match args.get("severity").unwrap_or("harsh") {
        "none" => OutageSeverity::None,
        "mild" => OutageSeverity::Mild,
        "harsh" => OutageSeverity::Harsh,
        other => bail!("unknown outage severity '{other}' (none | mild | harsh)"),
    };
    let fleet = placement::default_fleet(
        ClusterSpec::accre(),
        args.num("concurrent", 2_000) as u32,
        args.num("cloud-lanes", 64).max(1) as usize,
        args.num("local-lanes", 8).max(1) as usize,
    );
    let mut schedule = OutageSchedule::synthetic(severity, fleet.len(), horizon_s, seed);
    if let Some(w) = args.get("window") {
        schedule.compute.push(parse_outage_window(w, fleet.len())?);
    }
    if let Some(b) = args.get("brownout") {
        schedule.brownouts.push(parse_brownout(b)?);
    }
    schedule.validate().map_err(anyhow::Error::msg)?;
    let cfg = PlacementConfig {
        seed,
        transfer_faults: None,
        max_retries: retries,
        retry_backoff_s: args.num("backoff", 60) as f64,
    };
    let jobs = synthetic_fault_campaign(n, seed);
    println!(
        "chaos co-simulation: {n} jobs across {} backends under '{}' outages \
         ({} windows, {} brownouts, seed {seed})",
        fleet.len(),
        severity.label(),
        schedule.compute.len(),
        schedule.brownouts.len()
    );
    let threads = threads_arg(args)?;
    let out = RunSpec::new()
        .policy(policy)
        .outages(schedule)
        .threads(threads)
        .execute(&jobs, &fleet, &cfg);
    let completed = out.staged.timings.iter().filter(|t| t.completed).count();
    println!(
        "completed {completed}/{n}   cost ${:.2}   makespan {}\n",
        out.total_cost_dollars,
        fmt_duration(out.makespan_s)
    );
    if let Some(o) = &out.outage {
        print!("{}", report::format_outage(o));
    }
    print!("{}", report::format_placement(&policy.label(), &out.per_backend));
    print!("{}", report::format_transfer_stats(&out.transfer));
    Ok(())
}

/// Parse `--window BACKEND:down|drain:START:END`.
fn parse_outage_window(spec: &str, n_backends: usize) -> Result<ComputeOutage> {
    let parts: Vec<&str> = spec.split(':').collect();
    let fail = || format!("invalid outage window '{spec}' (expect BACKEND:down|drain:START:END)");
    if parts.len() != 4 {
        bail!(fail());
    }
    let backend: usize = parts[0].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    if backend >= n_backends {
        bail!("invalid outage window '{spec}': backend {backend} outside the {n_backends}-backend fleet");
    }
    let mode = match parts[1] {
        "down" => OutageMode::Down,
        "drain" => OutageMode::Drain,
        _ => bail!(fail()),
    };
    let start_s: f64 = parts[2].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    let end_s: f64 = parts[3].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    Ok(ComputeOutage {
        backend,
        mode,
        start_s,
        end_s,
    })
}

/// Parse `--brownout START:END:FACTOR`.
fn parse_brownout(spec: &str) -> Result<Brownout> {
    let parts: Vec<&str> = spec.split(':').collect();
    let fail = || format!("invalid brownout window '{spec}' (expect START:END:FACTOR)");
    if parts.len() != 3 {
        bail!(fail());
    }
    let start_s: f64 = parts[0].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    let end_s: f64 = parts[1].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    let factor: f64 = parts[2].parse().map_err(|_| anyhow::anyhow!(fail()))?;
    Ok(Brownout {
        start_s,
        end_s,
        factor,
    })
}

/// `medflow stream`: drive the streaming coordinator (DESIGN.md §17) —
/// a seeded arrival process lays sessions over simulated weeks, each
/// planning epoch admits the arrived delta, re-plans placement through
/// the composed [`RunSpec`], and co-simulates it on the shared fleet —
/// then print the steady-state telemetry (ingest-to-processed latency
/// percentiles, backlog over time, cost per session, re-plan counts).
fn cmd_stream(args: &Args) -> Result<()> {
    if args.has("help") {
        print_usage();
        return Ok(());
    }
    let sessions = args.num("sessions", 2_000);
    if sessions < 1 {
        bail!("invalid --sessions '{sessions}' (must be an integer ≥ 1)");
    }
    let horizon_days = args.num("horizon-days", 30);
    if horizon_days < 1 {
        bail!("invalid --horizon-days '{horizon_days}' (must be an integer ≥ 1)");
    }
    let epoch_hours = args.num("epoch-hours", 24);
    if epoch_hours < 1 {
        bail!("invalid --epoch-hours '{epoch_hours}' (must be an integer ≥ 1)");
    }
    let tenants = args.num("tenants", 1);
    if tenants < 1 {
        bail!("invalid --tenants '{tenants}' (must be an integer ≥ 1)");
    }
    let pattern = match args.get("pattern").unwrap_or("steady") {
        "t0" => ArrivalPattern::AtStart,
        "steady" => ArrivalPattern::Steady,
        "waves" => ArrivalPattern::Waves {
            count: args.num("waves", 4).max(1) as usize,
        },
        "daynight" => ArrivalPattern::DayNight,
        "backfill" => match args.get("burst").unwrap_or("0.3").parse::<f64>() {
            Ok(f) if f.is_finite() && (0.0..=1.0).contains(&f) => {
                ArrivalPattern::Backfill { burst_fraction: f }
            }
            _ => bail!(
                "invalid --burst '{}' (must be a number in [0, 1])",
                args.get("burst").unwrap_or("")
            ),
        },
        other => {
            bail!("unknown arrival pattern '{other}' (t0 | steady | waves | daynight | backfill)")
        }
    };
    let cutoff_s = match args.get("cutoff-days") {
        Some(d) => match d.parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => Some(v * 86_400.0),
            _ => bail!("invalid --cutoff-days '{d}' (must be a number ≥ 0)"),
        },
        None => None,
    };
    let severity = match args.get("severity").unwrap_or("none") {
        "none" => OutageSeverity::None,
        "mild" => OutageSeverity::Mild,
        "harsh" => OutageSeverity::Harsh,
        other => bail!("unknown outage severity '{other}' (none | mild | harsh)"),
    };
    let policy = parse_placement_policy(args.get("policy").unwrap_or("cheapest"), args)?;
    let model = match args.get("faults") {
        Some(name) => Some(parse_fault_model(name)?),
        None if args.has("faults") => Some(FaultModel::typical()),
        None => None,
    };
    if let Some(m) = &model {
        m.validate().map_err(anyhow::Error::msg)?;
    }
    let seed = args.num("seed", 42);
    let retries = args.num("retries", 3) as u32;
    let mut fleet = placement::default_fleet(
        ClusterSpec::accre(),
        args.num("concurrent", 2_000) as u32,
        args.num("cloud-lanes", 64).max(1) as usize,
        args.num("local-lanes", 8).max(1) as usize,
    );
    if let Some(m) = model {
        for backend in &mut fleet {
            backend.faults = Some(m);
        }
    }
    let horizon_s = horizon_days as f64 * 86_400.0;
    let cfg = StreamConfig {
        sessions: sessions as usize,
        horizon_s,
        epoch_s: epoch_hours as f64 * 3_600.0,
        pattern,
        seed,
        tenants: tenants as usize,
        cutoff_s,
    };
    let pcfg = PlacementConfig {
        seed,
        transfer_faults: model,
        max_retries: retries,
        retry_backoff_s: args.num("backoff", 60) as f64,
    };
    let mut spec = RunSpec::new().policy(policy).threads(threads_arg(args)?);
    if severity != OutageSeverity::None {
        spec = spec.outages(OutageSchedule::synthetic(severity, fleet.len(), horizon_s, seed));
    }
    println!(
        "stream co-simulation: {sessions} sessions over {horizon_days} simulated days \
         ('{}' arrivals, epoch {epoch_hours} h, {} backends, seed {seed})",
        pattern.label(),
        fleet.len()
    );
    let out = stream::run_stream(&cfg, &fleet, &pcfg, &spec);
    print!("{}", report::format_stream(&out));
    Ok(())
}

/// `medflow faults`: run the shared synthetic campaign
/// ([`synthetic_fault_campaign`]) through the staged co-simulation
/// fault-free and under the chosen model (in-engine injection,
/// DESIGN.md §11), and print the retry/abort telemetry plus the
/// makespan and queue-wait impact of re-contending retries.
fn cmd_faults(args: &Args) -> Result<()> {
    let n = args.num("jobs", 2_000) as usize;
    let retries = args.num("retries", 3) as u32;
    let seed = args.num("seed", 42);
    let cap = args.num("cap", 16).max(1) as usize;
    let model = parse_fault_model(args.get("model").unwrap_or("typical"))?;
    model.validate().map_err(anyhow::Error::msg)?;
    let jobs = synthetic_fault_campaign(n, seed);

    let backoff_s = args.num("backoff", 60) as f64;

    let run = |inject: bool| {
        let mut sched = Scheduler::new(ClusterSpec::accre());
        if inject {
            // the exact injection split campaign reports use — same
            // salts, same parking/backoff policy, comparable numbers
            sched.set_faults(Injection::campaign_compute(&model, retries, seed, backoff_s));
        }
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: args.num("concurrent", 2_000) as u32,
        };
        let mut sim = SlurmSim::new(sched, "medflow", Some(handle));
        let mut transfers =
            TransferScheduler::new(Topology::of(Env::Hpc).with_stream_cap(cap), seed ^ 0x7472);
        if inject {
            transfers.set_faults(Injection::campaign_transfer(&model, retries, seed));
        }
        let out = run_staged(&jobs, &mut sim, &mut transfers);
        let transfer_waits: Vec<f64> =
            transfers.records().iter().map(|r| r.queue_wait_s()).collect();
        let slurm_waits: Vec<f64> = sim
            .scheduler()
            .records()
            .iter()
            .map(|r| r.queue_wait_s())
            .collect();
        // the exact fold campaign reports use (FaultTelemetry::collect):
        // same tally rules, same cross-check seeding — comparable output
        let telemetry = FaultTelemetry::collect(
            inject.then_some(&model),
            retries,
            seed,
            sim.scheduler().fault_events(),
            transfers.fault_events(),
            (sim.scheduler().aborted_ids().len() + transfers.aborted_ids().len()) as u64,
        );
        let completed = out.timings.iter().filter(|t| t.completed).count();
        (out.makespan_s, completed, transfer_waits, slurm_waits, telemetry)
    };

    println!(
        "fault co-simulation: {n} jobs on ACCRE (stream cap {cap}, retries {retries}, seed {seed})"
    );
    println!(
        "model: checksum {:.3} pipeline {:.3} node {:.3} timeout {:.3}  (total {:.3}/attempt)\n",
        model.p_checksum,
        model.p_pipeline,
        model.p_node,
        model.p_timeout,
        model.total_rate()
    );
    let (free_mk, free_done, free_tw, free_sw, _) = run(false);
    let (mk, done, tw, sw, telemetry) = run(true);
    let p95 = |xs: &[f64]| percentiles(xs, &[95.0])[0];
    println!("{:<26}{:>14}{:>14}", "", "fault-free", "injected");
    println!(
        "{:<26}{:>14}{:>14}",
        "makespan",
        fmt_duration(free_mk),
        fmt_duration(mk)
    );
    println!("{:<26}{:>14}{:>14}", "completed jobs", free_done, done);
    println!(
        "{:<26}{:>14}{:>14}",
        "transfer wait p95",
        fmt_duration(p95(&free_tw)),
        fmt_duration(p95(&tw))
    );
    println!(
        "{:<26}{:>14}{:>14}\n",
        "cluster queue wait p95",
        fmt_duration(p95(&free_sw)),
        fmt_duration(p95(&sw))
    );
    print!("{}", report::format_fault_stats(&telemetry));
    Ok(())
}

/// `medflow transfer-sim`: simulate N concurrent streams over one
/// environment's shared storage→compute path (DESIGN.md §9) and print
/// per-stream timings plus link utilization.
fn cmd_transfer_sim(args: &Args) -> Result<()> {
    let env = match args.get("env").unwrap_or("hpc") {
        "hpc" => Env::Hpc,
        "cloud" => Env::Cloud,
        "local" => Env::Local,
        other => bail!("unknown env '{other}' (hpc | cloud | local)"),
    };
    let streams = args.num("streams", 8).max(1) as usize;
    let gb: f64 = args
        .get("gb")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let cap = args.num("cap", streams as u64).max(1) as usize;
    let seed = args.num("seed", 42);
    let bytes = (gb * 1e9) as u64;

    let topo = Topology::of(env).with_stream_cap(cap);
    println!(
        "transfer-sim: {} × {:.2} GB on {} (stream cap {cap}, seed {seed})",
        streams,
        gb,
        env.name()
    );
    for link in &topo.links {
        println!("  link {:<22} {:>7.3} Gb/s", link.name, link.capacity_gbps);
    }
    println!("  bottleneck {:>7.3} Gb/s\n", topo.bottleneck_gbps());

    let mut sim = TransferScheduler::new(topo, seed);
    for i in 0..streams {
        sim.submit_at(i as u64, 0, bytes, 0.0);
    }
    sim.run_to_completion();
    print!("{}", report::format_transfer_records(sim.records()));
    println!();
    print!("{}", report::format_transfer_stats(&sim.stats()));
    print!("{}", report::format_transfer_waits(sim.records()));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let ds = BidsDataset::open(&root.join("bids").join(args.require("dataset")?))?;
    let runtime = load_runtime(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let archive = Archive::at(&root.join("store"))?;
    let containers = ContainerArchive::open(&root.join("containers"))?;
    let mut coord = Coordinator::new(archive, containers, runtime.as_ref());
    let cfg = CampaignConfig::default();
    let sweep =
        medflow::coordinator::planner::run_sweep(&mut coord, &ds, SubmitTarget::Hpc, &cfg)?;
    for c in &sweep.campaigns {
        println!(
            "{:<22} completed {:>4} skipped {:>4} cost ${:>8.2}",
            c.pipeline, c.completed, c.skipped, c.total_cost_dollars
        );
    }
    println!(
        "sweep total: {} jobs, ${:.2}, {:.1} h",
        sweep.total_completed(),
        sweep.total_cost_dollars(),
        sweep.total_makespan_s() / 3600.0
    );
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    let root = root_of(args)?;
    let archive = Archive::at(&root.join("store"))?;
    println!("storage status:");
    for (name, tier) in archive.datasets().collect::<Vec<_>>() {
        let u = archive.usage(name)?;
        println!(
            "  {:<16} {:?}: {} files, {} bytes, {} raw images",
            name, tier, u.file_count, u.bytes, u.raw_image_count
        );
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    if args.has("help") {
        print_usage();
        return Ok(());
    }
    if args.has("list") {
        println!("{:<12} {:<6} {:<8} {}", "rule", "code", "scope", "summary");
        for r in analysis::rules::RULES {
            let scope = match r.scope {
                analysis::rules::Scope::Engine => "engine",
                analysis::rules::Scope::Billing => "billing",
            };
            println!("{:<12} {:<6} {:<8} {}", r.id, r.code, scope, r.summary);
        }
        return Ok(());
    }
    let src = match args.get("src") {
        Some(dir) => PathBuf::from(dir),
        None => default_lint_src()?,
    };
    let filter: Option<Vec<&'static analysis::rules::Rule>> = match args.get("rules") {
        None => None,
        Some(list) => {
            let mut picked = Vec::new();
            for id in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let r = analysis::rules::rule(id).with_context(|| {
                    format!("unknown lint rule '{id}' (see `medflow lint --list`)")
                })?;
                picked.push(r);
            }
            Some(picked)
        }
    };
    let report = analysis::lint_tree(&src, filter.as_deref())?;
    print!("{}", report.render());
    if args.has("deny") && report.deny_count() > 0 {
        bail!("lint --deny: {} deny-level finding(s)", report.deny_count());
    }
    Ok(())
}

/// The tree `medflow lint` scans when `--src` is not given: the crate's
/// own `src/` when the binary runs from a checkout, else a best-effort
/// relative guess.
fn default_lint_src() -> Result<PathBuf> {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    if manifest.is_dir() {
        return Ok(manifest);
    }
    for candidate in ["rust/src", "src"] {
        let p = PathBuf::from(candidate);
        if p.is_dir() {
            return Ok(p);
        }
    }
    bail!("cannot locate a src/ tree to lint — pass --src DIR");
}

fn print_usage() {
    println!(
        "medflow — scalable, reproducible, cost-effective medical-imaging processing

USAGE:
  medflow ingest    --root DIR --dataset NAME [--participants N] [--sessions M] [--gdpr]
  medflow validate  --root DIR --dataset NAME
  medflow query     --root DIR --dataset NAME --pipeline P [--full] [--workers N]
  medflow index     --root DIR --dataset NAME [--rebuild | --invalidate PIPELINE]
  medflow campaign  --root DIR --dataset NAME --pipeline P [--local WORKERS]
                    [--faults none|typical|harsh] [--retries N] [--threads N]
                    [--placement cheapest|deadline|budget [--deadline SECS] [--budget DOLLARS]]
  medflow status    --root DIR
  medflow sweep     --root DIR --dataset NAME     (all 16 pipelines, dependency order)
  medflow project   [--faults]                    (paper-scale cost projection)
  medflow growth                                  (storage capacity forecast)
  medflow transfer-sim [--env hpc|cloud|local] [--streams N] [--gb X] [--cap N] [--seed S]
                                                  (shared-link contention simulation)
  medflow faults    [--model none|typical|harsh] [--jobs N] [--retries N] [--cap N]
                    [--backoff SECS] [--seed S]   (in-engine failure/retry co-simulation)
  medflow place     [--policy cheapest|deadline|budget] [--deadline SECS] [--budget DOLLARS]
                    [--jobs N] [--frontier [STEPS]] [--faults none|typical|harsh]
                    [--cloud-lanes N] [--local-lanes N] [--seed S] [--threads N]
                                                  (heterogeneous fleet placement, DESIGN.md §12)
  medflow tenants   [--tenants N] [--jobs-per N] [--depth CAP] [--weights W1,W2,…]
                    [--priorities P1,P2,…] [--policy cheapest|deadline|budget]
                    [--faults none|typical|harsh] [--retries N] [--seed S] [--threads N]
                                                  (multi-tenant shared fleet, DESIGN.md §13)
  medflow chaos     [--severity none|mild|harsh] [--jobs N] [--horizon SECS]
                    [--window BACKEND:down|drain:START:END] [--brownout START:END:FACTOR]
                    [--policy cheapest|deadline|budget] [--retries N] [--seed S] [--threads N]
                                                  (infrastructure outages + graceful degradation, DESIGN.md §15)
  medflow stream    [--sessions N] [--horizon-days D] [--epoch-hours H]
                    [--pattern t0|steady|waves|daynight|backfill] [--waves N] [--burst F]
                    [--tenants N] [--policy cheapest|deadline|budget] [--cutoff-days D]
                    [--faults none|typical|harsh] [--severity none|mild|harsh]
                    [--retries N] [--seed S] [--threads N]
                                                  (streaming ingest + epoch re-planning, DESIGN.md §17)
  medflow lint      [--src DIR] [--rules id1,id2,…] [--deny] [--list]
                                                  (determinism static analysis, DESIGN.md §14)
  medflow pipelines
  medflow table1 | table2 | table3 | fig1"
    );
}
