//! Nightly backup to an Amazon-Glacier-like deep archive (paper §2.2):
//! dynamic storage space at $0.0036/GB/month, rare restores with tiered
//! retrieval latency.

use std::collections::BTreeMap;

use crate::cost::glacier_cost_per_month;

/// Glacier retrieval tiers (Deep Archive semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestoreTier {
    /// ~12 hours.
    Standard,
    /// ~48 hours (cheapest).
    Bulk,
}

impl RestoreTier {
    pub fn hours(self) -> f64 {
        match self {
            RestoreTier::Standard => 12.0,
            RestoreTier::Bulk => 48.0,
        }
    }
}

/// One stored snapshot object.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub day: u64,
    pub dataset: String,
    pub bytes: u64,
    /// Incremental: only bytes changed since previous snapshot are new.
    pub new_bytes: u64,
}

/// The deep-archive simulator: incremental nightly snapshots per dataset.
#[derive(Debug, Default)]
pub struct GlacierArchive {
    /// Latest full size per dataset (for incremental diffing).
    last_size: BTreeMap<String, u64>,
    snapshots: Vec<Snapshot>,
    /// Total archived bytes (grows by increments only).
    archived_bytes: u64,
}

impl GlacierArchive {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the nightly backup of `dataset` at `bytes` total size on
    /// simulation day `day`. Stores only the delta (RAID-side growth).
    pub fn nightly_backup(&mut self, day: u64, dataset: &str, bytes: u64) -> &Snapshot {
        let prev = self.last_size.get(dataset).copied().unwrap_or(0);
        let new_bytes = bytes.saturating_sub(prev);
        self.last_size.insert(dataset.to_string(), bytes);
        self.archived_bytes += new_bytes;
        self.snapshots.push(Snapshot {
            day,
            dataset: dataset.to_string(),
            bytes,
            new_bytes,
        });
        self.snapshots.last().unwrap()
    }

    pub fn archived_bytes(&self) -> u64 {
        self.archived_bytes
    }

    pub fn snapshots(&self) -> &[Snapshot] {
        &self.snapshots
    }

    /// Monthly holding cost at current archive size.
    pub fn monthly_cost(&self) -> f64 {
        glacier_cost_per_month(self.archived_bytes)
    }

    /// Latest backed-up size of a dataset (None if never backed up).
    pub fn latest(&self, dataset: &str) -> Option<u64> {
        self.last_size.get(dataset).copied()
    }

    /// Simulate a restore request; returns (hours_until_available, bytes).
    pub fn restore(&self, dataset: &str, tier: RestoreTier) -> Option<(f64, u64)> {
        self.latest(dataset).map(|b| (tier.hours(), b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{GB, TB};

    #[test]
    fn incremental_backup_stores_deltas() {
        let mut g = GlacierArchive::new();
        g.nightly_backup(1, "ADNI", 100 * GB);
        let s = g.nightly_backup(2, "ADNI", 110 * GB).clone();
        assert_eq!(s.new_bytes, 10 * GB);
        assert_eq!(g.archived_bytes(), 110 * GB);
    }

    #[test]
    fn shrinking_dataset_adds_nothing() {
        let mut g = GlacierArchive::new();
        g.nightly_backup(1, "DS", 50 * GB);
        let s = g.nightly_backup(2, "DS", 40 * GB).clone();
        assert_eq!(s.new_bytes, 0);
        assert_eq!(g.archived_bytes(), 50 * GB);
    }

    #[test]
    fn monthly_cost_matches_rate() {
        let mut g = GlacierArchive::new();
        g.nightly_backup(1, "ALL", 288 * TB); // paper's ~287.9 TB database
        // 288 TB = 288_000 GB × 0.0036 = $1036.8/month
        assert!((g.monthly_cost() - 1036.8).abs() < 0.1, "{}", g.monthly_cost());
    }

    #[test]
    fn restore_tiers() {
        let mut g = GlacierArchive::new();
        g.nightly_backup(1, "DS", GB);
        assert_eq!(g.restore("DS", RestoreTier::Standard), Some((12.0, GB)));
        assert_eq!(g.restore("DS", RestoreTier::Bulk), Some((48.0, GB)));
        assert_eq!(g.restore("NOPE", RestoreTier::Bulk), None);
    }

    #[test]
    fn multiple_datasets_tracked_independently() {
        let mut g = GlacierArchive::new();
        g.nightly_backup(1, "A", 10 * GB);
        g.nightly_backup(1, "B", 20 * GB);
        g.nightly_backup(2, "A", 15 * GB);
        assert_eq!(g.latest("A"), Some(15 * GB));
        assert_eq!(g.latest("B"), Some(20 * GB));
        assert_eq!(g.archived_bytes(), 35 * GB);
    }
}
