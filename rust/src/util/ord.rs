//! Total-order wrapper for finite `f64` keys in heaps and ordered maps.
//!
//! The event engines (DESIGN.md §10) index simulated times in
//! `BinaryHeap`/`BTreeMap`, which require `Ord`; `f64` only implements
//! `PartialOrd`. [`F64Ord`] closes the gap with IEEE-754
//! [`f64::total_cmp`] — identical to `<`/`==` for the finite,
//! non-degenerate times the simulators produce (NaN and `-0.0` never
//! enter an event queue: submit times are clamped to the clock and all
//! arithmetic stays finite).

use std::cmp::Ordering;

/// An `f64` with the IEEE-754 total order, usable as a heap/map key.
#[derive(Debug, Clone, Copy)]
pub struct F64Ord(pub f64);

impl PartialEq for F64Ord {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for F64Ord {}

impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl From<f64> for F64Ord {
    fn from(v: f64) -> Self {
        F64Ord(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_like_f64_for_finite_values() {
        let mut xs = [F64Ord(3.0), F64Ord(1.5), F64Ord(2.0)];
        xs.sort();
        assert_eq!(xs.map(|x| x.0), [1.5, 2.0, 3.0]);
        assert!(F64Ord(1.0) < F64Ord(2.0));
        assert_eq!(F64Ord(1.0), F64Ord(1.0));
    }

    #[test]
    fn usable_as_ordered_keys() {
        use std::cmp::Reverse;
        use std::collections::{BTreeMap, BinaryHeap};
        let mut heap = BinaryHeap::new();
        for t in [5.0, 1.0, 3.0] {
            heap.push(Reverse((F64Ord(t), 0u64)));
        }
        assert_eq!(heap.pop().unwrap().0 .0 .0, 1.0);
        let mut map: BTreeMap<(F64Ord, u64), &str> = BTreeMap::new();
        map.insert((F64Ord(2.0), 7), "b");
        map.insert((F64Ord(2.0), 3), "a");
        let first = *map.first_key_value().unwrap().1;
        assert_eq!(first, "a", "ties break by the second key component");
    }
}
