//! Substrate utilities built from scratch for offline operation.
//!
//! The offline crate cache has no serde/clap/tokio/criterion/proptest, so
//! medflow carries its own minimal substrates (documented in DESIGN.md §2):
//! JSON, CSV, RNG, units, a scoped thread pool, a property-test driver and
//! a bench harness. Each is small, tested, and tailored to what the
//! pipeline needs — not general-purpose replacements.

pub mod bench;
pub mod csv;
pub mod json;
pub mod ord;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod units;
