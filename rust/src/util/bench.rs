//! Tiny benchmark harness (criterion is not in the offline crate cache —
//! DESIGN.md §2). `cargo bench` runs the `rust/benches/*.rs` binaries,
//! each of which uses this module to time closures and print a stable,
//! greppable report format:
//!
//! ```text
//! bench <name>: mean 1.234 ms  std 0.012 ms  min 1.210 ms  iters 100
//! ```
//!
//! It also hosts the bench-regression gate ([`check_baseline`]): the
//! trajectory benches accept `--check-baseline <path>` and compare this
//! run's `runs[]` rows against the committed `BENCH_*.json` baseline,
//! failing CI when a matching row's wall-clock regressed beyond the
//! tolerance — a `report::gate`-style check for performance instead of
//! paper calibration.

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::units::{fmt_duration, mean_std};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {}: mean {}  std {}  min {}  iters {}",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
            fmt_duration(self.min_s),
            self.iters
        )
    }

    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let (mean_s, std_s) = mean_std(&samples);
    let min_s = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s,
        std_s,
        min_s,
    };
    println!("{}", r.report());
    r
}

/// Print a named scalar result row (for table-style benches that report
/// domain metrics, not wall time).
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name}: {value:.4} {unit}");
}

/// The `--check-baseline <path>` argument of a bench invocation, if
/// present (benches are plain binaries; args arrive after `--`).
pub fn baseline_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    let i = args.iter().position(|a| a == "--check-baseline")?;
    Some(
        args.get(i + 1)
            .unwrap_or_else(|| panic!("--check-baseline needs a path argument"))
            .clone(),
    )
}

/// Below this absolute baseline wall-clock a row is never gated: CI
/// timer noise on sub-second rows would flag phantom regressions.
const BASELINE_FLOOR_S: f64 = 0.25;

/// Apply the `--check-baseline <path>` gate when the invocation asked
/// for one: compare `runs` against the named baseline on `wall_s` at
/// the standard 1.5× tolerance, print the verdict, and exit non-zero
/// on a regression. The one gate shared by every trajectory bench —
/// call it before full mode overwrites the baseline file.
pub fn gate_against_baseline(runs: &[Json]) {
    let Some(path) = baseline_arg() else { return };
    match check_baseline(Path::new(&path), runs, "wall_s", 1.5) {
        Ok(note) => println!("baseline gate: {note}"),
        Err(report) => {
            eprintln!("baseline gate FAILED:\n{report}");
            std::process::exit(1);
        }
    }
}

/// Compare this run's `runs[]` rows against a committed `BENCH_*.json`
/// baseline: rows pair up by identity (every string-valued field plus
/// the `jobs`/`streams` counts), and a paired row fails when its
/// `metric_key` value exceeds the baseline's by more than `factor`×
/// (baselines under the 0.25 s noise floor are informational only).
///
/// An empty baseline `runs[]` — the committed placeholder before the
/// first full bench run on CI hardware — gates nothing and reports so.
/// Rows present on only one side are noted, not failed: semantic
/// changes legitimately reshape the sweep, and the nightly trajectory
/// workflow refreshes the baseline artifacts. Both directions are
/// counted — new rows the baseline lacks *and* baseline rows this run
/// no longer produces.
///
/// Returns `Ok(summary)` or `Err(report)` listing every regression in
/// sorted identity order, so the verdict is deterministic regardless
/// of the sweep's row order.
pub fn check_baseline(
    baseline_path: &Path,
    current_runs: &[Json],
    metric_key: &str,
    factor: f64,
) -> Result<String, String> {
    let text = std::fs::read_to_string(baseline_path)
        .map_err(|e| format!("read baseline {}: {e}", baseline_path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| format!("parse baseline {}: {e}", baseline_path.display()))?;
    let empty: [Json; 0] = [];
    let baseline_runs: &[Json] = doc
        .get_path("runs")
        .and_then(|r| r.as_arr())
        .unwrap_or(&empty);
    if baseline_runs.is_empty() {
        return Ok(format!(
            "baseline {} has empty runs[] (pending its first full run) — nothing to gate",
            baseline_path.display()
        ));
    }
    let mut matched = 0usize;
    let mut unmatched = 0usize;
    let mut failures = Vec::new();
    for row in current_runs {
        let Some(base) = baseline_runs.iter().find(|b| identity(b) == identity(row)) else {
            unmatched += 1;
            continue;
        };
        let (Some(cur), Some(was)) = (
            row.get_path(metric_key).and_then(Json::as_f64),
            base.get_path(metric_key).and_then(Json::as_f64),
        ) else {
            continue;
        };
        matched += 1;
        if was >= BASELINE_FLOOR_S && cur > was * factor {
            failures.push(format!(
                "  {:?}: {metric_key} {cur:.3} vs baseline {was:.3} (> {factor}×)",
                identity(row)
            ));
        }
    }
    let missing = baseline_runs
        .iter()
        .filter(|&b| !current_runs.iter().any(|r| identity(r) == identity(b)))
        .count();
    failures.sort();
    if failures.is_empty() {
        Ok(format!(
            "{matched} rows within {factor}× of {} ({unmatched} new rows not in baseline, \
             {missing} baseline rows absent from this run)",
            baseline_path.display()
        ))
    } else {
        Err(format!(
            "{} of {matched} rows regressed >{factor}× vs {}:\n{}",
            failures.len(),
            baseline_path.display(),
            failures.join("\n")
        ))
    }
}

/// A run row's identity: every string-valued field (engine, path,
/// model, policy, env…) plus the `jobs`/`streams` counts — the fields
/// that name *what* was measured, never the measurements themselves.
fn identity(row: &Json) -> Vec<(String, String)> {
    let Some(obj) = row.as_obj() else { return Vec::new() };
    let mut id: Vec<(String, String)> = obj
        .iter()
        .filter_map(|(k, v)| match v {
            Json::Str(s) => Some((k.to_string(), s.clone())),
            Json::Num(n) if k == "jobs" || k == "streams" => {
                Some((k.to_string(), format!("{n}")))
            }
            _ => None,
        })
        .collect();
    // JsonObj iterates in insertion order — two rows naming the same
    // run with fields emitted in a different order must still pair up
    id.sort();
    id
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(r.iters, 50);
        assert!(r.min_s <= r.mean_s);
        assert!(r.mean_s < 0.01);
        assert!(r.per_sec() > 100.0);
    }

    #[test]
    fn report_format_greppable() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_s: 0.00123,
            std_s: 0.00001,
            min_s: 0.00121,
        };
        assert!(r.report().starts_with("bench x: mean "));
    }

    fn row(jobs: f64, engine: &str, wall_s: f64) -> Json {
        let mut o = Json::obj();
        o.set("jobs", Json::num(jobs))
            .set("engine", Json::str(engine))
            .set("wall_s", Json::num(wall_s))
            .set("sim_makespan_s", Json::num(123.0));
        Json::Obj(o)
    }

    fn write_baseline(tag: &str, runs: Vec<Json>) -> std::path::PathBuf {
        let mut doc = Json::obj();
        doc.set("bench", Json::str("t")).set("runs", Json::Arr(runs));
        let path = std::env::temp_dir()
            .join(format!("medflow_baseline_{tag}_{}.json", std::process::id()));
        std::fs::write(&path, Json::Obj(doc).to_string_pretty()).unwrap();
        path
    }

    #[test]
    fn baseline_gate_passes_within_factor_and_fails_beyond() {
        let path = write_baseline("gate", vec![row(1000.0, "lanepool", 2.0)]);
        // 2.9 s vs 2.0 s baseline: under 1.5× — passes
        let ok = check_baseline(&path, &[row(1000.0, "lanepool", 2.9)], "wall_s", 1.5);
        assert!(ok.is_ok(), "{ok:?}");
        // 3.1 s vs 2.0 s: beyond 1.5× — fails with the row named
        let err = check_baseline(&path, &[row(1000.0, "lanepool", 3.1)], "wall_s", 1.5)
            .unwrap_err();
        assert!(err.contains("regressed") && err.contains("lanepool"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn baseline_gate_skips_empty_tiny_and_unmatched_rows() {
        // the committed placeholder: empty runs[] gates nothing
        let empty = write_baseline("empty", vec![]);
        let note = check_baseline(&empty, &[row(1000.0, "x", 9.0)], "wall_s", 1.5).unwrap();
        assert!(note.contains("empty runs[]"), "{note}");
        std::fs::remove_file(&empty).unwrap();

        // sub-floor baselines are informational; unmatched rows noted
        let tiny = write_baseline("tiny", vec![row(10.0, "x", 0.01)]);
        let ok = check_baseline(
            &tiny,
            &[row(10.0, "x", 5.0), row(99.0, "brand-new", 1.0)],
            "wall_s",
            1.5,
        );
        assert!(ok.is_ok(), "{ok:?}");
        assert!(ok.unwrap().contains("1 new rows"), "unmatched rows are counted");
        std::fs::remove_file(&tiny).unwrap();

        // a missing file is an error, not a silent pass
        assert!(check_baseline(Path::new("/nonexistent/b.json"), &[], "wall_s", 1.5).is_err());
    }

    #[test]
    fn identity_matching_is_field_order_independent() {
        // regression: identity() used to return fields in insertion
        // order, so a bench that reordered its row fields unpaired
        // every baseline row
        let mut a = Json::obj();
        a.set("engine", Json::str("lanepool")).set("jobs", Json::num(10.0));
        let mut b = Json::obj();
        b.set("jobs", Json::num(10.0)).set("engine", Json::str("lanepool"));
        assert_eq!(identity(&Json::Obj(a)), identity(&Json::Obj(b)));
    }

    #[test]
    fn baseline_rows_absent_from_run_are_reported() {
        let path = write_baseline("missing", vec![row(10.0, "x", 2.0), row(20.0, "y", 2.0)]);
        let note = check_baseline(&path, &[row(10.0, "x", 2.0)], "wall_s", 1.5).unwrap();
        assert!(note.contains("1 baseline rows absent"), "{note}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn regression_report_rows_are_sorted() {
        let path = write_baseline("sorted", vec![row(20.0, "b", 1.0), row(10.0, "a", 1.0)]);
        let err = check_baseline(&path, &[row(20.0, "b", 9.0), row(10.0, "a", 9.0)], "wall_s", 1.5)
            .unwrap_err();
        let a_pos = err.find("\"a\"").expect("row a in report");
        let b_pos = err.find("\"b\"").expect("row b in report");
        assert!(a_pos < b_pos, "failure rows sort by identity: {err}");
        std::fs::remove_file(&path).unwrap();
    }
}
