//! Tiny benchmark harness (criterion is not in the offline crate cache —
//! DESIGN.md §2). `cargo bench` runs the `rust/benches/*.rs` binaries,
//! each of which uses this module to time closures and print a stable,
//! greppable report format:
//!
//! ```text
//! bench <name>: mean 1.234 ms  std 0.012 ms  min 1.210 ms  iters 100
//! ```

use std::time::Instant;

use crate::util::units::{fmt_duration, mean_std};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {}: mean {}  std {}  min {}  iters {}",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
            fmt_duration(self.min_s),
            self.iters
        )
    }

    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let (mean_s, std_s) = mean_std(&samples);
    let min_s = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s,
        std_s,
        min_s,
    };
    println!("{}", r.report());
    r
}

/// Print a named scalar result row (for table-style benches that report
/// domain metrics, not wall time).
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name}: {value:.4} {unit}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(r.iters, 50);
        assert!(r.min_s <= r.mean_s);
        assert!(r.mean_s < 0.01);
        assert!(r.per_sec() > 100.0);
    }

    #[test]
    fn report_format_greppable() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_s: 0.00123,
            std_s: 0.00001,
            min_s: 0.00121,
        };
        assert!(r.report().starts_with("bench x: mean "));
    }
}
