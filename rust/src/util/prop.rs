//! Hand-rolled property-test driver (proptest is not in the offline crate
//! cache — DESIGN.md §2 records the substitution).
//!
//! Usage:
//! ```ignore
//! // (ignore: doctest binaries miss the xla rpath in this offline image)
//! use medflow::util::prop::forall;
//! forall("sum is commutative", 200, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! Each case gets a fresh deterministic [`Rng`]; on failure the panic
//! message names the property and the failing seed so the case can be
//! replayed with [`replay`].

use super::rng::Rng;

/// Base seed; change via MEDFLOW_PROP_SEED to explore a different corner.
fn base_seed() -> u64 {
    std::env::var("MEDFLOW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE42)
}

/// Run `cases` random cases of `property`. Panics (with seed) on the first
/// failing case.
pub fn forall(name: &str, cases: u32, property: impl Fn(&mut Rng)) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay(seed: u64, mut property: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("rng below bound", 100, |rng| {
            assert!(rng.below(10) < 10);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always fails", 5, |_| panic!("boom"));
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("always fails"), "{msg}");
    }

    #[test]
    fn replay_reproduces_case() {
        let mut seen = Vec::new();
        replay(0x1234, |rng| seen.push(rng.next_u64()));
        let mut seen2 = Vec::new();
        replay(0x1234, |rng| seen2.push(rng.next_u64()));
        assert_eq!(seen, seen2);
    }
}
