//! Minimal JSON value model, parser and writer.
//!
//! Used for BIDS sidecars, provenance records, the artifact manifest and
//! dataset descriptions. Supports the full JSON grammar (RFC 8259) with
//! `f64` numbers; object key order is preserved (BIDS sidecars are
//! conventionally ordered).

use std::collections::BTreeMap;

/// Objects at or above this many keys carry a key→position index;
/// smaller ones (typical sidecars) stay a plain Vec scan — the index
/// would cost more to maintain than it saves.
const INDEX_THRESHOLD: usize = 16;

/// A JSON value. Objects keep insertion order via a Vec of pairs; once
/// an object grows to `INDEX_THRESHOLD` keys a lookup index makes
/// `get`/`set` O(log n) (manifest/provenance reads sit on this path).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object: a Vec of (key, value) pairs, plus a
/// key→position index built lazily once the object holds
/// `INDEX_THRESHOLD` keys. Equality and serialization read only the
/// pairs, so an indexed object and a small unindexed one with the same
/// content compare equal.
#[derive(Debug, Clone, Default)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
    index: Option<BTreeMap<String, usize>>,
}

impl PartialEq for JsonObj {
    fn eq(&self, other: &Self) -> bool {
        self.pairs == other.pairs
    }
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    fn position(&self, key: &str) -> Option<usize> {
        match &self.index {
            Some(ix) => ix.get(key).copied(),
            None => self.pairs.iter().position(|(k, _)| k == key),
        }
    }

    fn maybe_build_index(&mut self) {
        if self.index.is_none() && self.pairs.len() >= INDEX_THRESHOLD {
            self.index = Some(
                self.pairs
                    .iter()
                    .enumerate()
                    .map(|(i, (k, _))| (k.clone(), i))
                    .collect(),
            );
        }
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self.position(key) {
            Some(i) => self.pairs[i].1 = value,
            None => {
                if let Some(ix) = &mut self.index {
                    ix.insert(key.to_string(), self.pairs.len());
                }
                self.pairs.push((key.to_string(), value));
                self.maybe_build_index();
            }
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.position(key).map(|i| &self.pairs[i].1)
    }

    pub fn remove(&mut self, key: &str) -> Option<Json> {
        let idx = self.position(key)?;
        let (_, value) = self.pairs.remove(idx);
        if let Some(ix) = &mut self.index {
            ix.remove(key);
            for pos in ix.values_mut() {
                if *pos > idx {
                    *pos -= 1;
                }
            }
        }
        Some(value)
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Sorted copy (useful for canonical hashing).
    pub fn sorted(&self) -> BTreeMap<String, Json> {
        self.pairs.iter().cloned().collect()
    }
}

impl Json {
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get("a.b.c")` descends nested objects.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.as_obj()?.get(seg)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(obj) => {
                if obj.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Json {
        Json::Obj(o)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset.
///
/// Manual `Display`/`Error` impls: the crate is offline-first with
/// `anyhow` as its only dependency (rust/Cargo.toml), so no derive
/// macro crate is available here.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.set(&key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // re-decode multi-byte UTF-8 from the raw slice
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": {"d": true}}"#).unwrap();
        assert_eq!(v.get_path("c.d"), Some(&Json::Bool(true)));
        assert_eq!(v.as_obj().unwrap().get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.to_string()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line\n\ttab \"quoted\" back\\slash é 中";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""é""#).unwrap().as_str().unwrap(), "é");
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "01x", "\"unterminated", "{} {}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::parse(r#"{"a":[1,2,3],"b":{"c":"d"}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn set_overwrites() {
        let mut o = Json::obj();
        o.set("k", Json::num(1)).set("k", Json::num(2));
        assert_eq!(o.get("k").unwrap().as_f64(), Some(2.0));
        assert_eq!(o.len(), 1);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::num(5).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn large_objects_index_transparently() {
        // cross the INDEX_THRESHOLD and verify get/set/remove semantics
        // and insertion order are unchanged by the lazy index
        let mut o = Json::obj();
        for i in 0..40 {
            o.set(&format!("k{i:02}"), Json::num(i));
        }
        assert_eq!(o.len(), 40);
        for i in 0..40 {
            assert_eq!(o.get(&format!("k{i:02}")).unwrap().as_f64(), Some(i as f64));
        }
        assert_eq!(o.get("missing"), None);
        // overwrite keeps position and count
        o.set("k05", Json::str("replaced"));
        assert_eq!(o.len(), 40);
        assert_eq!(o.iter().nth(5).unwrap().0, "k05");
        assert_eq!(o.get("k05").unwrap().as_str(), Some("replaced"));
        // removal shifts later positions; lookups stay correct
        assert!(o.remove("k00").is_some());
        assert_eq!(o.remove("k00"), None);
        assert_eq!(o.len(), 39);
        assert_eq!(o.iter().next().unwrap().0, "k01");
        assert_eq!(o.get("k39").unwrap().as_f64(), Some(39.0));
        // roundtrip preserves order through parse (parser uses set too)
        let v = Json::Obj(o.clone());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn indexed_and_unindexed_objects_compare_equal() {
        // an object that grew past the threshold and shrank back must
        // equal a small object built directly with the same content
        let mut big = Json::obj();
        for i in 0..20 {
            big.set(&format!("k{i:02}"), Json::num(i));
        }
        for i in 3..20 {
            big.remove(&format!("k{i:02}"));
        }
        let mut small = Json::obj();
        for i in 0..3 {
            small.set(&format!("k{i:02}"), Json::num(i));
        }
        assert_eq!(big, small);
        assert_eq!(Json::Obj(big), Json::Obj(small));
    }
}
