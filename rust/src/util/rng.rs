//! Deterministic RNG (SplitMix64) for synthetic data, simulation and
//! property tests. Reproducibility is a design criterion (paper §1), so all
//! randomness in medflow flows from explicit seeds.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and — unlike
/// `rand` — available offline.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, n). n must be > 0.
    ///
    /// A real `assert!`, not `debug_assert!`: release builds used to
    /// return 0 for `below(0)` — an out-of-range value for an empty
    /// range — which surfaced far from the call site (e.g. as an opaque
    /// index panic in [`Self::choose`]).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0): the range [0, 0) is empty");
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (for inter-arrival times).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / rate
    }

    /// Pick a random element. Panics (with a clear message) on an empty
    /// slice — there is nothing to choose.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "Rng::choose on an empty slice");
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Random lowercase alphanumeric string of length n.
    pub fn token(&mut self, n: usize) -> String {
        const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        (0..n)
            .map(|_| ALPHA[self.below(ALPHA.len() as u64) as usize] as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({
            let mut r = Rng::new(43);
            move |_| r.next_u64()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    #[should_panic(expected = "Rng::below(0)")]
    fn below_zero_panics_with_clear_message() {
        let mut r = Rng::new(1);
        r.below(0);
    }

    #[test]
    #[should_panic(expected = "Rng::choose on an empty slice")]
    fn choose_empty_panics_with_clear_message() {
        let mut r = Rng::new(1);
        let empty: [u32; 0] = [];
        r.choose(&empty);
    }

    #[test]
    fn choose_returns_elements_from_the_slice() {
        let mut r = Rng::new(2);
        let items = [10, 20, 30];
        for _ in 0..100 {
            assert!(items.contains(r.choose(&items)));
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn token_shape() {
        let mut r = Rng::new(2);
        let t = r.token(12);
        assert_eq!(t.len(), 12);
        assert!(t.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()));
    }
}
