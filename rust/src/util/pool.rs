//! Scoped worker pool — the "local server" parallel runner substrate.
//!
//! The paper's local-burst path (§2.3) emits a Python file that parallelizes
//! job execution on a workstation; medflow's equivalent is this pool: run N
//! closures across W OS threads and collect results in input order. Built on
//! `std::thread::scope` (no tokio in the offline cache; jobs here are
//! CPU/IO-bound batch work, so a blocking pool is the right shape anyway).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` on `workers` threads; returns results in input order.
/// Panics in jobs propagate (fail-fast, matching the paper's abort-on-error
/// transfer policy).
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().unwrap();
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect()
}

/// Statistics from a throttled run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolStats {
    pub jobs: usize,
    pub workers: usize,
    pub max_in_flight: usize,
}

/// Like [`run_parallel`] but also reports the maximum observed concurrency —
/// used by backpressure tests to prove the throttle engaged.
pub fn run_parallel_stats<T, F>(workers: usize, jobs: Vec<F>) -> (Vec<T>, PoolStats)
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let workers_clamped = workers.clamp(1, n.max(1));
    let in_flight = AtomicUsize::new(0);
    let max_in_flight = AtomicUsize::new(0);
    let wrapped: Vec<_> = jobs
        .into_iter()
        .map(|j| {
            let in_flight = &in_flight;
            let max_in_flight = &max_in_flight;
            move || {
                let cur = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                max_in_flight.fetch_max(cur, Ordering::SeqCst);
                let out = j();
                in_flight.fetch_sub(1, Ordering::SeqCst);
                out
            }
        })
        .collect();
    let results = run_parallel(workers, wrapped);
    let stats = PoolStats {
        jobs: n,
        workers: workers_clamped,
        max_in_flight: max_in_flight.load(Ordering::SeqCst),
    };
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn results_in_input_order() {
        let jobs: Vec<_> = (0..64).map(|i| move || i * 2).collect();
        let out = run_parallel(8, jobs);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        static COUNT: AtomicU32 = AtomicU32::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| || COUNT.fetch_add(1, Ordering::SeqCst))
            .collect();
        run_parallel(7, jobs);
        assert_eq!(COUNT.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn concurrency_bounded_by_workers() {
        let jobs: Vec<_> = (0..32)
            .map(|_| || std::thread::sleep(std::time::Duration::from_millis(2)))
            .collect();
        let (_, stats) = run_parallel_stats(4, jobs);
        assert!(stats.max_in_flight <= 4, "max={}", stats.max_in_flight);
    }

    #[test]
    fn empty_jobs_ok() {
        let out: Vec<u32> = run_parallel(4, Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_is_sequential_order() {
        let jobs: Vec<_> = (0..10).map(|i| move || i).collect();
        assert_eq!(run_parallel(1, jobs), (0..10).collect::<Vec<_>>());
    }
}
