//! Size/throughput/duration formatting + parsing used across reports.
//!
//! Convention notes (they bite): storage sizes are **bytes** (SI: 1 TB =
//! 1e12 B, matching how the paper quotes "407 TB"), network throughput is
//! **Gigabits**/s (paper Table 1), durations are seconds f64.

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;

/// Format a byte count with SI units ("47 TB", "1.1 GB").
///
/// Boundary rounding carries into the next unit *before* formatting —
/// the same carry [`fmt_duration`] applies: naively formatting
/// 999 999 999 999 B as `{:.1} GB` rounds to "1000.0 GB" just under the
/// branch boundary; it renders as "1.0 TB" instead (same at the KB/MB/GB
/// edges).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    let scaled = |unit: u64, name: &str, next: &str| -> String {
        let v = format!("{:.1}", b / unit as f64);
        if v == "1000.0" {
            format!("1.0 {next}")
        } else {
            format!("{v} {name}")
        }
    };
    if bytes >= TB {
        format!("{:.1} TB", b / TB as f64)
    } else if bytes >= GB {
        scaled(GB, "GB", "TB")
    } else if bytes >= MB {
        scaled(MB, "MB", "GB")
    } else if bytes >= KB {
        scaled(KB, "KB", "MB")
    } else {
        format!("{bytes} B")
    }
}

/// Bytes/second → Gigabits/second (paper Table 1's unit).
pub fn bytes_per_sec_to_gbps(bps: f64) -> f64 {
    bps * 8.0 / 1e9
}

/// Gigabits/second → bytes/second.
pub fn gbps_to_bytes_per_sec(gbps: f64) -> f64 {
    gbps * 1e9 / 8.0
}

/// Format seconds as "1h 02m", "3m 20s", "450 ms", …
///
/// Sub-unit remainders are rounded and the carry propagated *before*
/// formatting: naively rounding `secs % 60.0` in the format string turns
/// 119.7 into "1m 60s" (and 3599.5 into "59m 60s"). The same rounding
/// can overflow a whole unit just under a branch boundary (59.97 →
/// "60.0 s"), so those render as the next unit up instead.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 0.001 {
        let s = format!("{:.1} µs", secs * 1e6);
        if s == "1000.0 µs" {
            "1.0 ms".to_string()
        } else {
            s
        }
    } else if secs < 1.0 {
        let s = format!("{:.1} ms", secs * 1e3);
        if s == "1000.0 ms" {
            "1.0 s".to_string()
        } else {
            s
        }
    } else if secs < 60.0 {
        let s = format!("{secs:.1} s");
        if s == "60.0 s" {
            "1m 00s".to_string()
        } else {
            s
        }
    } else if secs < 3600.0 {
        let mut mins = (secs / 60.0) as u64;
        let mut s = (secs % 60.0).round() as u64;
        if s == 60 {
            s = 0;
            mins += 1;
        }
        if mins == 60 {
            "1h 00m".to_string()
        } else {
            format!("{mins}m {s:02}s")
        }
    } else {
        let mut hours = (secs / 3600.0) as u64;
        let mut mins = ((secs % 3600.0) / 60.0).round() as u64;
        if mins == 60 {
            mins = 0;
            hours += 1;
        }
        format!("{hours}h {mins:02}m")
    }
}

/// Mean and sample standard deviation.
///
/// **Empty-slice contract:** returns `(0.0, 0.0)` — never NaN. Report
/// folds call this on telemetry that can legitimately be empty (a
/// tenant with zero jobs, a run with zero transfers), and a 0.0 row
/// renders; a NaN row poisons every downstream aggregate. A single
/// sample likewise reports `std = 0.0`, not NaN from the `n - 1`
/// divisor.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

/// Percentile (linear interpolation), p in [0, 100].
///
/// O(n) selection (`select_nth_unstable_by`) instead of a full sort:
/// the lower order statistic partitions the buffer, and the upper
/// interpolation neighbour is the minimum of the right partition. For
/// several percentiles of one sample use [`percentiles`], which sorts
/// once instead of re-selecting per call.
///
/// NaN samples (reachable from any f64 telemetry) order after every
/// number via `total_cmp` instead of panicking the comparator; they
/// surface in the top percentiles rather than poisoning the call.
///
/// **Empty-slice contract:** returns `0.0` — same sentinel as
/// [`mean_std`], for the same reason (empty telemetry renders as a
/// zero row, never NaN). A percentile outside [0, 100] is a caller
/// bug and asserts instead of indexing out of range (p > 100) or
/// silently clamping (p < 0).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(
        (0.0..=100.0).contains(&p),
        "percentile {p} out of range [0, 100]"
    );
    if xs.is_empty() {
        return 0.0;
    }
    let mut buf = xs.to_vec();
    let rank = (p / 100.0) * (buf.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let (_, &mut lo_v, rest) = buf.select_nth_unstable_by(lo, f64::total_cmp);
    if lo == hi {
        return lo_v;
    }
    // sorted[lo + 1] = the smallest element right of the pivot
    let hi_v = rest
        .iter()
        .copied()
        .min_by(|a, b| a.total_cmp(b))
        .expect("hi > lo implies a non-empty right partition");
    lo_v + (rank - lo as f64) * (hi_v - lo_v)
}

/// Several percentiles of one sample: sorts once (O(n log n)) and reads
/// every requested percentile off the same order statistics — the
/// multi-percentile report tables (queue-wait p50/p95, transfer-wait
/// rows) sit on this instead of re-sorting per percentile.
///
/// **Empty-slice contract:** returns `0.0` for every requested
/// percentile ([`percentile`]'s sentinel, element-wise), and asserts
/// the same [0, 100] range on each `p`.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    for &p in ps {
        assert!(
            (0.0..=100.0).contains(&p),
            "percentile {p} out of range [0, 100]"
        );
    }
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let mut sorted = xs.to_vec();
    sorted.sort_unstable_by(|a, b| a.total_cmp(b));
    ps.iter()
        .map(|&p| {
            let rank = (p / 100.0) * (sorted.len() - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
            }
        })
        .collect()
}

/// Checked f64 → u64 conversion for billing and count paths: rounds,
/// then asserts instead of letting `as` saturate silently (the
/// `lossy-cast` determinism-lint rule, DESIGN.md §14). NaN would cast
/// to 0 — a free campaign — +∞ to `u64::MAX`, and anything beyond 2⁵³
/// has already lost integer precision; all three are caller bugs a
/// bill must not absorb.
pub fn checked_u64(x: f64) -> u64 {
    assert!(x.is_finite(), "checked_u64({x}) — not finite");
    assert!(x >= 0.0, "checked_u64({x}) — negative");
    assert!(
        x <= 9_007_199_254_740_992.0,
        "checked_u64({x}) — beyond 2^53, integer precision already lost"
    );
    x.round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(47 * TB), "47.0 TB");
        assert_eq!(fmt_bytes(1_100_000_000), "1.1 GB");
    }

    #[test]
    fn bytes_rollover_carries_rounded_units() {
        // regression: these used to render "1000.0 GB" / "1000.0 MB" /
        // "1000.0 KB" — rounding just under a branch boundary must carry
        // into the next unit, exactly like fmt_duration's "1m 60s" fix
        assert_eq!(fmt_bytes(999_999_999_999), "1.0 TB");
        assert_eq!(fmt_bytes(999_999_999), "1.0 GB");
        assert_eq!(fmt_bytes(999_999), "1.0 MB");
        assert_eq!(fmt_bytes(999_960), "1.0 MB");
        // just below the rounding threshold stays in its own unit
        assert_eq!(fmt_bytes(999_940), "999.9 KB");
        assert_eq!(fmt_bytes(999_900_000_000), "999.9 GB");
        // exact boundaries land in the larger unit directly
        assert_eq!(fmt_bytes(KB), "1.0 KB");
        assert_eq!(fmt_bytes(MB), "1.0 MB");
        assert_eq!(fmt_bytes(GB), "1.0 GB");
        assert_eq!(fmt_bytes(TB), "1.0 TB");
    }

    #[test]
    fn gbps_roundtrip() {
        let bps = gbps_to_bytes_per_sec(0.60);
        assert!((bytes_per_sec_to_gbps(bps) - 0.60).abs() < 1e-12);
        // 0.60 Gb/s = 75 MB/s
        assert!((bps - 75e6).abs() < 1.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.0000005), "0.5 µs");
        assert_eq!(fmt_duration(0.020), "20.0 ms");
        assert_eq!(fmt_duration(20.0), "20.0 s");
        assert_eq!(fmt_duration(200.0), "3m 20s");
        // 22 530 s = 6 h 15.5 min — minutes round, not truncate
        assert_eq!(fmt_duration(22_530.0), "6h 16m");
    }

    #[test]
    fn duration_rollover_carries_rounded_units() {
        // regression: these used to render "1m 60s" / "59m 60s"
        assert_eq!(fmt_duration(119.7), "2m 00s");
        assert_eq!(fmt_duration(3599.5), "1h 00m");
        assert_eq!(fmt_duration(119.2), "1m 59s");
        // hours branch: 6 h 59.99 m must carry to 7 h, not "6h 60m"
        assert_eq!(fmt_duration(7.0 * 3600.0 - 1.0), "7h 00m");
        assert_eq!(fmt_duration(60.0), "1m 00s");
        assert_eq!(fmt_duration(3600.0), "1h 00m");
        // branch-boundary rounding must roll into the next unit too
        assert_eq!(fmt_duration(59.97), "1m 00s");
        assert_eq!(fmt_duration(0.99996), "1.0 s");
        assert_eq!(fmt_duration(0.00099996), "1.0 ms");
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn mean_std_degenerate() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[3.0]), (3.0, 0.0));
    }

    #[test]
    fn empty_slices_return_zero_sentinels_not_nan() {
        // the documented contract, pinned for all three folds: empty
        // telemetry reports 0.0 rows, never NaN
        let (m, s) = mean_std(&[]);
        assert_eq!((m, s), (0.0, 0.0));
        assert!(!m.is_nan() && !s.is_nan());
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
        assert_eq!(percentiles(&[], &[50.0, 95.0]), vec![0.0, 0.0]);
        assert_eq!(percentiles(&[], &[]), Vec::<f64>::new());
        // non-empty slices of zeros are indistinguishable on purpose
        assert_eq!(percentiles(&[0.0], &[50.0]), vec![0.0]);
    }

    #[test]
    #[should_panic(expected = "out of range [0, 100]")]
    fn percentile_rejects_out_of_range_p() {
        percentile(&[1.0, 2.0], 101.0);
    }

    #[test]
    #[should_panic(expected = "out of range [0, 100]")]
    fn percentiles_reject_negative_p() {
        percentiles(&[1.0, 2.0], &[50.0, -0.5]);
    }

    #[test]
    fn checked_u64_rounds_and_accepts_exact_range() {
        assert_eq!(checked_u64(0.0), 0);
        assert_eq!(checked_u64(2.4), 2);
        assert_eq!(checked_u64(2.5), 3);
        assert_eq!(checked_u64(1e6), 1_000_000);
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn checked_u64_rejects_nan() {
        checked_u64(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn checked_u64_rejects_negative() {
        checked_u64(-1.0);
    }

    #[test]
    #[should_panic(expected = "beyond 2^53")]
    fn checked_u64_rejects_precision_loss() {
        checked_u64(1e18);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // regression: partial_cmp(..).unwrap() panicked on any NaN
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // NaN sorts last (total order), so only the top percentile sees it
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn percentiles_match_single_percentile() {
        // the sort-once batch helper and the O(n) selection path must
        // agree exactly — same ranks, same interpolation arithmetic
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0];
        let ps = [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0];
        let batch = percentiles(&xs, &ps);
        for (&p, &b) in ps.iter().zip(&batch) {
            let single = percentile(&xs, p);
            assert!(
                (single - b).abs() < 1e-12 || (single.is_nan() && b.is_nan()),
                "p={p}: selection {single} vs batch {b}"
            );
        }
        // NaN and degenerate inputs behave identically too
        let with_nan = [2.0, f64::NAN, 1.0];
        assert!(percentiles(&with_nan, &[100.0])[0].is_nan());
        assert_eq!(percentiles(&[], &[50.0, 95.0]), vec![0.0, 0.0]);
        assert_eq!(percentiles(&[4.0], &[0.0, 50.0, 100.0]), vec![4.0; 3]);
    }
}
