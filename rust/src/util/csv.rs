//! Minimal CSV writer/reader (RFC 4180 quoting).
//!
//! Used for the "missing-criteria" CSV the query engine emits (paper §2.3)
//! and for benchmark/report series output.

/// Write rows to CSV text. Fields containing `,`, `"` or newlines are quoted.
pub fn write_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    write_row(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    for row in rows {
        write_row(&mut out, row);
    }
    out
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, field) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            out.push('"');
            out.push_str(&field.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(field);
        }
    }
    out.push('\n');
}

/// Parse CSV text into rows of fields (handles quoted fields + escaped quotes).
pub fn parse_csv(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    chars.next();
                    field.push('"');
                }
                '"' => in_quotes = false,
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let rows = vec![vec!["a".into(), "b".into()], vec!["1".into(), "2".into()]];
        let text = write_csv(&["x", "y"], &rows);
        let parsed = parse_csv(&text);
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[1], vec!["a", "b"]);
    }

    #[test]
    fn quoting_roundtrip() {
        let tricky = vec![vec!["a,b".into(), "say \"hi\"".into(), "multi\nline".into()]];
        let text = write_csv(&["f1", "f2", "f3"], &tricky);
        let parsed = parse_csv(&text);
        assert_eq!(parsed[1][0], "a,b");
        assert_eq!(parsed[1][1], "say \"hi\"");
        assert_eq!(parsed[1][2], "multi\nline");
    }

    #[test]
    fn empty_input() {
        assert!(parse_csv("").is_empty());
    }

    #[test]
    fn crlf_handled() {
        let parsed = parse_csv("a,b\r\n1,2\r\n");
        assert_eq!(parsed, vec![vec!["a", "b"], vec!["1", "2"]]);
    }

    #[test]
    fn trailing_unterminated_row_kept() {
        let parsed = parse_csv("a,b\n1,2");
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1], vec!["1", "2"]);
    }
}
