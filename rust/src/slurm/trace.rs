//! Workload traces + queueing statistics over scheduler runs: the
//! quantitative view of "is the cluster busy" that the §2.3 resource
//! monitor exposes, plus fairness accounting across users.

use super::{ArrayHandle, ClusterSpec, JobRecord, Policy, Scheduler, SimJob};
use crate::util::rng::Rng;
use crate::util::units::{mean_std, percentiles};
use std::collections::BTreeMap;

/// Trace generator parameters (Poisson arrivals, lognormal-ish durations).
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    pub jobs: u64,
    pub users: u64,
    /// Mean inter-arrival seconds.
    pub mean_interarrival_s: f64,
    /// Short-job duration range (seconds).
    pub short_s: (f64, f64),
    /// Long-job duration range (seconds) and probability.
    pub long_s: (f64, f64),
    pub p_long: f64,
    pub array_throttle: u32,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            jobs: 500,
            users: 5,
            mean_interarrival_s: 20.0,
            short_s: (600.0, 5400.0),
            long_s: (4.0 * 3600.0, 12.0 * 3600.0),
            p_long: 0.15,
            array_throttle: 64,
        }
    }
}

/// Generate a deterministic trace.
pub fn generate_trace(spec: &TraceSpec, seed: u64) -> Vec<SimJob> {
    let mut rng = Rng::new(seed);
    let handle = ArrayHandle {
        array_id: 1,
        max_concurrent: spec.array_throttle,
    };
    let mut t = 0.0;
    let mut jobs = Vec::with_capacity(spec.jobs as usize);
    for id in 0..spec.jobs {
        t += rng.exponential(1.0 / spec.mean_interarrival_s);
        let long = rng.next_f64() < spec.p_long;
        let (lo, hi) = if long { spec.long_s } else { spec.short_s };
        jobs.push(SimJob {
            id,
            user: format!("u{}", rng.below(spec.users)),
            cores: if long { 8 } else { 1 + rng.below(2) as u32 },
            ram_gb: if long { 32 } else { 8 },
            duration_s: rng.range_f64(lo, hi),
            submit_s: t,
            array: if rng.below(2) == 0 { Some(handle) } else { None },
        });
    }
    jobs
}

/// Queueing + fairness statistics over completed records.
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub jobs: usize,
    pub makespan_s: f64,
    pub wait_mean_s: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    pub utilization: f64,
    /// Jain's fairness index over per-user mean waits (1.0 = perfectly fair).
    pub wait_fairness: f64,
}

/// Run a trace through a scheduler and collect statistics.
pub fn run_trace(cluster: ClusterSpec, policy: Policy, jobs: Vec<SimJob>) -> TraceStats {
    let mut sched = Scheduler::with_policy(cluster, policy);
    for j in jobs {
        sched.submit(j);
    }
    sched.run_to_completion();
    stats_of(&sched)
}

fn stats_of(sched: &Scheduler) -> TraceStats {
    let records: &[JobRecord] = sched.records();
    let waits: Vec<f64> = records.iter().map(|r| r.queue_wait_s()).collect();
    let (wait_mean_s, _) = mean_std(&waits);
    let mut per_user: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for r in records {
        per_user.entry(&r.job.user).or_default().push(r.queue_wait_s());
    }
    let user_means: Vec<f64> = per_user.values().map(|w| mean_std(w).0 + 1.0).collect();
    // Jain: (Σx)² / (n·Σx²)
    let sum: f64 = user_means.iter().sum();
    let sq: f64 = user_means.iter().map(|x| x * x).sum();
    let wait_fairness = if user_means.is_empty() {
        1.0
    } else {
        sum * sum / (user_means.len() as f64 * sq)
    };
    // one sort serves both percentiles (units::percentiles)
    let wait_ps = percentiles(&waits, &[50.0, 95.0]);
    TraceStats {
        jobs: records.len(),
        makespan_s: sched.makespan(),
        wait_mean_s,
        wait_p50_s: wait_ps[0],
        wait_p95_s: wait_ps[1],
        utilization: sched.utilization(),
        wait_fairness,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sized() {
        let spec = TraceSpec::default();
        let a = generate_trace(&spec, 1);
        let b = generate_trace(&spec, 1);
        assert_eq!(a.len(), 500);
        assert_eq!(a[17], b[17]);
        assert_ne!(a[17], generate_trace(&spec, 2)[17]);
    }

    #[test]
    fn arrivals_monotone() {
        let jobs = generate_trace(&TraceSpec::default(), 3);
        for w in jobs.windows(2) {
            assert!(w[0].submit_s <= w[1].submit_s);
        }
    }

    #[test]
    fn stats_consistent() {
        let spec = TraceSpec {
            jobs: 200,
            ..Default::default()
        };
        let stats = run_trace(
            ClusterSpec::small(8, 16, 128),
            Policy::default(),
            generate_trace(&spec, 5),
        );
        assert_eq!(stats.jobs, 200);
        assert!(stats.wait_p50_s <= stats.wait_p95_s);
        assert!(stats.wait_mean_s >= 0.0);
        assert!((0.0..=1.0).contains(&stats.utilization));
        assert!((0.0..=1.0 + 1e-9).contains(&stats.wait_fairness));
    }

    #[test]
    fn fairshare_improves_fairness_on_skewed_load() {
        // one user floods the cluster; fairshare should keep other users'
        // waits closer together than FIFO does
        let mut jobs = generate_trace(
            &TraceSpec {
                jobs: 300,
                users: 3,
                mean_interarrival_s: 5.0,
                ..Default::default()
            },
            7,
        );
        for (i, j) in jobs.iter_mut().enumerate() {
            if i % 2 == 0 {
                j.user = "flooder".into();
            }
        }
        let cluster = ClusterSpec::small(4, 8, 64);
        // fairshare's promise is that LIGHT users don't pay for the
        // flooder's queue: their mean wait must drop vs FIFO
        let light_wait = |policy: Policy, jobs: Vec<SimJob>| {
            let mut sched = Scheduler::with_policy(cluster.clone(), policy);
            for j in jobs {
                sched.submit(j);
            }
            sched.run_to_completion();
            let waits: Vec<f64> = sched
                .records()
                .iter()
                .filter(|r| r.job.user != "flooder")
                .map(|r| r.queue_wait_s())
                .collect();
            mean_std(&waits).0
        };
        let fair = light_wait(Policy { fairshare: true, backfill: true }, jobs.clone());
        let fifo = light_wait(Policy { fairshare: false, backfill: true }, jobs);
        assert!(fair < fifo, "light users: fairshare {fair} vs fifo {fifo}");
    }

    #[test]
    fn bigger_cluster_reduces_waits() {
        let spec = TraceSpec {
            jobs: 300,
            mean_interarrival_s: 5.0,
            ..Default::default()
        };
        let small =
            run_trace(ClusterSpec::small(2, 8, 64), Policy::default(), generate_trace(&spec, 9));
        let big =
            run_trace(ClusterSpec::small(32, 8, 64), Policy::default(), generate_trace(&spec, 9));
        assert!(big.wait_mean_s < small.wait_mean_s);
        assert!(big.makespan_s <= small.makespan_s);
    }
}
