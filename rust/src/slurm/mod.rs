//! SLURM-style cluster simulator — the ACCRE substrate (paper §2.2).
//!
//! Discrete-event simulation of a shared HPC cluster: nodes with cores +
//! RAM, a pending queue ordered by fairshare priority, EASY backfill,
//! job-array concurrency throttles, and maintenance windows (during which
//! no job starts — the coordinator's burst-to-local trigger, §2.3).
//!
//! ACCRE's published scale: 750 compute nodes, 20,100 CPU cores, ~200 TB
//! RAM (§2.2); `ClusterSpec::accre()` encodes it.
//!
//! **Event-engine scale (DESIGN.md §10):** arrivals are heap-ordered,
//! running-job end times are indexed in a binary heap, scheduling passes
//! only run when cluster state actually changed (arrival, completion,
//! maintenance boundary — a pass without one is a provable no-op), and
//! the EASY-backfill start estimate is a resource-release skyline that
//! touches only the node each release lands on. The retained pre-PR
//! engine ([`crate::sim_legacy`]) re-sorted the whole pending vector and
//! re-scanned every running job on every event; the rewrite is
//! record-for-record identical to it (`rust/tests/engine_parity.rs`).
//!
//! **In-engine failure injection (DESIGN.md §11):** with
//! [`Scheduler::set_faults`], every started attempt samples a failure
//! verdict deterministically per (job id, attempt) from the
//! [`crate::faults::FaultModel`]. A failing attempt holds its allocation
//! for `wasted_fraction()` of the nominal duration, releases it at the
//! failure instant, and is requeued with exponential retry backoff — so
//! retried jobs *re-contend* for nodes, fairshare, and array throttles
//! instead of being scaled after the fact. Timed-out attempts can be
//! parked for the staged co-simulation to re-stage inputs first
//! ([`crate::coordinator::staged`]); exhausted retries abort the job.
//! With no injection configured (or a zero-rate model) the event
//! arithmetic is bit-identical to the fault-free engine.

pub mod trace;

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

use crate::faults::outage::{OutageMode, OutageWindow};
use crate::faults::{FailureMode, FaultAction, FaultEvent, Injection};
use crate::util::ord::F64Ord;

/// One node's capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeSpec {
    pub cores: u32,
    pub ram_gb: u32,
}

/// Cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// The ACCRE cluster at paper scale: 750 nodes ≈ 20,100 cores, ~200 TB.
    pub fn accre() -> Self {
        Self {
            name: "ACCRE".into(),
            nodes: vec![NodeSpec { cores: 27, ram_gb: 267 }; 750],
        }
    }

    /// A small cluster for tests/examples.
    pub fn small(nodes: usize, cores: u32, ram_gb: u32) -> Self {
        Self {
            name: format!("test-{nodes}x{cores}"),
            nodes: vec![NodeSpec { cores, ram_gb }; nodes],
        }
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.cores as u64).sum()
    }

    /// How many jobs of shape (`cores`, `ram_gb`) the cluster can hold
    /// concurrently — per node, the binding resource limits the count;
    /// summed over nodes. This is the placement planner's release-skyline
    /// width for the HPC backend (DESIGN.md §12): the co-simulated
    /// [`Scheduler`] enforces the real packing, the planner only needs
    /// the parallelism ceiling.
    pub fn concurrent_slots(&self, cores: u32, ram_gb: u32) -> u64 {
        assert!(cores >= 1, "concurrent_slots: a job occupies at least one core");
        self.nodes
            .iter()
            .map(|n| {
                let by_cores = n.cores / cores;
                let by_ram = if ram_gb == 0 { u32::MAX } else { n.ram_gb / ram_gb };
                u64::from(by_cores.min(by_ram))
            })
            .sum()
    }
}

/// A job submitted to the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct SimJob {
    pub id: u64,
    pub user: String,
    pub cores: u32,
    pub ram_gb: u32,
    /// Wall-clock duration once started (seconds).
    pub duration_s: f64,
    /// Submission time (seconds).
    pub submit_s: f64,
    /// Job-array handle (jobs sharing an array share a concurrency cap).
    pub array: Option<ArrayHandle>,
}

/// Identifies a job array + its `%max_concurrent` throttle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayHandle {
    pub array_id: u64,
    pub max_concurrent: u32,
}

/// Completed-job record.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub job: SimJob,
    pub start_s: f64,
    pub end_s: f64,
    pub node: usize,
}

impl JobRecord {
    pub fn queue_wait_s(&self) -> f64 {
        self.start_s - self.job.submit_s
    }
}

/// Scheduling policy (ablation axis: the paper relies on ACCRE's
/// fairshare+backfill; `bench ablation_scheduler` quantifies what each
/// piece buys).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// Order pending jobs by per-user fairshare usage (else pure FIFO).
    pub fairshare: bool,
    /// EASY backfill around the blocked head job (else strict order).
    pub backfill: bool,
}

impl Default for Policy {
    fn default() -> Self {
        Self {
            fairshare: true,
            backfill: true,
        }
    }
}

/// A window during which no new job may start (maintenance / outage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Maintenance {
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Clone, Copy)]
struct NodeState {
    free_cores: u32,
    free_ram_gb: u32,
}

#[derive(Debug, Clone, Copy)]
struct Running {
    /// The flat interned row (ROADMAP item 2 follow-up): a start holds
    /// the `Copy` row, so starting an attempt allocates nothing. The
    /// owned [`SimJob`] is materialized from the user-name arena only
    /// when a *completed* attempt's [`JobRecord`] is emitted — killed
    /// and failed attempts never pay for one.
    job: DueJob,
    node: usize,
    start_s: f64,
    /// When this *attempt* releases its allocation: the nominal end for
    /// a clean run, the failure instant for a sampled-to-fail one.
    end_s: f64,
    /// 0-based attempt index (0 unless the job was requeued).
    attempt: u32,
    /// The failure this attempt will surface at `end_s`, sampled at
    /// start; `None` = the attempt completes.
    fail: Option<FailureMode>,
    /// Start generation: matches this attempt's ends-heap entry. An
    /// outage kill leaves the entry stale; [`Scheduler::complete_finished`]
    /// skips entries whose generation no longer matches.
    start_seq: u64,
}

/// A pending job as a flat `Copy` row (DESIGN.md §16): the owned user
/// `String` of [`SimJob`] is interned to a dense id at submission, so
/// the scheduling pass examines candidates by copy instead of cloning a
/// heap-allocated `SimJob` per examined job per pass. Running attempts
/// hold the same row; the full [`SimJob`] is re-materialized from the
/// interned-name arena only when a completed attempt's [`JobRecord`]
/// is emitted.
#[derive(Debug, Clone, Copy)]
struct DueJob {
    id: u64,
    /// Index into `Scheduler::user_names` / `Scheduler::usage`.
    user: u32,
    cores: u32,
    ram_gb: u32,
    duration_s: f64,
    submit_s: f64,
    array: Option<ArrayHandle>,
}

/// A not-yet-due submission, heap-ordered by (submit_s, id, seq). The
/// submission sequence number disambiguates pathological duplicate ids
/// so the heap order stays total.
#[derive(Debug, Clone, Copy)]
struct FutureJob {
    key: (F64Ord, u64, u64),
    job: DueJob,
}

impl PartialEq for FutureJob {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl Eq for FutureJob {}

impl PartialOrd for FutureJob {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FutureJob {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.cmp(&other.key)
    }
}

/// The discrete-event scheduler.
///
/// Scale note (DESIGN.md §10): future arrivals live in a binary heap,
/// due jobs in an unordered bag that scheduling passes order by a
/// priority key computed once per job, started jobs leave the bag via
/// swap-removal, and completions pop from an end-time heap (replayed in
/// the exact pre-PR emission order). Passes are skipped entirely when
/// no arrival/completion/maintenance boundary occurred since the last
/// one — the pre-PR engine re-sorted all pending jobs on every event.
/// Job ids must be unique while a job is tracked (every in-tree caller
/// allocates unique ids).
#[derive(Debug)]
pub struct Scheduler {
    pub spec: ClusterSpec,
    nodes: Vec<NodeState>,
    clock: f64,
    /// Not-yet-due submissions, min-heap by (submit_s, id).
    future: BinaryHeap<Reverse<FutureJob>>,
    submit_seq: u64,
    /// Arrived-and-waiting jobs (submit_s ≤ clock) as flat [`DueJob`]
    /// rows, unordered; each pass sorts priority keys over it, started
    /// jobs leave via swap-remove.
    due: Vec<DueJob>,
    running: Vec<Running>,
    /// Job id → position in `running`, maintained across swap-removals
    /// so end-heap pops translate to positions in O(1).
    running_pos: HashMap<u64, usize>,
    /// Min-heap of (end_s, id, start generation) over running jobs:
    /// `next_event_time` is a peek, `complete_finished` pops instead of
    /// scanning every runner. The generation disambiguates entries left
    /// stale by outage kills; live (end, id) pairs are unique, so
    /// appending it never reorders live completions.
    ends: BinaryHeap<Reverse<(F64Ord, u64, u64)>>,
    /// Monotone start counter feeding `Running::start_seq`.
    start_seq: u64,
    records: Vec<JobRecord>,
    /// Fairshare: accumulated core-seconds per user, indexed by interned
    /// user id; lower usage → higher priority. The per-user cells see
    /// the exact update sequence the pre-SoA `BTreeMap<String, f64>`
    /// did, so every f64 is bit-identical.
    usage: Vec<f64>,
    /// Interned user names, indexed by [`DueJob::user`].
    user_names: Vec<String>,
    /// User name → interned id (keyed access only — never iterated).
    user_ids: HashMap<String, u32>,
    maintenance: Vec<Maintenance>,
    /// Running count per array id (for `%max_concurrent`).
    array_running: BTreeMap<u64, u32>,
    core_seconds_capacity: f64,
    core_seconds_used: f64,
    /// Submissions arrived since the last scheduling pass — lets
    /// [`Self::next_event_time`] report "a scheduling attempt is due
    /// now" exactly once instead of livelocking on blocked jobs.
    needs_schedule: bool,
    /// Cluster state changed since the last completed pass (arrival,
    /// completion, maintenance boundary). A pass without a change can
    /// start nothing — the backfill window only narrows as the clock
    /// advances and resources only free at completions — so it is
    /// skipped wholesale.
    sched_dirty: bool,
    /// Scratch node states for the release skyline (no per-call clone).
    skyline: Vec<NodeState>,
    /// In-engine failure injection; `None` = the fault-free engine.
    faults: Option<Injection>,
    /// Job id → retry count so far (only jobs with ≥ 1 failed attempt).
    attempts: HashMap<u64, u32>,
    /// Every failed attempt, in completion-processing order.
    fault_events: Vec<FaultEvent>,
    /// (job id, fail time) of timed-out attempts awaiting an external
    /// re-stage + resubmit ([`Injection::park_timeouts`]).
    parked: Vec<(u64, f64)>,
    /// Jobs dropped after exhausting retries.
    aborted: Vec<u64>,
    /// Cluster outage windows (DESIGN.md §15); empty = immortal cluster.
    outages: Vec<OutageWindow>,
    /// Onset-processed flag per window, aligned with `outages`.
    outage_fired: Vec<bool>,
    /// Requeue delay for attempts killed at a [`OutageMode::Down`] onset.
    outage_backoff_s: f64,
    /// Queued jobs released to the planner at onsets: (job id, onset
    /// time). Drained by [`Self::take_orphans`]; undrained orphans drop
    /// out of the simulation like parked jobs without a driver.
    orphans: Vec<(u64, f64)>,
    /// Running attempts killed at `Down` onsets.
    outage_killed: u64,
    /// Allocation seconds wasted by outage-killed attempts.
    outage_wasted_s: f64,
    /// Scheduling policy. Set it before submitting work: the dirty-gated
    /// pass skipping assumes the policy is fixed for a simulation run.
    pub policy: Policy,
}

impl Scheduler {
    pub fn new(spec: ClusterSpec) -> Self {
        Self::with_policy(spec, Policy::default())
    }

    pub fn with_policy(spec: ClusterSpec, policy: Policy) -> Self {
        let nodes: Vec<NodeState> = spec
            .nodes
            .iter()
            .map(|n| NodeState {
                free_cores: n.cores,
                free_ram_gb: n.ram_gb,
            })
            .collect();
        Self {
            nodes,
            clock: 0.0,
            future: BinaryHeap::new(),
            submit_seq: 0,
            due: Vec::new(),
            running: Vec::new(),
            running_pos: HashMap::new(),
            ends: BinaryHeap::new(),
            start_seq: 0,
            records: Vec::new(),
            usage: Vec::new(),
            user_names: Vec::new(),
            user_ids: HashMap::new(),
            maintenance: Vec::new(),
            array_running: BTreeMap::new(),
            core_seconds_capacity: 0.0,
            core_seconds_used: 0.0,
            needs_schedule: false,
            sched_dirty: false,
            skyline: Vec::new(),
            faults: None,
            attempts: HashMap::new(),
            fault_events: Vec::new(),
            parked: Vec::new(),
            aborted: Vec::new(),
            outages: Vec::new(),
            outage_fired: Vec::new(),
            outage_backoff_s: 0.0,
            orphans: Vec::new(),
            outage_killed: 0,
            outage_wasted_s: 0.0,
            policy,
            spec,
        }
    }

    /// Install the cluster's outage windows (before submitting work).
    /// Inside a window no job starts; at each window's onset every
    /// queued job is released back to the planner ([`Self::take_orphans`])
    /// and — under [`OutageMode::Down`] — every running attempt is
    /// killed (progress wasted) and requeued after `kill_backoff_s`.
    /// An empty schedule is bit-identical to never calling this.
    pub fn set_outages(&mut self, windows: Vec<OutageWindow>, kill_backoff_s: f64) {
        for w in &windows {
            assert!(
                w.start_s.is_finite() && w.end_s.is_finite() && w.start_s >= 0.0,
                "outage window bounds must be finite and ≥ 0"
            );
            assert!(w.end_s > w.start_s, "outage window end must exceed start");
        }
        assert!(
            kill_backoff_s.is_finite() && kill_backoff_s >= 0.0,
            "kill backoff must be finite and ≥ 0"
        );
        assert!(
            self.records.is_empty()
                && self.running.is_empty()
                && self.due.is_empty()
                && self.future.is_empty(),
            "set_outages must precede all submissions"
        );
        self.outage_fired = vec![false; windows.len()];
        self.outages = windows;
        self.outage_backoff_s = kill_backoff_s;
    }

    /// Drain (job id, onset time) pairs released by outage onsets. The
    /// driver owns them now: re-place (and re-stage) each job or it
    /// never finishes.
    pub fn take_orphans(&mut self) -> Vec<(u64, f64)> {
        std::mem::take(&mut self.orphans)
    }

    /// Running attempts killed at [`OutageMode::Down`] onsets so far.
    pub fn outage_killed(&self) -> u64 {
        self.outage_killed
    }

    /// Allocation seconds wasted by outage-killed attempts so far.
    pub fn outage_wasted_s(&self) -> f64 {
        self.outage_wasted_s
    }

    /// True if `t` falls inside any outage window (no job starts).
    fn in_outage_at(&self, t: f64) -> bool {
        self.outages.iter().any(|w| t >= w.start_s && t < w.end_s)
    }

    /// Fire every outage onset the clock has reached, once per window:
    /// orphan the queued jobs back to the planner; under
    /// [`OutageMode::Down`] also kill the running attempts — their
    /// progress is wasted, their remaining allocation is refunded, and
    /// they requeue locally after the kill backoff. A no-op without an
    /// outage schedule.
    fn process_outage_onsets(&mut self) {
        for k in 0..self.outages.len() {
            if self.outage_fired[k] || self.clock < self.outages[k].start_s {
                continue;
            }
            self.outage_fired[k] = true;
            let w = self.outages[k];
            for job in std::mem::take(&mut self.due) {
                self.orphans.push((job.id, self.clock));
            }
            self.sched_dirty = true;
            if w.mode == OutageMode::Down {
                for r in std::mem::take(&mut self.running) {
                    self.running_pos.remove(&r.job.id);
                    self.nodes[r.node].free_cores += r.job.cores;
                    self.nodes[r.node].free_ram_gb += r.job.ram_gb;
                    if let Some(h) = &r.job.array {
                        if let Some(c) = self.array_running.get_mut(&h.array_id) {
                            *c -= 1;
                        }
                    }
                    // the attempt was charged for its full allocation at
                    // start; refund the part the kill never let it hold
                    let unheld = (r.end_s - self.clock).max(0.0) * r.job.cores as f64;
                    self.core_seconds_used -= unheld;
                    // the row's user id indexes the fairshare cell directly
                    self.usage[r.job.user as usize] -= unheld;
                    self.outage_killed += 1;
                    self.outage_wasted_s += self.clock - r.start_s;
                    let mut job = r.job;
                    job.submit_s = self.clock + self.outage_backoff_s;
                    self.submit_row(job);
                    // the killed attempt's ends-heap entry is now stale;
                    // its start_seq no longer matches and is skipped
                }
            }
        }
    }

    /// Enable in-engine failure injection (before submitting work). The
    /// model must be valid ([`crate::faults::FaultModel::validate`]) —
    /// an over-unity rate set would silently truncate the Timeout band.
    pub fn set_faults(&mut self, inj: Injection) {
        if let Err(e) = inj.model.validate() {
            panic!("Scheduler::set_faults: {e}");
        }
        assert!(
            self.records.is_empty()
                && self.running.is_empty()
                && self.due.is_empty()
                && self.future.is_empty(),
            "set_faults must precede all submissions"
        );
        self.faults = Some(inj);
    }

    /// Failed-attempt events recorded so far (empty without injection).
    pub fn fault_events(&self) -> &[FaultEvent] {
        &self.fault_events
    }

    /// Jobs dropped after exhausting their retries.
    pub fn aborted_ids(&self) -> &[u64] {
        &self.aborted
    }

    /// Allocation seconds consumed by failed attempts so far.
    pub fn wasted_alloc_s(&self) -> f64 {
        self.fault_events.iter().map(|e| e.wasted_s).sum()
    }

    /// Drain (job id, fail time) pairs parked by timed-out attempts
    /// ([`Injection::park_timeouts`]). The driver owns them now: it must
    /// re-stage the job's inputs and resubmit (same id — the retry count
    /// is retained), or the job never finishes. Without a driver, parked
    /// jobs simply drop out of the simulation like aborts.
    pub fn take_parked(&mut self) -> Vec<(u64, f64)> {
        std::mem::take(&mut self.parked)
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn add_maintenance(&mut self, w: Maintenance) {
        self.maintenance.push(w);
        // conservative: a new window can only block starts, but re-run
        // the next pass rather than reason about which one
        self.sched_dirty = true;
    }

    /// True if `t` falls in a maintenance window (no job starts).
    pub fn in_maintenance(&self, t: f64) -> bool {
        self.maintenance.iter().any(|w| t >= w.start_s && t < w.end_s)
    }

    /// Intern a user name to its dense id, allocating a fresh fairshare
    /// cell on first sight.
    fn intern_user(&mut self, name: &str) -> u32 {
        if let Some(&uid) = self.user_ids.get(name) {
            return uid;
        }
        let uid = self.user_names.len() as u32;
        self.user_names.push(name.to_string());
        self.user_ids.insert(name.to_string(), uid);
        self.usage.push(0.0);
        uid
    }

    pub fn submit(&mut self, job: SimJob) {
        let row = DueJob {
            id: job.id,
            user: self.intern_user(&job.user),
            cores: job.cores,
            ram_gb: job.ram_gb,
            duration_s: job.duration_s,
            submit_s: job.submit_s,
            array: job.array,
        };
        self.submit_row(row);
    }

    /// Requeue an already-interned row (fault retries, outage kills):
    /// the internal resubmission path allocates nothing — the row IS
    /// the arena-backed form of the job.
    fn submit_row(&mut self, row: DueJob) {
        assert!(
            row.submit_s >= self.clock,
            "cannot submit in the past (job {} at {}, clock {})",
            row.id,
            row.submit_s,
            self.clock
        );
        self.needs_schedule = true;
        self.sched_dirty = true;
        if row.submit_s <= self.clock {
            self.due.push(row);
        } else {
            self.submit_seq += 1;
            self.future.push(Reverse(FutureJob {
                key: (F64Ord(row.submit_s), row.id, self.submit_seq),
                job: row,
            }));
        }
    }

    pub fn pending_count(&self) -> usize {
        self.due.len() + self.future.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn records(&self) -> &[JobRecord] {
        &self.records
    }

    /// Cluster-wide core utilization over simulated time so far (0..1) —
    /// the §2.3 resource monitor's compute view.
    pub fn utilization(&self) -> f64 {
        if self.core_seconds_capacity <= 0.0 {
            return 0.0;
        }
        self.core_seconds_used / self.core_seconds_capacity
    }

    fn priority(&self, job: &DueJob) -> (f64, f64, u64) {
        // fairshare first (lower accumulated usage wins), then FIFO.
        let usage = if self.policy.fairshare {
            self.usage[job.user as usize]
        } else {
            0.0
        };
        (usage, job.submit_s, job.id)
    }

    fn fits_on(&self, node: usize, job: &DueJob) -> bool {
        self.nodes[node].free_cores >= job.cores && self.nodes[node].free_ram_gb >= job.ram_gb
    }

    fn first_fit(&self, job: &DueJob) -> Option<usize> {
        (0..self.nodes.len()).find(|&n| self.fits_on(n, job))
    }

    fn array_ok(&self, job: &DueJob) -> bool {
        match &job.array {
            None => true,
            Some(h) => self.array_running.get(&h.array_id).copied().unwrap_or(0) < h.max_concurrent,
        }
    }

    fn start_job(&mut self, job: DueJob, node: usize) {
        let attempt = self.attempts.get(&job.id).copied().unwrap_or(0);
        let fail = match &self.faults {
            Some(inj) => inj.sample(job.id, attempt),
            None => None,
        };
        // A failing attempt holds its allocation only until the failure
        // surfaces. Fault-free (or zero-rate model) `alloc_s` IS
        // `job.duration_s` — no scaling touches the f64, so the engine
        // stays bit-identical to the pre-injection one.
        let alloc_s = match fail {
            Some(mode) => job.duration_s * mode.wasted_fraction(),
            None => job.duration_s,
        };
        self.nodes[node].free_cores -= job.cores;
        self.nodes[node].free_ram_gb -= job.ram_gb;
        if let Some(h) = &job.array {
            *self.array_running.entry(h.array_id).or_insert(0) += 1;
        }
        self.usage[job.user as usize] += job.cores as f64 * alloc_s;
        self.core_seconds_used += job.cores as f64 * alloc_s;
        let end_s = self.clock + alloc_s;
        self.start_seq += 1;
        self.ends.push(Reverse((F64Ord(end_s), job.id, self.start_seq)));
        self.running_pos.insert(job.id, self.running.len());
        // allocation-free start: the attempt holds the flat row; the
        // owned SimJob is materialized only if this attempt completes
        // and emits a JobRecord
        self.running.push(Running {
            job,
            node,
            start_s: self.clock,
            end_s,
            attempt,
            fail,
            start_seq: self.start_seq,
        });
    }

    /// Materialize the owned [`SimJob`] a [`JobRecord`] needs from its
    /// flat row — the single remaining per-*record* allocation (the
    /// user `String` clone out of the interned-name arena); starts,
    /// retries, and outage kills are allocation-free.
    fn materialize(&self, row: DueJob) -> SimJob {
        SimJob {
            id: row.id,
            user: self.user_names[row.user as usize].clone(),
            cores: row.cores,
            ram_gb: row.ram_gb,
            duration_s: row.duration_s,
            submit_s: row.submit_s,
            array: row.array,
        }
    }

    /// Migrate heap-ordered arrivals whose submit time has passed into
    /// the due bag (independent of maintenance — bookkeeping only).
    fn drain_due(&mut self) {
        while let Some(Reverse(f)) = self.future.peek() {
            if f.key.0 .0 > self.clock {
                break;
            }
            let Reverse(f) = self.future.pop().expect("peeked entry");
            self.due.push(f.job);
            self.sched_dirty = true;
        }
    }

    /// Try to start pending jobs (priority order + EASY backfill): the
    /// highest-priority blocked job reserves its earliest start; later jobs
    /// may start now only if they finish before that reservation (or don't
    /// take its resources — approximated by the end-before test).
    ///
    /// The pass is skipped when nothing changed since the last one
    /// (`sched_dirty`): with resources and arrivals unchanged and the
    /// backfill window only narrowing over time, a re-run provably
    /// starts nothing.
    fn schedule(&mut self) {
        self.process_outage_onsets();
        self.drain_due();
        if self.in_maintenance(self.clock) || self.in_outage_at(self.clock) {
            return;
        }
        debug_assert!(
            !self.needs_schedule || self.sched_dirty,
            "needs_schedule implies a dirty pass"
        );
        if !self.sched_dirty {
            return;
        }
        self.sched_dirty = false;
        self.needs_schedule = false;
        // priority keys computed ONCE per job, not per comparison (the
        // BTreeMap lookup inside priority() dominated the sort before;
        // see EXPERIMENTS.md §Perf L3)
        let mut order: Vec<(usize, (f64, f64, u64))> = (0..self.due.len())
            .map(|i| (i, self.priority(&self.due[i])))
            .collect();
        // total_cmp keys: priority() components are finite and ids are
        // unique, so the order is total — identical to the old
        // partial_cmp comparator, minus its NaN panic path
        order.sort_unstable_by_key(|&(_, (u, s, id))| (F64Ord(u), F64Ord(s), id));

        let mut started: Vec<usize> = Vec::new();
        let mut shadow: Option<f64> = None; // head job's reserved start
        // perf (EXPERIMENTS.md §Perf L3): memoize requirement pairs that
        // failed to fit this pass — any job needing ≥ that much also fails,
        // so the O(nodes) scan runs once per distinct requirement class
        // instead of once per pending job.
        let mut failed_reqs: Vec<(u32, u32)> = Vec::new();
        for &(idx, _) in &order {
            // a flat Copy row — the pre-SoA engine cloned an owned
            // SimJob (heap String) per examined job per pass here
            let job = self.due[idx];
            if !self.array_ok(&job) {
                continue;
            }
            // cheap rejections before the node scan
            if let Some(sh) = shadow {
                if !self.policy.backfill || self.clock + job.duration_s > sh {
                    continue;
                }
            }
            if failed_reqs
                .iter()
                .any(|&(c, r)| job.cores >= c && job.ram_gb >= r)
            {
                if shadow.is_none() {
                    shadow = Some(self.earliest_start_estimate(&job));
                }
                continue;
            }
            match self.first_fit(&job) {
                Some(node) => {
                    self.start_job(job, node);
                    started.push(idx);
                }
                None => {
                    failed_reqs.push((job.cores, job.ram_gb));
                    if shadow.is_none() {
                        shadow = Some(self.earliest_start_estimate(&job));
                    }
                }
            }
        }
        // swap-list removal: positions descending, so each swap_remove
        // pulls a not-yet-removed tail element into the hole (the due
        // bag is unordered — the pre-PR O(n) ordered Vec::remove per
        // started job is gone)
        started.sort_unstable_by(|a, b| b.cmp(a));
        for idx in started {
            self.due.swap_remove(idx);
        }
    }

    /// Earliest time the blocked job could start, assuming running jobs
    /// release resources at their end times (ignores other pending jobs —
    /// the EASY reservation).
    ///
    /// Release skyline: callers only ask when *no* node currently fits
    /// the job, and a release only improves the node it lands on, so
    /// after each release just that node needs re-checking —
    /// O(R log R + R + N) over a reused scratch buffer, versus the
    /// pre-PR full-node rescan per release (O(R·N)) on a fresh clone.
    fn earliest_start_estimate(&mut self, job: &DueJob) -> f64 {
        debug_assert!(
            self.first_fit(job).is_none(),
            "estimate asked while the job already fits"
        );
        let mut frees: Vec<(f64, usize, u32, u32)> = self
            .running
            .iter()
            .map(|r| (r.end_s, r.node, r.job.cores, r.job.ram_gb))
            .collect();
        frees.sort_by_key(|&(end, ..)| F64Ord(end));
        self.skyline.clear();
        self.skyline.extend_from_slice(&self.nodes);
        for (end, node, cores, ram) in frees {
            self.skyline[node].free_cores += cores;
            self.skyline[node].free_ram_gb += ram;
            if self.skyline[node].free_cores >= job.cores
                && self.skyline[node].free_ram_gb >= job.ram_gb
            {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Time of the next event (arrival, completion, or maintenance end),
    /// or `Some(clock)` when submissions arrived since the last
    /// scheduling pass and could be due immediately. `None` means the
    /// simulation cannot progress (drained, or deadlocked on an
    /// oversized job). Used by the staged-campaign co-simulation
    /// ([`crate::coordinator::staged`]) to interleave this scheduler
    /// with the transfer scheduler without overshooting either.
    /// Heap peeks — O(maintenance windows), no job scans.
    pub fn next_event_time(&self) -> Option<f64> {
        if self.needs_schedule
            && !self.in_maintenance(self.clock)
            && !self.in_outage_at(self.clock)
            && !self.due.is_empty()
        {
            return Some(self.clock);
        }
        let next_end = match self.ends.peek() {
            Some(&Reverse((end, ..))) => end.0,
            None => f64::INFINITY,
        };
        let next_arrival = match self.future.peek() {
            Some(Reverse(f)) => f.key.0 .0,
            None => f64::INFINITY,
        };
        // if blocked purely by maintenance or throttle, jump to next boundary
        let next_maint_end = self
            .maintenance
            .iter()
            .filter(|w| w.end_s > self.clock && w.start_s <= self.clock)
            .map(|w| w.end_s)
            .fold(f64::INFINITY, f64::min);
        // outage boundaries are events too: onsets must fire exactly on
        // time (they orphan the queue), and blocked starts resume at
        // each window's end
        let mut next_outage = f64::INFINITY;
        for (k, w) in self.outages.iter().enumerate() {
            if !self.outage_fired[k] && w.start_s > self.clock {
                next_outage = next_outage.min(w.start_s);
            }
            if w.start_s <= self.clock && w.end_s > self.clock {
                next_outage = next_outage.min(w.end_s);
            }
        }
        let next_t = next_end.min(next_arrival).min(next_maint_end).min(next_outage);
        next_t.is_finite().then_some(next_t)
    }

    /// Release resources of every running job whose end time has passed
    /// and append its [`JobRecord`].
    ///
    /// Completions pop off the end-time heap; the emission replays the
    /// pre-PR swap-remove scan (smallest position first, a tail element
    /// swapped into the hole is re-examined at that index) so the record
    /// order — and therefore every downstream consumer — is
    /// byte-identical to [`crate::sim_legacy`].
    fn complete_finished(&mut self) {
        let mut due_pos: BTreeSet<usize> = BTreeSet::new();
        while let Some(&Reverse((end, id, seq))) = self.ends.peek() {
            if end.0 > self.clock {
                break;
            }
            self.ends.pop();
            // an outage kill leaves its attempt's entry behind: the job
            // is gone from `running` (or re-running under a newer
            // generation) — skip the stale entry either way
            let Some(&pos) = self.running_pos.get(&id) else {
                debug_assert!(!self.outages.is_empty(), "running job indexed");
                continue;
            };
            if self.running[pos].start_seq != seq {
                continue;
            }
            due_pos.insert(pos);
        }
        while let Some(pos) = due_pos.pop_first() {
            let last = self.running.len() - 1;
            let r = self.running.swap_remove(pos);
            self.running_pos.remove(&r.job.id);
            if pos != last {
                let moved = self.running[pos].job.id;
                self.running_pos.insert(moved, pos);
                if due_pos.remove(&last) {
                    due_pos.insert(pos);
                }
            }
            self.nodes[r.node].free_cores += r.job.cores;
            self.nodes[r.node].free_ram_gb += r.job.ram_gb;
            if let Some(h) = &r.job.array {
                if let Some(c) = self.array_running.get_mut(&h.array_id) {
                    *c -= 1;
                }
            }
            self.sched_dirty = true;
            match r.fail {
                None => {
                    let job = self.materialize(r.job);
                    self.records.push(JobRecord {
                        start_s: r.start_s,
                        end_s: r.end_s,
                        node: r.node,
                        job,
                    });
                }
                Some(mode) => self.fail_attempt(r, mode),
            }
        }
    }

    /// A sampled-to-fail attempt just released its allocation: requeue
    /// with backoff, park for an external re-stage (timeouts under
    /// [`Injection::park_timeouts`]), or abort on exhausted retries —
    /// and record the [`FaultEvent`] either way.
    fn fail_attempt(&mut self, r: Running, mode: FailureMode) {
        let inj = self.faults.expect("failing attempt implies an injection config");
        let Running {
            job,
            attempt,
            start_s,
            end_s,
            ..
        } = r;
        let wasted_s = end_s - start_s;
        let id = job.id;
        let action = inj.disposition(attempt, mode);
        match action {
            FaultAction::Aborted => {
                self.attempts.remove(&id);
                self.aborted.push(id);
            }
            FaultAction::Parked => {
                // a timeout wipes node-local scratch: the driver must
                // re-stage inputs before resubmitting this id
                self.attempts.insert(id, attempt + 1);
                self.parked.push((id, end_s));
            }
            FaultAction::Requeued => {
                self.attempts.insert(id, attempt + 1);
                let mut job = job;
                job.submit_s = (end_s + inj.backoff_s(attempt)).max(self.clock);
                self.submit_row(job);
            }
        }
        self.fault_events.push(FaultEvent {
            id,
            attempt,
            mode,
            fail_s: end_s,
            wasted_s,
            action,
        });
    }

    /// Advance the clock, accounting capacity and flagging a pass when a
    /// maintenance window ended inside the step.
    fn tick_to(&mut self, next_t: f64) {
        let dt = next_t - self.clock;
        self.core_seconds_capacity += self.spec.total_cores() as f64 * dt.max(0.0);
        let was_maint = self.in_maintenance(self.clock);
        let was_out = self.in_outage_at(self.clock);
        self.clock = self.clock.max(next_t);
        if was_maint && !self.in_maintenance(self.clock) {
            self.sched_dirty = true;
        }
        if was_out && !self.in_outage_at(self.clock) {
            // an outage window ended inside the step: blocked jobs may start
            self.sched_dirty = true;
        }
    }

    /// Advance to the next event (arrival, completion, or maintenance end);
    /// returns false when nothing remains.
    pub fn step(&mut self) -> bool {
        self.schedule();
        let Some(next_t) = self.next_event_time() else {
            // nothing running, nothing arriving: if pending non-empty we are
            // deadlocked (job larger than any node) — surface by returning
            // false with pending jobs left.
            return false;
        };
        self.tick_to(next_t);
        self.complete_finished();
        true
    }

    /// Advance the simulation to absolute time `t`, processing every
    /// event up to and including `t`; the clock ends at exactly `t`.
    /// Unlike [`Self::step`] this never overshoots, so the staged
    /// campaign co-simulation can submit jobs discovered by the transfer
    /// scheduler at times between slurm events.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t + 1e-9 >= self.clock,
            "cannot advance backwards (to {t}, clock {})",
            self.clock
        );
        loop {
            self.schedule();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.tick_to(target);
            self.complete_finished();
            if target + 1e-9 >= t {
                self.schedule();
                return;
            }
        }
    }

    /// Run until all submitted jobs have completed (or deadlock).
    pub fn run_to_completion(&mut self) -> &[JobRecord] {
        while !self.due.is_empty() || !self.future.is_empty() || !self.running.is_empty() {
            if !self.step() {
                break;
            }
        }
        &self.records
    }

    /// Makespan of everything completed so far.
    pub fn makespan(&self) -> f64 {
        self.records.iter().map(|r| r.end_s).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, cores: u32, dur: f64, submit: f64) -> SimJob {
        SimJob {
            id,
            user: "u".into(),
            cores,
            ram_gb: 1,
            duration_s: dur,
            submit_s: submit,
            array: None,
        }
    }

    #[test]
    fn single_job_runs_immediately() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 2, 100.0, 0.0));
        let recs = s.run_to_completion();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].start_s, 0.0);
        assert_eq!(recs[0].end_s, 100.0);
    }

    #[test]
    fn capacity_forces_queueing() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        assert_eq!(r2.start_s, 100.0);
        assert_eq!(s.makespan(), 200.0);
    }

    #[test]
    fn parallel_when_fits() {
        let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        s.run_to_completion();
        assert_eq!(s.makespan(), 100.0);
    }

    // Heap tie-break audit (DESIGN.md §16): every pop site's key is
    // total, so equal primary keys resolve by the pinned secondary key
    // — never by insertion order or user-name interning order.

    #[test]
    fn future_heap_ties_drain_by_id_not_submission_order() {
        // equal submit instants on a one-job-at-a-time cluster: the
        // (submit_s, id, seq) arrival key + the (usage, submit_s, id)
        // priority key start ids ascending however they were submitted
        let run = |ids: &[u64]| {
            let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
            for &id in ids {
                s.submit(job(id, 4, 50.0, 10.0));
            }
            s.run_to_completion().to_vec()
        };
        let fwd = run(&[1, 2, 3]);
        let rev = run(&[3, 2, 1]);
        assert_eq!(fwd, rev, "insertion order must not leak through equal keys");
        let ids: Vec<u64> = fwd.iter().map(|r| r.job.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn fairshare_ties_ignore_user_interning_order() {
        // distinct users with identical (zero) usage: the tie falls to
        // (submit_s, id), not to the interned user ids — user "z"
        // interns first here but its job id loses the tie
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        let mut j2 = job(2, 4, 50.0, 0.0);
        j2.user = "z".into();
        let mut j1 = job(1, 4, 50.0, 0.0);
        j1.user = "a".into();
        s.submit(j2);
        s.submit(j1);
        let recs = s.run_to_completion();
        assert_eq!(recs[0].job.id, 1);
        assert_eq!(recs[1].job.id, 2);
    }

    #[test]
    fn ends_heap_ties_emit_in_start_order() {
        // both attempts end at exactly t=100: the (end_s, id, start_seq)
        // key plus the ascending-position replay emit id 1 (started
        // first) before id 2
        let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
        s.submit(job(2, 4, 100.0, 0.0));
        s.submit(job(1, 4, 100.0, 0.0));
        let recs = s.run_to_completion();
        let ids: Vec<u64> = recs.iter().map(|r| r.job.id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(recs[0].end_s, recs[1].end_s);
    }

    #[test]
    fn ram_constraint_respected() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 8, 16));
        let mut j1 = job(1, 1, 100.0, 0.0);
        j1.ram_gb = 12;
        let mut j2 = job(2, 1, 100.0, 0.0);
        j2.ram_gb = 12;
        s.submit(j1);
        s.submit(j2);
        s.run_to_completion();
        assert_eq!(s.makespan(), 200.0); // RAM serializes despite free cores
    }

    #[test]
    fn backfill_fills_hole_without_delaying_head() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0)); // runs now
        s.submit(job(2, 4, 100.0, 0.0)); // head blocked until t=100
        s.submit(job(3, 1, 10.0, 0.0)); // can't fit (0 cores free) …
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert_eq!(r2.start_s, 100.0, "head job must not be delayed");
        assert!(r3.start_s >= 100.0);
    }

    #[test]
    fn backfill_uses_free_cores_when_it_ends_before_shadow() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 2, 100.0, 0.0)); // 2 cores busy until 100
        s.submit(job(2, 4, 50.0, 0.0)); // needs all 4 → blocked to t=100
        s.submit(job(3, 1, 20.0, 0.0)); // fits now, ends (20) before 100 → backfill
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert_eq!(r3.start_s, 0.0, "short job should backfill");
        assert_eq!(r2.start_s, 100.0);
    }

    #[test]
    fn array_throttle_caps_concurrency() {
        let mut s = Scheduler::new(ClusterSpec::small(10, 4, 16));
        let h = ArrayHandle {
            array_id: 7,
            max_concurrent: 2,
        };
        for i in 0..6 {
            let mut j = job(i, 1, 100.0, 0.0);
            j.array = Some(h);
            s.submit(j);
        }
        s.run_to_completion();
        // 6 jobs, 2 at a time → 3 waves of 100 s
        assert_eq!(s.makespan(), 300.0);
    }

    #[test]
    fn maintenance_delays_starts() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.add_maintenance(Maintenance {
            start_s: 0.0,
            end_s: 500.0,
        });
        s.submit(job(1, 1, 10.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        assert_eq!(recs[0].start_s, 500.0);
    }

    #[test]
    fn fairshare_prefers_light_user() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        // heavy user builds usage
        let mut j1 = job(1, 4, 1000.0, 0.0);
        j1.user = "heavy".into();
        s.submit(j1);
        // at t=1000 both users have one job pending; light should win
        let mut j2 = job(2, 4, 10.0, 1.0);
        j2.user = "heavy".into();
        let mut j3 = job(3, 4, 10.0, 2.0);
        j3.user = "light".into();
        s.submit(j2);
        s.submit(j3);
        let recs = s.run_to_completion().to_vec();
        let heavy2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let light = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(
            light.start_s < heavy2.start_s,
            "light {} vs heavy {}",
            light.start_s,
            heavy2.start_s
        );
    }

    #[test]
    fn oversized_job_deadlocks_gracefully() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 8, 10.0, 0.0)); // bigger than any node
        s.run_to_completion();
        assert_eq!(s.records().len(), 0);
        assert_eq!(s.pending_count(), 1);
    }

    #[test]
    fn no_backfill_serializes_behind_blocked_head() {
        // same scenario as backfill_uses_free_cores…, with backfill off the
        // short job must wait behind the blocked 4-core job.
        let mut s = Scheduler::with_policy(
            ClusterSpec::small(1, 4, 16),
            Policy {
                fairshare: true,
                backfill: false,
            },
        );
        s.submit(job(1, 2, 100.0, 0.0));
        s.submit(job(2, 4, 50.0, 0.0));
        s.submit(job(3, 1, 20.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(r3.start_s >= 100.0, "short job must NOT backfill: {}", r3.start_s);
    }

    #[test]
    fn fifo_policy_ignores_usage() {
        let mut s = Scheduler::with_policy(
            ClusterSpec::small(1, 4, 16),
            Policy {
                fairshare: false,
                backfill: true,
            },
        );
        let mut j1 = job(1, 4, 1000.0, 0.0);
        j1.user = "heavy".into();
        s.submit(j1);
        let mut j2 = job(2, 4, 10.0, 1.0);
        j2.user = "heavy".into();
        let mut j3 = job(3, 4, 10.0, 2.0);
        j3.user = "light".into();
        s.submit(j2);
        s.submit(j3);
        let recs = s.run_to_completion().to_vec();
        let heavy2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let light = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(heavy2.start_s < light.start_s, "FIFO: earlier submit wins");
    }

    #[test]
    fn accre_spec_scale() {
        let c = ClusterSpec::accre();
        assert_eq!(c.nodes.len(), 750);
        let cores = c.total_cores();
        assert!((20_000..21_000).contains(&cores), "{cores}");
    }

    #[test]
    fn concurrent_slots_bound_by_binding_resource() {
        let c = ClusterSpec::small(3, 8, 16);
        assert_eq!(c.concurrent_slots(1, 1), 3 * 8, "core-bound");
        assert_eq!(c.concurrent_slots(1, 8), 3 * 2, "RAM-bound");
        assert_eq!(c.concurrent_slots(4, 4), 3 * 2, "cores bind before RAM");
        assert_eq!(c.concurrent_slots(16, 1), 0, "oversized jobs fit nowhere");
        assert_eq!(c.concurrent_slots(1, 0), 3 * 8, "zero RAM = unconstrained");
        assert_eq!(ClusterSpec::accre().concurrent_slots(1, 4), 750 * 27);
    }

    #[test]
    fn advance_to_processes_events_without_overshoot() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        assert_eq!(s.next_event_time(), Some(0.0), "scheduling due now");
        s.advance_to(50.0);
        assert_eq!(s.clock(), 50.0);
        assert_eq!(s.records().len(), 0);
        assert_eq!(s.running_count(), 1);
        s.advance_to(100.0);
        assert_eq!(s.records().len(), 1, "first job completes at 100");
        assert_eq!(s.running_count(), 1, "second starts at 100");
        s.advance_to(250.0);
        assert_eq!(s.records().len(), 2);
        assert_eq!(s.makespan(), 200.0);
        // mid-simulation submission at the current clock is legal
        s.submit(job(3, 1, 10.0, 250.0));
        assert_eq!(s.next_event_time(), Some(250.0));
        s.advance_to(260.0);
        assert_eq!(s.records().len(), 3);
    }

    #[test]
    fn utilization_tracks_usage() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.run_to_completion();
        assert!((s.utilization() - 1.0).abs() < 1e-9, "{}", s.utilization());
    }

    #[test]
    fn long_arrival_storm_stays_fast() {
        // 20k one-core jobs trickling into a 64-core cluster at roughly
        // its drain rate: the pre-PR engine re-scanned all 20k pending
        // submissions inside every next_event_time call; the arrival
        // heap + end-time heap + dirty-gated passes keep this
        // test-speed in debug builds.
        let mut s = Scheduler::new(ClusterSpec::small(8, 8, 64));
        for id in 0..20_000u64 {
            s.submit(job(id, 1, 30.0, (id / 2) as f64));
        }
        s.run_to_completion();
        assert_eq!(s.records().len(), 20_000);
        assert!(s.utilization() > 0.0);
    }

    use crate::faults::{FaultAction, FaultModel, Injection};

    /// Model in which every attempt fails with `mode` (deterministic).
    fn always(mode: FailureMode) -> FaultModel {
        let mut m = FaultModel::none();
        match mode {
            FailureMode::ChecksumMismatch => m.p_checksum = 1.0,
            FailureMode::PipelineError => m.p_pipeline = 1.0,
            FailureMode::NodeFailure => m.p_node = 1.0,
            FailureMode::Timeout => m.p_timeout = 1.0,
        }
        m
    }

    #[test]
    fn zero_rate_injection_changes_nothing() {
        let run = |inject: bool| {
            let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
            if inject {
                s.set_faults(Injection::new(FaultModel::none(), 3, 99));
            }
            for id in 0..40u64 {
                s.submit(job(id, 1 + (id % 4) as u32, 50.0 + id as f64, (id / 3) as f64));
            }
            s.run_to_completion();
            (s.records().to_vec(), s.makespan(), s.utilization())
        };
        let (plain_recs, plain_mk, plain_ut) = run(false);
        let (inj_recs, inj_mk, inj_ut) = run(true);
        assert_eq!(plain_recs, inj_recs, "zero-rate injection must be a no-op");
        assert_eq!(plain_mk, inj_mk);
        assert_eq!(plain_ut, inj_ut);
    }

    #[test]
    fn always_failing_job_retries_then_aborts() {
        // NodeFailure wastes exactly half the allocation (0.5 — exact in
        // f64), backoff base 10 s doubles per retry: fail times are
        // 50, 50+10+50 = 110, 110+20+50 = 180.
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.set_faults(Injection::new(always(FailureMode::NodeFailure), 2, 7).with_backoff(10.0));
        s.submit(job(1, 2, 100.0, 0.0));
        s.run_to_completion();
        assert!(s.records().is_empty(), "an always-failing job never completes");
        assert_eq!(s.aborted_ids(), &[1]);
        let fails: Vec<f64> = s.fault_events().iter().map(|e| e.fail_s).collect();
        assert_eq!(fails, vec![50.0, 110.0, 180.0]);
        assert!(s.fault_events().iter().all(|e| e.wasted_s == 50.0));
        assert_eq!(s.fault_events()[0].action, FaultAction::Requeued);
        assert_eq!(s.fault_events()[2].action, FaultAction::Aborted);
        assert_eq!(s.wasted_alloc_s(), 150.0);
        assert_eq!(s.pending_count(), 0, "aborted jobs leave the system");
    }

    #[test]
    fn failed_attempts_hold_slots_and_delay_others() {
        // one 4-core node; job 1's failing attempt occupies the node for
        // 50 s, so job 2 cannot start before t = 50 — the re-contention
        // the post-hoc model never produced.
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.set_faults(Injection::new(always(FailureMode::NodeFailure), 0, 3).with_backoff(0.0));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        s.run_to_completion();
        assert!(s.records().is_empty());
        let fails: Vec<(u64, f64)> = s.fault_events().iter().map(|e| (e.id, e.fail_s)).collect();
        assert_eq!(fails, vec![(1, 50.0), (2, 100.0)], "job 2 waited behind the failed slot");
        assert_eq!(s.aborted_ids(), &[1, 2]);
    }

    #[test]
    fn timeouts_park_for_external_restage() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.set_faults(
            Injection::new(always(FailureMode::Timeout), 1, 5)
                .with_backoff(0.0)
                .with_parked_timeouts(),
        );
        s.submit(job(9, 1, 100.0, 0.0));
        s.run_to_completion();
        // a timeout consumes the whole allocation, then parks
        assert_eq!(s.take_parked(), vec![(9, 100.0)]);
        assert!(s.take_parked().is_empty(), "drained");
        assert!(s.records().is_empty() && s.aborted_ids().is_empty());
        // the driver re-stages and resubmits; the retry count carried
        // over makes this the final attempt → abort, not park
        s.submit(job(9, 1, 100.0, 150.0));
        s.run_to_completion();
        assert_eq!(s.aborted_ids(), &[9]);
        assert!(s.take_parked().is_empty());
        assert_eq!(s.fault_events().len(), 2);
        assert_eq!(s.fault_events()[1].fail_s, 250.0);
        assert_eq!(s.fault_events()[1].attempt, 1);
    }

    #[test]
    fn injected_campaign_still_completes_with_retries() {
        // harsh rates with a generous retry budget: every job should
        // finish (abort probability 0.19⁶ ≈ 5e-5 per job), later than
        // the fault-free run, with utilization accounting the waste.
        let spec = ClusterSpec::small(4, 8, 64);
        let submit_all = |s: &mut Scheduler| {
            for id in 0..200u64 {
                let dur = 60.0 + (id % 11) as f64 * 30.0;
                s.submit(job(id, 1 + (id % 3) as u32, dur, (id / 4) as f64));
            }
        };
        let mut clean = Scheduler::new(spec.clone());
        submit_all(&mut clean);
        clean.run_to_completion();

        let mut faulty = Scheduler::new(spec);
        let inj = Injection::new(FaultModel::harsh().compute_only(), 5, 11).with_backoff(5.0);
        faulty.set_faults(inj);
        submit_all(&mut faulty);
        faulty.run_to_completion();

        assert_eq!(faulty.records().len() + faulty.aborted_ids().len(), 200);
        assert!(faulty.fault_events().len() > 5, "harsh rates must fail some attempts");
        assert!(faulty.wasted_alloc_s() > 0.0);
        assert!(
            faulty.makespan() > clean.makespan(),
            "retries must extend the makespan: {} vs {}",
            faulty.makespan(),
            clean.makespan()
        );
        // completed jobs carry their *successful* attempt's record only
        for r in faulty.records() {
            assert!(r.end_s - r.start_s > 0.0);
        }
    }

    fn window(mode: OutageMode, start_s: f64, end_s: f64) -> OutageWindow {
        OutageWindow { mode, start_s, end_s }
    }

    #[test]
    fn empty_outage_schedule_changes_nothing() {
        let run = |set: bool| {
            let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
            if set {
                s.set_outages(Vec::new(), 30.0);
            }
            for id in 0..40u64 {
                s.submit(job(id, 1 + (id % 4) as u32, 50.0 + id as f64, (id / 3) as f64));
            }
            s.run_to_completion();
            (s.records().to_vec(), s.makespan(), s.utilization())
        };
        assert_eq!(run(false), run(true), "empty schedule must be a no-op");
    }

    #[test]
    fn drain_window_blocks_starts_and_orphans_the_queue() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.set_outages(vec![window(OutageMode::Drain, 50.0, 200.0)], 0.0);
        s.submit(job(1, 4, 100.0, 0.0)); // starts at 0, survives the drain
        s.submit(job(2, 4, 100.0, 10.0)); // queued at the onset → orphaned
        s.submit(job(3, 4, 50.0, 70.0)); // arrives inside the window → waits
        s.run_to_completion();
        assert_eq!(s.take_orphans(), vec![(2, 50.0)]);
        assert!(s.take_orphans().is_empty(), "drained");
        assert_eq!(s.outage_killed(), 0);
        let r1 = s.records().iter().find(|r| r.job.id == 1).unwrap();
        assert_eq!((r1.start_s, r1.end_s), (0.0, 100.0), "running attempts survive a drain");
        let r3 = s.records().iter().find(|r| r.job.id == 3).unwrap();
        assert_eq!(r3.start_s, 200.0, "no start inside the window");
        assert!(s.records().iter().all(|r| r.job.id != 2), "the orphan left the cluster");
    }

    #[test]
    fn down_window_kills_running_attempts_and_requeues_with_backoff() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.set_outages(vec![window(OutageMode::Down, 40.0, 60.0)], 5.0);
        s.submit(job(1, 4, 100.0, 0.0));
        s.run_to_completion();
        assert_eq!(s.outage_killed(), 1);
        assert_eq!(s.outage_wasted_s(), 40.0);
        assert!(s.take_orphans().is_empty(), "killed attempts requeue locally, not orphan");
        // the killed attempt's stale ends-heap entry (end 100) must not
        // complete the retry early — the start generation skips it
        assert_eq!(s.records().len(), 1);
        let r = &s.records()[0];
        assert_eq!(r.start_s, 60.0, "the retry waits out the window");
        assert_eq!(r.end_s, 160.0);
        // the kill refunded the allocation the attempt never held
        assert!(s.utilization() <= 1.0 + 1e-9, "{}", s.utilization());
    }

    #[test]
    fn outage_runs_are_deterministic() {
        let run = || {
            let mut s = Scheduler::new(ClusterSpec::small(2, 8, 32));
            s.set_outages(
                vec![
                    window(OutageMode::Down, 30.0, 80.0),
                    window(OutageMode::Drain, 120.0, 150.0),
                ],
                10.0,
            );
            for id in 0..60u64 {
                let dur = 20.0 + (id % 9) as f64 * 10.0;
                s.submit(job(id, 1 + (id % 3) as u32, dur, (id / 2) as f64));
            }
            s.run_to_completion();
            (s.records().to_vec(), s.take_orphans(), s.outage_killed(), s.outage_wasted_s())
        };
        assert_eq!(run(), run());
    }
}
