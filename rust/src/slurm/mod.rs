//! SLURM-style cluster simulator — the ACCRE substrate (paper §2.2).
//!
//! Discrete-event simulation of a shared HPC cluster: nodes with cores +
//! RAM, a pending queue ordered by fairshare priority, EASY backfill,
//! job-array concurrency throttles, and maintenance windows (during which
//! no job starts — the coordinator's burst-to-local trigger, §2.3).
//!
//! ACCRE's published scale: 750 compute nodes, 20,100 CPU cores, ~200 TB
//! RAM (§2.2); `ClusterSpec::accre()` encodes it.

pub mod trace;

use std::collections::BTreeMap;

/// One node's capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeSpec {
    pub cores: u32,
    pub ram_gb: u32,
}

/// Cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// The ACCRE cluster at paper scale: 750 nodes ≈ 20,100 cores, ~200 TB.
    pub fn accre() -> Self {
        Self {
            name: "ACCRE".into(),
            nodes: vec![NodeSpec { cores: 27, ram_gb: 267 }; 750],
        }
    }

    /// A small cluster for tests/examples.
    pub fn small(nodes: usize, cores: u32, ram_gb: u32) -> Self {
        Self {
            name: format!("test-{nodes}x{cores}"),
            nodes: vec![NodeSpec { cores, ram_gb }; nodes],
        }
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.cores as u64).sum()
    }
}

/// A job submitted to the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct SimJob {
    pub id: u64,
    pub user: String,
    pub cores: u32,
    pub ram_gb: u32,
    /// Wall-clock duration once started (seconds).
    pub duration_s: f64,
    /// Submission time (seconds).
    pub submit_s: f64,
    /// Job-array handle (jobs sharing an array share a concurrency cap).
    pub array: Option<ArrayHandle>,
}

/// Identifies a job array + its `%max_concurrent` throttle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayHandle {
    pub array_id: u64,
    pub max_concurrent: u32,
}

/// Completed-job record.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub job: SimJob,
    pub start_s: f64,
    pub end_s: f64,
    pub node: usize,
}

impl JobRecord {
    pub fn queue_wait_s(&self) -> f64 {
        self.start_s - self.job.submit_s
    }
}

/// Scheduling policy (ablation axis: the paper relies on ACCRE's
/// fairshare+backfill; `bench ablation_scheduler` quantifies what each
/// piece buys).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// Order pending jobs by per-user fairshare usage (else pure FIFO).
    pub fairshare: bool,
    /// EASY backfill around the blocked head job (else strict order).
    pub backfill: bool,
}

impl Default for Policy {
    fn default() -> Self {
        Self {
            fairshare: true,
            backfill: true,
        }
    }
}

/// A window during which no new job may start (maintenance / outage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Maintenance {
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Clone, Copy)]
struct NodeState {
    free_cores: u32,
    free_ram_gb: u32,
}

#[derive(Debug, Clone)]
struct Running {
    job: SimJob,
    node: usize,
    start_s: f64,
    end_s: f64,
}

/// The discrete-event scheduler.
#[derive(Debug)]
pub struct Scheduler {
    pub spec: ClusterSpec,
    nodes: Vec<NodeState>,
    clock: f64,
    pending: Vec<SimJob>,
    running: Vec<Running>,
    records: Vec<JobRecord>,
    /// Fairshare: accumulated core-seconds per user (decayed); lower usage
    /// → higher priority.
    usage: BTreeMap<String, f64>,
    maintenance: Vec<Maintenance>,
    /// Running count per array id (for `%max_concurrent`).
    array_running: BTreeMap<u64, u32>,
    core_seconds_capacity: f64,
    core_seconds_used: f64,
    /// Submissions arrived since the last scheduling pass — lets
    /// [`Self::next_event_time`] report "a scheduling attempt is due
    /// now" exactly once instead of livelocking on blocked jobs.
    needs_schedule: bool,
    pub policy: Policy,
}

impl Scheduler {
    pub fn new(spec: ClusterSpec) -> Self {
        Self::with_policy(spec, Policy::default())
    }

    pub fn with_policy(spec: ClusterSpec, policy: Policy) -> Self {
        let nodes = spec
            .nodes
            .iter()
            .map(|n| NodeState {
                free_cores: n.cores,
                free_ram_gb: n.ram_gb,
            })
            .collect();
        Self {
            nodes,
            clock: 0.0,
            pending: Vec::new(),
            running: Vec::new(),
            records: Vec::new(),
            usage: BTreeMap::new(),
            maintenance: Vec::new(),
            array_running: BTreeMap::new(),
            core_seconds_capacity: 0.0,
            core_seconds_used: 0.0,
            needs_schedule: false,
            policy,
            spec,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn add_maintenance(&mut self, w: Maintenance) {
        self.maintenance.push(w);
    }

    /// True if `t` falls in a maintenance window (no job starts).
    pub fn in_maintenance(&self, t: f64) -> bool {
        self.maintenance.iter().any(|w| t >= w.start_s && t < w.end_s)
    }

    pub fn submit(&mut self, job: SimJob) {
        assert!(
            job.submit_s >= self.clock,
            "cannot submit in the past (job {} at {}, clock {})",
            job.id,
            job.submit_s,
            self.clock
        );
        self.pending.push(job);
        self.needs_schedule = true;
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn records(&self) -> &[JobRecord] {
        &self.records
    }

    /// Cluster-wide core utilization over simulated time so far (0..1) —
    /// the §2.3 resource monitor's compute view.
    pub fn utilization(&self) -> f64 {
        if self.core_seconds_capacity <= 0.0 {
            return 0.0;
        }
        self.core_seconds_used / self.core_seconds_capacity
    }

    fn priority(&self, job: &SimJob) -> (f64, f64, u64) {
        // fairshare first (lower accumulated usage wins), then FIFO.
        let usage = if self.policy.fairshare {
            self.usage.get(&job.user).copied().unwrap_or(0.0)
        } else {
            0.0
        };
        (usage, job.submit_s, job.id)
    }

    fn fits_on(&self, node: usize, job: &SimJob) -> bool {
        self.nodes[node].free_cores >= job.cores && self.nodes[node].free_ram_gb >= job.ram_gb
    }

    fn first_fit(&self, job: &SimJob) -> Option<usize> {
        (0..self.nodes.len()).find(|&n| self.fits_on(n, job))
    }

    fn array_ok(&self, job: &SimJob) -> bool {
        match &job.array {
            None => true,
            Some(h) => self.array_running.get(&h.array_id).copied().unwrap_or(0) < h.max_concurrent,
        }
    }

    fn start_job(&mut self, job: SimJob, node: usize) {
        self.nodes[node].free_cores -= job.cores;
        self.nodes[node].free_ram_gb -= job.ram_gb;
        if let Some(h) = &job.array {
            *self.array_running.entry(h.array_id).or_insert(0) += 1;
        }
        *self.usage.entry(job.user.clone()).or_insert(0.0) +=
            job.cores as f64 * job.duration_s;
        self.core_seconds_used += job.cores as f64 * job.duration_s;
        let end_s = self.clock + job.duration_s;
        self.running.push(Running {
            job,
            node,
            start_s: self.clock,
            end_s,
        });
    }

    /// Try to start pending jobs (priority order + EASY backfill): the
    /// highest-priority blocked job reserves its earliest start; later jobs
    /// may start now only if they finish before that reservation (or don't
    /// take its resources — approximated by the end-before test).
    fn schedule(&mut self) {
        if self.in_maintenance(self.clock) {
            return;
        }
        self.needs_schedule = false;
        // arrivals only — priority keys computed ONCE per job, not per
        // comparison (the BTreeMap lookup inside priority() dominated the
        // sort before; see EXPERIMENTS.md §Perf L3)
        let mut arrived: Vec<(usize, (f64, f64, u64))> = (0..self.pending.len())
            .filter(|&i| self.pending[i].submit_s <= self.clock)
            .map(|i| (i, self.priority(&self.pending[i])))
            .collect();
        arrived.sort_unstable_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let arrived: Vec<usize> = arrived.into_iter().map(|(i, _)| i).collect();

        let mut started: Vec<usize> = Vec::new();
        let mut shadow: Option<f64> = None; // head job's reserved start
        // perf (EXPERIMENTS.md §Perf L3): memoize requirement pairs that
        // failed to fit this pass — any job needing ≥ that much also fails,
        // so the O(nodes) scan runs once per distinct requirement class
        // instead of once per pending job.
        let mut failed_reqs: Vec<(u32, u32)> = Vec::new();
        for &idx in &arrived {
            let job = self.pending[idx].clone();
            if !self.array_ok(&job) {
                continue;
            }
            // cheap rejections before the node scan
            if let Some(sh) = shadow {
                if !self.policy.backfill || self.clock + job.duration_s > sh {
                    continue;
                }
            }
            if failed_reqs
                .iter()
                .any(|&(c, r)| job.cores >= c && job.ram_gb >= r)
            {
                if shadow.is_none() {
                    shadow = Some(self.earliest_start_estimate(&job));
                }
                continue;
            }
            match self.first_fit(&job) {
                Some(node) => {
                    self.start_job(job, node);
                    started.push(idx);
                }
                None => {
                    failed_reqs.push((job.cores, job.ram_gb));
                    if shadow.is_none() {
                        shadow = Some(self.earliest_start_estimate(&job));
                    }
                }
            }
        }
        started.sort_unstable_by(|a, b| b.cmp(a));
        for idx in started {
            self.pending.remove(idx);
        }
    }

    /// Earliest time the blocked job could start, assuming running jobs
    /// release resources at their end times (ignores other pending jobs —
    /// the EASY reservation).
    fn earliest_start_estimate(&self, job: &SimJob) -> f64 {
        let mut frees: Vec<(f64, usize, u32, u32)> = self
            .running
            .iter()
            .map(|r| (r.end_s, r.node, r.job.cores, r.job.ram_gb))
            .collect();
        frees.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut nodes = self.nodes.clone();
        for (end, node, cores, ram) in frees {
            nodes[node].free_cores += cores;
            nodes[node].free_ram_gb += ram;
            if nodes
                .iter()
                .any(|n| n.free_cores >= job.cores && n.free_ram_gb >= job.ram_gb)
            {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Time of the next event (arrival, completion, or maintenance end),
    /// or `Some(clock)` when submissions arrived since the last
    /// scheduling pass and could be due immediately. `None` means the
    /// simulation cannot progress (drained, or deadlocked on an
    /// oversized job). Used by the staged-campaign co-simulation
    /// ([`crate::coordinator::staged`]) to interleave this scheduler
    /// with the transfer scheduler without overshooting either.
    pub fn next_event_time(&self) -> Option<f64> {
        if self.needs_schedule
            && !self.in_maintenance(self.clock)
            && self.pending.iter().any(|j| j.submit_s <= self.clock)
        {
            return Some(self.clock);
        }
        let next_end = self
            .running
            .iter()
            .map(|r| r.end_s)
            .fold(f64::INFINITY, f64::min);
        let next_arrival = self
            .pending
            .iter()
            .map(|j| j.submit_s)
            .filter(|&t| t > self.clock)
            .fold(f64::INFINITY, f64::min);
        // if blocked purely by maintenance or throttle, jump to next boundary
        let next_maint_end = self
            .maintenance
            .iter()
            .filter(|w| w.end_s > self.clock && w.start_s <= self.clock)
            .map(|w| w.end_s)
            .fold(f64::INFINITY, f64::min);
        let next_t = next_end.min(next_arrival).min(next_maint_end);
        next_t.is_finite().then_some(next_t)
    }

    /// Release resources of every running job whose end time has passed
    /// and append its [`JobRecord`].
    fn complete_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].end_s <= self.clock {
                let r = self.running.swap_remove(i);
                self.nodes[r.node].free_cores += r.job.cores;
                self.nodes[r.node].free_ram_gb += r.job.ram_gb;
                if let Some(h) = &r.job.array {
                    if let Some(c) = self.array_running.get_mut(&h.array_id) {
                        *c -= 1;
                    }
                }
                self.records.push(JobRecord {
                    start_s: r.start_s,
                    end_s: r.end_s,
                    node: r.node,
                    job: r.job,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Advance to the next event (arrival, completion, or maintenance end);
    /// returns false when nothing remains.
    pub fn step(&mut self) -> bool {
        self.schedule();
        let Some(next_t) = self.next_event_time() else {
            // nothing running, nothing arriving: if pending non-empty we are
            // deadlocked (job larger than any node) — surface by returning
            // false with pending jobs left.
            return false;
        };
        let dt = next_t - self.clock;
        self.core_seconds_capacity += self.spec.total_cores() as f64 * dt.max(0.0);
        self.clock = next_t;
        self.complete_finished();
        true
    }

    /// Advance the simulation to absolute time `t`, processing every
    /// event up to and including `t`; the clock ends at exactly `t`.
    /// Unlike [`Self::step`] this never overshoots, so the staged
    /// campaign co-simulation can submit jobs discovered by the transfer
    /// scheduler at times between slurm events.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t + 1e-9 >= self.clock,
            "cannot advance backwards (to {t}, clock {})",
            self.clock
        );
        loop {
            self.schedule();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            let dt = (target - self.clock).max(0.0);
            self.core_seconds_capacity += self.spec.total_cores() as f64 * dt;
            self.clock = self.clock.max(target);
            self.complete_finished();
            if target + 1e-9 >= t {
                self.schedule();
                return;
            }
        }
    }

    /// Run until all submitted jobs have completed (or deadlock).
    pub fn run_to_completion(&mut self) -> &[JobRecord] {
        while !self.pending.is_empty() || !self.running.is_empty() {
            if !self.step() {
                break;
            }
        }
        &self.records
    }

    /// Makespan of everything completed so far.
    pub fn makespan(&self) -> f64 {
        self.records.iter().map(|r| r.end_s).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, cores: u32, dur: f64, submit: f64) -> SimJob {
        SimJob {
            id,
            user: "u".into(),
            cores,
            ram_gb: 1,
            duration_s: dur,
            submit_s: submit,
            array: None,
        }
    }

    #[test]
    fn single_job_runs_immediately() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 2, 100.0, 0.0));
        let recs = s.run_to_completion();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].start_s, 0.0);
        assert_eq!(recs[0].end_s, 100.0);
    }

    #[test]
    fn capacity_forces_queueing() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        assert_eq!(r2.start_s, 100.0);
        assert_eq!(s.makespan(), 200.0);
    }

    #[test]
    fn parallel_when_fits() {
        let mut s = Scheduler::new(ClusterSpec::small(2, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        s.run_to_completion();
        assert_eq!(s.makespan(), 100.0);
    }

    #[test]
    fn ram_constraint_respected() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 8, 16));
        let mut j1 = job(1, 1, 100.0, 0.0);
        j1.ram_gb = 12;
        let mut j2 = job(2, 1, 100.0, 0.0);
        j2.ram_gb = 12;
        s.submit(j1);
        s.submit(j2);
        s.run_to_completion();
        assert_eq!(s.makespan(), 200.0); // RAM serializes despite free cores
    }

    #[test]
    fn backfill_fills_hole_without_delaying_head() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0)); // runs now
        s.submit(job(2, 4, 100.0, 0.0)); // head blocked until t=100
        s.submit(job(3, 1, 10.0, 0.0)); // can't fit (0 cores free) …
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert_eq!(r2.start_s, 100.0, "head job must not be delayed");
        assert!(r3.start_s >= 100.0);
    }

    #[test]
    fn backfill_uses_free_cores_when_it_ends_before_shadow() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 2, 100.0, 0.0)); // 2 cores busy until 100
        s.submit(job(2, 4, 50.0, 0.0)); // needs all 4 → blocked to t=100
        s.submit(job(3, 1, 20.0, 0.0)); // fits now, ends (20) before 100 → backfill
        let recs = s.run_to_completion().to_vec();
        let r2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert_eq!(r3.start_s, 0.0, "short job should backfill");
        assert_eq!(r2.start_s, 100.0);
    }

    #[test]
    fn array_throttle_caps_concurrency() {
        let mut s = Scheduler::new(ClusterSpec::small(10, 4, 16));
        let h = ArrayHandle {
            array_id: 7,
            max_concurrent: 2,
        };
        for i in 0..6 {
            let mut j = job(i, 1, 100.0, 0.0);
            j.array = Some(h);
            s.submit(j);
        }
        s.run_to_completion();
        // 6 jobs, 2 at a time → 3 waves of 100 s
        assert_eq!(s.makespan(), 300.0);
    }

    #[test]
    fn maintenance_delays_starts() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.add_maintenance(Maintenance {
            start_s: 0.0,
            end_s: 500.0,
        });
        s.submit(job(1, 1, 10.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        assert_eq!(recs[0].start_s, 500.0);
    }

    #[test]
    fn fairshare_prefers_light_user() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        // heavy user builds usage
        let mut j1 = job(1, 4, 1000.0, 0.0);
        j1.user = "heavy".into();
        s.submit(j1);
        // at t=1000 both users have one job pending; light should win
        let mut j2 = job(2, 4, 10.0, 1.0);
        j2.user = "heavy".into();
        let mut j3 = job(3, 4, 10.0, 2.0);
        j3.user = "light".into();
        s.submit(j2);
        s.submit(j3);
        let recs = s.run_to_completion().to_vec();
        let heavy2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let light = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(
            light.start_s < heavy2.start_s,
            "light {} vs heavy {}",
            light.start_s,
            heavy2.start_s
        );
    }

    #[test]
    fn oversized_job_deadlocks_gracefully() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 8, 10.0, 0.0)); // bigger than any node
        s.run_to_completion();
        assert_eq!(s.records().len(), 0);
        assert_eq!(s.pending_count(), 1);
    }

    #[test]
    fn no_backfill_serializes_behind_blocked_head() {
        // same scenario as backfill_uses_free_cores…, with backfill off the
        // short job must wait behind the blocked 4-core job.
        let mut s = Scheduler::with_policy(
            ClusterSpec::small(1, 4, 16),
            Policy {
                fairshare: true,
                backfill: false,
            },
        );
        s.submit(job(1, 2, 100.0, 0.0));
        s.submit(job(2, 4, 50.0, 0.0));
        s.submit(job(3, 1, 20.0, 0.0));
        let recs = s.run_to_completion().to_vec();
        let r3 = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(r3.start_s >= 100.0, "short job must NOT backfill: {}", r3.start_s);
    }

    #[test]
    fn fifo_policy_ignores_usage() {
        let mut s = Scheduler::with_policy(
            ClusterSpec::small(1, 4, 16),
            Policy {
                fairshare: false,
                backfill: true,
            },
        );
        let mut j1 = job(1, 4, 1000.0, 0.0);
        j1.user = "heavy".into();
        s.submit(j1);
        let mut j2 = job(2, 4, 10.0, 1.0);
        j2.user = "heavy".into();
        let mut j3 = job(3, 4, 10.0, 2.0);
        j3.user = "light".into();
        s.submit(j2);
        s.submit(j3);
        let recs = s.run_to_completion().to_vec();
        let heavy2 = recs.iter().find(|r| r.job.id == 2).unwrap();
        let light = recs.iter().find(|r| r.job.id == 3).unwrap();
        assert!(heavy2.start_s < light.start_s, "FIFO: earlier submit wins");
    }

    #[test]
    fn accre_spec_scale() {
        let c = ClusterSpec::accre();
        assert_eq!(c.nodes.len(), 750);
        let cores = c.total_cores();
        assert!((20_000..21_000).contains(&cores), "{cores}");
    }

    #[test]
    fn advance_to_processes_events_without_overshoot() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.submit(job(2, 4, 100.0, 0.0));
        assert_eq!(s.next_event_time(), Some(0.0), "scheduling due now");
        s.advance_to(50.0);
        assert_eq!(s.clock(), 50.0);
        assert_eq!(s.records().len(), 0);
        assert_eq!(s.running_count(), 1);
        s.advance_to(100.0);
        assert_eq!(s.records().len(), 1, "first job completes at 100");
        assert_eq!(s.running_count(), 1, "second starts at 100");
        s.advance_to(250.0);
        assert_eq!(s.records().len(), 2);
        assert_eq!(s.makespan(), 200.0);
        // mid-simulation submission at the current clock is legal
        s.submit(job(3, 1, 10.0, 250.0));
        assert_eq!(s.next_event_time(), Some(250.0));
        s.advance_to(260.0);
        assert_eq!(s.records().len(), 3);
    }

    #[test]
    fn utilization_tracks_usage() {
        let mut s = Scheduler::new(ClusterSpec::small(1, 4, 16));
        s.submit(job(1, 4, 100.0, 0.0));
        s.run_to_completion();
        assert!((s.utilization() - 1.0).abs() < 1e-9, "{}", s.utilization());
    }
}
