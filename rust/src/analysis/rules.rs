//! The determinism rule set (DESIGN.md §14).
//!
//! Every guarantee the reproduction makes — the f64-record-identical
//! parity batteries (`engine_parity.rs`, `placement_parity.rs`,
//! `tenancy_parity.rs`), the paper's "consistent and reproducible
//! manner" — rests on the engines being bit-deterministic. These rules
//! encode the replay contract as token-level static checks over the
//! crate's own source, so the hazard class is caught at lint time
//! instead of when a parity test breaks three PRs later.
//!
//! Scoping: `Engine` rules cover the simulation-critical modules
//! (`slurm`, `netsim`, `coordinator`, `faults`, `compute`,
//! `sim_legacy`); `Billing` rules cover the money paths (`cost`).
//! `#[cfg(test)]` blocks are skipped — tests assert on engine output,
//! they do not produce it.

use std::collections::BTreeSet;

use super::lexer::Line;

/// Which part of the tree a rule patrols.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Simulation-critical modules: anything whose execution order or
    /// arithmetic reaches a simulated record.
    Engine,
    /// Money paths: lossy numeric conversions silently corrupt bills.
    Billing,
}

/// One determinism rule: a stable id for suppressions and CLI filters,
/// a short code for reports, and the rationale the report prints.
#[derive(Debug)]
pub struct Rule {
    pub id: &'static str,
    pub code: &'static str,
    pub scope: Scope,
    pub summary: &'static str,
    pub rationale: &'static str,
}

/// The registry. Order is the report's rule-table order.
pub const RULES: &[Rule] = &[
    Rule {
        id: "map-iter",
        code: "DL001",
        scope: Scope::Engine,
        summary: "iteration over HashMap/HashSet in engine code",
        rationale: "std hash collections iterate in RandomState order; any iteration \
                    order that reaches simulated state or telemetry breaks bit-identical \
                    replay. Keyed get/insert/remove is fine — iterate a BTreeMap/BTreeSet \
                    or an explicitly sorted collect instead.",
    },
    Rule {
        id: "float-ord",
        code: "DL002",
        scope: Scope::Engine,
        summary: "float ordering via partial_cmp instead of total_cmp/F64Ord",
        rationale: "partial_cmp(..).unwrap() panics on NaN and treats -0.0 == +0.0, so \
                    a single poisoned sample either aborts replay or reorders ties \
                    platform-dependently. Use f64::total_cmp or util::ord::F64Ord keys.",
    },
    Rule {
        id: "wall-clock",
        code: "DL003",
        scope: Scope::Engine,
        summary: "wall-clock or entropy source in engine code",
        rationale: "Instant::now/SystemTime/external RNG inject host state into the \
                    simulation; replay then depends on when and where it ran. All engine \
                    time comes from the simulated clock, all randomness from explicit \
                    seeds via util::rng.",
    },
    Rule {
        id: "lossy-cast",
        code: "DL004",
        scope: Scope::Billing,
        summary: "lossy `as` cast to an integer type in a billing path",
        rationale: "`as` silently saturates and truncates; on time/money values that \
                    turns NaN into $0 and overflow into a plausible-looking bill. Use a \
                    checked conversion (util::units::checked_u64) that panics loudly.",
    },
    Rule {
        id: "thread-spawn",
        code: "DL005",
        scope: Scope::Engine,
        summary: "threading/channel primitive outside an annotated sync layer",
        rationale: "the engines parallelize behind coordinator::sync's conservative \
                    time-window layer (DESIGN.md §16), the one file-level-allowed home \
                    for spawn/channel plumbing; a thread::spawn or mpsc anywhere else \
                    in engine code is schedule nondeterminism waiting to reach a record.",
    },
    Rule {
        id: "sync-primitive",
        code: "DL006",
        scope: Scope::Engine,
        summary: "lock/atomic shared-state primitive in engine code",
        rationale: "the window-sync layer shares nothing: workers own disjoint engine \
                    shards and exchange owned messages at window bounds, so replay \
                    equality holds by construction. A Mutex/RwLock/Condvar/Atomic in \
                    engine code implies shared mutable simulation state whose access \
                    order the OS scheduler decides — replay-breaking even inside the \
                    annotated sync layer, hence a rule of its own.",
    },
];

/// Look up a rule by its stable id.
pub fn rule(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// Whether `rel_path` (slash-separated, relative to `src/`) is patrolled
/// by `scope`. The engine set is every replay-contract directory —
/// `coordinator/` includes the streaming loop (`coordinator/stream.rs`,
/// DESIGN.md §17) — plus two standalone files feeding engine decisions:
/// the legacy simulator and the stream's arrival ledger
/// (`query/incremental.rs`).
pub fn in_scope(scope: Scope, rel_path: &str) -> bool {
    const ENGINE_DIRS: [&str; 5] = ["slurm/", "netsim/", "coordinator/", "faults/", "compute/"];
    const ENGINE_FILES: [&str; 2] = ["sim_legacy.rs", "query/incremental.rs"];
    match scope {
        Scope::Engine => {
            ENGINE_DIRS.iter().any(|d| rel_path.starts_with(d))
                || ENGINE_FILES.contains(&rel_path)
        }
        Scope::Billing => rel_path.starts_with("cost/"),
    }
}

/// A rule hit before suppression is applied.
#[derive(Debug, Clone)]
pub struct RawFinding {
    pub rule: &'static Rule,
    /// 1-based source line.
    pub line: usize,
    pub what: String,
}

/// Run every rule in `active` over one file's stripped lines.
/// `excluded[i]` marks `#[cfg(test)]` lines the rules skip.
pub fn scan(
    rel_path: &str,
    lines: &[Line],
    excluded: &[bool],
    active: &[&'static Rule],
) -> Vec<RawFinding> {
    let mut out = Vec::new();
    for &r in active {
        if !in_scope(r.scope, rel_path) {
            continue;
        }
        match r.id {
            "map-iter" => map_iter(r, lines, excluded, &mut out),
            "float-ord" => float_ord(r, lines, excluded, &mut out),
            "wall-clock" => wall_clock(r, lines, excluded, &mut out),
            "lossy-cast" => lossy_cast(r, lines, excluded, &mut out),
            "thread-spawn" => thread_spawn(r, lines, excluded, &mut out),
            "sync-primitive" => sync_primitive(r, lines, excluded, &mut out),
            other => unreachable!("rule '{other}' has no matcher"),
        }
    }
    out.sort_by(|a, b| (a.line, a.rule.id).cmp(&(b.line, b.rule.id)));
    out
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of `needle` in `hay` where it is a whole word (not part
/// of a longer identifier on either side).
fn word_positions(hay: &str, needle: &str) -> Vec<usize> {
    hay.match_indices(needle)
        .filter(|(i, _)| {
            let before_ok = !hay[..*i].chars().next_back().is_some_and(is_ident);
            let after_ok = !hay[*i + needle.len()..].chars().next().is_some_and(is_ident);
            before_ok && after_ok
        })
        .map(|(i, _)| i)
        .collect()
}

/// Lines eligible for scanning: in-range and not `#[cfg(test)]`.
fn included<'a>(
    lines: &'a [Line],
    excluded: &'a [bool],
) -> impl Iterator<Item = (usize, &'a str)> + 'a {
    lines
        .iter()
        .enumerate()
        .filter(move |(i, _)| !excluded.get(*i).copied().unwrap_or(false))
        .map(|(i, l)| (i + 1, l.code.as_str()))
}

// --- DL001 map-iter -------------------------------------------------------

const HASH_TYPES: [&str; 2] = ["HashMap", "HashSet"];
const ITER_METHODS: [&str; 8] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "retain",
];

/// Two-pass, flow-insensitive: pass 1 collects identifiers bound to a
/// hash-ordered collection anywhere in the file (struct fields, lets,
/// fn params); pass 2 flags iteration syntax over those identifiers and
/// hash-typed return positions. Keyed access (`get`/`insert`/`remove`)
/// never fires. A same-named Vec elsewhere in the file would
/// false-positive — that is the conservative trade of a token-level
/// pass, and `lint:allow` is the documented escape hatch.
fn map_iter(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    let mut names: BTreeSet<String> = BTreeSet::new();
    for (lineno, code) in included(lines, excluded) {
        let chars: Vec<char> = code.chars().collect();
        for ty in HASH_TYPES {
            for pos in word_positions(code, ty) {
                let cpos = code[..pos].chars().count();
                if returns_hash(&chars, cpos) {
                    out.push(RawFinding {
                        rule: r,
                        line: lineno,
                        what: format!("engine function returns a {ty} (order leaks to callers)"),
                    });
                } else if let Some(name) = bound_ident(&chars, cpos) {
                    names.insert(name);
                }
            }
        }
    }
    for (lineno, code) in included(lines, excluded) {
        for name in &names {
            for pos in word_positions(code, name) {
                let after = &code[pos + name.len()..];
                if let Some(m) = iter_method_after(after) {
                    out.push(RawFinding {
                        rule: r,
                        line: lineno,
                        what: format!("`{name}.{m}()` iterates a hash-ordered collection"),
                    });
                }
            }
        }
        if let Some(name) = for_loop_over(code, &names) {
            out.push(RawFinding {
                rule: r,
                line: lineno,
                what: format!("`for … in {name}` iterates a hash-ordered collection"),
            });
        }
    }
}

/// After hopping a `std::collections::`-style path prefix backwards
/// from the type token at `pos`, the char index where the full path
/// expression starts.
fn path_start(chars: &[char], pos: usize) -> usize {
    let mut j = pos;
    while j >= 2 && chars[j - 1] == ':' && chars[j - 2] == ':' {
        j -= 2;
        while j > 0 && is_ident(chars[j - 1]) {
            j -= 1;
        }
    }
    j
}

/// Is the hash type at `pos` in return position (`-> HashMap<…>`)?
fn returns_hash(chars: &[char], pos: usize) -> bool {
    let mut j = path_start(chars, pos);
    while j > 0 && chars[j - 1].is_whitespace() {
        j -= 1;
    }
    j >= 2 && chars[j - 1] == '>' && chars[j - 2] == '-'
}

/// The identifier a hash type at `pos` is bound to, if the line reads
/// `name: HashMap<…>` / `name: &mut HashSet<…>` (field, param, or
/// struct-literal init) or `let [mut] name = HashMap::new()`.
fn bound_ident(chars: &[char], pos: usize) -> Option<String> {
    let mut j = path_start(chars, pos);
    // skip type decorations backwards: whitespace, `&`, `mut`, `'a`
    loop {
        while j > 0 && chars[j - 1].is_whitespace() {
            j -= 1;
        }
        if j > 0 && chars[j - 1] == '&' {
            j -= 1;
            continue;
        }
        if j > 0 && is_ident(chars[j - 1]) {
            let mut k = j;
            while k > 0 && is_ident(chars[k - 1]) {
                k -= 1;
            }
            let word: String = chars[k..j].iter().collect();
            if word == "mut" {
                j = k;
                continue;
            }
            if k > 0 && chars[k - 1] == '\'' {
                j = k - 1; // a lifetime like `&'a `
                continue;
            }
            return None; // some other token — not a binding we track
        }
        break;
    }
    if j == 0 {
        return None;
    }
    if chars[j - 1] == ':' && !(j >= 2 && chars[j - 2] == ':') {
        // `name: HashMap<…>` — read the identifier before the colon
        let mut k = j - 1;
        while k > 0 && chars[k - 1].is_whitespace() {
            k -= 1;
        }
        let end = k;
        while k > 0 && is_ident(chars[k - 1]) {
            k -= 1;
        }
        let name: String = chars[k..end].iter().collect();
        return if name.is_empty() { None } else { Some(name) };
    }
    if chars[j - 1] == '=' {
        // `let [mut] name = HashMap::new()` — find the let binding
        let line: String = chars.iter().collect();
        let let_pos = word_positions(&line, "let").into_iter().next()?;
        let after = line[let_pos + 3..].trim_start();
        let after = after.strip_prefix("mut ").unwrap_or(after).trim_start();
        let name: String = after.chars().take_while(|&c| is_ident(c)).collect();
        return if name.is_empty() { None } else { Some(name) };
    }
    None
}

/// If `after` (text following a tracked identifier) starts with a call
/// to an iteration method, that method's name.
fn iter_method_after(after: &str) -> Option<&'static str> {
    let rest = after.strip_prefix('.')?;
    for m in ITER_METHODS {
        if let Some(tail) = rest.strip_prefix(m) {
            let mut t = tail.chars();
            if t.next() == Some('(') {
                return Some(m);
            }
        }
    }
    None
}

/// If the line is a `for … in <expr>` loop whose iterated expression
/// starts with a tracked identifier, that identifier.
fn for_loop_over(code: &str, names: &BTreeSet<String>) -> Option<String> {
    if word_positions(code, "for").is_empty() {
        return None;
    }
    let in_pos = code.find(" in ")?;
    let mut expr = code[in_pos + 4..].trim_start();
    loop {
        if let Some(rest) = expr.strip_prefix('&') {
            expr = rest;
        } else if let Some(rest) = expr.strip_prefix("mut ") {
            expr = rest.trim_start();
        } else if let Some(rest) = expr.strip_prefix("self.") {
            expr = rest;
        } else if let Some(rest) = expr.strip_prefix('(') {
            expr = rest;
        } else {
            break;
        }
    }
    let ident: String = expr.chars().take_while(|&c| is_ident(c)).collect();
    let tail = expr[ident.len()..].chars().next();
    // `map.keys()`-style tails are the method scan's finding, not ours
    if names.contains(&ident) && tail != Some('.') {
        Some(ident)
    } else {
        None
    }
}

// --- DL002 float-ord ------------------------------------------------------

/// Flags `.partial_cmp(` / `::partial_cmp(` call sites. Implementing
/// `fn partial_cmp` (a `PartialOrd` impl delegating to `Ord`) has
/// neither prefix and stays legal.
fn float_ord(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    for (lineno, code) in included(lines, excluded) {
        if code.contains(".partial_cmp(") || code.contains("::partial_cmp(") {
            out.push(RawFinding {
                rule: r,
                line: lineno,
                what: "partial_cmp call site — use total_cmp or util::ord::F64Ord".into(),
            });
        }
    }
}

// --- DL003 wall-clock -----------------------------------------------------

const CLOCK_TOKENS: [&str; 8] = [
    "Instant::now",
    "SystemTime",
    "UNIX_EPOCH",
    "thread_rng",
    "from_entropy",
    "rand::random",
    "RandomState",
    "getrandom",
];

fn wall_clock(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    for (lineno, code) in included(lines, excluded) {
        for tok in CLOCK_TOKENS {
            if let Some(pos) = code.find(tok) {
                let before_ok = !code[..pos].chars().next_back().is_some_and(is_ident);
                if before_ok {
                    out.push(RawFinding {
                        rule: r,
                        line: lineno,
                        what: format!("`{tok}` reads host state the replay cannot reproduce"),
                    });
                    break; // one finding per line is enough to act on
                }
            }
        }
    }
}

// --- DL004 lossy-cast -----------------------------------------------------

const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Flags `<expr> as <int-type>` in billing modules. Casts to float
/// types (`count as f64`) are widening and stay legal.
fn lossy_cast(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    for (lineno, code) in included(lines, excluded) {
        for pos in word_positions(code, "as") {
            let after = code[pos + 2..].trim_start();
            let target: String = after.chars().take_while(|&c| is_ident(c)).collect();
            if INT_TYPES.contains(&target.as_str()) {
                out.push(RawFinding {
                    rule: r,
                    line: lineno,
                    what: format!(
                        "`as {target}` silently truncates/saturates — use \
                         util::units::checked_u64 or a widening conversion"
                    ),
                });
            }
        }
    }
}

// --- DL005 thread-spawn ---------------------------------------------------

const SYNC_TOKENS: [&str; 5] = ["thread::spawn", "std::thread", "mpsc", "crossbeam", "rayon"];

fn thread_spawn(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    token_scan(r, lines, excluded, &SYNC_TOKENS, out, |tok| {
        format!("`{tok}` — engine parallelism belongs to the annotated sync layer")
    });
}

// --- DL006 sync-primitive -------------------------------------------------

/// Shared-mutable-state primitives. `Atomic` is a prefix match by
/// design: it catches every `AtomicU64`/`AtomicBool`/... variant (the
/// word-boundary check still rejects identifiers merely containing it).
const SYNC_PRIMITIVE_TOKENS: [&str; 4] = ["Mutex<", "RwLock<", "Condvar", "Atomic"];

fn sync_primitive(r: &'static Rule, lines: &[Line], excluded: &[bool], out: &mut Vec<RawFinding>) {
    token_scan(r, lines, excluded, &SYNC_PRIMITIVE_TOKENS, out, |tok| {
        format!("`{tok}` — shared mutable state has no place in a replayable engine")
    });
}

/// Shared matcher for the token-set rules: one finding per line (the
/// first matching token), gated on a word boundary before the match.
fn token_scan(
    r: &'static Rule,
    lines: &[Line],
    excluded: &[bool],
    tokens: &[&str],
    out: &mut Vec<RawFinding>,
    what: impl Fn(&str) -> String,
) {
    for (lineno, code) in included(lines, excluded) {
        for &tok in tokens {
            if let Some(pos) = code.find(tok) {
                let before_ok = !code[..pos].chars().next_back().is_some_and(is_ident);
                if before_ok {
                    out.push(RawFinding {
                        rule: r,
                        line: lineno,
                        what: what(tok),
                    });
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint_source;

    #[test]
    fn engine_scope_gates_stream_loop_and_arrival_ledger() {
        // the streaming coordinator rides the coordinator/ prefix; the
        // arrival ledger is a standalone engine file — both must stay
        // deny-gated or the replay contract silently loses coverage
        assert!(in_scope(Scope::Engine, "coordinator/stream.rs"));
        assert!(in_scope(Scope::Engine, "query/incremental.rs"));
        assert!(in_scope(Scope::Engine, "sim_legacy.rs"));
        assert!(!in_scope(Scope::Engine, "query/mod.rs"));
        assert!(!in_scope(Scope::Billing, "coordinator/stream.rs"));
    }

    fn deny_rules(path: &str, src: &str) -> Vec<String> {
        let scan = lint_source(path, src, None);
        scan.findings
            .iter()
            .filter(|f| f.suppressed.is_none())
            .map(|f| f.rule.id.to_string())
            .collect()
    }

    // -- map-iter ----------------------------------------------------------

    #[test]
    fn map_iter_flags_iteration_not_keyed_access() {
        let src = "\
use std::collections::HashMap;\n\
struct S { attempts: HashMap<u64, u32> }\n\
impl S {\n\
    fn ok(&self) -> u32 { *self.attempts.get(&1).unwrap_or(&0) }\n\
    fn bad(&self) -> u32 { self.attempts.values().sum() }\n\
}\n";
        let hits = deny_rules("slurm/mod.rs", src);
        assert_eq!(hits, vec!["map-iter"], "values() fires, get() does not");
    }

    #[test]
    fn map_iter_flags_for_loops_and_returns() {
        let src = "\
fn leak() -> std::collections::HashMap<u64, u32> { todo!() }\n\
fn walk() {\n\
    let mut seen = std::collections::HashSet::new();\n\
    seen.insert(1u64);\n\
    for v in &seen { drop(v); }\n\
}\n";
        let hits = deny_rules("netsim/scheduler.rs", src);
        assert_eq!(hits, vec!["map-iter", "map-iter"], "return position + for loop");
    }

    #[test]
    fn map_iter_ignores_other_modules_and_other_types() {
        let src = "\
struct S { attempts: HashMap<u64, u32>, log: Vec<u64> }\n\
impl S { fn f(&self) { for v in &self.log { drop(v); } } }\n";
        assert!(deny_rules("report/mod.rs", src).is_empty(), "report/ is out of scope");
        assert!(
            deny_rules("slurm/mod.rs", "fn f(xs: &[u64]) { for x in xs { drop(x); } }").is_empty(),
            "slice iteration is fine"
        );
    }

    #[test]
    fn map_iter_suppression_with_reason_downgrades() {
        let src = "\
struct S { attempts: std::collections::HashMap<u64, u32> }\n\
impl S {\n\
    fn sum(&self) -> u32 {\n\
        // lint:allow(map-iter) — order-independent fold (sum is commutative)\n\
        self.attempts.values().sum()\n\
    }\n\
}\n";
        let scan = lint_source("slurm/mod.rs", src, None);
        assert!(scan.findings.iter().all(|f| f.suppressed.is_some()), "{:?}", scan.findings);
        assert_eq!(scan.findings.len(), 1);
        assert!(scan.malformed.is_empty());
    }

    // -- float-ord ---------------------------------------------------------

    #[test]
    fn float_ord_flags_call_sites_not_impls() {
        let bad = "fn f(xs: &mut Vec<f64>) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        assert_eq!(deny_rules("coordinator/staged.rs", bad), vec!["float-ord"]);
        let good = "fn f(xs: &mut Vec<f64>) { xs.sort_by(|a, b| a.total_cmp(b)); }\n";
        assert!(deny_rules("coordinator/staged.rs", good).is_empty());
        let impl_ok = "\
impl PartialOrd for K {\n\
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> { Some(self.cmp(other)) }\n\
}\n";
        assert!(deny_rules("slurm/mod.rs", impl_ok).is_empty(), "PartialOrd impls are legal");
    }

    #[test]
    fn float_ord_ignores_comments_and_strings() {
        let src = "// a.partial_cmp(b) used to live here\nlet s = \".partial_cmp(\";\n";
        assert!(deny_rules("slurm/mod.rs", src).is_empty());
    }

    // -- wall-clock --------------------------------------------------------

    #[test]
    fn wall_clock_flags_host_time_and_entropy() {
        for tok in ["std::time::Instant::now()", "SystemTime::now()", "RandomState::new()"] {
            let src = format!("fn f() {{ let t = {tok}; }}\n");
            assert_eq!(deny_rules("faults/mod.rs", &src), vec!["wall-clock"], "{tok}");
        }
        // out of engine scope: the bench harness may time things
        assert!(deny_rules("util/bench.rs", "let t0 = Instant::now();\n").is_empty());
    }

    #[test]
    fn wall_clock_suppressed_inline() {
        let src = "\
fn f() {\n\
    let t0 = std::time::Instant::now(); // lint:allow(wall-clock) — measured, not simulated\n\
    drop(t0);\n\
}\n";
        let scan = lint_source("compute/mod.rs", src, None);
        assert_eq!(scan.findings.len(), 1);
        assert!(scan.findings[0].suppressed.is_some());
    }

    // -- lossy-cast --------------------------------------------------------

    #[test]
    fn lossy_cast_flags_int_casts_in_billing_only() {
        let src = "fn f(x: f64) -> u64 { x.round() as u64 }\n";
        assert_eq!(deny_rules("cost/planner.rs", src), vec!["lossy-cast"]);
        assert!(deny_rules("report/mod.rs", src).is_empty(), "report/ is not a billing path");
        let widening = "fn f(n: u64) -> f64 { n as f64 * 0.5 }\n";
        assert!(deny_rules("cost/mod.rs", widening).is_empty(), "casts to float are widening");
    }

    // -- thread-spawn ------------------------------------------------------

    #[test]
    fn thread_spawn_flags_sync_primitives_in_engines() {
        for tok in ["std::thread::spawn(|| {})", "std::sync::mpsc::channel::<u64>()"] {
            let src = format!("fn f() {{ let _ = {tok}; }}\n");
            assert_eq!(deny_rules("coordinator/tenancy.rs", &src), vec!["thread-spawn"], "{tok}");
        }
        assert!(deny_rules("coordinator/staged.rs", "fn f() { let x = 1; }\n").is_empty());
    }

    // -- sync-primitive ----------------------------------------------------

    #[test]
    fn sync_primitive_flags_locks_and_atomics_in_engines_only() {
        for decl in [
            "let m: std::sync::Mutex<u64> = std::sync::Mutex::new(0);",
            "let l: std::sync::RwLock<f64> = std::sync::RwLock::new(0.0);",
            "let c = std::sync::Condvar::new();",
            "let a = std::sync::atomic::AtomicU64::new(0);",
        ] {
            let src = format!("fn f() {{ {decl} }}\n");
            assert_eq!(deny_rules("netsim/scheduler.rs", &src), vec!["sync-primitive"], "{decl}");
            assert!(deny_rules("util/bench.rs", &src).is_empty(), "util/ is not engine scope");
        }
        let named = "fn f() { let x = MyAtomicCounter::default(); }\n";
        assert!(
            deny_rules("slurm/mod.rs", named).is_empty(),
            "identifiers merely containing a token are not hits"
        );
    }

    #[test]
    fn sync_primitive_is_allowed_per_site_like_any_rule() {
        let src = "\
// lint:allow(sync-primitive) — fixture: drained only at window bounds\n\
static KILLS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);\n";
        let scan = lint_source("coordinator/sync.rs", src, None);
        assert_eq!(scan.findings.len(), 1);
        assert_eq!(scan.findings[0].rule.code, "DL006");
        assert!(scan.findings[0].suppressed.is_some());
        assert!(scan.unused_allows.is_empty());
    }

    // -- shared machinery --------------------------------------------------

    #[test]
    fn cfg_test_blocks_are_skipped() {
        let src = "\
fn live() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    #[test]\n\
    fn t() {\n\
        let m: HashMap<u64, u64> = HashMap::new();\n\
        for v in m.values() { drop(v); }\n\
        let t0 = std::time::Instant::now();\n\
        drop(t0);\n\
    }\n\
}\n";
        assert!(deny_rules("slurm/mod.rs", src).is_empty(), "test code is exempt");
    }

    #[test]
    fn file_level_allow_covers_every_hit() {
        let src = "\
// lint:allow-file(float-ord) — frozen golden reference\n\
fn a(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n\
fn b(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let scan = lint_source("sim_legacy.rs", src, None);
        assert_eq!(scan.findings.len(), 2);
        assert!(scan.findings.iter().all(|f| f.suppressed.is_some()));
        assert!(scan.unused_allows.is_empty());
    }

    #[test]
    fn unused_and_malformed_allows_are_reported() {
        let src = "\
fn clean() {}\n\
// lint:allow(float-ord) — nothing here actually needs it\n\
fn also_clean() {}\n\
// lint:allow(float-ord)\n\
fn c(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let scan = lint_source("slurm/mod.rs", src, None);
        // the reasonless directive is malformed, so line 5's hit stays live
        assert_eq!(scan.malformed.len(), 1);
        assert_eq!(scan.findings.iter().filter(|f| f.suppressed.is_none()).count(), 1);
        assert_eq!(scan.unused_allows.len(), 1, "{:?}", scan.unused_allows);
    }

    #[test]
    fn findings_sort_by_line_then_rule() {
        let src = "\
fn z(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n\
fn f() { let t = std::time::Instant::now(); drop(t); }\n";
        let scan = lint_source("netsim/mod.rs", src, None);
        let ids: Vec<_> = scan.findings.iter().map(|f| (f.line, f.rule.id)).collect();
        assert_eq!(ids, vec![(1, "float-ord"), (2, "wall-clock")]);
    }
}
