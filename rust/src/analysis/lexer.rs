//! Source stripper for the determinism lint ([`crate::analysis`]).
//!
//! Splits Rust source into per-line **code** and **comment** channels so
//! the rule matchers in [`crate::analysis::rules`] never fire on tokens
//! inside comments or string literals, and suppression directives are
//! only read from comments. A character-level state machine, not a
//! parser: it tracks line comments, nested block comments, string
//! literals (including byte strings and raw strings of any `#` arity),
//! char literals, and lifetimes — exactly the fidelity the token-level
//! rules need, and deliberately no more (DESIGN.md §14 explains why the
//! lint stops short of full type analysis).

/// One source line, split into masked code and extracted comment text.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code with every comment/string/char-literal character replaced by
    /// a single space, so stripping never glues adjacent tokens
    /// together and rule tokens inside literals are invisible.
    pub code: String,
    /// Concatenated comment text of the line (the body after `//`, or
    /// this line's portion of a `/* … */` block), without delimiters.
    pub comment: String,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

enum State {
    Code,
    /// Nested block comment depth (Rust block comments nest).
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string; the payload is the `#` arity of the opener.
    RawStr(u32),
}

/// Strip `source` into per-line code/comment channels.
pub fn strip(source: &str) -> Vec<Line> {
    let chars: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    // line comment: everything to end-of-line is comment
                    i += 2;
                    while i < chars.len() && chars[i] != '\n' {
                        cur.comment.push(chars[i]);
                        i += 1;
                    }
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    cur.code.push(' ');
                    i += 2;
                } else if let Some(skip) = raw_string_open(&chars, i) {
                    let hashes = skip - raw_quote_offset(&chars, i) - 1;
                    state = State::RawStr(hashes as u32);
                    cur.code.push(' ');
                    i += skip;
                } else if c == 'b' && chars.get(i + 1) == Some(&'"') && !prev_is_ident(&chars, i) {
                    state = State::Str;
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if c == '\'' {
                    // char literal vs lifetime: `'\…'` and `'x'` are
                    // literals; `'a` (no closing quote) is a lifetime
                    if chars.get(i + 1) == Some(&'\\') {
                        cur.code.push(' ');
                        i += 2; // opening quote + backslash
                        if i < chars.len() {
                            i += 1; // the escaped character itself (handles '\'')
                        }
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            i += 1;
                        }
                        if i < chars.len() && chars[i] == '\'' {
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::Block(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth > 1 { State::Block(depth - 1) } else { State::Code };
                    if matches!(state, State::Code) {
                        cur.code.push(' ');
                    }
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // `\<newline>` is a line continuation: leave the
                    // newline for the top-of-loop handler so line
                    // numbering never drifts
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2; // escape: skip the escaped char too
                    }
                } else if c == '"' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let n = hashes as usize;
                if c == '"' && (0..n).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    state = State::Code;
                    i += 1 + n;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

/// Offset from `i` to the opening quote of an `r`/`br` raw string
/// candidate starting at `i` (past the `r` or `br` prefix).
fn raw_quote_offset(chars: &[char], i: usize) -> usize {
    if chars[i] == 'b' && chars.get(i + 1) == Some(&'r') {
        2
    } else {
        1
    }
}

/// If a raw string literal (`r"…"`, `r#"…"#`, `br##"…"##`, …) opens at
/// `i`, the number of chars the opener spans; `None` otherwise.
fn raw_string_open(chars: &[char], i: usize) -> Option<usize> {
    if prev_is_ident(chars, i) {
        return None;
    }
    let start = match chars[i] {
        'r' => i + 1,
        'b' if chars.get(i + 1) == Some(&'r') => i + 2,
        _ => return None,
    };
    let mut j = start;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(j + 1 - i)
    } else {
        None
    }
}

/// A parsed `lint:allow` suppression directive.
///
/// Syntax, recognized only at the **start** of a comment's text:
///
/// ```text
/// … hazardous line   // lint:allow(rule-id) — reason
/// // lint:allow(rule-id) — reason
/// … hazardous line (the directive covers the next code line)
/// // lint:allow-file(rule-id) — reason   (whole-file suppression)
/// ```
///
/// The reason is mandatory — an allow nobody can audit is itself a
/// hazard — and separator punctuation (`—`, `-`, `:`) is optional.
#[derive(Debug, Clone)]
pub struct Directive {
    /// 1-based line the directive sits on.
    pub line: usize,
    pub rule: String,
    pub reason: String,
    pub file_level: bool,
}

/// A directive that failed to parse — surfaced as a deny-level finding
/// so a suppression can never silently fail to apply.
#[derive(Debug, Clone)]
pub struct Malformed {
    /// 1-based line of the broken directive.
    pub line: usize,
    pub detail: String,
}

/// Extract suppression directives from stripped lines. `known_rule`
/// vets rule ids; unknown ids and missing reasons come back malformed.
pub fn directives(
    lines: &[Line],
    known_rule: impl Fn(&str) -> bool,
) -> (Vec<Directive>, Vec<Malformed>) {
    let mut out = Vec::new();
    let mut bad = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let text = line.comment.trim_start();
        let (rest, file_level) = if let Some(r) = text.strip_prefix("lint:allow-file") {
            (r, true)
        } else if let Some(r) = text.strip_prefix("lint:allow") {
            (r, false)
        } else {
            continue;
        };
        let lineno = idx + 1;
        let Some(rest) = rest.strip_prefix('(') else {
            bad.push(Malformed {
                line: lineno,
                detail: "lint:allow must name a rule: `lint:allow(rule-id) — reason`".into(),
            });
            continue;
        };
        let Some(close) = rest.find(')') else {
            bad.push(Malformed {
                line: lineno,
                detail: "unclosed `(` in lint:allow directive".into(),
            });
            continue;
        };
        let rule = rest[..close].trim().to_string();
        if !known_rule(&rule) {
            bad.push(Malformed {
                line: lineno,
                detail: format!("lint:allow names unknown rule '{rule}'"),
            });
            continue;
        }
        let reason = rest[close + 1..]
            .trim_start()
            .trim_start_matches(&['—', '–', '-', ':'][..])
            .trim()
            .to_string();
        if reason.is_empty() {
            bad.push(Malformed {
                line: lineno,
                detail: format!(
                    "lint:allow({rule}) has no reason — suppressions must be auditable"
                ),
            });
            continue;
        }
        out.push(Directive {
            line: lineno,
            rule,
            reason,
            file_level,
        });
    }
    (out, bad)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        strip(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let lines = strip("let a = 1; // HashMap here\nlet b = 2; /* SystemTime */ let c;\n");
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].comment.contains("HashMap"));
        assert!(!lines[1].code.contains("SystemTime"));
        assert!(lines[1].code.contains("let c;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let lines = code_of("a /* one /* two */ still */ b\n/* open\nInstant::now\n*/ tail");
        assert!(lines[0].starts_with('a') && lines[0].ends_with('b'));
        assert!(!lines[2].contains("Instant::now"));
        assert!(lines[3].contains("tail"));
    }

    #[test]
    fn strips_strings_and_raw_strings() {
        let lines = code_of("let s = \"Instant::now \\\" quoted\"; let t = 1;");
        assert!(!lines[0].contains("Instant::now"));
        assert!(lines[0].contains("let t = 1;"));
        let lines = code_of("let r = r#\"partial_cmp \" inner\"#; end();");
        assert!(!lines[0].contains("partial_cmp"));
        assert!(lines[0].contains("end();"));
        let lines = code_of("let b = br##\"thread_rng\"##; after();");
        assert!(!lines[0].contains("thread_rng"));
        assert!(lines[0].contains("after();"));
    }

    #[test]
    fn char_literals_masked_lifetimes_kept() {
        let lines = code_of("let q = '\"'; let l: &'static str = x; let e = '\\n';");
        // the quote char literal must not open a string
        assert!(lines[0].contains("static"));
        assert!(lines[0].contains("let e ="));
    }

    #[test]
    fn multiline_string_masks_middle_lines() {
        let lines = code_of("let s = \"first\nHashMap second\nthird\"; done();");
        assert!(!lines[1].contains("HashMap"));
        assert!(lines[2].contains("done();"));
    }

    #[test]
    fn parses_directives_and_rejects_malformed() {
        let src = "\
// lint:allow(float-ord) — frozen reference\n\
// lint:allow-file(map-iter): keyed access only\n\
// lint:allow(unknown-rule) — whatever\n\
// lint:allow(float-ord)\n\
// prose that merely mentions lint:allow syntax later is prose\n";
        let lines = strip(src);
        let (dirs, bad) = directives(&lines, |r| r == "float-ord" || r == "map-iter");
        assert_eq!(dirs.len(), 2);
        assert_eq!(dirs[0].rule, "float-ord");
        assert_eq!(dirs[0].reason, "frozen reference");
        assert!(!dirs[0].file_level);
        assert!(dirs[1].file_level);
        assert_eq!(dirs[1].reason, "keyed access only");
        assert_eq!(bad.len(), 2, "{bad:?}");
        assert!(bad[0].detail.contains("unknown rule"));
        assert!(bad[1].detail.contains("no reason"));
    }

    #[test]
    fn directives_in_strings_are_invisible() {
        let src = "let s = \"// lint:allow(float-ord) — not a directive\";";
        let (dirs, bad) = directives(&strip(src), |_| true);
        assert!(dirs.is_empty() && bad.is_empty());
    }
}
