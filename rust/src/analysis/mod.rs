//! medflow-lint: the determinism static-analysis pass (`medflow lint`).
//!
//! Walks the crate's own source tree and flags hazards that would break
//! the replay contract — the property that every engine run is
//! bit-identical given the same inputs, which the parity batteries
//! (`engine_parity.rs`, `placement_parity.rs`, `tenancy_parity.rs`)
//! check dynamically and this pass enforces statically. DESIGN.md §14
//! is the contract document; [`rules::RULES`] is the machine-readable
//! half of it.
//!
//! Pipeline: [`lexer::strip`] splits each file into code/comment
//! channels → [`excluded_lines`] masks `#[cfg(test)]` items →
//! [`rules::scan`] runs the token-level matchers → suppression
//! directives (`lexer::directives`) downgrade intentional exceptions,
//! each carrying an auditable reason. The report is deterministic:
//! files in sorted path order, findings by (path, line, rule).
//!
//! Exit semantics (`--deny`): unsuppressed findings and malformed
//! directives are deny-level; unused allows are warn-level notes so a
//! fixed hazard whose stale annotation lingers never blocks CI.

pub mod lexer;
pub mod rules;

use std::path::Path;

use anyhow::{Context, Result};

use self::lexer::Line;
use self::rules::Rule;

/// One rule hit, carrying its suppression state.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static Rule,
    /// Slash-separated path relative to the linted source root.
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    pub what: String,
    /// The directive's reason when a `lint:allow` covers this hit.
    pub suppressed: Option<String>,
}

/// A location-tagged diagnostic that is not a rule finding (malformed
/// directive, unused allow).
#[derive(Debug, Clone)]
pub struct Note {
    pub path: String,
    pub line: usize,
    pub detail: String,
}

/// Scan results for one file ([`lint_source`]) or a whole tree
/// ([`lint_tree`]).
#[derive(Debug, Default)]
pub struct LintReport {
    /// Files scanned.
    pub files: usize,
    /// Every rule hit, suppressed or not, in (path, line, rule) order.
    pub findings: Vec<Finding>,
    /// Broken suppression directives — deny-level: a suppression that
    /// silently fails to apply would hide a real hazard.
    pub malformed: Vec<Note>,
    /// Directives that matched no finding — warn-level notes.
    pub unused_allows: Vec<Note>,
}

impl LintReport {
    /// Findings an auditable `lint:allow` downgraded.
    pub fn suppressed_count(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed.is_some()).count()
    }

    /// What `--deny` gates on: live findings plus malformed directives.
    pub fn deny_count(&self) -> usize {
        let live = self.findings.len() - self.suppressed_count();
        live + self.malformed.len()
    }

    /// Human-readable report, byte-identical across runs on the same
    /// tree (paths sorted, findings ordered by line then rule id).
    pub fn render(&self) -> String {
        let suppressed = self.suppressed_count();
        let mut out = format!(
            "determinism lint: {} file(s) scanned, {} finding(s) ({suppressed} suppressed), \
             {} malformed directive(s), {} unused allow(s)\n",
            self.files,
            self.findings.len(),
            self.malformed.len(),
            self.unused_allows.len()
        );
        for f in &self.findings {
            match &f.suppressed {
                None => {
                    out.push_str(&format!(
                        "  {} {:<12} {}:{}  {}\n",
                        f.rule.code, f.rule.id, f.path, f.line, f.what
                    ));
                }
                Some(reason) => {
                    out.push_str(&format!(
                        "  {} {:<12} {}:{}  allowed ({reason}) — {}\n",
                        f.rule.code, f.rule.id, f.path, f.line, f.what
                    ));
                }
            }
        }
        for n in &self.malformed {
            out.push_str(&format!("  DENY  {}:{}  {}\n", n.path, n.line, n.detail));
        }
        for n in &self.unused_allows {
            out.push_str(&format!("  note  {}:{}  {}\n", n.path, n.line, n.detail));
        }
        out
    }
}

/// Lint one file. `rel_path` is slash-separated relative to the source
/// root and decides rule scope ([`rules::in_scope`]); `filter`, when
/// `Some`, restricts the active rules and mutes unused-allow notes
/// (a directive for a filtered-out rule is not stale).
pub fn lint_source(rel_path: &str, source: &str, filter: Option<&[&'static Rule]>) -> LintReport {
    let lines = lexer::strip(source);
    let excluded = excluded_lines(&lines);
    let (dirs, bad) = lexer::directives(&lines, |id| rules::rule(id).is_some());
    let all: Vec<&'static Rule> = rules::RULES.iter().collect();
    let active: &[&'static Rule] = filter.unwrap_or(&all);
    let raw = rules::scan(rel_path, &lines, &excluded, active);

    let mut used = vec![false; dirs.len()];
    let mut findings = Vec::new();
    for hit in raw {
        let suppressed = suppression_for(&hit, &lines, &dirs, &mut used);
        findings.push(Finding {
            rule: hit.rule,
            path: rel_path.to_string(),
            line: hit.line,
            what: hit.what,
            suppressed,
        });
    }

    let malformed = bad
        .into_iter()
        .map(|m| Note { path: rel_path.to_string(), line: m.line, detail: m.detail })
        .collect();

    let mut unused_allows = Vec::new();
    if filter.is_none() {
        for (d, was_used) in dirs.iter().zip(used.iter()) {
            if !was_used {
                unused_allows.push(Note {
                    path: rel_path.to_string(),
                    line: d.line,
                    detail: format!("unused lint:allow({}) — no matching finding", d.rule),
                });
            }
        }
    }

    LintReport { files: 1, findings, malformed, unused_allows }
}

/// Lint every `.rs` file under `src_root`, in sorted path order.
pub fn lint_tree(src_root: &Path, filter: Option<&[&'static Rule]>) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for rel in files {
        let path = src_root.join(&rel);
        let source = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let one = lint_source(&rel, &source, filter);
        report.files += one.files;
        report.findings.extend(one.findings);
        report.malformed.extend(one.malformed);
        report.unused_allows.extend(one.unused_allows);
    }
    Ok(report)
}

/// Recursively collect slash-separated `.rs` paths relative to `root`.
fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// The suppression covering `hit`, if any: a file-level allow for its
/// rule, a directive on the same line, or one reachable by walking up
/// over a contiguous run of comment-only/blank lines directly above.
fn suppression_for(
    hit: &rules::RawFinding,
    lines: &[Line],
    dirs: &[lexer::Directive],
    used: &mut [bool],
) -> Option<String> {
    for (i, d) in dirs.iter().enumerate() {
        if d.file_level && d.rule == hit.rule.id {
            used[i] = true;
            return Some(d.reason.clone());
        }
    }
    let mut line = hit.line;
    loop {
        for (i, d) in dirs.iter().enumerate() {
            if !d.file_level && d.line == line && d.rule == hit.rule.id {
                used[i] = true;
                return Some(d.reason.clone());
            }
        }
        if line <= 1 {
            return None;
        }
        let above = &lines[line - 2];
        if !above.code.trim().is_empty() {
            return None;
        }
        line -= 1;
    }
}

/// Mark lines belonging to `#[cfg(test)]` items (attribute through the
/// item's closing brace, or its terminating `;` for brace-less items).
/// Tests assert on engine output rather than producing it, and
/// idiomatic test scaffolding (HashMap scratch state, wall-clock
/// timing around assertions) would drown the report in noise.
fn excluded_lines(lines: &[Line]) -> Vec<bool> {
    let mut out = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            out[j] = true;
            let mut done = false;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            done = true;
                        }
                    }
                    ';' if !opened => done = true,
                    _ => {}
                }
            }
            if done {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_deterministically_and_counts_deny() {
        let src = "fn f(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let a = lint_source("slurm/mod.rs", src, None);
        let b = lint_source("slurm/mod.rs", src, None);
        assert_eq!(a.render(), b.render());
        assert_eq!(a.deny_count(), 1);
        assert!(a.render().contains("DL002"));
        assert!(a.render().contains("slurm/mod.rs:1"));
    }

    #[test]
    fn filter_restricts_rules_and_mutes_unused_allow_notes() {
        let src = "\
// lint:allow(wall-clock) — reserved for a future measured section\n\
fn f(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let float_only: Vec<_> = rules::RULES.iter().filter(|r| r.id == "float-ord").collect();
        let scan = lint_source("netsim/mod.rs", src, Some(&float_only));
        assert_eq!(scan.findings.len(), 1);
        assert!(scan.unused_allows.is_empty(), "no unused-allow noise under a rule filter");
        let full = lint_source("netsim/mod.rs", src, None);
        assert_eq!(full.unused_allows.len(), 1);
    }

    #[test]
    fn cfg_test_exclusion_spans_the_block_only() {
        let src = "\
fn live(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n\
#[cfg(test)]\n\
mod tests {\n\
    fn t(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n\
}\n\
fn live2(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let scan = lint_source("faults/mod.rs", src, None);
        let hit_lines: Vec<_> = scan.findings.iter().map(|f| f.line).collect();
        assert_eq!(hit_lines, vec![1, 6]);
    }

    #[test]
    fn single_line_cfg_test_item_is_excluded() {
        let src = "\
#[cfg(test)] use std::collections::HashMap;\n\
fn live(x: f64, y: f64) { let _ = x.partial_cmp(&y); }\n";
        let scan = lint_source("slurm/mod.rs", src, None);
        let hit_lines: Vec<_> = scan.findings.iter().map(|f| f.line).collect();
        assert_eq!(hit_lines, vec![2], "the cfg(test) use must not leak exclusion downward");
    }
}
