//! Minimal DICOM substrate (paper §2.1: data arrives as DICOM when
//! available; medflow converts to NIfTI + JSON sidecar).
//!
//! Implements a real-if-small subset of DICOM Part 10: 128-byte preamble,
//! "DICM" magic, Explicit VR Little Endian data elements for the tags the
//! converter needs (patient/study/series/instance IDs, acquisition
//! parameters, pixel spacing, image geometry, and 16-bit pixel data). A
//! synthetic scanner ([`synth`]) emits per-slice files like a real session.

pub mod synth;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// DICOM tag (group, element).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tag(pub u16, pub u16);

pub mod tags {
    use super::Tag;
    pub const PATIENT_ID: Tag = Tag(0x0010, 0x0020);
    pub const PATIENT_NAME: Tag = Tag(0x0010, 0x0010);
    pub const STUDY_DATE: Tag = Tag(0x0008, 0x0020);
    pub const MODALITY: Tag = Tag(0x0008, 0x0060);
    pub const SERIES_DESC: Tag = Tag(0x0008, 0x103E);
    pub const PROTOCOL_NAME: Tag = Tag(0x0018, 0x1030);
    pub const STUDY_UID: Tag = Tag(0x0020, 0x000D);
    pub const SERIES_UID: Tag = Tag(0x0020, 0x000E);
    pub const SERIES_NUMBER: Tag = Tag(0x0020, 0x0011);
    pub const INSTANCE_NUMBER: Tag = Tag(0x0020, 0x0013);
    pub const ROWS: Tag = Tag(0x0028, 0x0010);
    pub const COLS: Tag = Tag(0x0028, 0x0011);
    pub const PIXEL_SPACING: Tag = Tag(0x0028, 0x0030);
    pub const SLICE_THICKNESS: Tag = Tag(0x0018, 0x0050);
    pub const ECHO_TIME: Tag = Tag(0x0018, 0x0081);
    pub const REPETITION_TIME: Tag = Tag(0x0018, 0x0080);
    pub const MAGNETIC_FIELD: Tag = Tag(0x0018, 0x0087);
    pub const MANUFACTURER: Tag = Tag(0x0008, 0x0070);
    pub const B_VALUE: Tag = Tag(0x0018, 0x9087);
    pub const PIXEL_DATA: Tag = Tag(0x7FE0, 0x0010);
}

/// Element value: strings (any text VR), u16 (US), or raw pixel payload (OW).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    U16(u16),
    Pixels(Vec<u16>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u16(&self) -> Option<u16> {
        match self {
            Value::U16(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Str(s) => s.trim().parse().ok(),
            Value::U16(v) => Some(*v as f64),
            _ => None,
        }
    }
}

/// One DICOM object (a slice file).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DicomObject {
    pub elements: BTreeMap<Tag, Value>,
}

impl DicomObject {
    pub fn set_str(&mut self, tag: Tag, v: impl Into<String>) -> &mut Self {
        self.elements.insert(tag, Value::Str(v.into()));
        self
    }

    pub fn set_u16(&mut self, tag: Tag, v: u16) -> &mut Self {
        self.elements.insert(tag, Value::U16(v));
        self
    }

    pub fn get(&self, tag: Tag) -> Option<&Value> {
        self.elements.get(&tag)
    }

    pub fn str_of(&self, tag: Tag) -> Option<&str> {
        self.get(tag).and_then(Value::as_str)
    }

    /// Encode as DICOM Part 10: preamble + DICM + Explicit VR LE elements.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = vec![0u8; 128];
        out.extend_from_slice(b"DICM");
        for (tag, value) in &self.elements {
            out.extend_from_slice(&tag.0.to_le_bytes());
            out.extend_from_slice(&tag.1.to_le_bytes());
            match value {
                Value::Str(s) => {
                    // LO (long string); even-length padded with space
                    let mut bytes = s.as_bytes().to_vec();
                    if bytes.len() % 2 == 1 {
                        bytes.push(b' ');
                    }
                    out.extend_from_slice(b"LO");
                    out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
                    out.extend_from_slice(&bytes);
                }
                Value::U16(v) => {
                    out.extend_from_slice(b"US");
                    out.extend_from_slice(&2u16.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
                Value::Pixels(px) => {
                    // OW with 32-bit length (reserved 2 bytes zero)
                    out.extend_from_slice(b"OW");
                    out.extend_from_slice(&[0, 0]);
                    out.extend_from_slice(&((px.len() * 2) as u32).to_le_bytes());
                    for v in px {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Parse DICOM Part 10 bytes (the subset [`Self::to_bytes`] emits).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 132 || &bytes[128..132] != b"DICM" {
            bail!("not a DICOM part-10 file");
        }
        let mut obj = DicomObject::default();
        let mut pos = 132;
        while pos + 8 <= bytes.len() {
            let group = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]);
            let elem = u16::from_le_bytes([bytes[pos + 2], bytes[pos + 3]]);
            let vr = &bytes[pos + 4..pos + 6];
            pos += 6;
            let tag = Tag(group, elem);
            match vr {
                b"LO" => {
                    let len = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
                    pos += 2;
                    if pos + len > bytes.len() {
                        bail!("truncated LO element at {pos}");
                    }
                    let s = String::from_utf8_lossy(&bytes[pos..pos + len])
                        .trim_end()
                        .to_string();
                    obj.elements.insert(tag, Value::Str(s));
                    pos += len;
                }
                b"US" => {
                    let len = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
                    pos += 2;
                    if len != 2 || pos + 2 > bytes.len() {
                        bail!("bad US element at {pos}");
                    }
                    obj.elements
                        .insert(tag, Value::U16(u16::from_le_bytes([bytes[pos], bytes[pos + 1]])));
                    pos += 2;
                }
                b"OW" => {
                    pos += 2; // reserved
                    if pos + 4 > bytes.len() {
                        bail!("truncated OW length");
                    }
                    let len = u32::from_le_bytes([
                        bytes[pos],
                        bytes[pos + 1],
                        bytes[pos + 2],
                        bytes[pos + 3],
                    ]) as usize;
                    pos += 4;
                    if pos + len > bytes.len() {
                        bail!("truncated pixel data: need {len} at {pos}");
                    }
                    let px: Vec<u16> = bytes[pos..pos + len]
                        .chunks_exact(2)
                        .map(|c| u16::from_le_bytes([c[0], c[1]]))
                        .collect();
                    obj.elements.insert(tag, Value::Pixels(px));
                    pos += len;
                }
                other => bail!("unsupported VR {:?}", String::from_utf8_lossy(other)),
            }
        }
        Ok(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DicomObject {
        let mut o = DicomObject::default();
        o.set_str(tags::PATIENT_ID, "sub01")
            .set_str(tags::MODALITY, "MR")
            .set_str(tags::PROTOCOL_NAME, "T1w_MPRAGE")
            .set_str(tags::PIXEL_SPACING, "1.0\\1.0")
            .set_u16(tags::ROWS, 32)
            .set_u16(tags::COLS, 32)
            .set_u16(tags::INSTANCE_NUMBER, 7);
        o.elements
            .insert(tags::PIXEL_DATA, Value::Pixels((0..32 * 32).map(|i| i as u16).collect()));
        o
    }

    #[test]
    fn roundtrip() {
        let o = sample();
        let back = DicomObject::from_bytes(&o.to_bytes()).unwrap();
        assert_eq!(back.str_of(tags::PATIENT_ID), Some("sub01"));
        assert_eq!(back.get(tags::ROWS).unwrap().as_u16(), Some(32));
        match back.get(tags::PIXEL_DATA).unwrap() {
            Value::Pixels(px) => assert_eq!(px.len(), 1024),
            _ => panic!("pixels lost"),
        }
    }

    #[test]
    fn odd_length_string_padded() {
        let mut o = DicomObject::default();
        o.set_str(tags::PATIENT_ID, "abc"); // odd length
        let back = DicomObject::from_bytes(&o.to_bytes()).unwrap();
        assert_eq!(back.str_of(tags::PATIENT_ID), Some("abc"));
    }

    #[test]
    fn rejects_non_dicom() {
        assert!(DicomObject::from_bytes(b"not dicom").is_err());
        let mut garbage = vec![0u8; 132];
        garbage[128..132].copy_from_slice(b"XXXX");
        assert!(DicomObject::from_bytes(&garbage).is_err());
    }

    #[test]
    fn rejects_truncated_pixels() {
        let o = sample();
        let bytes = o.to_bytes();
        assert!(DicomObject::from_bytes(&bytes[..bytes.len() - 10]).is_err());
    }

    #[test]
    fn numeric_string_parsing() {
        let mut o = DicomObject::default();
        o.set_str(tags::ECHO_TIME, "2.95");
        assert_eq!(o.get(tags::ECHO_TIME).unwrap().as_f64(), Some(2.95));
    }
}
