//! Synthetic scanner: emits DICOM series the way a site transfer would
//! (per-slice files, shared study/series UIDs). This is the substitution
//! for the paper's national-study data feeds (DESIGN.md §2): curation and
//! conversion logic depend on structure, not anatomy.

use super::{tags, DicomObject, Value};
use crate::util::rng::Rng;

/// Scan protocol kinds medflow curates (paper keeps T1w + DWI only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    T1w,
    Dwi,
    /// Protocols the curator filters out (fMRI, FLAIR…, paper §2).
    Other,
}

impl Protocol {
    pub fn name(self) -> &'static str {
        match self {
            Protocol::T1w => "T1w_MPRAGE",
            Protocol::Dwi => "DWI_dir98",
            Protocol::Other => "rsfMRI_bold",
        }
    }
}

/// Parameters for one synthetic series.
#[derive(Debug, Clone)]
pub struct SeriesSpec {
    pub patient_id: String,
    pub study_date: String,
    pub protocol: Protocol,
    pub series_number: u16,
    pub rows: u16,
    pub cols: u16,
    pub slices: u16,
    pub b_value: Option<f64>,
}

impl SeriesSpec {
    pub fn t1w(patient_id: &str, study_date: &str, dim: u16) -> Self {
        Self {
            patient_id: patient_id.into(),
            study_date: study_date.into(),
            protocol: Protocol::T1w,
            series_number: 2,
            rows: dim,
            cols: dim,
            slices: dim,
            b_value: None,
        }
    }

    pub fn dwi(patient_id: &str, study_date: &str, dim: u16, b: f64) -> Self {
        Self {
            patient_id: patient_id.into(),
            study_date: study_date.into(),
            protocol: Protocol::Dwi,
            series_number: 8,
            rows: dim,
            cols: dim,
            slices: dim,
            b_value: Some(b),
        }
    }
}

/// Deterministic pseudo-UID from the series identity (reproducible runs).
fn uid(parts: &[&str], rng: &mut Rng) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in parts {
        for b in p.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    format!("1.2.840.99.{}.{}", h % 1_000_000_007, rng.below(1_000_000))
}

/// Generate one series as per-slice DICOM objects with a simple phantom:
/// concentric intensity shells + noise (enough structure for the seg
/// pipeline to find three tissue classes).
pub fn synth_series(spec: &SeriesSpec, seed: u64) -> Vec<DicomObject> {
    let mut rng = Rng::new(seed);
    let study_uid = uid(&[&spec.patient_id, &spec.study_date], &mut rng);
    let series_uid = uid(&[&spec.patient_id, &spec.study_date, spec.protocol.name()], &mut rng);
    let (r, c, s) = (spec.rows as usize, spec.cols as usize, spec.slices as usize);
    let center = [r as f64 / 2.0, c as f64 / 2.0, s as f64 / 2.0];
    let mut out = Vec::with_capacity(s);
    for z in 0..s {
        let mut px = Vec::with_capacity(r * c);
        for y in 0..c {
            for x in 0..r {
                let d = ((x as f64 - center[0]).powi(2)
                    + (y as f64 - center[1]).powi(2)
                    + (z as f64 - center[2]).powi(2))
                .sqrt();
                let rmax = r as f64 / 2.0;
                let base = if d < rmax * 0.4 {
                    900.0
                } else if d < rmax * 0.65 {
                    600.0
                } else if d < rmax * 0.9 {
                    300.0
                } else {
                    50.0
                };
                let v = (base + rng.normal_ms(0.0, 15.0)).clamp(0.0, 4095.0);
                px.push(v as u16);
            }
        }
        let mut o = DicomObject::default();
        o.set_str(tags::PATIENT_ID, &spec.patient_id)
            .set_str(tags::PATIENT_NAME, format!("SYNTH^{}", spec.patient_id))
            .set_str(tags::STUDY_DATE, &spec.study_date)
            .set_str(tags::MODALITY, "MR")
            .set_str(tags::PROTOCOL_NAME, spec.protocol.name())
            .set_str(tags::SERIES_DESC, spec.protocol.name())
            .set_str(tags::STUDY_UID, &study_uid)
            .set_str(tags::SERIES_UID, &series_uid)
            .set_str(tags::MANUFACTURER, "MedflowSynth")
            .set_str(tags::PIXEL_SPACING, "1.0\\1.0")
            .set_str(tags::SLICE_THICKNESS, "1.0")
            .set_str(tags::ECHO_TIME, "2.95")
            .set_str(tags::REPETITION_TIME, "2300")
            .set_str(tags::MAGNETIC_FIELD, "3")
            .set_u16(tags::SERIES_NUMBER, spec.series_number)
            .set_u16(tags::INSTANCE_NUMBER, (z + 1) as u16)
            .set_u16(tags::ROWS, spec.rows)
            .set_u16(tags::COLS, spec.cols);
        if let Some(b) = spec.b_value {
            o.set_str(tags::B_VALUE, format!("{b}"));
        }
        o.elements.insert(tags::PIXEL_DATA, Value::Pixels(px));
        out.push(o);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_has_one_file_per_slice() {
        let spec = SeriesSpec::t1w("sub01", "20240101", 16);
        let objs = synth_series(&spec, 1);
        assert_eq!(objs.len(), 16);
        for (i, o) in objs.iter().enumerate() {
            assert_eq!(o.get(tags::INSTANCE_NUMBER).unwrap().as_u16(), Some(i as u16 + 1));
        }
    }

    #[test]
    fn uids_shared_within_series_distinct_across_patients() {
        let a = synth_series(&SeriesSpec::t1w("s1", "20240101", 4), 1);
        let b = synth_series(&SeriesSpec::t1w("s2", "20240101", 4), 1);
        let ua: Vec<_> = a.iter().map(|o| o.str_of(tags::SERIES_UID).unwrap()).collect();
        assert!(ua.windows(2).all(|w| w[0] == w[1]));
        assert_ne!(ua[0], b[0].str_of(tags::SERIES_UID).unwrap());
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = SeriesSpec::t1w("sub01", "20240101", 8);
        let a = synth_series(&spec, 7);
        let b = synth_series(&spec, 7);
        assert_eq!(a[3].to_bytes(), b[3].to_bytes());
    }

    #[test]
    fn phantom_has_tissue_contrast() {
        let spec = SeriesSpec::t1w("sub01", "20240101", 32);
        let objs = synth_series(&spec, 2);
        let mid = &objs[16];
        if let Value::Pixels(px) = mid.get(tags::PIXEL_DATA).unwrap() {
            let center = px[16 * 32 + 16] as f64;
            let edge = px[0] as f64;
            assert!(center > 700.0, "center {center}");
            assert!(edge < 200.0, "edge {edge}");
        } else {
            panic!("no pixels");
        }
    }

    #[test]
    fn dwi_series_has_bvalue() {
        let spec = SeriesSpec::dwi("sub01", "20240101", 8, 1000.0);
        let objs = synth_series(&spec, 3);
        assert_eq!(objs[0].get(tags::B_VALUE).unwrap().as_f64(), Some(1000.0));
    }
}
