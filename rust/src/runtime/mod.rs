//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the job path. Python is
//! never involved at runtime — this module is the whole L2/L1 bridge.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §4).
//!
//! PJRT execution depends on the vendored `xla` crate, which only exists
//! on the offline build image — it is gated behind the `pjrt` cargo
//! feature. Without it, [`Runtime::load`] returns an error, so
//! [`crate::compute::load_runtime`] yields `None` and every consumer falls
//! back to the calibrated duration model (identical to running without
//! `artifacts/`). Manifest parsing and integrity checking work either way.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Context, Result};

use crate::integrity::sha256_hex;
use crate::util::json::Json;

/// Input spec from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: String,
}

impl InputSpec {
    /// Total element count of the input buffer. Manifest parsing rejects
    /// negative dims ([`ArtifactManifest::load`]); a hand-built spec that
    /// smuggles one in panics here with the offending dim instead of
    /// wrapping `as usize` into an astronomically large buffer size.
    pub fn elements(&self) -> usize {
        self.shape
            .iter()
            .map(|&d| {
                usize::try_from(d).unwrap_or_else(|_| {
                    panic!(
                        "input '{}': negative dimension {d} in shape {:?}",
                        self.name, self.shape
                    )
                })
            })
            .product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        let mut artifacts = Vec::new();
        for a in json
            .get_path("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?
        {
            let artifact_name: String = a
                .get_path("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .into();
            let inputs = a
                .get_path("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|i| -> Result<InputSpec> {
                    let name: String = i
                        .get_path("name")
                        .and_then(Json::as_str)
                        .context("input missing name")?
                        .into();
                    let shape: Vec<i64> = i
                        .get_path("shape")
                        .and_then(Json::as_arr)
                        .context("input missing shape")?
                        .iter()
                        .filter_map(Json::as_i64)
                        .collect();
                    // a negative dim `as usize` would wrap to an enormous
                    // buffer size downstream — reject it at the source
                    if let Some(&bad) = shape.iter().find(|&&d| d < 0) {
                        bail!(
                            "manifest.json: artifact '{artifact_name}', input '{name}': \
                             negative dimension {bad} in shape {shape:?} — a corrupt or \
                             hand-edited manifest cannot size input buffers"
                        );
                    }
                    Ok(InputSpec {
                        name,
                        shape,
                        dtype: i
                            .get_path("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .into(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: artifact_name,
                file: a
                    .get_path("file")
                    .and_then(Json::as_str)
                    .context("artifact missing file")?
                    .into(),
                sha256: a
                    .get_path("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .into(),
                inputs,
                outputs: a
                    .get_path("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_str)
                    .map(String::from)
                    .collect(),
            });
        }
        Ok(Self { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled executable; its manifest metadata lives in `Runtime::specs`
/// (single source of truth for both cfg variants).
#[cfg(feature = "pjrt")]
struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT CPU client, one compiled executable per artifact.
/// Constructible only with the `pjrt` feature (see module docs).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    #[allow(dead_code)]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    loaded: HashMap<String, LoadedArtifact>,
    /// Manifest metadata of the loaded artifacts (name-sorted views come
    /// from [`Self::artifact_names`]).
    specs: HashMap<String, ArtifactSpec>,
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Create the CPU client, verify artifact hashes, compile everything.
    #[cfg(feature = "pjrt")]
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut loaded = HashMap::new();
        let mut specs = HashMap::new();
        for spec in manifest.artifacts {
            let path = artifact_dir.join(&spec.file);
            let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
            if !spec.sha256.is_empty() && sha256_hex(text.as_bytes()) != spec.sha256 {
                bail!(
                    "artifact '{}' fails integrity check (stale artifacts/? re-run make artifacts)",
                    spec.name
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parse hlo text {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile '{}': {e:?}", spec.name))?;
            loaded.insert(spec.name.clone(), LoadedArtifact { exe });
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Self {
            client,
            loaded,
            specs,
            artifact_dir: artifact_dir.to_path_buf(),
        })
    }

    /// Without the `pjrt` feature there is no PJRT client to create:
    /// still verifies the manifest parses and artifact hashes match, then
    /// reports the build limitation (callers degrade to the duration
    /// model, exactly as when `artifacts/` is absent).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifact_dir)?;
        for spec in &manifest.artifacts {
            let path = artifact_dir.join(&spec.file);
            let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
            if !spec.sha256.is_empty() && sha256_hex(text.as_bytes()) != spec.sha256 {
                bail!(
                    "artifact '{}' fails integrity check (stale artifacts/? re-run make artifacts)",
                    spec.name
                );
            }
        }
        bail!(
            "medflow was built without the 'pjrt' feature — PJRT artifact \
             execution is unavailable (enable it on the offline image that \
             vendors the xla crate; see DESIGN.md §4)"
        )
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Execute an artifact on f32 input buffers (shape-checked against the
    /// manifest). Returns the output tuple as Vec<f32> per output.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute_f32(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!("cannot execute artifact '{name}': built without the 'pjrt' feature")
    }

    /// Execute an artifact on f32 input buffers (shape-checked against the
    /// manifest). Returns the output tuple as Vec<f32> per output.
    #[cfg(feature = "pjrt")]
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let art_spec = self
            .specs
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let art = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not compiled"))?;
        if inputs.len() != art_spec.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                art_spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&art_spec.inputs) {
            if data.len() != spec.elements() {
                bail!(
                    "input '{}' of '{name}' wants {} elements (shape {:?}), got {}",
                    spec.name,
                    spec.elements(),
                    spec.shape,
                    data.len()
                );
            }
            let lit = xla::Literal::vec1(data);
            let lit = lit
                .reshape(&spec.shape)
                .map_err(|e| anyhow!("reshape input '{}': {e:?}", spec.name))?;
            literals.push(lit);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute '{name}': {e:?}"))?;
        // PJRT may untuple the root tuple into one buffer per output
        // (result[0].len() > 1) or hand back a single tuple buffer — handle
        // both (aot.py lowers with return_tuple=True).
        let buffers = &result[0];
        let parts: Vec<xla::Literal> = if buffers.len() > 1 {
            buffers
                .iter()
                .map(|b| {
                    b.to_literal_sync()
                        .map_err(|e| anyhow!("fetch result of '{name}': {e:?}"))
                })
                .collect::<Result<_>>()?
        } else {
            let out = buffers[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result of '{name}': {e:?}"))?;
            match out.to_tuple() {
                Ok(parts) => parts,
                // single non-tuple output
                Err(_) => vec![buffers[0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("refetch: {e:?}"))?],
            }
        };
        if parts.len() != art_spec.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                art_spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Typed view of the `seg_pipeline` artifact outputs.
#[derive(Debug, Clone)]
pub struct SegOutputs {
    pub seg: Vec<f32>,
    pub volumes: [f32; 3],
    pub means: [f32; 3],
    pub edge_qa: f32,
    pub snr_qa: f32,
}

/// Typed view of the `dwi_preproc` artifact outputs.
#[derive(Debug, Clone)]
pub struct DwiOutputs {
    pub md_map: Vec<f32>,
    pub mean_adc: Vec<f32>,
    pub b0_snr: f32,
}

/// Typed view of the `atlas_register` artifact outputs.
#[derive(Debug, Clone)]
pub struct RegisterOutputs {
    /// (tx, ty, tz, log_scale).
    pub theta: [f32; 4],
    pub warped: Vec<f32>,
    pub final_mse: f32,
    pub mse_trace: Vec<f32>,
}

pub const VOL_SHAPE: [usize; 3] = [64, 64, 64];
pub const VOL_ELEMS: usize = 64 * 64 * 64;
pub const DWI_DIRS: usize = 6;

impl Runtime {
    /// Run the structural segmentation pipeline on one 64³ volume.
    pub fn run_seg(&self, vol: &[f32]) -> Result<SegOutputs> {
        let outs = self.execute_f32("seg_pipeline", &[vol])?;
        if outs.len() != 5 {
            bail!("seg_pipeline returned {} outputs, want 5", outs.len());
        }
        Ok(SegOutputs {
            seg: outs[0].clone(),
            volumes: [outs[1][0], outs[1][1], outs[1][2]],
            means: [outs[2][0], outs[2][1], outs[2][2]],
            edge_qa: outs[3][0],
            snr_qa: outs[4][0],
        })
    }

    /// Run DWI preprocessing on one (7, 64³) shell + b-values.
    pub fn run_dwi(&self, dwi: &[f32], bvals: &[f32]) -> Result<DwiOutputs> {
        let outs = self.execute_f32("dwi_preproc", &[dwi, bvals])?;
        if outs.len() != 3 {
            bail!("dwi_preproc returned {} outputs, want 3", outs.len());
        }
        Ok(DwiOutputs {
            md_map: outs[0].clone(),
            mean_adc: outs[1].clone(),
            b0_snr: outs[2][0],
        })
    }

    /// Register a moving 64³ volume onto a fixed one (4-DOF, 60 sign-descent
    /// iterations baked into the artifact).
    pub fn run_register(&self, moving: &[f32], fixed: &[f32]) -> Result<RegisterOutputs> {
        let outs = self.execute_f32("atlas_register", &[moving, fixed])?;
        if outs.len() != 4 {
            bail!("atlas_register returned {} outputs, want 4", outs.len());
        }
        Ok(RegisterOutputs {
            theta: [outs[0][0], outs[0][1], outs[0][2], outs[0][3]],
            warped: outs[1].clone(),
            final_mse: outs[2][0],
            mse_trace: outs[3].clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    /// Synthetic 64³ phantom matching the python test fixture.
    fn phantom() -> Vec<f32> {
        let mut v = Vec::with_capacity(VOL_ELEMS);
        for z in 0..64 {
            for y in 0..64 {
                for x in 0..64 {
                    let d = (((x as f32 - 32.0).powi(2)
                        + (y as f32 - 32.0).powi(2)
                        + (z as f32 - 32.0).powi(2)) as f32)
                        .sqrt();
                    let val = if d < 12.0 {
                        0.9
                    } else if d < 20.0 {
                        0.6
                    } else if d < 28.0 {
                        0.3
                    } else {
                        0.05
                    };
                    v.push(val);
                }
            }
        }
        v
    }

    fn write_manifest(tag: &str, body: &str) -> PathBuf {
        let pid = std::process::id();
        let dir = std::env::temp_dir().join(format!("medflow_manifest_{tag}_{pid}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        dir
    }

    #[test]
    fn manifest_rejects_negative_dims_with_context() {
        // regression: a negative dim cast `as usize` wrapped to an
        // enormous element count; the parse must refuse it instead
        let dir = write_manifest(
            "negdim",
            r#"{"artifacts": [{"name": "seg_pipeline", "file": "seg.hlo.txt",
                "inputs": [{"name": "vol", "shape": [64, -64, 64], "dtype": "float32"}],
                "outputs": ["seg"]}]}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("seg_pipeline"), "{err}");
        assert!(err.contains("vol"), "{err}");
        assert!(err.contains("-64"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_accepts_well_formed_shapes() {
        let dir = write_manifest(
            "posdim",
            r#"{"artifacts": [{"name": "a", "file": "a.hlo.txt",
                "inputs": [{"name": "x", "shape": [2, 3, 4]}],
                "outputs": ["y"]}]}"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.get("a").unwrap().inputs[0].elements(), 24);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic(expected = "negative dimension")]
    fn elements_panics_clearly_on_smuggled_negative_dim() {
        let spec = InputSpec {
            name: "x".into(),
            shape: vec![4, -2],
            dtype: "float32".into(),
        };
        let _ = spec.elements();
    }

    #[test]
    fn manifest_loads() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&artifact_dir()).unwrap();
        assert!(m.get("seg_pipeline").is_some());
        assert!(m.get("dwi_preproc").is_some());
        let seg = m.get("seg_pipeline").unwrap();
        assert_eq!(seg.inputs[0].shape, vec![64, 64, 64]);
        assert_eq!(seg.outputs.len(), 5);
    }

    #[test]
    fn seg_pipeline_executes_and_conserves_voxels() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&artifact_dir()).unwrap();
        let out = rt.run_seg(&phantom()).unwrap();
        assert_eq!(out.seg.len(), VOL_ELEMS);
        // labels 0/1/2
        assert!(out.seg.iter().all(|&v| v == 0.0 || v == 1.0 || v == 2.0));
        // soft volumes conserve voxel count
        let total: f32 = out.volumes.iter().sum();
        assert!((total - VOL_ELEMS as f32).abs() < 2.0, "total={total}");
        // means ascending (sorted classes)
        assert!(out.means[0] <= out.means[1] && out.means[1] <= out.means[2]);
        assert!(out.edge_qa > 0.0 && out.snr_qa.is_finite());
    }

    #[test]
    fn dwi_pipeline_executes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&artifact_dir()).unwrap();
        let b0: Vec<f32> = phantom().iter().map(|v| v + 1.0).collect();
        let mut dwi = b0.clone();
        for k in 0..DWI_DIRS {
            let att = 0.4 + 0.05 * k as f32;
            dwi.extend(b0.iter().map(|v| v * att));
        }
        let bvals = [0.0f32, 1000.0, 1000.0, 1000.0, 1000.0, 1000.0, 1000.0];
        let out = rt.run_dwi(&dwi, &bvals).unwrap();
        assert_eq!(out.md_map.len(), VOL_ELEMS);
        assert_eq!(out.mean_adc.len(), DWI_DIRS);
        // stronger attenuation (earlier dirs) → larger ADC
        for w in out.mean_adc.windows(2) {
            assert!(w[0] > w[1], "{:?}", out.mean_adc);
        }
        assert!(out.md_map.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn wrong_shape_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&artifact_dir()).unwrap();
        assert!(rt.execute_f32("seg_pipeline", &[&[0.0f32; 10]]).is_err());
        assert!(rt.execute_f32("nope", &[]).is_err());
    }

    #[test]
    fn atlas_register_recovers_translation() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&artifact_dir()).unwrap();
        let fixed = phantom();
        // moving = fixed shifted +2 voxels along x (axis 0, stride 64²)
        let stride = 64 * 64;
        let mut moving = vec![0.05f32; VOL_ELEMS];
        for x in 0..62 {
            let (a, b) = (x * stride, (x + 2) * stride);
            moving[a..a + stride].copy_from_slice(&fixed[b..b + stride]);
        }
        let out = rt.run_register(&moving, &fixed).unwrap();
        // warped(x) = moving(x + t) = fixed(x + t + 2) ⇒ t ≈ −2
        assert!(
            (out.theta[0] + 2.0).abs() < 0.4,
            "theta = {:?}",
            out.theta
        );
        assert!(out.theta[1].abs() < 0.4 && out.theta[2].abs() < 0.4);
        assert_eq!(out.mse_trace.len(), 60);
        assert!(out.final_mse < out.mse_trace[0], "mse must improve");
        assert_eq!(out.warped.len(), VOL_ELEMS);
    }

    #[test]
    fn deterministic_across_calls() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&artifact_dir()).unwrap();
        let p = phantom();
        let a = rt.run_seg(&p).unwrap();
        let b = rt.run_seg(&p).unwrap();
        assert_eq!(a.seg, b.seg);
        assert_eq!(a.volumes, b.volumes);
    }
}
