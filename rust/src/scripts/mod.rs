//! Script generation (paper §2.3): for each runnable instance the system
//! emits a per-instance process script; on top of those it emits either a
//! SLURM job-array submission script (HPC path) or a Python parallel
//! runner (local-burst path). Users submit with a single command.
//!
//! The generated text mirrors what the paper describes: stage inputs to
//! node-local scratch with checksums, `singularity exec` the pipeline,
//! checksum + copy outputs back, write provenance. The simulator executes
//! `JobSpec`s directly; these scripts are the durable, inspectable
//! artifacts (and are tested for structure).

use crate::query::JobSpec;

/// Options the user supplies at generation time (paper: "a SLURM job array
/// script is generated according to specifications the user provides").
#[derive(Debug, Clone)]
pub struct SlurmOptions {
    pub partition: String,
    pub time_limit_hours: u32,
    pub mem_gb_per_job: u32,
    pub cores_per_job: u32,
    pub max_concurrent: u32,
    pub account: String,
}

impl Default for SlurmOptions {
    fn default() -> Self {
        Self {
            partition: "production".into(),
            time_limit_hours: 12,
            mem_gb_per_job: 16,
            cores_per_job: 1,
            max_concurrent: 200,
            account: "masi".into(),
        }
    }
}

/// Per-instance process script (bash).
pub fn instance_script(job: &JobSpec, container_sif: &str, user: &str) -> String {
    let mut s = String::new();
    s.push_str("#!/bin/bash\nset -euo pipefail\n");
    s.push_str(&format!("# medflow instance: {}\n", job.instance_id()));
    s.push_str(&format!("# generated for user: {user}\n\n"));
    s.push_str("SCRATCH=$(mktemp -d /tmp/medflow.XXXXXX)\ntrap 'rm -rf \"$SCRATCH\"' EXIT\n\n");
    s.push_str("# --- stage inputs to node-local scratch (checksummed) ---\n");
    for input in &job.inputs {
        let p = input.display();
        s.push_str(&format!("sha_src=$(sha256sum {p} | cut -d' ' -f1)\n"));
        s.push_str(&format!("cp {p} \"$SCRATCH/\"\n"));
        s.push_str(&format!(
            "sha_dst=$(sha256sum \"$SCRATCH/$(basename {p})\" | cut -d' ' -f1)\n"
        ));
        s.push_str(
            "[ \"$sha_src\" = \"$sha_dst\" ] || { echo 'CHECKSUM MISMATCH' >&2; exit 64; }\n",
        );
    }
    s.push_str("\n# --- run containerized pipeline ---\n");
    s.push_str(&format!(
        "singularity exec --bind \"$SCRATCH\":/data /containers/{container_sif} run_{} /data\n",
        job.pipeline
    ));
    s.push_str("\n# --- copy outputs back (checksummed) + provenance ---\n");
    s.push_str(&format!(
        "OUT=/store/{}/proc/{}/sub-{}{}\nmkdir -p \"$OUT\"\n",
        job.dataset,
        job.pipeline,
        job.subject,
        job.session.as_ref().map(|x| format!("/ses-{x}")).unwrap_or_default()
    ));
    s.push_str("for f in \"$SCRATCH\"/out/*; do\n");
    s.push_str("  sha_a=$(sha256sum \"$f\" | cut -d' ' -f1)\n  cp \"$f\" \"$OUT/\"\n");
    s.push_str("  sha_b=$(sha256sum \"$OUT/$(basename \"$f\")\" | cut -d' ' -f1)\n");
    s.push_str(
        "  [ \"$sha_a\" = \"$sha_b\" ] || { echo 'CHECKSUM MISMATCH' >&2; exit 64; }\ndone\n",
    );
    s.push_str(&format!(
        "medflow provenance --pipeline {} --user {user} --out \"$OUT\"\n",
        job.pipeline
    ));
    s
}

/// SLURM job-array script over N instances.
pub fn slurm_array_script(jobs: &[JobSpec], opts: &SlurmOptions) -> String {
    let n = jobs.len();
    let mut s = String::new();
    s.push_str("#!/bin/bash\n");
    s.push_str(&format!(
        "#SBATCH --job-name=medflow_{}\n",
        jobs.first().map(|j| j.pipeline.as_str()).unwrap_or("empty")
    ));
    s.push_str(&format!("#SBATCH --partition={}\n", opts.partition));
    s.push_str(&format!("#SBATCH --account={}\n", opts.account));
    s.push_str(&format!("#SBATCH --time={}:00:00\n", opts.time_limit_hours));
    s.push_str(&format!("#SBATCH --mem={}G\n", opts.mem_gb_per_job));
    s.push_str(&format!("#SBATCH --cpus-per-task={}\n", opts.cores_per_job));
    if n > 0 {
        s.push_str(&format!("#SBATCH --array=0-{}%{}\n", n - 1, opts.max_concurrent));
    }
    s.push_str("#SBATCH --output=logs/%A_%a.out\n\n");
    s.push_str("SCRIPTS=(\n");
    for job in jobs {
        s.push_str(&format!("  scripts/{}.sh\n", job.instance_id().replace('/', "_")));
    }
    s.push_str(")\n\nbash \"${SCRIPTS[$SLURM_ARRAY_TASK_ID]}\"\n");
    s
}

/// Local-burst runner: a Python file that fans instances across local
/// cores (the paper's non-SLURM fallback output).
pub fn local_runner_script(jobs: &[JobSpec], workers: usize) -> String {
    let mut s = String::new();
    s.push_str("#!/usr/bin/env python3\n");
    s.push_str("\"\"\"medflow local-burst runner (generated). Runs instance scripts\n");
    s.push_str("in parallel on a workstation when the HPC is unavailable.\"\"\"\n");
    s.push_str("import subprocess\nfrom concurrent.futures import ThreadPoolExecutor\n\n");
    s.push_str("SCRIPTS = [\n");
    for job in jobs {
        s.push_str(&format!("    \"scripts/{}.sh\",\n", job.instance_id().replace('/', "_")));
    }
    s.push_str("]\n\n");
    s.push_str("def run(script):\n");
    s.push_str("    return subprocess.run([\"bash\", script], check=True)\n\n");
    s.push_str(&format!("with ThreadPoolExecutor(max_workers={workers}) as pool:\n"));
    s.push_str("    list(pool.map(run, SCRIPTS))\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn job(sub: &str) -> JobSpec {
        JobSpec {
            dataset: "DS".into(),
            pipeline: "freesurfer".into(),
            subject: sub.into(),
            session: Some("a".into()),
            inputs: vec![PathBuf::from(format!("/store/DS/raw/sub-{sub}_T1w.nii.gz"))],
            cores: 1,
            ram_gb: 8,
        }
    }

    #[test]
    fn instance_script_has_all_stages() {
        let s = instance_script(&job("01"), "freesurfer_7.2.0.sif", "mkim");
        assert!(s.contains("sha256sum"));
        assert!(s.contains("singularity exec"));
        assert!(s.contains("freesurfer_7.2.0.sif"));
        assert!(s.contains("CHECKSUM MISMATCH"));
        assert!(s.contains("provenance"));
        assert!(s.contains("set -euo pipefail"));
        assert!(s.contains("/store/DS/proc/freesurfer/sub-01/ses-a"));
    }

    #[test]
    fn slurm_array_bounds_and_throttle() {
        let jobs: Vec<_> = (0..25).map(|i| job(&format!("{i:02}"))).collect();
        let opts = SlurmOptions {
            max_concurrent: 10,
            ..Default::default()
        };
        let s = slurm_array_script(&jobs, &opts);
        assert!(s.contains("#SBATCH --array=0-24%10"));
        assert!(s.contains("--partition=production"));
        assert_eq!(s.matches(".sh").count(), 25);
    }

    #[test]
    fn empty_job_list_has_no_array_directive() {
        let s = slurm_array_script(&[], &SlurmOptions::default());
        assert!(!s.contains("--array"));
    }

    #[test]
    fn local_runner_lists_scripts_and_workers() {
        let jobs: Vec<_> = (0..3).map(|i| job(&format!("{i:02}"))).collect();
        let s = local_runner_script(&jobs, 4);
        assert!(s.contains("max_workers=4"));
        assert_eq!(s.matches("scripts/DS_sub-").count(), 3);
        assert!(s.contains("ThreadPoolExecutor"));
    }

    #[test]
    fn scripts_differ_per_instance() {
        let a = instance_script(&job("01"), "x.sif", "u");
        let b = instance_script(&job("02"), "x.sif", "u");
        assert_ne!(a, b);
    }
}
