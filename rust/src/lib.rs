//! # medflow
//!
//! Scalable, reproducible, cost-effective processing of large-scale medical
//! imaging datasets — a full reproduction of Kim et al. (2024) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * **L3 (this crate)**: BIDS curation, archive query, script generation,
//!   SLURM-style scheduling, checksum-verified staging, provenance, cost
//!   accounting, and the semi-automated coordinator tying them together.
//! * **L2/L1 (python/compile)**: the imaging pipelines' numeric cores (JAX
//!   graphs calling Pallas kernels), AOT-lowered to `artifacts/*.hlo.txt`.
//! * **runtime**: loads those artifacts via PJRT (`xla` crate, gated
//!   behind the `pjrt` cargo feature — see [`runtime`]) and executes
//!   them from the job path — Python is never on the request path.
//!
//! Campaign-scale curation runs on the sharded entity index and
//! persistent processed-set of [`archive::index`], queried incrementally
//! by [`query::incremental`] — a second campaign over an unchanged
//! archive performs no full rescan.
//!
//! See README.md for the quickstart and paper→module map, and DESIGN.md
//! for the full system inventory and experiment index.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod archive;
pub mod backup;
pub mod bids;
pub mod compute;
pub mod config;
pub mod container;
pub mod convert;
pub mod coordinator;
pub mod cost;
pub mod dicom;
pub mod faults;
pub mod integrity;
pub mod netsim;
pub mod nifti;
pub mod pipeline;
pub mod provenance;
pub mod query;
pub mod report;
pub mod runtime;
pub mod scripts;
pub mod sim_legacy;
pub mod slurm;
pub mod util;
pub mod workload;
