//! Failure injection + retry economics (paper §4: "actual costs would
//! likely be much greater due to processing errors, debugging, and
//! resubmitting failed jobs").
//!
//! A `FaultModel` assigns each job attempt a failure mode drawn from
//! calibrated rates; the retry policy resubmits up to `max_retries` times.
//! Failed attempts still consume compute time (a fraction of the full
//! duration — most pipeline failures surface mid-run), so the *effective*
//! cost per completed job exceeds the naive estimate. The
//! `ablation_faults` bench quantifies that overrun — the paper's warning,
//! made measurable.

use crate::util::rng::Rng;

/// Why an attempt failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureMode {
    /// Transfer checksum mismatch (§2.3 abort). Fails early, cheap.
    ChecksumMismatch,
    /// Pipeline crash (bad input, OOM…). Fails mid-run.
    PipelineError,
    /// Node failure / preemption. Fails anywhere; requeue.
    NodeFailure,
    /// Wall-clock limit exceeded. Consumes the whole allocation.
    Timeout,
}

impl FailureMode {
    /// Fraction of the job's duration consumed before the failure shows.
    pub fn wasted_fraction(self) -> f64 {
        match self {
            FailureMode::ChecksumMismatch => 0.02,
            FailureMode::PipelineError => 0.45,
            FailureMode::NodeFailure => 0.50,
            FailureMode::Timeout => 1.0,
        }
    }
}

/// Per-attempt failure probabilities.
#[derive(Debug, Clone, Copy)]
pub struct FaultModel {
    pub p_checksum: f64,
    pub p_pipeline: f64,
    pub p_node: f64,
    pub p_timeout: f64,
}

impl FaultModel {
    /// No faults (the baseline cost model).
    pub fn none() -> Self {
        Self {
            p_checksum: 0.0,
            p_pipeline: 0.0,
            p_node: 0.0,
            p_timeout: 0.0,
        }
    }

    /// Rates typical of large MRI-processing campaigns (a few % of jobs
    /// fail per attempt, dominated by pipeline errors on atypical scans).
    pub fn typical() -> Self {
        Self {
            p_checksum: 0.002,
            p_pipeline: 0.04,
            p_node: 0.005,
            p_timeout: 0.01,
        }
    }

    /// A rough patch of bad input data / flaky nodes.
    pub fn harsh() -> Self {
        Self {
            p_checksum: 0.01,
            p_pipeline: 0.12,
            p_node: 0.03,
            p_timeout: 0.04,
        }
    }

    pub fn total_rate(&self) -> f64 {
        self.p_checksum + self.p_pipeline + self.p_node + self.p_timeout
    }

    /// Sample one attempt's outcome.
    pub fn sample(&self, rng: &mut Rng) -> Option<FailureMode> {
        let x = rng.next_f64();
        let mut acc = self.p_checksum;
        if x < acc {
            return Some(FailureMode::ChecksumMismatch);
        }
        acc += self.p_pipeline;
        if x < acc {
            return Some(FailureMode::PipelineError);
        }
        acc += self.p_node;
        if x < acc {
            return Some(FailureMode::NodeFailure);
        }
        acc += self.p_timeout;
        if x < acc {
            return Some(FailureMode::Timeout);
        }
        None
    }
}

/// Outcome of running one job under a fault model with retries.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptTrace {
    /// Failure modes of the failed attempts, in order.
    pub failures: Vec<FailureMode>,
    /// Whether the job ultimately completed.
    pub completed: bool,
    /// Total compute minutes consumed across all attempts, as a multiple
    /// of the nominal single-attempt duration.
    pub effective_duration_factor: f64,
}

/// Simulate attempts until success or `max_retries` resubmissions.
pub fn run_with_retries(model: &FaultModel, max_retries: u32, rng: &mut Rng) -> AttemptTrace {
    let mut failures = Vec::new();
    let mut factor = 0.0;
    for _attempt in 0..=max_retries {
        match model.sample(rng) {
            None => {
                factor += 1.0;
                return AttemptTrace {
                    failures,
                    completed: true,
                    effective_duration_factor: factor,
                };
            }
            Some(mode) => {
                factor += mode.wasted_fraction();
                failures.push(mode);
            }
        }
    }
    AttemptTrace {
        failures,
        completed: false,
        effective_duration_factor: factor,
    }
}

/// Expected cost-overrun factor for a campaign: mean effective duration of
/// *completed* jobs ÷ 1.0 (the naive estimate). The paper's §4 claim is
/// that this is noticeably above 1 in practice.
pub fn expected_overrun(model: &FaultModel, max_retries: u32, samples: u32, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut completed = 0u32;
    for _ in 0..samples {
        let t = run_with_retries(model, max_retries, &mut rng);
        if t.completed {
            total += t.effective_duration_factor;
            completed += 1;
        }
    }
    if completed == 0 {
        return f64::INFINITY;
    }
    total / completed as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_faults_means_factor_one() {
        let mut rng = Rng::new(1);
        let t = run_with_retries(&FaultModel::none(), 3, &mut rng);
        assert!(t.completed);
        assert_eq!(t.effective_duration_factor, 1.0);
        assert!(t.failures.is_empty());
        assert!((expected_overrun(&FaultModel::none(), 3, 1000, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn failure_rates_approximately_respected() {
        let model = FaultModel::typical();
        let mut rng = Rng::new(3);
        let n = 100_000;
        let fails = (0..n).filter(|_| model.sample(&mut rng).is_some()).count();
        let want = model.total_rate();
        let got = fails as f64 / n as f64;
        assert!((got - want).abs() < 0.005, "got {got} want {want}");
    }

    #[test]
    fn overrun_grows_with_fault_rate() {
        let none = expected_overrun(&FaultModel::none(), 3, 20_000, 7);
        let typical = expected_overrun(&FaultModel::typical(), 3, 20_000, 7);
        let harsh = expected_overrun(&FaultModel::harsh(), 3, 20_000, 7);
        assert!(none < typical && typical < harsh, "{none} {typical} {harsh}");
        assert!(typical > 1.01, "typical faults must cost >1% extra: {typical}");
        assert!(harsh > 1.08, "harsh faults must cost >8% extra: {harsh}");
    }

    #[test]
    fn zero_retries_can_fail() {
        let model = FaultModel::harsh();
        let mut rng = Rng::new(5);
        let any_failed = (0..1000).any(|_| !run_with_retries(&model, 0, &mut rng).completed);
        assert!(any_failed);
    }

    #[test]
    fn retries_raise_completion_rate() {
        let model = FaultModel::harsh();
        let rate = |retries| {
            let mut rng = Rng::new(9);
            (0..10_000)
                .filter(|_| run_with_retries(&model, retries, &mut rng).completed)
                .count() as f64
                / 10_000.0
        };
        let r0 = rate(0);
        let r3 = rate(3);
        assert!(r3 > r0, "{r3} vs {r0}");
        // harsh rate 0.2 ⇒ P(4 consecutive failures) = 0.2⁴ = 0.16%
        assert!(r3 > 0.995, "3 retries should nearly always complete: {r3}");
    }

    #[test]
    fn timeout_wastes_full_allocation() {
        assert_eq!(FailureMode::Timeout.wasted_fraction(), 1.0);
        assert!(FailureMode::ChecksumMismatch.wasted_fraction() < 0.1);
    }
}
