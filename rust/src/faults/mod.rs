//! Failure injection + retry economics (paper §4: "actual costs would
//! likely be much greater due to processing errors, debugging, and
//! resubmitting failed jobs").
//!
//! A [`FaultModel`] assigns each job attempt a failure mode drawn from
//! calibrated rates; the retry policy resubmits up to `max_retries` times.
//! Failed attempts still consume compute time (a fraction of the full
//! duration — most pipeline failures surface mid-run), so the *effective*
//! cost per completed job exceeds the naive estimate.
//!
//! Two generations of the model coexist (DESIGN.md §11):
//!
//! * the **closed form** ([`run_with_retries`], [`expected_overrun`]) —
//!   the §4 overrun factor in expectation, used by the cost planner and
//!   as a cross-check against the co-simulation (`benches/ablations.rs`
//!   measures it per fault regime);
//! * the **in-engine injection** ([`Injection`]) — failures sampled
//!   deterministically per (job id, attempt) *inside* the discrete-event
//!   engines (`slurm::Scheduler`, `netsim::scheduler::TransferScheduler`,
//!   `coordinator::staged::LanePool`), so retried jobs re-contend for
//!   cluster slots and shared links instead of being scaled post hoc.
//!   `benches/fault_resilience.rs` sweeps fault rates through the
//!   co-simulation at 10³–10⁵ jobs.

use crate::util::rng::Rng;

pub mod outage;

/// Why an attempt failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureMode {
    /// Transfer checksum mismatch (§2.3 abort). Fails early, cheap.
    ChecksumMismatch,
    /// Pipeline crash (bad input, OOM…). Fails mid-run.
    PipelineError,
    /// Node failure / preemption. Fails anywhere; requeue.
    NodeFailure,
    /// Wall-clock limit exceeded. Consumes the whole allocation.
    Timeout,
}

impl FailureMode {
    /// Fraction of the job's duration consumed before the failure shows.
    pub fn wasted_fraction(self) -> f64 {
        match self {
            FailureMode::ChecksumMismatch => 0.02,
            FailureMode::PipelineError => 0.45,
            FailureMode::NodeFailure => 0.50,
            FailureMode::Timeout => 1.0,
        }
    }
}

/// Per-attempt failure probabilities.
#[derive(Debug, Clone, Copy)]
pub struct FaultModel {
    pub p_checksum: f64,
    pub p_pipeline: f64,
    pub p_node: f64,
    pub p_timeout: f64,
}

impl FaultModel {
    /// No faults (the baseline cost model).
    pub fn none() -> Self {
        Self {
            p_checksum: 0.0,
            p_pipeline: 0.0,
            p_node: 0.0,
            p_timeout: 0.0,
        }
    }

    /// Rates typical of large MRI-processing campaigns (a few % of jobs
    /// fail per attempt, dominated by pipeline errors on atypical scans).
    pub fn typical() -> Self {
        Self {
            p_checksum: 0.002,
            p_pipeline: 0.04,
            p_node: 0.005,
            p_timeout: 0.01,
        }
    }

    /// A rough patch of bad input data / flaky nodes.
    pub fn harsh() -> Self {
        Self {
            p_checksum: 0.01,
            p_pipeline: 0.12,
            p_node: 0.03,
            p_timeout: 0.04,
        }
    }

    pub fn total_rate(&self) -> f64 {
        self.p_checksum + self.p_pipeline + self.p_node + self.p_timeout
    }

    /// Check the rates form a valid sub-probability distribution: every
    /// band in [0, 1] and the bands summing to ≤ 1. [`Self::sample`]'s
    /// cumulative walk silently truncates the Timeout band otherwise
    /// (e.g. `p_pipeline = 0.9, p_timeout = 0.9` would time out with
    /// probability 0.1, not 0.9) — consumers must reject such models
    /// loudly instead.
    pub fn validate(&self) -> Result<(), String> {
        let bands = [
            ("p_checksum", self.p_checksum),
            ("p_pipeline", self.p_pipeline),
            ("p_node", self.p_node),
            ("p_timeout", self.p_timeout),
        ];
        for (name, p) in bands {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!(
                    "fault model: {name} = {p} is not a probability (want 0 ≤ p ≤ 1)"
                ));
            }
        }
        let total = self.total_rate();
        if total > 1.0 {
            return Err(format!(
                "fault model: rates sum to {total} > 1 (checksum {} + pipeline {} + node {} + \
                 timeout {}) — the cumulative sampling walk would truncate the Timeout band",
                self.p_checksum, self.p_pipeline, self.p_node, self.p_timeout
            ));
        }
        Ok(())
    }

    /// The compute-side bands only (checksum mismatches belong to the
    /// transfer engine in the co-simulated split — see
    /// [`crate::coordinator`]).
    pub fn compute_only(&self) -> Self {
        Self {
            p_checksum: 0.0,
            ..*self
        }
    }

    /// The transfer-side band only (everything but checksum zeroed).
    pub fn transfer_only(&self) -> Self {
        Self {
            p_pipeline: 0.0,
            p_node: 0.0,
            p_timeout: 0.0,
            ..*self
        }
    }

    /// Sample one attempt's outcome.
    pub fn sample(&self, rng: &mut Rng) -> Option<FailureMode> {
        debug_assert!(self.validate().is_ok(), "{:?}", self.validate());
        let x = rng.next_f64();
        let mut acc = self.p_checksum;
        if x < acc {
            return Some(FailureMode::ChecksumMismatch);
        }
        acc += self.p_pipeline;
        if x < acc {
            return Some(FailureMode::PipelineError);
        }
        acc += self.p_node;
        if x < acc {
            return Some(FailureMode::NodeFailure);
        }
        acc += self.p_timeout;
        if x < acc {
            return Some(FailureMode::Timeout);
        }
        None
    }

    /// Sample the outcome of attempt `attempt` of job/transfer `id` from
    /// the deterministic per-(id, attempt) stream ([`attempt_rng`]): the
    /// verdict does not depend on event interleaving, cluster load, or
    /// how many other jobs retried first — the co-simulated engines stay
    /// replayable from the seed alone.
    pub fn sample_attempt(&self, seed: u64, id: u64, attempt: u32) -> Option<FailureMode> {
        self.sample(&mut attempt_rng(seed, id, attempt))
    }
}

/// Deterministic sampling stream for attempt `attempt` of job `id` —
/// shared by every engine that injects failures, so compute and transfer
/// verdicts are independent exactly when their seeds are.
pub fn attempt_rng(seed: u64, id: u64, attempt: u32) -> Rng {
    Rng::new(
        seed.wrapping_add(id.wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add((attempt as u64 + 1).wrapping_mul(0xD1B54A32D192ED03)),
    )
}

/// In-engine failure-injection config (the co-simulated path): which
/// model to sample, how many resubmissions a job gets, the sampling
/// seed, and the requeue policy.
#[derive(Debug, Clone, Copy)]
pub struct Injection {
    pub model: FaultModel,
    /// Resubmissions allowed per job; the attempt indexed `max_retries`
    /// is the last one.
    pub max_retries: u32,
    /// Seed of the per-(id, attempt) sampling stream.
    pub seed: u64,
    /// Requeue delay after a failed attempt: `backoff_base_s · 2^attempt`
    /// (the submit-loop's resubmit-with-backoff, paper Fig. 3).
    pub backoff_base_s: f64,
    /// Ceiling on the exponential backoff: [`Self::backoff_s`] never
    /// exceeds this. `f64::INFINITY` (the default) keeps the historical
    /// uncapped doubling — `x.min(INFINITY)` is `x` bit-for-bit, so the
    /// default replays every pre-cap trace identically.
    pub backoff_cap_s: f64,
    /// Park timed-out attempts for the caller to re-stage inputs and
    /// resubmit (the staged co-simulation drives this; a timeout wipes
    /// the node-local scratch, so the retry needs a fresh stage-in)
    /// instead of self-requeueing.
    pub park_timeouts: bool,
}

impl Injection {
    /// Injection with the default backoff (60 s base) and no parking.
    /// Panics on an invalid model — validate first at the API boundary
    /// for a recoverable error.
    pub fn new(model: FaultModel, max_retries: u32, seed: u64) -> Self {
        if let Err(e) = model.validate() {
            panic!("Injection::new: {e}");
        }
        Self {
            model,
            max_retries,
            seed,
            backoff_base_s: 60.0,
            backoff_cap_s: f64::INFINITY,
            park_timeouts: false,
        }
    }

    pub fn with_backoff(mut self, base_s: f64) -> Self {
        assert!(base_s >= 0.0 && base_s.is_finite(), "backoff must be ≥ 0");
        self.backoff_base_s = base_s;
        self
    }

    /// Cap the exponential backoff at `cap_s` seconds (must be ≥ 0; NaN
    /// rejected). Without a cap the doubling saturates only at
    /// `2^16 · base` — hours of simulated dead air at high attempt
    /// counts.
    pub fn with_backoff_cap(mut self, cap_s: f64) -> Self {
        assert!(cap_s >= 0.0 && !cap_s.is_nan(), "backoff cap must be ≥ 0");
        self.backoff_cap_s = cap_s;
        self
    }

    pub fn with_parked_timeouts(mut self) -> Self {
        self.park_timeouts = true;
        self
    }

    /// The campaign split (DESIGN.md §11), compute side: the pipeline /
    /// node / timeout bands, timeouts parked so the staged loop can
    /// re-stage inputs, sampling salted with [`FAULT_COMPUTE_SALT`].
    /// One definition shared by the campaign coordinator and the
    /// `medflow faults` CLI — the same campaign seed must replay the
    /// same retry trace in both.
    pub fn campaign_compute(
        model: &FaultModel,
        max_retries: u32,
        seed: u64,
        backoff_s: f64,
    ) -> Self {
        Self {
            model: model.compute_only(),
            max_retries,
            seed: seed ^ FAULT_COMPUTE_SALT,
            backoff_base_s: backoff_s,
            backoff_cap_s: f64::INFINITY,
            park_timeouts: true,
        }
    }

    /// The campaign split, transfer side: the checksum band only, with
    /// immediate re-enqueue (the host FIFO is the backoff), sampling
    /// salted with [`FAULT_TRANSFER_SALT`].
    pub fn campaign_transfer(model: &FaultModel, max_retries: u32, seed: u64) -> Self {
        Self {
            model: model.transfer_only(),
            max_retries,
            seed: seed ^ FAULT_TRANSFER_SALT,
            backoff_base_s: 0.0,
            backoff_cap_s: f64::INFINITY,
            park_timeouts: false,
        }
    }

    /// Compute-side injection for backend `backend` of a placement
    /// fleet (DESIGN.md §12): the [`Self::campaign_compute`] split,
    /// additionally decorrelated per backend — job ids repeat across a
    /// frontier sweep's alternative placements, and two backends must
    /// not replay each other's verdicts for the same (job, attempt).
    /// One definition shared by `coordinator::placement` and the
    /// `medflow place` CLI so the same seed replays the same per-(job,
    /// backend, attempt) trace everywhere.
    pub fn placement_compute(
        model: &FaultModel,
        max_retries: u32,
        seed: u64,
        backend: usize,
        backoff_s: f64,
    ) -> Self {
        let salted = seed
            .wrapping_add((backend as u64 + 1).wrapping_mul(FAULT_PLACEMENT_SALT));
        Self::campaign_compute(model, max_retries, salted, backoff_s)
    }

    /// Outcome of attempt `attempt` of job `id` (deterministic).
    pub fn sample(&self, id: u64, attempt: u32) -> Option<FailureMode> {
        self.model.sample_attempt(self.seed, id, attempt)
    }

    /// Retry-policy verdict for failed attempt `attempt` with mode
    /// `mode` — the single definition of the exhaustion and parking
    /// rules every engine applies (`slurm::Scheduler`, `LanePool`,
    /// `TransferScheduler` keep only the requeue *mechanics* local, so
    /// the policy cannot drift between them).
    pub fn disposition(&self, attempt: u32, mode: FailureMode) -> FaultAction {
        if attempt >= self.max_retries {
            FaultAction::Aborted
        } else if self.park_timeouts && mode == FailureMode::Timeout {
            FaultAction::Parked
        } else {
            FaultAction::Requeued
        }
    }

    /// Requeue delay after failed attempt `attempt`: exponential in the
    /// attempt index (the exponent saturates at 16 so the doubling
    /// cannot overflow), then clamped to [`Self::backoff_cap_s`].
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        (self.backoff_base_s * f64::from(2u32.saturating_pow(attempt.min(16))))
            .min(self.backoff_cap_s)
    }
}

/// What an engine did with a failed attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Requeued internally (resubmitted after backoff).
    Requeued,
    /// Parked for the driver to re-stage inputs and resubmit
    /// ([`Injection::park_timeouts`]).
    Parked,
    /// Retries exhausted; the job/transfer was dropped.
    Aborted,
}

/// One failed attempt, as recorded by a discrete-event engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Job id (compute engines) or transfer id (transfer engine).
    pub id: u64,
    /// 0-based index of the attempt that failed.
    pub attempt: u32,
    pub mode: FailureMode,
    /// Simulated time the failure surfaced.
    pub fail_s: f64,
    /// Allocation/wire seconds consumed by the failed attempt.
    pub wasted_s: f64,
    pub action: FaultAction,
}

/// Failed-attempt counts by mode.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub checksum: u64,
    pub pipeline: u64,
    pub node: u64,
    pub timeout: u64,
}

impl FaultCounts {
    pub fn record(&mut self, mode: FailureMode) {
        match mode {
            FailureMode::ChecksumMismatch => self.checksum += 1,
            FailureMode::PipelineError => self.pipeline += 1,
            FailureMode::NodeFailure => self.node += 1,
            FailureMode::Timeout => self.timeout += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.checksum + self.pipeline + self.node + self.timeout
    }
}

/// Campaign-level fault telemetry ([`crate::coordinator`] reports,
/// `medflow faults`): per-mode failed-attempt counts, retry/requeue
/// traffic, and the waste both engines accounted — plus the closed-form
/// §4 overrun as a cross-check on the co-simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultTelemetry {
    /// Failed attempts by mode, compute + transfer engines combined.
    pub counts: FaultCounts,
    /// Compute attempts requeued in-engine (backoff resubmissions).
    pub compute_retries: u64,
    /// Transfer attempts re-enqueued after a checksum mismatch.
    pub transfer_retries: u64,
    /// Timed-out attempts whose inputs were re-staged before resubmission.
    pub restages: u64,
    /// Jobs or transfers dropped after exhausting retries.
    pub aborted: u64,
    /// Allocation minutes consumed by failed compute attempts.
    pub wasted_compute_minutes: f64,
    /// Wire seconds consumed by failed transfer attempts.
    pub wasted_transfer_s: f64,
    /// Running attempts killed at infrastructure `Down` onsets
    /// ([`outage::OutageSchedule`], DESIGN.md §15); zero without a
    /// chaos schedule.
    pub outage_kills: u64,
    /// Queued jobs orphaned back to the planner at outage onsets.
    pub outage_orphans: u64,
    /// Allocation minutes wasted by outage-killed attempts.
    pub outage_wasted_minutes: f64,
    /// Closed-form §4 expected duration-overrun factor for the same
    /// model + retry budget (1.0 when fault-free) — the pre-co-simulation
    /// model, kept as a cross-check.
    pub expected_overrun_factor: f64,
}

impl Default for FaultTelemetry {
    fn default() -> Self {
        Self {
            counts: FaultCounts::default(),
            compute_retries: 0,
            transfer_retries: 0,
            restages: 0,
            aborted: 0,
            wasted_compute_minutes: 0.0,
            wasted_transfer_s: 0.0,
            outage_kills: 0,
            outage_orphans: 0,
            outage_wasted_minutes: 0.0,
            expected_overrun_factor: 1.0,
        }
    }
}

impl FaultTelemetry {
    /// Assemble campaign telemetry from both engines' outputs — the one
    /// fold (tally rules, closed-form cross-check seeding) shared by the
    /// campaign coordinator and the `medflow faults` CLI, so the two
    /// reports cannot drift for the same model and seed.
    pub fn collect(
        model: Option<&FaultModel>,
        max_retries: u32,
        seed: u64,
        compute_events: &[FaultEvent],
        transfer_events: &[FaultEvent],
        aborted: u64,
    ) -> Self {
        let mut t = Self {
            expected_overrun_factor: match model {
                Some(m) => expected_overrun(m, max_retries, 20_000, seed ^ FAULT_CROSSCHECK_SALT),
                None => 1.0,
            },
            ..Self::default()
        };
        for ev in compute_events {
            t.record_compute_event(ev);
        }
        for ev in transfer_events {
            t.record_transfer_event(ev);
        }
        t.aborted = aborted;
        t
    }

    /// Fold one compute-engine fault event in (counts, retry/restage
    /// tally, wasted minutes).
    pub fn record_compute_event(&mut self, ev: &FaultEvent) {
        self.counts.record(ev.mode);
        self.wasted_compute_minutes += ev.wasted_s / 60.0;
        match ev.action {
            FaultAction::Requeued => self.compute_retries += 1,
            FaultAction::Parked => {
                self.compute_retries += 1;
                self.restages += 1;
            }
            FaultAction::Aborted => {}
        }
    }

    /// Fold one transfer-engine fault event in.
    pub fn record_transfer_event(&mut self, ev: &FaultEvent) {
        self.counts.record(ev.mode);
        self.wasted_transfer_s += ev.wasted_s;
        if ev.action == FaultAction::Requeued {
            self.transfer_retries += 1;
        }
    }

    /// Fold an infrastructure-outage summary in (DESIGN.md §15).
    pub fn record_outage(&mut self, o: &outage::OutageStats) {
        self.outage_kills += o.killed;
        self.outage_orphans += o.orphaned;
        self.outage_wasted_minutes += o.killed_wasted_s / 60.0;
    }
}

/// Seed salts decorrelating the fault-sampling streams from each other
/// and from the compute-duration / transfer-sampling streams. Shared by
/// every injection site (`coordinator`, `medflow faults`) so the same
/// campaign seed replays the same retry trace everywhere.
pub const FAULT_COMPUTE_SALT: u64 = 0x636f_6d70_6661_756c; // "compfaul"
pub const FAULT_TRANSFER_SALT: u64 = 0x7866_6572_6661_756c; // "xferfaul"
pub const FAULT_CROSSCHECK_SALT: u64 = 0x6f76_6572_7275_6e31; // "overrun1"
/// Multiplied by `backend index + 1` to decorrelate the per-backend
/// compute-fault streams of a placement fleet (DESIGN.md §12).
pub const FAULT_PLACEMENT_SALT: u64 = 0x706c_6163_6661_756c; // "placfaul"
/// Multiplied by `tenant index + 1` to decorrelate per-tenant streams
/// of a multi-tenant co-simulation (DESIGN.md §13): tenants with
/// identical job lists must not draw identical workloads or verdicts.
pub const FAULT_TENANT_SALT: u64 = 0x7465_6e61_6e74_3031; // "tenant01"

/// Seed for tenant `tenant`'s private deterministic streams, following
/// the [`FAULT_PLACEMENT_SALT`] pattern (`Injection::placement_compute`):
/// `+1` so tenant 0 is salted too, multiply so nearby tenants land far
/// apart. In-engine compute/transfer verdicts are *additionally*
/// decorrelated per (tenant, job, attempt) without any per-tenant
/// injection: `coordinator::tenancy` flattens tenants into one global
/// job-id space, so [`attempt_rng`]'s id term separates two tenants'
/// same-numbered jobs.
pub fn tenant_seed(seed: u64, tenant: usize) -> u64 {
    seed.wrapping_add((tenant as u64 + 1).wrapping_mul(FAULT_TENANT_SALT))
}

/// Outcome of running one job under a fault model with retries.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptTrace {
    /// Failure modes of the failed attempts, in order.
    pub failures: Vec<FailureMode>,
    /// Whether the job ultimately completed.
    pub completed: bool,
    /// Total compute minutes consumed across all attempts, as a multiple
    /// of the nominal single-attempt duration.
    pub effective_duration_factor: f64,
}

/// Simulate attempts until success or `max_retries` resubmissions (the
/// closed-form model: no contention, no queueing — see [`Injection`] for
/// the in-engine path).
pub fn run_with_retries(model: &FaultModel, max_retries: u32, rng: &mut Rng) -> AttemptTrace {
    debug_assert!(model.validate().is_ok(), "{:?}", model.validate());
    let mut failures = Vec::new();
    let mut factor = 0.0;
    for _attempt in 0..=max_retries {
        match model.sample(rng) {
            None => {
                factor += 1.0;
                return AttemptTrace {
                    failures,
                    completed: true,
                    effective_duration_factor: factor,
                };
            }
            Some(mode) => {
                factor += mode.wasted_fraction();
                failures.push(mode);
            }
        }
    }
    AttemptTrace {
        failures,
        completed: false,
        effective_duration_factor: factor,
    }
}

/// Expected cost-overrun factor for a campaign: mean effective duration of
/// *completed* jobs ÷ 1.0 (the naive estimate). The paper's §4 claim is
/// that this is noticeably above 1 in practice.
pub fn expected_overrun(model: &FaultModel, max_retries: u32, samples: u32, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut completed = 0u32;
    for _ in 0..samples {
        let t = run_with_retries(model, max_retries, &mut rng);
        if t.completed {
            total += t.effective_duration_factor;
            completed += 1;
        }
    }
    if completed == 0 {
        return f64::INFINITY;
    }
    total / completed as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_seed_decorrelates_and_replays() {
        // deterministic: same (seed, tenant) → same stream seed
        assert_eq!(tenant_seed(42, 7), tenant_seed(42, 7));
        // tenant 0 is salted away from the raw seed, like backend 0 in
        // Injection::placement_compute
        assert_ne!(tenant_seed(42, 0), 42);
        // neighbours land far apart
        let a = tenant_seed(42, 0);
        let b = tenant_seed(42, 1);
        assert_ne!(a, b);
        assert!(a.abs_diff(b) > 1 << 32, "{a:#x} vs {b:#x}");
    }

    #[test]
    fn no_faults_means_factor_one() {
        let mut rng = Rng::new(1);
        let t = run_with_retries(&FaultModel::none(), 3, &mut rng);
        assert!(t.completed);
        assert_eq!(t.effective_duration_factor, 1.0);
        assert!(t.failures.is_empty());
        assert!((expected_overrun(&FaultModel::none(), 3, 1000, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn failure_rates_approximately_respected() {
        let model = FaultModel::typical();
        let mut rng = Rng::new(3);
        let n = 100_000;
        let fails = (0..n).filter(|_| model.sample(&mut rng).is_some()).count();
        let want = model.total_rate();
        let got = fails as f64 / n as f64;
        assert!((got - want).abs() < 0.005, "got {got} want {want}");
    }

    #[test]
    fn overrun_grows_with_fault_rate() {
        let none = expected_overrun(&FaultModel::none(), 3, 20_000, 7);
        let typical = expected_overrun(&FaultModel::typical(), 3, 20_000, 7);
        let harsh = expected_overrun(&FaultModel::harsh(), 3, 20_000, 7);
        assert!(none < typical && typical < harsh, "{none} {typical} {harsh}");
        assert!(typical > 1.01, "typical faults must cost >1% extra: {typical}");
        assert!(harsh > 1.08, "harsh faults must cost >8% extra: {harsh}");
    }

    #[test]
    fn zero_retries_can_fail() {
        let model = FaultModel::harsh();
        let mut rng = Rng::new(5);
        let any_failed = (0..1000).any(|_| !run_with_retries(&model, 0, &mut rng).completed);
        assert!(any_failed);
    }

    #[test]
    fn retries_raise_completion_rate() {
        let model = FaultModel::harsh();
        let rate = |retries| {
            let mut rng = Rng::new(9);
            (0..10_000)
                .filter(|_| run_with_retries(&model, retries, &mut rng).completed)
                .count() as f64
                / 10_000.0
        };
        let r0 = rate(0);
        let r3 = rate(3);
        assert!(r3 > r0, "{r3} vs {r0}");
        // harsh rate 0.2 ⇒ P(4 consecutive failures) = 0.2⁴ = 0.16%
        assert!(r3 > 0.995, "3 retries should nearly always complete: {r3}");
    }

    #[test]
    fn timeout_wastes_full_allocation() {
        assert_eq!(FailureMode::Timeout.wasted_fraction(), 1.0);
        assert!(FailureMode::ChecksumMismatch.wasted_fraction() < 0.1);
    }

    #[test]
    fn validate_accepts_stock_models() {
        for m in [FaultModel::none(), FaultModel::typical(), FaultModel::harsh()] {
            assert!(m.validate().is_ok(), "{m:?}");
        }
        // total exactly 1 is a valid (always-failing) distribution
        let all = FaultModel {
            p_checksum: 0.25,
            p_pipeline: 0.25,
            p_node: 0.25,
            p_timeout: 0.25,
        };
        assert!(all.validate().is_ok());
    }

    #[test]
    fn validate_rejects_truncating_rates() {
        // the regression: sample() would truncate the Timeout band here
        let over = FaultModel {
            p_checksum: 0.0,
            p_pipeline: 0.9,
            p_node: 0.0,
            p_timeout: 0.9,
        };
        let err = over.validate().unwrap_err();
        assert!(err.contains("sum to"), "{err}");
        assert!(err.contains("Timeout band"), "{err}");
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            let m = FaultModel {
                p_checksum: bad,
                ..FaultModel::none()
            };
            assert!(m.validate().is_err(), "p_checksum = {bad} must be rejected");
        }
    }

    #[test]
    #[should_panic(expected = "Injection::new")]
    fn injection_rejects_invalid_model() {
        let over = FaultModel {
            p_checksum: 0.6,
            p_pipeline: 0.6,
            p_node: 0.0,
            p_timeout: 0.0,
        };
        let _ = Injection::new(over, 3, 1);
    }

    #[test]
    fn attempt_sampling_is_deterministic_and_independent() {
        let m = FaultModel::harsh();
        for id in 0..50u64 {
            for attempt in 0..4u32 {
                assert_eq!(
                    m.sample_attempt(7, id, attempt),
                    m.sample_attempt(7, id, attempt),
                    "id {id} attempt {attempt} must replay"
                );
            }
        }
        // different attempts of one id draw from distinct streams
        let distinct = (0..200u64).any(|id| {
            m.sample_attempt(7, id, 0) != m.sample_attempt(7, id, 1)
                || m.sample_attempt(7, id, 1) != m.sample_attempt(7, id, 2)
        });
        assert!(distinct, "attempt index must perturb the stream");
        // and different seeds decorrelate the same (id, attempt)
        let seed_matters =
            (0..200u64).any(|id| m.sample_attempt(7, id, 0) != m.sample_attempt(8, id, 0));
        assert!(seed_matters);
    }

    #[test]
    fn attempt_rates_match_model() {
        let m = FaultModel::harsh();
        let n = 50_000u64;
        let fails = (0..n).filter(|&id| m.sample_attempt(13, id, 0).is_some()).count();
        let got = fails as f64 / n as f64;
        assert!((got - m.total_rate()).abs() < 0.01, "got {got}");
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        let inj = Injection::new(FaultModel::typical(), 3, 1).with_backoff(10.0);
        assert_eq!(inj.backoff_s(0), 10.0);
        assert_eq!(inj.backoff_s(1), 20.0);
        assert_eq!(inj.backoff_s(3), 80.0);
        assert!(inj.backoff_s(100).is_finite(), "cap must prevent overflow");
        let immediate = Injection::new(FaultModel::typical(), 3, 1).with_backoff(0.0);
        assert_eq!(immediate.backoff_s(5), 0.0);
    }

    #[test]
    fn backoff_cap_bounds_the_doubling() {
        let inj = Injection::new(FaultModel::typical(), 3, 1)
            .with_backoff(10.0)
            .with_backoff_cap(120.0);
        // below the ceiling the doubling is untouched
        assert_eq!(inj.backoff_s(0), 10.0);
        assert_eq!(inj.backoff_s(3), 80.0);
        // at and beyond the crossing attempt the ceiling binds
        assert_eq!(inj.backoff_s(4), 120.0);
        assert_eq!(inj.backoff_s(16), 120.0);
        assert_eq!(inj.backoff_s(1000), 120.0);
        // a zero cap disables backoff entirely
        let none = Injection::new(FaultModel::typical(), 3, 1)
            .with_backoff(10.0)
            .with_backoff_cap(0.0);
        assert_eq!(none.backoff_s(7), 0.0);
    }

    #[test]
    fn default_backoff_cap_is_bit_identical_to_uncapped() {
        // the default INFINITY cap must not perturb a single pre-cap
        // delay: x.min(INFINITY) == x for every finite x
        let inj = Injection::new(FaultModel::typical(), 3, 1).with_backoff(60.0);
        assert_eq!(inj.backoff_cap_s, f64::INFINITY);
        for attempt in 0..40u32 {
            let uncapped = 60.0 * f64::from(2u32.saturating_pow(attempt.min(16)));
            assert_eq!(inj.backoff_s(attempt), uncapped, "attempt {attempt}");
        }
    }

    #[test]
    #[should_panic(expected = "backoff cap")]
    fn backoff_cap_rejects_negative() {
        let _ = Injection::new(FaultModel::typical(), 3, 1).with_backoff_cap(-1.0);
    }

    #[test]
    fn fault_counts_record_and_total() {
        let mut c = FaultCounts::default();
        c.record(FailureMode::ChecksumMismatch);
        c.record(FailureMode::PipelineError);
        c.record(FailureMode::PipelineError);
        c.record(FailureMode::NodeFailure);
        c.record(FailureMode::Timeout);
        assert_eq!(c.checksum, 1);
        assert_eq!(c.pipeline, 2);
        assert_eq!(c.total(), 5);
        assert_eq!(FaultTelemetry::default().expected_overrun_factor, 1.0);
    }

    #[test]
    fn placement_injection_decorrelates_backends() {
        let m = FaultModel::harsh();
        let a = Injection::placement_compute(&m, 3, 42, 0, 60.0);
        let b = Injection::placement_compute(&m, 3, 42, 1, 60.0);
        assert_ne!(a.seed, b.seed, "backends must sample distinct streams");
        assert!(a.park_timeouts && b.park_timeouts, "campaign_compute split applies");
        assert_eq!(a.model.p_checksum, 0.0, "checksum band stays with the transfer engine");
        // some (id, attempt) verdict differs between the two backends
        let differs = (0..500u64).any(|id| {
            a.model.sample_attempt(a.seed, id, 0) != b.model.sample_attempt(b.seed, id, 0)
        });
        assert!(differs, "per-backend salting must perturb verdicts");
        // and the same backend replays identically
        let a2 = Injection::placement_compute(&m, 3, 42, 0, 60.0);
        assert_eq!(a.seed, a2.seed);
    }

    #[test]
    fn model_splits_partition_the_bands() {
        let m = FaultModel::harsh();
        let c = m.compute_only();
        let t = m.transfer_only();
        assert_eq!(c.p_checksum, 0.0);
        assert_eq!(c.p_pipeline, m.p_pipeline);
        assert_eq!(t.p_checksum, m.p_checksum);
        assert_eq!(t.p_pipeline + t.p_node + t.p_timeout, 0.0);
        assert!((c.total_rate() + t.total_rate() - m.total_rate()).abs() < 1e-15);
    }
}
