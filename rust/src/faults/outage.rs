//! Infrastructure-fault schedules (DESIGN.md §15): whole-backend
//! down/drain windows and shared-link capacity brownouts, co-simulated
//! inside the engines.
//!
//! [`super::Injection`] (DESIGN.md §11) makes individual *job attempts*
//! fail; this module makes the *infrastructure* fail. An
//! [`OutageSchedule`] is a deterministic, validated list of
//! per-backend [`ComputeOutage`] windows (a backend drains or dies for
//! an interval) and fleet-wide [`Brownout`] windows (the shared
//! bottleneck link degrades to a fraction of its capacity — factor 0 is
//! a full storage-egress stall). The engines respond in kind:
//!
//! * `slurm::Scheduler` / `coordinator::staged::LanePool` block starts
//!   inside a window (maintenance-like), orphan their queued jobs back
//!   to the planner at onset, and — under [`OutageMode::Down`] — kill
//!   running attempts (progress wasted and billed) and requeue them
//!   locally after [`OutageSchedule::kill_backoff_s`];
//! * `netsim::TransferScheduler` re-runs max-min fair share against the
//!   degraded capacity, so in-flight transfers re-contend;
//! * `coordinator::placement` re-places orphans onto surviving
//!   backends, and `coordinator::tenancy` layers SLO *enforcement* on
//!   top (budget-burn admission stops, deadline escalation).
//!
//! Everything is seeded and replayable: [`OutageSchedule::synthetic`]
//! derives a severity-scaled schedule from `(severity, fleet, horizon,
//! seed)` alone, and an empty schedule is contractually a no-op — the
//! chaos execution paths are f64-record-identical to the non-chaos ones
//! (`rust/tests/chaos_cosim.rs`).

use crate::util::rng::Rng;

/// Salt decorrelating the synthetic-schedule stream from the fault and
/// workload streams sharing the campaign seed.
pub const OUTAGE_SALT: u64 = 0x6f75_7461_6765_3031; // "outage01"

/// How a compute backend fails during an outage window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutageMode {
    /// The backend dies: running attempts are killed at onset (their
    /// progress is wasted and billed), requeued locally with the
    /// schedule's kill backoff; queued jobs are orphaned to the planner.
    Down,
    /// Administrative drain: running attempts survive to completion but
    /// nothing new starts; queued jobs are orphaned to the planner.
    Drain,
}

/// One backend-outage window `[start_s, end_s)` of a fleet schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeOutage {
    /// Fleet backend index (`coordinator::placement` order).
    pub backend: usize,
    pub mode: OutageMode,
    pub start_s: f64,
    pub end_s: f64,
}

/// A backend-local outage window, as handed to one compute engine —
/// [`ComputeOutage`] stripped of its backend index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutageWindow {
    pub mode: OutageMode,
    pub start_s: f64,
    pub end_s: f64,
}

/// One shared-link brownout window `[start_s, end_s)`: the bottleneck
/// capacity is multiplied by `factor` while the window is active
/// (`factor = 0` stalls storage egress completely).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Brownout {
    pub start_s: f64,
    pub end_s: f64,
    /// Remaining capacity fraction in `[0, 1]`.
    pub factor: f64,
}

/// A full infrastructure-fault schedule for one co-simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageSchedule {
    pub compute: Vec<ComputeOutage>,
    pub brownouts: Vec<Brownout>,
    /// Requeue delay applied to attempts killed at a [`OutageMode::Down`]
    /// onset (the infrastructure analogue of `Injection::backoff_s`).
    pub kill_backoff_s: f64,
}

impl Default for OutageSchedule {
    fn default() -> Self {
        Self::empty()
    }
}

impl OutageSchedule {
    /// The no-op schedule: contractually f64-record-identical to not
    /// passing a schedule at all (`rust/tests/chaos_cosim.rs`).
    pub fn empty() -> Self {
        Self {
            compute: Vec::new(),
            brownouts: Vec::new(),
            kill_backoff_s: 30.0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.compute.is_empty() && self.brownouts.is_empty()
    }

    /// Reject malformed windows loudly — a backwards window would make
    /// the engines' boundary events fire in the past, and an over-unity
    /// brownout factor would *add* link capacity.
    pub fn validate(&self) -> Result<(), String> {
        for (k, w) in self.compute.iter().enumerate() {
            if !w.start_s.is_finite() || !w.end_s.is_finite() || w.start_s < 0.0 {
                return Err(format!(
                    "invalid outage window #{k}: bounds must be finite and ≥ 0 \
                     (got [{}, {}))",
                    w.start_s, w.end_s
                ));
            }
            if w.end_s <= w.start_s {
                return Err(format!(
                    "invalid outage window #{k}: end {} must exceed start {}",
                    w.end_s, w.start_s
                ));
            }
        }
        for (k, b) in self.brownouts.iter().enumerate() {
            if !b.start_s.is_finite() || !b.end_s.is_finite() || b.start_s < 0.0 {
                return Err(format!(
                    "invalid brownout window #{k}: bounds must be finite and ≥ 0 \
                     (got [{}, {}))",
                    b.start_s, b.end_s
                ));
            }
            if b.end_s <= b.start_s {
                return Err(format!(
                    "invalid brownout window #{k}: end {} must exceed start {}",
                    b.end_s, b.start_s
                ));
            }
            if !b.factor.is_finite() || !(0.0..=1.0).contains(&b.factor) {
                return Err(format!(
                    "invalid brownout window #{k}: factor {} must be in [0, 1]",
                    b.factor
                ));
            }
        }
        if !self.kill_backoff_s.is_finite() || self.kill_backoff_s < 0.0 {
            return Err(format!(
                "invalid kill backoff {} (want finite, ≥ 0)",
                self.kill_backoff_s
            ));
        }
        Ok(())
    }

    /// The windows hitting backend `backend`, in schedule order.
    pub fn windows_for(&self, backend: usize) -> Vec<OutageWindow> {
        self.compute
            .iter()
            .filter(|w| w.backend == backend)
            .map(|w| OutageWindow {
                mode: w.mode,
                start_s: w.start_s,
                end_s: w.end_s,
            })
            .collect()
    }

    /// If backend `backend` is inside any outage window at time `t`,
    /// the latest end among the covering windows (the earliest instant
    /// the planner may hand it new work); `None` when the backend is up.
    pub fn in_window(&self, backend: usize, t: f64) -> Option<f64> {
        self.compute
            .iter()
            .filter(|w| w.backend == backend && w.start_s <= t && t < w.end_s)
            .map(|w| w.end_s)
            .fold(None, |acc, end| Some(acc.map_or(end, |a: f64| a.max(end))))
    }

    /// Severity-scaled synthetic schedule for an `n_backends` fleet over
    /// `horizon_s` simulated seconds — deterministic in the seed, the
    /// shared preset behind `medflow chaos --severity` and
    /// `benches/chaos_resilience.rs`.
    pub fn synthetic(
        severity: OutageSeverity,
        n_backends: usize,
        horizon_s: f64,
        seed: u64,
    ) -> Self {
        assert!(
            horizon_s > 0.0 && horizon_s.is_finite(),
            "outage horizon must be finite and > 0"
        );
        let mut sched = Self::empty();
        if n_backends == 0 {
            return sched;
        }
        let mut rng = Rng::new(seed ^ OUTAGE_SALT);
        match severity {
            OutageSeverity::None => {}
            OutageSeverity::Mild => {
                // an administrative drain on roughly half the fleet plus
                // one half-capacity brownout
                for backend in 0..n_backends {
                    if rng.next_f64() < 0.5 {
                        let start_s = (0.10 + 0.40 * rng.next_f64()) * horizon_s;
                        sched.compute.push(ComputeOutage {
                            backend,
                            mode: OutageMode::Drain,
                            start_s,
                            end_s: start_s + 0.10 * horizon_s,
                        });
                    }
                }
                sched.brownouts.push(Brownout {
                    start_s: 0.20 * horizon_s,
                    end_s: 0.35 * horizon_s,
                    factor: 0.5,
                });
            }
            OutageSeverity::Harsh => {
                // every backend dies once; half also drain later; the
                // link browns out to quarter capacity and then stalls
                for backend in 0..n_backends {
                    let start_s = (0.05 + 0.35 * rng.next_f64()) * horizon_s;
                    let len_s = (0.10 + 0.15 * rng.next_f64()) * horizon_s;
                    sched.compute.push(ComputeOutage {
                        backend,
                        mode: OutageMode::Down,
                        start_s,
                        end_s: start_s + len_s,
                    });
                    if rng.next_f64() < 0.5 {
                        let start_s = (0.55 + 0.20 * rng.next_f64()) * horizon_s;
                        sched.compute.push(ComputeOutage {
                            backend,
                            mode: OutageMode::Drain,
                            start_s,
                            end_s: start_s + 0.10 * horizon_s,
                        });
                    }
                }
                sched.brownouts.push(Brownout {
                    start_s: 0.15 * horizon_s,
                    end_s: 0.40 * horizon_s,
                    factor: 0.25,
                });
                sched.brownouts.push(Brownout {
                    start_s: 0.45 * horizon_s,
                    end_s: 0.50 * horizon_s,
                    factor: 0.0,
                });
            }
        }
        debug_assert!(sched.validate().is_ok(), "{:?}", sched.validate());
        sched
    }
}

/// Synthetic-schedule severity presets (`medflow chaos --severity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutageSeverity {
    None,
    Mild,
    Harsh,
}

impl OutageSeverity {
    pub fn label(self) -> &'static str {
        match self {
            OutageSeverity::None => "none",
            OutageSeverity::Mild => "mild",
            OutageSeverity::Harsh => "harsh",
        }
    }
}

/// Outage/degradation telemetry for one chaos run, folded into
/// `PlacementOutcome`/`TenancyReport` and `FaultTelemetry`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OutageStats {
    /// Compute-outage windows in the schedule.
    pub windows: usize,
    /// Brownout windows in the schedule.
    pub brownouts: usize,
    /// Running attempts killed at `Down` onsets.
    pub killed: u64,
    /// Queued jobs orphaned back to the planner at onsets.
    pub orphaned: u64,
    /// Orphans re-placed onto a surviving backend (the rest resubmit to
    /// their original backend at window end).
    pub re_placed: u64,
    /// Allocation seconds wasted by outage-killed attempts.
    pub killed_wasted_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_schedule_is_empty_and_valid() {
        let s = OutageSchedule::empty();
        assert!(s.is_empty());
        assert!(s.validate().is_ok());
        assert!(s.windows_for(0).is_empty());
        assert_eq!(s.in_window(0, 10.0), None);
        assert_eq!(s, OutageSchedule::default());
    }

    #[test]
    fn validate_rejects_malformed_windows() {
        let mut s = OutageSchedule::empty();
        s.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Down,
            start_s: 10.0,
            end_s: 5.0,
        });
        let err = s.validate().unwrap_err();
        assert!(err.contains("invalid outage window"), "{err}");

        let mut s = OutageSchedule::empty();
        s.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Drain,
            start_s: f64::NAN,
            end_s: 5.0,
        });
        assert!(s.validate().is_err());

        let mut s = OutageSchedule::empty();
        s.brownouts.push(Brownout {
            start_s: 0.0,
            end_s: 10.0,
            factor: 1.5,
        });
        let err = s.validate().unwrap_err();
        assert!(err.contains("factor"), "{err}");

        let mut s = OutageSchedule::empty();
        s.brownouts.push(Brownout {
            start_s: 20.0,
            end_s: 10.0,
            factor: 0.5,
        });
        assert!(s.validate().is_err());

        let mut s = OutageSchedule::empty();
        s.kill_backoff_s = -1.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn windows_for_filters_by_backend() {
        let mut s = OutageSchedule::empty();
        s.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Down,
            start_s: 10.0,
            end_s: 20.0,
        });
        s.compute.push(ComputeOutage {
            backend: 1,
            mode: OutageMode::Drain,
            start_s: 30.0,
            end_s: 40.0,
        });
        assert_eq!(s.windows_for(0).len(), 1);
        assert_eq!(s.windows_for(0)[0].mode, OutageMode::Down);
        assert_eq!(s.windows_for(1)[0].start_s, 30.0);
        assert!(s.windows_for(2).is_empty());
    }

    #[test]
    fn in_window_reports_latest_covering_end() {
        let mut s = OutageSchedule::empty();
        s.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Down,
            start_s: 10.0,
            end_s: 20.0,
        });
        s.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Drain,
            start_s: 15.0,
            end_s: 30.0,
        });
        assert_eq!(s.in_window(0, 5.0), None);
        assert_eq!(s.in_window(0, 10.0), Some(20.0), "window start is inclusive");
        assert_eq!(s.in_window(0, 16.0), Some(30.0), "overlap: latest end wins");
        assert_eq!(s.in_window(0, 20.0), Some(30.0), "window end is exclusive");
        assert_eq!(s.in_window(0, 30.0), None);
        assert_eq!(s.in_window(1, 16.0), None);
    }

    #[test]
    fn synthetic_is_deterministic_and_severity_scaled() {
        let a = OutageSchedule::synthetic(OutageSeverity::Harsh, 3, 10_000.0, 42);
        let b = OutageSchedule::synthetic(OutageSeverity::Harsh, 3, 10_000.0, 42);
        assert_eq!(a, b, "same seed must replay the same schedule");
        let c = OutageSchedule::synthetic(OutageSeverity::Harsh, 3, 10_000.0, 43);
        assert_ne!(a, c, "the seed must matter");

        let none = OutageSchedule::synthetic(OutageSeverity::None, 3, 10_000.0, 42);
        assert!(none.is_empty());
        let mild = OutageSchedule::synthetic(OutageSeverity::Mild, 3, 10_000.0, 42);
        // harsh hits every backend with a Down window; mild only drains
        assert!(a.compute.len() >= 3, "{a:?}");
        assert!(a.compute.iter().filter(|w| w.mode == OutageMode::Down).count() >= 3);
        assert!(mild.compute.iter().all(|w| w.mode == OutageMode::Drain), "{mild:?}");
        assert!(a.brownouts.len() > mild.brownouts.len());
        assert!(a.brownouts.iter().any(|b| b.factor == 0.0), "harsh includes a stall");
        for s in [&a, &mild] {
            assert!(s.validate().is_ok());
        }
    }

    #[test]
    fn synthetic_handles_empty_fleet() {
        let s = OutageSchedule::synthetic(OutageSeverity::Harsh, 0, 1_000.0, 7);
        assert!(s.compute.is_empty());
    }

    #[test]
    #[should_panic(expected = "horizon")]
    fn synthetic_rejects_bad_horizon() {
        let _ = OutageSchedule::synthetic(OutageSeverity::Mild, 2, 0.0, 7);
    }
}
