//! Compute-environment executors: one job instance = stage inputs
//! (netsim-timed, checksum-verified), execute the pipeline's artifact
//! through PJRT (real compute), copy outputs back (netsim-timed), emit
//! provenance. The wall-clock at paper scale comes from the calibrated
//! duration model; the *numeric* outputs come from the real artifact.

use std::path::Path;

use anyhow::{Context, Result};

use crate::cost::compute_cost;
use crate::netsim::{Env, NetProfile};
use crate::pipeline::PipelineSpec;
use crate::query::JobSpec;
use crate::runtime::{Runtime, DWI_DIRS, VOL_ELEMS};
use crate::util::rng::Rng;

/// Outcome of one executed job instance.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub instance_id: String,
    pub env: Env,
    /// Simulated staging time (storage → compute), seconds.
    pub stage_in_s: f64,
    /// Simulated copy-back time, seconds.
    pub stage_out_s: f64,
    /// Modeled pipeline wall-clock at paper scale, minutes.
    pub compute_minutes: f64,
    /// Measured PJRT execution time for the artifact (real), seconds.
    pub artifact_exec_s: f64,
    /// Direct cost in dollars (compute-slot time × env rate).
    pub cost_dollars: f64,
    /// QA scalars from the artifact (empty for model-only pipelines).
    pub qa: Vec<(String, f64)>,
}

/// Executes jobs in a given environment profile.
pub struct Executor<'rt> {
    pub env: Env,
    pub profile: NetProfile,
    runtime: Option<&'rt Runtime>,
    /// Relative compute speed vs HPC (paper Table 1: cloud slightly faster,
    /// local slightly slower — 375.5 / 355.2 / 386.0 minutes).
    speed_factor: f64,
}

/// Paper Table 1 Freesurfer minutes per environment (the calibration
/// anchor for relative compute speed).
pub fn env_speed_factor(env: Env) -> f64 {
    match env {
        Env::Hpc => 1.0,
        Env::Cloud => 375.5 / 355.2,
        Env::Local => 375.5 / 386.0,
    }
}

impl<'rt> Executor<'rt> {
    pub fn new(env: Env, runtime: Option<&'rt Runtime>) -> Self {
        Self {
            env,
            profile: NetProfile::of(env),
            runtime,
            speed_factor: env_speed_factor(env),
        }
    }

    /// Execute one job instance: returns the outcome, or an error if input
    /// staging fails integrity checks (the paper's abort condition).
    ///
    /// Transfers are sampled **independently** per job — the
    /// single-stream special case of the transfer model. Campaigns run
    /// through [`Self::run_compute`] instead and take their transfer
    /// times from the contention-aware scheduler
    /// ([`crate::netsim::scheduler`]).
    pub fn run(
        &self,
        job: &JobSpec,
        spec: &PipelineSpec,
        input_bytes: u64,
        rng: &mut Rng,
        volume: Option<&[f32]>,
    ) -> Result<JobOutcome> {
        // --- stage in ---
        let stage_in_s = self.profile.transfer_time(rng, input_bytes);
        // --- compute: sample the paper-scale duration, scaled by env ---
        let compute_minutes = spec.sample_minutes(rng) / self.speed_factor;
        // --- real artifact execution (when the pipeline has one) ---
        let (artifact_exec_s, qa) = self.run_artifact(spec, rng, volume)?;
        // --- stage out ---
        let stage_out_s = self.profile.transfer_time(rng, spec.output_bytes);
        // --- cost: slot held for transfer + compute ---
        let total_minutes = compute_minutes + (stage_in_s + stage_out_s) / 60.0;
        let cost_dollars = compute_cost(self.env, total_minutes);
        Ok(JobOutcome {
            instance_id: job.instance_id(),
            env: self.env,
            stage_in_s,
            stage_out_s,
            compute_minutes,
            artifact_exec_s,
            cost_dollars,
            qa,
        })
    }

    /// Execute one job's **compute phase only**: sample the paper-scale
    /// duration and run the real artifact. Staging fields start at zero
    /// and `cost_dollars` covers compute only — the staged campaign path
    /// ([`crate::coordinator::staged`]) fills both in from the transfer
    /// scheduler's contended timings via [`crate::cost::staged_job_cost`].
    pub fn run_compute(
        &self,
        job: &JobSpec,
        spec: &PipelineSpec,
        rng: &mut Rng,
        volume: Option<&[f32]>,
    ) -> Result<JobOutcome> {
        let compute_minutes = spec.sample_minutes(rng) / self.speed_factor;
        let (artifact_exec_s, qa) = self.run_artifact(spec, rng, volume)?;
        Ok(JobOutcome {
            instance_id: job.instance_id(),
            env: self.env,
            stage_in_s: 0.0,
            stage_out_s: 0.0,
            compute_minutes,
            artifact_exec_s,
            cost_dollars: compute_cost(self.env, compute_minutes),
            qa,
        })
    }

    /// Run the pipeline's PJRT artifact (when it has one and a runtime is
    /// loaded), returning measured execution seconds and QA scalars.
    fn run_artifact(
        &self,
        spec: &PipelineSpec,
        rng: &mut Rng,
        volume: Option<&[f32]>,
    ) -> Result<(f64, Vec<(String, f64)>)> {
        let mut artifact_exec_s = 0.0;
        let mut qa = Vec::new();
        if let (Some(artifact), Some(rt)) = (spec.artifact, self.runtime) {
            // lint:allow(wall-clock) — measures real PJRT artifact execution,
            // reported as artifact_exec_s; it never feeds the simulated clock
            let t0 = std::time::Instant::now();
            match artifact {
                "seg_pipeline" => {
                    let vol = volume
                        .map(|v| v.to_vec())
                        .unwrap_or_else(|| default_volume(rng));
                    let out = rt.run_seg(&vol).context("seg artifact")?;
                    qa.push(("edge_qa".into(), out.edge_qa as f64));
                    qa.push(("snr_qa".into(), out.snr_qa as f64));
                    qa.push(("csf_voxels".into(), out.volumes[0] as f64));
                    qa.push(("gm_voxels".into(), out.volumes[1] as f64));
                    qa.push(("wm_voxels".into(), out.volumes[2] as f64));
                }
                "dwi_preproc" => {
                    let (dwi, bvals) = default_dwi(rng);
                    let out = rt.run_dwi(&dwi, &bvals).context("dwi artifact")?;
                    qa.push(("b0_snr".into(), out.b0_snr as f64));
                    let md_mean =
                        out.md_map.iter().map(|&v| v as f64).sum::<f64>() / out.md_map.len() as f64;
                    qa.push(("md_mean".into(), md_mean));
                }
                "atlas_register" => {
                    // register the session volume onto the canonical phantom
                    // "atlas" (noise-free default volume)
                    let moving = volume
                        .map(|v| v.to_vec())
                        .unwrap_or_else(|| default_volume(rng));
                    let atlas = default_volume(&mut crate::util::rng::Rng::new(0));
                    let out = rt.run_register(&moving, &atlas).context("register artifact")?;
                    qa.push(("reg_tx".into(), out.theta[0] as f64));
                    qa.push(("reg_ty".into(), out.theta[1] as f64));
                    qa.push(("reg_tz".into(), out.theta[2] as f64));
                    qa.push(("reg_log_scale".into(), out.theta[3] as f64));
                    qa.push(("reg_final_mse".into(), out.final_mse as f64));
                }
                other => anyhow::bail!("unknown artifact '{other}'"),
            }
            artifact_exec_s = t0.elapsed().as_secs_f64();
        }
        Ok((artifact_exec_s, qa))
    }
}

/// Deterministic filler volume when the job has no staged NIfTI (64³,
/// normalized phantom + noise).
pub fn default_volume(rng: &mut Rng) -> Vec<f32> {
    let mut v = Vec::with_capacity(VOL_ELEMS);
    for z in 0..64u32 {
        for y in 0..64u32 {
            for x in 0..64u32 {
                let d = (((x as f64 - 32.0).powi(2)
                    + (y as f64 - 32.0).powi(2)
                    + (z as f64 - 32.0).powi(2)) as f64)
                    .sqrt();
                let base = if d < 12.0 {
                    0.9
                } else if d < 20.0 {
                    0.6
                } else if d < 28.0 {
                    0.3
                } else {
                    0.05
                };
                v.push((base + rng.normal_ms(0.0, 0.02)).clamp(0.0, 1.0) as f32);
            }
        }
    }
    v
}

/// Deterministic DWI shell (b0 + 6 attenuated directions).
pub fn default_dwi(rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    let b0: Vec<f32> = default_volume(rng).iter().map(|v| v + 1.0).collect();
    let mut dwi = b0.clone();
    for k in 0..DWI_DIRS {
        let att = 0.4 + 0.05 * k as f32;
        dwi.extend(b0.iter().map(|v| v * att));
    }
    let mut bvals = vec![0.0f32];
    bvals.extend(std::iter::repeat(1000.0).take(DWI_DIRS));
    (dwi, bvals)
}

/// Load the shared runtime from the conventional artifact dir, if built.
pub fn load_runtime(repo_root: &Path) -> Option<Runtime> {
    let dir = repo_root.join("artifacts");
    if dir.join("manifest.json").exists() {
        Runtime::load(&dir).ok()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::by_name;

    fn job() -> JobSpec {
        JobSpec {
            dataset: "DS".into(),
            pipeline: "freesurfer".into(),
            subject: "01".into(),
            session: None,
            inputs: vec![],
            cores: 1,
            ram_gb: 8,
        }
    }

    #[test]
    fn model_only_pipeline_runs_without_runtime() {
        let ex = Executor::new(Env::Hpc, None);
        let spec = by_name("biscuit").unwrap();
        let mut rng = Rng::new(1);
        let out = ex.run(&job(), &spec, 30_000_000, &mut rng, None).unwrap();
        assert!(out.compute_minutes > 0.0);
        assert!(out.cost_dollars > 0.0);
        assert!(out.qa.is_empty());
        assert_eq!(out.artifact_exec_s, 0.0);
    }

    #[test]
    fn run_compute_samples_no_transfers() {
        let ex = Executor::new(Env::Hpc, None);
        let spec = by_name("biscuit").unwrap();
        let mut rng = Rng::new(4);
        let out = ex.run_compute(&job(), &spec, &mut rng, None).unwrap();
        assert_eq!(out.stage_in_s, 0.0);
        assert_eq!(out.stage_out_s, 0.0);
        assert!(out.compute_minutes > 0.0);
        let compute_only = crate::cost::compute_cost(Env::Hpc, out.compute_minutes);
        assert!((out.cost_dollars - compute_only).abs() < 1e-12);
    }

    #[test]
    fn env_speed_factors_match_table1() {
        assert!((env_speed_factor(Env::Hpc) - 1.0).abs() < 1e-12);
        assert!(env_speed_factor(Env::Cloud) > 1.0);
        assert!(env_speed_factor(Env::Local) < 1.0);
    }

    #[test]
    fn cloud_costs_dominate_hpc() {
        let spec = by_name("freesurfer").unwrap();
        let mut a = Rng::new(2);
        let mut b = Rng::new(2);
        let hpc = Executor::new(Env::Hpc, None)
            .run(&job(), &spec, 30_000_000, &mut a, None)
            .unwrap();
        let cloud = Executor::new(Env::Cloud, None)
            .run(&job(), &spec, 30_000_000, &mut b, None)
            .unwrap();
        assert!(cloud.cost_dollars > 10.0 * hpc.cost_dollars);
    }

    #[test]
    fn artifact_backed_pipeline_reports_qa() {
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let Some(rt) = load_runtime(&root) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ex = Executor::new(Env::Hpc, Some(&rt));
        let spec = by_name("freesurfer").unwrap();
        let mut rng = Rng::new(3);
        let out = ex.run(&job(), &spec, 30_000_000, &mut rng, None).unwrap();
        assert!(out.artifact_exec_s > 0.0);
        let qa: std::collections::HashMap<_, _> = out.qa.iter().cloned().collect();
        assert!(qa.contains_key("gm_voxels"));
        let total = qa["csf_voxels"] + qa["gm_voxels"] + qa["wm_voxels"];
        assert!((total - VOL_ELEMS as f64).abs() < 2.0, "total={total}");
    }

    #[test]
    fn default_volume_deterministic() {
        let a = default_volume(&mut Rng::new(5));
        let b = default_volume(&mut Rng::new(5));
        assert_eq!(a, b);
    }
}
