//! Network + storage transfer simulation (paper §2.4, Table 1).
//!
//! Models the three compute environments' storage→compute data paths as a
//! latency + composite-throughput model calibrated to the paper's measured
//! values (DESIGN.md §2 records the substitution):
//!
//! | env   | throughput (Gb/s) | latency (ms)  | path                          |
//! |-------|-------------------|---------------|-------------------------------|
//! | HPC   | 0.60 ± 0.08       | 0.16 ± 0.25   | HDD store → 100 Gb fabric → HDD node |
//! | cloud | 0.33 ± 0.01       | 19.56 ± 0.17  | HDD store → WAN → SSD EC2     |
//! | local | 0.81 ± 0.01       | 1.64 ± 0.25   | SSD → workstation LAN → SSD   |
//!
//! The composite throughput is dominated by disk read+write on the HPC path
//! (hence < 1 Gb/s despite the 100 Gb fabric — paper §4) and by the WAN on
//! the cloud path. Samples are drawn per transfer so repeated experiments
//! reproduce the paper's mean ± stdev columns.
//!
//! [`NetProfile::transfer_time`] samples each transfer **independently**
//! — it is the single-stream special case. Concurrent data movement
//! (campaign stage-in storms, overlapping copy-back) goes through the
//! contention-aware [`scheduler`], which divides the shared component
//! capacities of [`components`] fairly among active streams
//! (DESIGN.md §9).

pub mod components;
pub mod scheduler;

use crate::util::rng::Rng;
use crate::util::units::gbps_to_bytes_per_sec;

/// Compute environment identity (Table 1 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Env {
    Hpc,
    Cloud,
    Local,
}

impl Env {
    pub fn name(self) -> &'static str {
        match self {
            Env::Hpc => "HPC (ACCRE)",
            Env::Cloud => "Cloud (AWS t2.xlarge)",
            Env::Local => "Local",
        }
    }

    pub fn all() -> [Env; 3] {
        [Env::Hpc, Env::Cloud, Env::Local]
    }
}

/// Transfer-path model for one environment.
#[derive(Debug, Clone, Copy)]
pub struct NetProfile {
    pub env: Env,
    /// Composite storage→compute throughput, Gb/s (mean, std).
    pub throughput_gbps: (f64, f64),
    /// Round-trip latency for a 64-byte packet, ms (mean, std). The std in
    /// the paper is measurement jitter; we clamp samples at 10 µs.
    pub latency_ms: (f64, f64),
}

impl NetProfile {
    pub fn of(env: Env) -> Self {
        match env {
            // HDD read (~155 MB/s) → 100 Gb fabric → HDD write (~150 MB/s)
            // composite ≈ 75 MB/s ≈ 0.60 Gb/s.
            Env::Hpc => Self {
                env,
                throughput_gbps: (0.60, 0.08),
                latency_ms: (0.16, 0.25),
            },
            // HDD read → ~63 MB/s WAN → SSD write; WAN RTT dominates latency.
            Env::Cloud => Self {
                env,
                throughput_gbps: (0.33, 0.01),
                latency_ms: (19.56, 0.17),
            },
            // SSD → workstation LAN → SSD.
            Env::Local => Self {
                env,
                throughput_gbps: (0.81, 0.01),
                latency_ms: (1.64, 0.25),
            },
        }
    }

    /// Sample the time (seconds) to move `bytes` from storage to compute.
    pub fn transfer_time(&self, rng: &mut Rng, bytes: u64) -> f64 {
        let gbps = rng
            .normal_ms(self.throughput_gbps.0, self.throughput_gbps.1)
            .max(0.01);
        let latency_s = self.ping_ms(rng) / 1e3;
        latency_s + bytes as f64 / gbps_to_bytes_per_sec(gbps)
    }

    /// Sample one 64-byte round trip (milliseconds).
    pub fn ping_ms(&self, rng: &mut Rng) -> f64 {
        rng.normal_ms(self.latency_ms.0, self.latency_ms.1).max(0.01)
    }

    /// Observed throughput (Gb/s) for one sampled transfer of `bytes`.
    pub fn observed_gbps(&self, rng: &mut Rng, bytes: u64) -> f64 {
        let t = self.transfer_time(rng, bytes);
        bytes as f64 * 8.0 / 1e9 / t
    }
}

/// The paper's §2.4 bandwidth experiment: copy a 1 GB file `n` times,
/// report per-copy observed throughput samples (Gb/s).
pub fn bandwidth_experiment(env: Env, n: usize, seed: u64) -> Vec<f64> {
    let profile = NetProfile::of(env);
    let mut rng = Rng::new(seed);
    let gb = 1_000_000_000u64;
    (0..n).map(|_| profile.observed_gbps(&mut rng, gb)).collect()
}

/// The paper's §2.4 latency experiment: 100 pings of 64 bytes (ms samples).
pub fn latency_experiment(env: Env, n: usize, seed: u64) -> Vec<f64> {
    let profile = NetProfile::of(env);
    let mut rng = Rng::new(seed);
    (0..n).map(|_| profile.ping_ms(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::mean_std;

    #[test]
    fn bandwidth_matches_paper_calibration() {
        // (env, expected mean Gb/s, tolerance)
        for (env, want) in [(Env::Hpc, 0.60), (Env::Cloud, 0.33), (Env::Local, 0.81)] {
            let samples = bandwidth_experiment(env, 100, 42);
            let (mean, _) = mean_std(&samples);
            assert!(
                (mean - want).abs() < 0.05,
                "{env:?}: mean {mean} want {want}"
            );
        }
    }

    #[test]
    fn latency_matches_paper_calibration() {
        for (env, want, tol) in [
            (Env::Hpc, 0.16, 0.1),
            (Env::Cloud, 19.56, 0.2),
            (Env::Local, 1.64, 0.15),
        ] {
            let samples = latency_experiment(env, 100, 42);
            let (mean, _) = mean_std(&samples);
            assert!((mean - want).abs() < tol, "{env:?}: mean {mean} want {want}");
        }
    }

    #[test]
    fn cloud_latency_dominates() {
        let (hpc, _) = mean_std(&latency_experiment(Env::Hpc, 100, 1));
        let (cloud, _) = mean_std(&latency_experiment(Env::Cloud, 100, 1));
        let (local, _) = mean_std(&latency_experiment(Env::Local, 100, 1));
        assert!(cloud > 10.0 * local && local > hpc);
    }

    #[test]
    fn ordering_local_fastest_cloud_slowest() {
        let m = |e| mean_std(&bandwidth_experiment(e, 100, 7)).0;
        assert!(m(Env::Local) > m(Env::Hpc));
        assert!(m(Env::Hpc) > m(Env::Cloud));
    }

    #[test]
    fn transfer_time_scales_with_size() {
        let p = NetProfile::of(Env::Hpc);
        let mut rng = Rng::new(3);
        let t_small: f64 = (0..50).map(|_| p.transfer_time(&mut rng, 1_000_000)).sum();
        let mut rng = Rng::new(3);
        let t_big: f64 = (0..50).map(|_| p.transfer_time(&mut rng, 1_000_000_000)).sum();
        assert!(t_big > 50.0 * t_small / 10.0);
    }

    #[test]
    fn small_files_latency_bound_on_cloud() {
        // a 1 KB file on cloud should take ≈ latency, not bandwidth time
        let p = NetProfile::of(Env::Cloud);
        let mut rng = Rng::new(5);
        let t = p.transfer_time(&mut rng, 1_000);
        assert!(t > 0.015 && t < 0.025, "t={t}");
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(bandwidth_experiment(Env::Hpc, 10, 9), bandwidth_experiment(Env::Hpc, 10, 9));
        assert_ne!(bandwidth_experiment(Env::Hpc, 10, 9), bandwidth_experiment(Env::Hpc, 10, 10));
    }

    #[test]
    fn samples_always_positive() {
        for env in Env::all() {
            for s in bandwidth_experiment(env, 1000, 11) {
                assert!(s > 0.0);
            }
            for s in latency_experiment(env, 1000, 11) {
                assert!(s > 0.0);
            }
        }
    }
}
