//! Contention-aware transfer scheduler (DESIGN.md §9): a discrete-event
//! model of *concurrent* storage→compute data movement.
//!
//! [`super::NetProfile::transfer_time`] samples every transfer
//! independently, which silently overstates throughput the moment more
//! than one job moves data (the paper's §2.4 numbers are measured on a
//! shared path — HDD store, fabric/WAN, node disk). This module models
//! the sharing explicitly:
//!
//! * a [`Topology`] is the environment's ordered component capacities
//!   (disk read, fabric/WAN, disk write) reused verbatim from
//!   [`super::components::TransferPath`] — every stream traverses every
//!   component, so the binding constraint is the **bottleneck** link;
//! * active streams divide the bottleneck capacity by **progressive
//!   filling** (max-min fair share, [`fair_share`]): adding a stream
//!   re-splits capacity and re-times every in-flight completion, and a
//!   stream whose own sampled ceiling is below its fair share donates
//!   the surplus to the others;
//! * each host admits at most [`Topology::max_streams_per_host`]
//!   concurrent streams; excess transfers queue FIFO and their queue
//!   wait is reported separately from transfer time;
//! * per-stream ceilings and latencies are sampled from the calibrated
//!   [`super::NetProfile`] with a deterministic per-transfer RNG, so a
//!   **single stream reproduces the sampling API exactly** (the Table 1
//!   calibration is the 1-stream special case — see
//!   `rust/tests/transfer_parity.rs`).
//!
//! The scheduler advances with [`TransferScheduler::advance_to`] /
//! [`TransferScheduler::next_event_time`] so it can be co-simulated with
//! a compute backend ([`crate::coordinator::staged`]), overlapping
//! stage-in, compute, and stage-out across a campaign.
//!
//! **Event-engine scale (DESIGN.md §10):** future submissions sit in a
//! binary heap keyed by (submit time, id), due-but-blocked transfers in
//! per-host FIFO queues, and the fair-share allocation is cached
//! between events instead of being recomputed inside every
//! `next_event_time`/`integrate` call. One event costs O(log n + k)
//! for k concurrently open streams (k ≤ hosts × stream cap), so 10⁶
//! transfers simulate in near-linear time — versus the retained pre-PR
//! engine ([`crate::sim_legacy`]) whose globally sorted queue was
//! re-scanned per event (O(n²) per campaign, usable to ~10⁴). The
//! rewrite is record-for-record identical to the pre-PR engine,
//! enforced by `rust/tests/engine_parity.rs`.
//!
//! **In-engine checksum faults (DESIGN.md §11):** with
//! [`TransferScheduler::set_faults`], each drained stream samples a
//! §2.3 verification verdict deterministically per (id, attempt); a
//! mismatch discards the landed bytes and re-enqueues the transfer at
//! the failure instant, so retries re-contend for the bottleneck link
//! and the per-host stream cap. Fault-free (or zero-rate) the engine is
//! bit-identical to the pre-injection one.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

use super::components::TransferPath;
use super::{Env, NetProfile};
use crate::faults::outage::Brownout;
use crate::faults::{FaultAction, FaultEvent, Injection};
use crate::util::ord::F64Ord;
use crate::util::rng::Rng;
use crate::util::units::gbps_to_bytes_per_sec;

/// Comparison slack for event times (seconds) — transfers are O(ms..h).
const EPS: f64 = 1e-9;

/// Remaining-byte threshold below which a stream counts as drained.
const DONE_BYTES: f64 = 0.5;

/// One shared capacity component on the storage→compute path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub name: &'static str,
    pub capacity_gbps: f64,
}

/// An environment's shared-transfer topology: the component capacities
/// every stream traverses, plus the per-host concurrent-stream cap.
#[derive(Debug, Clone)]
pub struct Topology {
    pub env: Env,
    pub links: Vec<LinkSpec>,
    /// Max concurrent streams a single host may hold open; further
    /// submissions queue FIFO (DESIGN.md §9: admission).
    pub max_streams_per_host: usize,
    /// Per-host overrides of `max_streams_per_host` (DESIGN.md §12:
    /// in a heterogeneous placement fleet each backend is a host on
    /// the shared path, and the cloud's WAN admits fewer concurrent
    /// streams than the HPC fabric). Hosts not listed use the uniform
    /// cap; lookups are a linear scan — fleets hold a handful of hosts.
    pub host_caps: Vec<(u64, usize)>,
}

impl Topology {
    /// Build the topology from the environment's compositional transfer
    /// path ([`TransferPath::of`]) — disk/fabric/WAN capacities converted
    /// from MB/s to Gb/s.
    pub fn of(env: Env) -> Self {
        let path = TransferPath::of(env);
        Self {
            env,
            links: path
                .stages
                .iter()
                .map(|s| LinkSpec {
                    name: s.name,
                    capacity_gbps: s.mbps * 8.0 / 1000.0,
                })
                .collect(),
            max_streams_per_host: 8,
            host_caps: Vec::new(),
        }
    }

    /// Override the per-host concurrent-stream cap (must be ≥ 1).
    pub fn with_stream_cap(mut self, cap: usize) -> Self {
        assert!(cap >= 1, "stream cap must be at least 1");
        self.max_streams_per_host = cap;
        self
    }

    /// Override the concurrent-stream cap of one specific host (must be
    /// ≥ 1); other hosts keep the uniform `max_streams_per_host`.
    pub fn with_host_stream_cap(mut self, host: u64, cap: usize) -> Self {
        assert!(cap >= 1, "stream cap must be at least 1");
        match self.host_caps.iter_mut().find(|(h, _)| *h == host) {
            Some(entry) => entry.1 = cap,
            None => self.host_caps.push((host, cap)),
        }
        self
    }

    /// The concurrent-stream cap in force for `host`.
    pub fn stream_cap(&self, host: u64) -> usize {
        self.host_caps
            .iter()
            .find(|(h, _)| *h == host)
            .map_or(self.max_streams_per_host, |&(_, cap)| cap)
    }

    /// The binding shared capacity: every stream crosses every link, so
    /// aggregate throughput can never exceed the slowest component.
    pub fn bottleneck_gbps(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.capacity_gbps)
            .fold(f64::INFINITY, f64::min)
    }
}

/// Max-min fair allocation of `capacity_gbps` across streams with
/// individual ceilings `caps` (progressive filling): repeatedly split the
/// remaining capacity equally; streams whose ceiling is below the equal
/// share keep their ceiling and donate the surplus to the rest.
pub fn fair_share(caps: &[f64], capacity_gbps: f64) -> Vec<f64> {
    let mut rates = vec![0.0; caps.len()];
    let mut todo: Vec<usize> = (0..caps.len()).collect();
    let mut left = capacity_gbps;
    while !todo.is_empty() && left > 1e-12 {
        let share = left / todo.len() as f64;
        let (capped, uncapped): (Vec<usize>, Vec<usize>) =
            todo.into_iter().partition(|&i| caps[i] <= share);
        if capped.is_empty() {
            for &i in &uncapped {
                rates[i] = share;
            }
            return rates;
        }
        for &i in &capped {
            rates[i] = caps[i];
            left -= caps[i];
        }
        todo = uncapped;
    }
    rates
}

/// A completed transfer, as recorded by the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferRecord {
    pub id: u64,
    pub host: u64,
    pub bytes: u64,
    pub submit_s: f64,
    /// Admission time (stream opened); `start_s - submit_s` is queue wait.
    pub start_s: f64,
    pub end_s: f64,
    /// Sampled first-byte latency (dead time before bytes flow), seconds.
    pub latency_s: f64,
    /// Sampled per-stream throughput ceiling (Gb/s) — what this stream
    /// would sustain alone, before fair-share contention.
    pub stream_gbps: f64,
}

impl TransferRecord {
    /// Time spent queued behind the host's stream cap.
    pub fn queue_wait_s(&self) -> f64 {
        self.start_s - self.submit_s
    }

    /// Wire time (latency + contended byte movement), excluding queue wait.
    pub fn transfer_s(&self) -> f64 {
        self.end_s - self.start_s
    }

    /// Observed throughput over the wire time (Gb/s).
    pub fn observed_gbps(&self) -> f64 {
        let t = self.transfer_s();
        if t > 0.0 {
            self.bytes as f64 * 8.0 / 1e9 / t
        } else {
            0.0
        }
    }
}

/// Aggregate scheduler telemetry (campaign reports, `medflow transfer-sim`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TransferStats {
    pub transfers: usize,
    pub bytes: u64,
    /// Latest completion time.
    pub makespan_s: f64,
    /// Time with at least one stream open (flowing or in latency).
    pub busy_s: f64,
    pub peak_streams: usize,
    pub mean_queue_wait_s: f64,
    /// Fraction of the bottleneck link's capacity used while busy (0..1).
    pub link_utilization: f64,
    /// Total bytes over the whole makespan (Gb/s) — the Table 1 unit.
    pub aggregate_gbps: f64,
}

#[derive(Debug, Clone)]
struct QueuedTransfer {
    id: u64,
    host: u64,
    bytes: u64,
    submit_s: f64,
}

#[derive(Debug, Clone)]
struct ActiveStream {
    id: u64,
    host: u64,
    bytes: u64,
    submit_s: f64,
    start_s: f64,
    latency_s: f64,
    stream_gbps: f64,
}

impl ActiveStream {
    fn flow_start_s(&self) -> f64 {
        self.start_s + self.latency_s
    }
}

/// The discrete-event transfer scheduler.
///
/// Scale note (DESIGN.md §10): arrivals are heap-ordered, blocked
/// transfers wait in per-host FIFO queues, and the fair-share
/// allocation is cached between events, so an event costs
/// O(log n + k) for k open streams instead of the pre-PR O(n) queue
/// scan — 10⁶-transfer campaigns simulate in near-linear time. The
/// pre-PR engine is preserved in [`crate::sim_legacy`] and
/// `rust/tests/engine_parity.rs` proves the two produce byte-identical
/// [`TransferRecord`] sequences.
#[derive(Debug)]
pub struct TransferScheduler {
    topo: Topology,
    profile: NetProfile,
    bottleneck_gbps: f64,
    seed: u64,
    clock: f64,
    /// Future submissions (submit_s beyond the clock), min-heap by
    /// (submit_s, id); due entries migrate to `host_queues` in `admit`.
    arrivals: BinaryHeap<Reverse<(F64Ord, u64, u64, u64)>>, // (submit, id, host, bytes)
    /// Due-but-blocked transfers per host, FIFO by (submit_s, id).
    host_queues: BTreeMap<u64, BTreeMap<(F64Ord, u64), QueuedTransfer>>,
    /// Total entries across `host_queues`.
    queued: usize,
    /// Open-stream count per host (admission checks without scanning
    /// `active`); hosts at zero are evicted.
    host_active: BTreeMap<u64, usize>,
    active: Vec<ActiveStream>,
    /// Remaining bytes per open stream, split out of [`ActiveStream`]
    /// into a flat column aligned with `active` (DESIGN.md §16): the
    /// per-event `integrate` walk touches only this column and `rates`,
    /// not the 7-field stream records.
    bytes_left: Vec<f64>,
    /// Fair-share allocation cache, aligned with `active`; recomputed
    /// only when the flowing composition changes (admission, completion,
    /// latency expiry) — the pre-PR engine recomputed it inside every
    /// `next_event_time` *and* `integrate` call.
    rates: Vec<f64>,
    rates_dirty: bool,
    /// Earliest pending latency expiry among active streams (∞ when all
    /// flow): crossing it on a clock advance invalidates `rates`.
    next_flow_start: f64,
    /// Shared-link brownout windows (DESIGN.md §15): while one is
    /// active the bottleneck capacity is scaled by its factor and every
    /// flowing stream re-contends. Empty = full capacity forever,
    /// contractually bit-identical to the pre-chaos engine.
    brownouts: Vec<Brownout>,
    /// Earliest brownout boundary strictly ahead of the clock (∞ when
    /// none): crossing it on a clock advance invalidates `rates`.
    next_cap_change: f64,
    /// Scratch buffers reused across `refresh_rates` calls (the event
    /// loop's hottest allocation site at 10⁶ transfers).
    flowing_scratch: Vec<usize>,
    caps_scratch: Vec<f64>,
    records: Vec<TransferRecord>,
    busy_s: f64,
    bytes_done: u64,
    peak_streams: usize,
    /// Checksum-mismatch injection (DESIGN.md §11); `None` = fault-free.
    faults: Option<Injection>,
    /// Transfer id → retry count (only transfers with ≥ 1 failed attempt).
    attempts: HashMap<u64, u32>,
    /// Every failed attempt, in completion-processing order.
    fault_events: Vec<FaultEvent>,
    /// Transfers dropped after exhausting retries.
    aborted: Vec<u64>,
    #[cfg(debug_assertions)]
    ids_seen: std::collections::HashSet<u64>,
}

impl TransferScheduler {
    pub fn new(topo: Topology, seed: u64) -> Self {
        let profile = NetProfile::of(topo.env);
        let bottleneck_gbps = topo.bottleneck_gbps();
        Self {
            topo,
            profile,
            bottleneck_gbps,
            seed,
            clock: 0.0,
            arrivals: BinaryHeap::new(),
            host_queues: BTreeMap::new(),
            queued: 0,
            host_active: BTreeMap::new(),
            active: Vec::new(),
            bytes_left: Vec::new(),
            rates: Vec::new(),
            rates_dirty: false,
            next_flow_start: f64::INFINITY,
            brownouts: Vec::new(),
            next_cap_change: f64::INFINITY,
            flowing_scratch: Vec::new(),
            caps_scratch: Vec::new(),
            records: Vec::new(),
            busy_s: 0.0,
            bytes_done: 0,
            peak_streams: 0,
            faults: None,
            attempts: HashMap::new(),
            fault_events: Vec::new(),
            aborted: Vec::new(),
            #[cfg(debug_assertions)]
            ids_seen: std::collections::HashSet::new(),
        }
    }

    /// Enable checksum-mismatch injection (before submitting transfers):
    /// each drained stream samples a verification verdict
    /// deterministically per (transfer id, attempt); a mismatch discards
    /// the bytes and re-enqueues the transfer at the failure instant, so
    /// the retry **re-contends** for the bottleneck link and the host's
    /// stream cap. Callers normally pass
    /// [`crate::faults::FaultModel::transfer_only`] — any non-checksum
    /// mode sampled here is still treated as a transfer abort + retry.
    /// Exhausted retries drop the transfer ([`Self::aborted_ids`]).
    pub fn set_faults(&mut self, inj: Injection) {
        if let Err(e) = inj.model.validate() {
            panic!("TransferScheduler::set_faults: {e}");
        }
        assert!(
            self.records.is_empty()
                && self.active.is_empty()
                && self.queued == 0
                && self.arrivals.is_empty(),
            "set_faults must precede all submissions"
        );
        self.faults = Some(inj);
    }

    /// Install shared-link brownout windows (before submitting
    /// transfers): while a window is active the bottleneck capacity is
    /// scaled by its factor (0 = full storage-egress stall) and the
    /// max-min fair share is re-run against the degraded capacity, so
    /// in-flight streams re-contend at every window boundary. An empty
    /// schedule is bit-identical to never calling this.
    pub fn set_brownouts(&mut self, brownouts: Vec<Brownout>) {
        for b in &brownouts {
            assert!(
                b.start_s.is_finite() && b.end_s.is_finite() && b.start_s >= 0.0,
                "brownout bounds must be finite and ≥ 0"
            );
            assert!(b.end_s > b.start_s, "brownout end must exceed start");
            assert!(
                b.factor.is_finite() && (0.0..=1.0).contains(&b.factor),
                "brownout factor must be in [0, 1]"
            );
        }
        assert!(
            self.records.is_empty()
                && self.active.is_empty()
                && self.queued == 0
                && self.arrivals.is_empty(),
            "set_brownouts must precede all submissions"
        );
        self.brownouts = brownouts;
        self.next_cap_change = self.next_cap_boundary();
    }

    /// The bottleneck capacity in force at time `t`: the topology's
    /// bottleneck scaled by the most severe brownout covering `t`.
    /// Without a covering window this returns the cached bottleneck
    /// *unchanged* — no arithmetic — so brownout-free runs stay
    /// bit-identical to the pre-chaos engine.
    fn capacity_at(&self, t: f64) -> f64 {
        let mut factor = f64::INFINITY;
        for b in &self.brownouts {
            if t + EPS >= b.start_s && t + EPS < b.end_s {
                factor = factor.min(b.factor);
            }
        }
        if factor.is_finite() {
            self.bottleneck_gbps * factor
        } else {
            self.bottleneck_gbps
        }
    }

    /// Earliest brownout boundary strictly ahead of the clock (∞ when
    /// none remain) — each boundary is an event while streams are open.
    fn next_cap_boundary(&self) -> f64 {
        let mut t = f64::INFINITY;
        for b in &self.brownouts {
            if b.start_s > self.clock + EPS {
                t = t.min(b.start_s);
            }
            if b.end_s > self.clock + EPS {
                t = t.min(b.end_s);
            }
        }
        t
    }

    /// Failed-attempt events recorded so far (empty without injection).
    pub fn fault_events(&self) -> &[FaultEvent] {
        &self.fault_events
    }

    /// Transfers dropped after exhausting their retries.
    pub fn aborted_ids(&self) -> &[u64] {
        &self.aborted
    }

    /// Wire seconds consumed by failed attempts so far.
    pub fn wasted_wire_s(&self) -> f64 {
        self.fault_events.iter().map(|e| e.wasted_s).sum()
    }

    /// Convenience: environment topology with an explicit stream cap.
    pub fn for_env(env: Env, max_streams_per_host: usize, seed: u64) -> Self {
        Self::new(Topology::of(env).with_stream_cap(max_streams_per_host), seed)
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn records(&self) -> &[TransferRecord] {
        &self.records
    }

    /// Submit a transfer of `bytes` from `host` at absolute time
    /// `submit_s` (must not be in the scheduler's past). Ids must be
    /// unique per scheduler — they key the deterministic per-transfer
    /// sampling and the staged-campaign bookkeeping.
    pub fn submit_at(&mut self, id: u64, host: u64, bytes: u64, submit_s: f64) {
        assert!(
            submit_s + EPS >= self.clock,
            "transfer {id}: cannot submit in the past (submit {submit_s}, clock {})",
            self.clock
        );
        #[cfg(debug_assertions)]
        {
            assert!(self.ids_seen.insert(id), "transfer id {id} reused");
        }
        let submit_s = submit_s.max(self.clock);
        if submit_s <= self.clock + EPS {
            self.enqueue(QueuedTransfer {
                id,
                host,
                bytes,
                submit_s,
            });
            self.admit();
            self.refresh_rates();
        } else {
            self.arrivals.push(Reverse((F64Ord(submit_s), id, host, bytes)));
        }
    }

    /// Deterministic per-transfer sampling stream: the ceilings and
    /// latencies of transfer `id` do not depend on how many competitors
    /// it has (which makes per-stream throughput provably monotone in
    /// stream count — asserted by `benches/transfer_contention.rs`).
    fn transfer_rng(&self, id: u64) -> Rng {
        Rng::new(self.seed.wrapping_add(id.wrapping_mul(0x9E3779B97F4A7C15)))
    }

    /// Append a due transfer to its host's FIFO (ordered by (submit, id),
    /// matching the pre-PR globally sorted queue restricted to one host).
    fn enqueue(&mut self, q: QueuedTransfer) {
        self.host_queues
            .entry(q.host)
            .or_default()
            .insert((F64Ord(q.submit_s), q.id), q);
        self.queued += 1;
    }

    /// Admit queued transfers due at the current clock in global
    /// (submit_s, id) order — FIFO per host, skipping hosts at their
    /// stream cap — after migrating newly due arrivals from the heap.
    /// Sampling order matches [`NetProfile::transfer_time`]: throughput
    /// first, then latency.
    fn admit(&mut self) {
        while let Some(&Reverse((submit, id, host, bytes))) = self.arrivals.peek() {
            if submit.0 > self.clock + EPS {
                break; // min-heap: everything after is future too
            }
            self.arrivals.pop();
            self.enqueue(QueuedTransfer {
                id,
                host,
                bytes,
                submit_s: submit.0,
            });
        }
        if self.queued == 0 {
            return;
        }
        // Candidate heads: the earliest queued transfer of every host
        // still under its cap, popped in global (submit, id) order so
        // admissions interleave across hosts exactly like the pre-PR
        // sorted-queue scan. Caps are per host ([`Topology::stream_cap`]
        // — uniform unless a placement fleet overrode a backend's).
        let mut heads: BinaryHeap<Reverse<(F64Ord, u64, u64)>> = BinaryHeap::new();
        for (&host, queue) in &self.host_queues {
            if self.host_active.get(&host).copied().unwrap_or(0) < self.topo.stream_cap(host) {
                if let Some((&(submit, id), _)) = queue.first_key_value() {
                    heads.push(Reverse((submit, id, host)));
                }
            }
        }
        while let Some(Reverse((submit, id, host))) = heads.pop() {
            let queue = self.host_queues.get_mut(&host).expect("candidate host queue");
            let q = queue.remove(&(submit, id)).expect("candidate head present");
            let next_head = queue.first_key_value().map(|(&k, _)| k);
            if queue.is_empty() {
                self.host_queues.remove(&host);
            }
            self.queued -= 1;
            self.start_stream(q);
            if self.host_active.get(&host).copied().unwrap_or(0) < self.topo.stream_cap(host) {
                if let Some((submit, id)) = next_head {
                    heads.push(Reverse((submit, id, host)));
                }
            }
        }
    }

    /// Open the stream: sample its ceiling + latency and make it active.
    fn start_stream(&mut self, q: QueuedTransfer) {
        let mut rng = self.transfer_rng(q.id);
        let stream_gbps = rng
            .normal_ms(self.profile.throughput_gbps.0, self.profile.throughput_gbps.1)
            .max(0.01);
        let latency_s = rng
            .normal_ms(self.profile.latency_ms.0, self.profile.latency_ms.1)
            .max(0.01)
            / 1e3;
        *self.host_active.entry(q.host).or_insert(0) += 1;
        self.bytes_left.push(q.bytes as f64);
        self.active.push(ActiveStream {
            id: q.id,
            host: q.host,
            bytes: q.bytes,
            submit_s: q.submit_s,
            start_s: self.clock,
            latency_s,
            stream_gbps,
        });
        self.peak_streams = self.peak_streams.max(self.active.len());
        self.rates_dirty = true;
    }

    /// Recompute the fair-share allocation cache (and the earliest
    /// pending latency expiry) after a composition change. The flowing
    /// set is enumerated in `active` order so [`fair_share`] sees the
    /// caps in exactly the pre-PR order — f64 reduction order matters
    /// for record-for-record parity with [`crate::sim_legacy`].
    fn refresh_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        self.rates_dirty = false;
        // reuse the scratch buffers: this runs ~twice per transfer, so a
        // 10⁶-transfer campaign would otherwise allocate millions of
        // short-lived Vecs here (same trick as slurm's skyline scratch)
        let mut flowing = std::mem::take(&mut self.flowing_scratch);
        let mut caps = std::mem::take(&mut self.caps_scratch);
        flowing.clear();
        caps.clear();
        let mut next_flow = f64::INFINITY;
        for (i, a) in self.active.iter().enumerate() {
            if self.clock + EPS >= a.flow_start_s() {
                flowing.push(i);
            } else {
                next_flow = next_flow.min(a.flow_start_s());
            }
        }
        caps.extend(flowing.iter().map(|&i| self.active[i].stream_gbps));
        let shares = fair_share(&caps, self.capacity_at(self.clock));
        self.rates.clear();
        self.rates.resize(self.active.len(), 0.0);
        for (k, &i) in flowing.iter().enumerate() {
            self.rates[i] = shares[k];
        }
        self.next_flow_start = next_flow;
        self.next_cap_change = self.next_cap_boundary();
        self.flowing_scratch = flowing;
        self.caps_scratch = caps;
    }

    /// Time of the next state change: the earliest future arrival (heap
    /// peek), a latency window ending, or an in-flight stream draining
    /// at its cached rate — O(log n + k), no queue scan.
    pub fn next_event_time(&self) -> Option<f64> {
        debug_assert!(!self.rates_dirty, "rates cache stale outside a mutation");
        let mut t = f64::INFINITY;
        if let Some(&Reverse((submit, ..))) = self.arrivals.peek() {
            debug_assert!(submit.0 > self.clock + EPS, "due arrival left undrained");
            t = t.min(submit.0);
        }
        for ((a, &r), &left) in self.active.iter().zip(&self.rates).zip(&self.bytes_left) {
            if self.clock + EPS < a.flow_start_s() {
                t = t.min(a.flow_start_s());
            } else if r > 0.0 {
                t = t.min(self.clock + left.max(0.0) / gbps_to_bytes_per_sec(r));
            }
        }
        if !self.active.is_empty() {
            // brownout boundaries change the capacity every open stream
            // contends for (a full stall leaves zero-rate streams whose
            // only way forward is the window's end)
            t = t.min(self.next_cap_change);
        }
        t.is_finite().then_some(t)
    }

    /// Move bytes at the cached allocation from `clock` to `target`
    /// (no event may occur strictly inside the interval).
    fn integrate(&mut self, target: f64) {
        let dt = target - self.clock;
        if dt <= 0.0 {
            return;
        }
        if !self.active.is_empty() {
            self.busy_s += dt;
        }
        // pure column walk: two flat f64 slices, no stream records
        for (left, &r) in self.bytes_left.iter_mut().zip(&self.rates) {
            if r > 0.0 {
                *left -= gbps_to_bytes_per_sec(r) * dt;
            }
        }
    }

    fn complete_finished(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            if self.clock + EPS >= a.flow_start_s() && self.bytes_left[i] <= DONE_BYTES {
                let a = self.active.swap_remove(i);
                self.bytes_left.swap_remove(i);
                self.rates.swap_remove(i);
                self.rates_dirty = true;
                if let Some(c) = self.host_active.get_mut(&a.host) {
                    *c -= 1;
                    if *c == 0 {
                        self.host_active.remove(&a.host);
                    }
                }
                // §2.3 verify-after-transfer: a checksum mismatch at the
                // drain instant discards the landed bytes and re-enqueues
                // the whole transfer — it re-contends for the link and
                // the host's stream cap like any fresh submission
                if self.verification_failed(&a) {
                    continue; // position i already holds the swapped-in tail
                }
                self.bytes_done += a.bytes;
                self.records.push(TransferRecord {
                    id: a.id,
                    host: a.host,
                    bytes: a.bytes,
                    submit_s: a.submit_s,
                    start_s: a.start_s,
                    end_s: self.clock,
                    latency_s: a.latency_s,
                    stream_gbps: a.stream_gbps,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Sample the post-transfer checksum verdict for a drained stream;
    /// on mismatch, record the [`FaultEvent`] and either re-enqueue the
    /// transfer at the failure instant or abort it. Returns true when
    /// the attempt failed (no [`TransferRecord`] is emitted).
    fn verification_failed(&mut self, a: &ActiveStream) -> bool {
        let Some(inj) = self.faults else { return false };
        let attempt = self.attempts.get(&a.id).copied().unwrap_or(0);
        let Some(mode) = inj.sample(a.id, attempt) else { return false };
        // transfers never park (no park_timeouts in a transfer-side
        // injection): the shared disposition reduces to requeue-or-abort
        let action = inj.disposition(attempt, mode);
        match action {
            FaultAction::Aborted => {
                self.attempts.remove(&a.id);
                self.aborted.push(a.id);
            }
            FaultAction::Requeued | FaultAction::Parked => {
                self.attempts.insert(a.id, attempt + 1);
                self.enqueue(QueuedTransfer {
                    id: a.id,
                    host: a.host,
                    bytes: a.bytes,
                    submit_s: self.clock,
                });
            }
        }
        self.fault_events.push(FaultEvent {
            id: a.id,
            attempt,
            mode,
            fail_s: self.clock,
            wasted_s: self.clock - a.start_s,
            action,
        });
        true
    }

    /// Advance to absolute time `t`, processing every event (arrival,
    /// latency expiry, completion, admission) up to and including `t`.
    /// The clock ends at exactly `t`. Completions land in [`Self::records`].
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t + EPS >= self.clock,
            "cannot advance backwards (to {t}, clock {})",
            self.clock
        );
        loop {
            self.admit();
            self.refresh_rates();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.integrate(target);
            self.clock = self.clock.max(target);
            if self.clock + EPS >= self.next_flow_start {
                // a latency window ended inside this step: the flowing
                // set (and thus the allocation) changes at the new clock
                self.rates_dirty = true;
            }
            if self.clock + EPS >= self.next_cap_change {
                // a brownout boundary crossed: the shared capacity (and
                // thus the allocation) changes at the new clock
                self.rates_dirty = true;
            }
            self.complete_finished();
            self.refresh_rates();
            if target + EPS >= t {
                self.admit();
                self.refresh_rates();
                return;
            }
        }
    }

    /// Run until every submitted transfer has completed.
    pub fn run_to_completion(&mut self) -> &[TransferRecord] {
        while let Some(t) = self.next_event_time() {
            self.advance_to(t);
        }
        &self.records
    }

    /// Aggregate telemetry over everything completed so far.
    pub fn stats(&self) -> TransferStats {
        let makespan_s = self.records.iter().map(|r| r.end_s).fold(0.0, f64::max);
        let gbits = self.bytes_done as f64 * 8.0 / 1e9;
        let waits: f64 = self.records.iter().map(|r| r.queue_wait_s()).sum();
        TransferStats {
            transfers: self.records.len(),
            bytes: self.bytes_done,
            makespan_s,
            busy_s: self.busy_s,
            peak_streams: self.peak_streams,
            mean_queue_wait_s: if self.records.is_empty() {
                0.0
            } else {
                waits / self.records.len() as f64
            },
            link_utilization: if self.busy_s > 0.0 {
                gbits / (self.bottleneck_gbps * self.busy_s)
            } else {
                0.0
            },
            aggregate_gbps: if makespan_s > 0.0 {
                gbits / makespan_s
            } else {
                0.0
            },
        }
    }
}

/// The paper's §2.4 bandwidth experiment through the scheduler: `n`
/// serialized 1 GB copies (stream cap 1), per-copy observed Gb/s — the
/// scheduler-side analogue of [`super::bandwidth_experiment`], shared by
/// the calibration gates in `rust/tests/transfer_parity.rs`,
/// `benches/transfer_contention.rs`, and this module's tests so the
/// Table 1 parity check has exactly one implementation.
pub fn scheduler_bandwidth_experiment(env: Env, n: usize, seed: u64) -> Vec<f64> {
    let mut sim = TransferScheduler::for_env(env, 1, seed);
    let gb = 1_000_000_000u64;
    for i in 0..n {
        sim.submit_at(i as u64, 0, gb, 0.0);
    }
    sim.run_to_completion();
    sim.records().iter().map(|r| r.observed_gbps()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::mean_std;

    const GB: u64 = 1_000_000_000;

    fn run_n(env: Env, n: usize, bytes: u64, seed: u64) -> (Vec<TransferRecord>, TransferStats) {
        let mut sim = TransferScheduler::for_env(env, n.max(1), seed);
        for i in 0..n {
            sim.submit_at(i as u64, 0, bytes, 0.0);
        }
        sim.run_to_completion();
        let mut recs = sim.records().to_vec();
        recs.sort_by_key(|r| r.id);
        (recs, sim.stats())
    }

    #[test]
    fn single_stream_is_the_sampling_special_case() {
        for env in Env::all() {
            let (recs, _) = run_n(env, 1, GB, 7);
            let r = &recs[0];
            let expect = r.latency_s + GB as f64 / gbps_to_bytes_per_sec(r.stream_gbps);
            assert!(
                (r.transfer_s() - expect).abs() < 1e-6 * expect,
                "{env:?}: got {} expect {expect}",
                r.transfer_s()
            );
            assert_eq!(r.queue_wait_s(), 0.0);
        }
    }

    #[test]
    fn single_stream_mean_matches_table1() {
        for (env, want) in [(Env::Hpc, 0.60), (Env::Cloud, 0.33), (Env::Local, 0.81)] {
            let (mean, _) = mean_std(&scheduler_bandwidth_experiment(env, 100, 42));
            assert!((mean - want).abs() < 0.05, "{env:?}: mean {mean} want {want}");
        }
    }

    #[test]
    fn contention_slows_every_stream() {
        // the same transfer id takes at least as long with a competitor
        let (solo, _) = run_n(Env::Hpc, 1, GB, 3);
        let (pair, _) = run_n(Env::Hpc, 2, GB, 3);
        assert!(pair[0].transfer_s() >= solo[0].transfer_s() - 1e-9);
        assert!(pair[0].observed_gbps() <= solo[0].observed_gbps() + 1e-9);
    }

    #[test]
    fn aggregate_never_exceeds_bottleneck() {
        for env in Env::all() {
            for n in [1usize, 2, 4, 8, 16] {
                let cap = Topology::of(env).bottleneck_gbps();
                let (_, stats) = run_n(env, n, 200_000_000, 11);
                assert!(
                    stats.aggregate_gbps <= cap * (1.0 + 1e-9),
                    "{env:?} n={n}: {} > {cap}",
                    stats.aggregate_gbps
                );
                assert!(stats.link_utilization <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn per_stream_throughput_monotone_in_stream_count() {
        // max-min fair share is population-monotone: adding a competitor
        // can never speed an existing stream up. Sampling is keyed by
        // transfer id, so stream i sees identical draws at every sweep
        // point and the comparison is pointwise, not on the (noisy) mean.
        for env in Env::all() {
            let mut prev: Vec<f64> = Vec::new();
            for n in [1usize, 2, 4, 8] {
                let (recs, _) = run_n(env, n, GB, 5);
                let obs: Vec<f64> = recs.iter().map(|r| r.observed_gbps()).collect();
                for (id, (&now, &before)) in obs.iter().zip(&prev).enumerate() {
                    assert!(
                        now <= before + 1e-6,
                        "{env:?} n={n} stream {id}: {now} > {before}"
                    );
                }
                prev = obs;
            }
        }
    }

    #[test]
    fn progressive_filling_resplits_on_arrival() {
        // a competitor arriving mid-flight delays the first stream, but
        // less than full serialization would. Cloud: two ~0.33 Gb/s
        // streams always exceed the 0.504 Gb/s WAN, so the re-split is
        // guaranteed (on HPC two streams can fit under the bottleneck).
        let mut solo = TransferScheduler::for_env(Env::Cloud, 4, 9);
        solo.submit_at(0, 0, GB, 0.0);
        solo.run_to_completion();
        let solo_end = solo.records()[0].end_s;

        let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 9);
        sim.submit_at(0, 0, GB, 0.0);
        sim.submit_at(1, 0, GB, solo_end / 2.0);
        sim.run_to_completion();
        let r0 = sim.records().iter().find(|r| r.id == 0).unwrap().clone();
        let r1 = sim.records().iter().find(|r| r.id == 1).unwrap().clone();
        assert!(r0.end_s > solo_end, "arrival must re-split capacity");
        assert!(r1.start_s > 0.0 && r1.end_s > r0.end_s);
        assert!(r0.end_s < solo_end * 2.0, "sharing beats serialization");
    }

    #[test]
    fn host_cap_queues_fifo() {
        let mut sim = TransferScheduler::for_env(Env::Local, 1, 13);
        sim.submit_at(0, 0, 100_000_000, 0.0);
        sim.submit_at(1, 0, 100_000_000, 0.0);
        sim.run_to_completion();
        let mut recs = sim.records().to_vec();
        recs.sort_by_key(|r| r.id);
        assert!(recs[1].start_s + 1e-9 >= recs[0].end_s, "cap 1 must serialize");
        assert!(recs[1].queue_wait_s() > 0.0);
        assert_eq!(sim.stats().peak_streams, 1);
    }

    #[test]
    fn independent_hosts_do_not_share_stream_caps() {
        let mut sim = TransferScheduler::for_env(Env::Local, 1, 17);
        sim.submit_at(0, 0, 100_000_000, 0.0);
        sim.submit_at(1, 1, 100_000_000, 0.0);
        sim.run_to_completion();
        assert_eq!(sim.stats().peak_streams, 2, "caps are per host");
    }

    #[test]
    fn per_host_cap_overrides_apply_only_to_that_host() {
        let topo = Topology::of(Env::Local).with_stream_cap(4).with_host_stream_cap(1, 1);
        assert_eq!(topo.stream_cap(0), 4);
        assert_eq!(topo.stream_cap(1), 1);
        assert_eq!(topo.with_host_stream_cap(1, 2).stream_cap(1), 2, "override replaces");
        let topo = Topology::of(Env::Local).with_stream_cap(4).with_host_stream_cap(1, 1);
        let mut sim = TransferScheduler::new(topo, 61);
        // two transfers per host: host 0 admits both at once, host 1
        // (capped at 1) serializes its pair
        sim.submit_at(0, 0, 100_000_000, 0.0);
        sim.submit_at(1, 0, 100_000_000, 0.0);
        sim.submit_at(2, 1, 100_000_000, 0.0);
        sim.submit_at(3, 1, 100_000_000, 0.0);
        sim.run_to_completion();
        let mut recs = sim.records().to_vec();
        recs.sort_by_key(|r| r.id);
        assert_eq!(recs[1].queue_wait_s(), 0.0, "host 0 admits both");
        assert!(recs[3].queue_wait_s() > 0.0, "host 1 cap 1 must queue its second");
        assert!(recs[3].start_s + 1e-9 >= recs[2].end_s);
    }

    // Heap tie-break audit (DESIGN.md §16): the arrivals heap key is
    // (submit_s, id, host, bytes) and the admission heads heap key is
    // (submit_s, id, host) — both total for unique ids, so equal submit
    // instants resolve by id, never by heap insertion order.

    #[test]
    fn arrival_heap_ties_admit_by_id_not_submission_order() {
        let run = |first: u64, second: u64| {
            let mut sim = TransferScheduler::for_env(Env::Hpc, 1, 9);
            sim.submit_at(first, 0, GB, 5.0);
            sim.submit_at(second, 0, GB, 5.0);
            sim.run_to_completion();
            sim.records().to_vec()
        };
        let fwd = run(0, 1);
        let rev = run(1, 0);
        assert_eq!(fwd, rev, "insertion order must not leak through equal keys");
        assert_eq!(fwd[0].id, 0, "lower id admits first under a cap of 1");
        assert_eq!(fwd[0].queue_wait_s(), 0.0);
        assert!(fwd[1].queue_wait_s() > 0.0);
    }

    #[test]
    fn admission_heads_interleave_across_hosts_by_id() {
        // both hosts capped at 1 with a queued second transfer; the
        // running pair drains at the same fair-shared instant, so both
        // heads become admissible in the same admit() pass — the
        // (submit_s, id, host) heads key pins the global order
        let run = |order: &[(u64, u64)]| {
            let mut sim = TransferScheduler::for_env(Env::Local, 1, 13);
            for &(id, host) in order {
                // a future submit instant routes every transfer through
                // the arrivals heap (t=0 submissions admit eagerly in
                // call order, which is semantics, not a heap tie)
                sim.submit_at(id, host, 100_000_000, 5.0);
            }
            sim.run_to_completion();
            let mut recs = sim.records().to_vec();
            recs.sort_by_key(|r| r.id);
            recs
        };
        let fwd = run(&[(0, 0), (1, 1), (2, 0), (3, 1)]);
        let rev = run(&[(3, 1), (2, 0), (1, 1), (0, 0)]);
        assert_eq!(fwd, rev, "insertion order must not leak through equal keys");
        assert!(fwd[2].queue_wait_s() > 0.0);
        assert!(fwd[3].queue_wait_s() > 0.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let (a, _) = run_n(Env::Cloud, 4, 300_000_000, 21);
        let (b, _) = run_n(Env::Cloud, 4, 300_000_000, 21);
        let (c, _) = run_n(Env::Cloud, 4, 300_000_000, 22);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn fair_share_splits_and_caps() {
        let even = fair_share(&[10.0, 10.0], 1.0);
        assert!((even[0] - 0.5).abs() < 1e-12 && (even[1] - 0.5).abs() < 1e-12);
        let capped = fair_share(&[0.2, 10.0], 1.0);
        assert!((capped[0] - 0.2).abs() < 1e-12, "slow stream keeps its ceiling");
        assert!((capped[1] - 0.8).abs() < 1e-12, "surplus goes to the fast stream");
        let under = fair_share(&[0.1, 0.1], 1.0);
        assert!((under[0] - 0.1).abs() < 1e-12 && (under[1] - 0.1).abs() < 1e-12);
        assert!(fair_share(&[], 1.0).is_empty());
        for n in 1..6 {
            let caps = vec![5.0; n];
            let total: f64 = fair_share(&caps, 2.0).iter().sum();
            assert!((total - 2.0).abs() < 1e-9, "allocation exhausts capacity");
        }
    }

    #[test]
    fn stats_account_all_completed_bytes() {
        let (recs, stats) = run_n(Env::Hpc, 3, 50_000_000, 31);
        assert_eq!(stats.transfers, 3);
        assert_eq!(stats.bytes, 150_000_000);
        assert!(stats.makespan_s >= recs.iter().map(|r| r.end_s).fold(0.0, f64::max) - 1e-9);
        assert!(stats.busy_s > 0.0 && stats.busy_s <= stats.makespan_s + 1e-9);
        assert!(stats.aggregate_gbps > 0.0);
    }

    #[test]
    fn topology_bottlenecks_match_components() {
        // HPC: node HDD write (150 MB/s → 1.2 Gb/s); cloud: WAN
        assert!((Topology::of(Env::Hpc).bottleneck_gbps() - 1.2).abs() < 1e-9);
        assert!((Topology::of(Env::Cloud).bottleneck_gbps() - 0.504).abs() < 1e-9);
        assert!((Topology::of(Env::Local).bottleneck_gbps() - 1.36).abs() < 1e-9);
    }

    #[test]
    fn single_host_storm_stays_near_linear() {
        // 20k transfers through one stream-capped host: the pre-PR
        // engine's O(n²) queue scans made this take minutes in debug;
        // the event-heap engine finishes it comfortably inside a test.
        let n = 20_000usize;
        let mut sim = TransferScheduler::for_env(Env::Local, 8, 29);
        for i in 0..n {
            sim.submit_at(i as u64, 0, 2_000_000, 0.0);
        }
        sim.run_to_completion();
        assert_eq!(sim.records().len(), n);
        let stats = sim.stats();
        assert_eq!(stats.transfers, n);
        assert!(stats.peak_streams <= 8);
    }

    use crate::faults::{FaultAction, FaultModel, Injection};

    fn always_mismatch() -> FaultModel {
        FaultModel {
            p_checksum: 1.0,
            ..FaultModel::none()
        }
    }

    #[test]
    fn zero_rate_injection_changes_nothing() {
        let run = |inject: bool| {
            let mut sim = TransferScheduler::for_env(Env::Hpc, 2, 37);
            if inject {
                sim.set_faults(Injection::new(FaultModel::none(), 3, 99));
            }
            for i in 0..20u64 {
                sim.submit_at(i, i % 3, 50_000_000, (i % 5) as f64);
            }
            sim.run_to_completion();
            (sim.records().to_vec(), sim.stats())
        };
        let (plain, plain_stats) = run(false);
        let (injected, inj_stats) = run(true);
        assert_eq!(plain, injected, "zero-rate injection must be a no-op");
        assert_eq!(plain_stats, inj_stats);
    }

    #[test]
    fn checksum_mismatch_reenqueues_until_retries_exhausted() {
        let mut sim = TransferScheduler::for_env(Env::Local, 4, 41);
        sim.set_faults(Injection::new(always_mismatch(), 2, 7));
        sim.submit_at(0, 0, 100_000_000, 0.0);
        sim.run_to_completion();
        // attempts 0..=2 all mismatch → no record, transfer aborted
        assert!(sim.records().is_empty());
        assert_eq!(sim.aborted_ids(), &[0]);
        assert_eq!(sim.fault_events().len(), 3);
        assert_eq!(sim.fault_events()[0].action, FaultAction::Requeued);
        assert_eq!(sim.fault_events()[2].action, FaultAction::Aborted);
        // each attempt's wasted wire time is a full (latency + bytes) run
        assert!(sim.wasted_wire_s() > 0.0);
        let fails: Vec<f64> = sim.fault_events().iter().map(|e| e.fail_s).collect();
        assert!(fails.windows(2).all(|w| w[1] > w[0]), "attempts serialize: {fails:?}");
        assert_eq!(sim.stats().transfers, 0);
        assert_eq!(sim.stats().bytes, 0, "discarded bytes are not counted done");
    }

    #[test]
    fn retried_transfer_recontends_with_the_queue() {
        // stream cap 1: transfer 0 always mismatches once; its retry
        // re-enqueues behind nothing, but transfer 1 (queued the whole
        // time) was submitted earlier, so the retry must wait its turn —
        // FIFO order is (submit_s, id) and the retry's submit is late.
        let inj = Injection {
            model: FaultModel {
                p_checksum: 0.5,
                ..FaultModel::none()
            },
            max_retries: 5,
            seed: 0,
            backoff_base_s: 0.0,
            backoff_cap_s: f64::INFINITY,
            park_timeouts: false,
        };
        // find a seed where id 0 fails attempt 0 and succeeds attempt 1,
        // and id 1 never fails — deterministic, discovered by scanning
        let seed = (0..200u64)
            .find(|&s| {
                let m = inj.model;
                m.sample_attempt(s, 0, 0).is_some()
                    && m.sample_attempt(s, 0, 1).is_none()
                    && m.sample_attempt(s, 1, 0).is_none()
            })
            .expect("a seed with this pattern exists in 200 tries");
        let mut sim = TransferScheduler::for_env(Env::Local, 1, 43);
        sim.set_faults(Injection { seed, ..inj });
        sim.submit_at(0, 0, 100_000_000, 0.0);
        sim.submit_at(1, 0, 100_000_000, 0.0);
        sim.run_to_completion();
        let mut recs = sim.records().to_vec();
        recs.sort_by_key(|r| r.id);
        assert_eq!(recs.len(), 2);
        assert_eq!(sim.fault_events().len(), 1);
        let fail_s = sim.fault_events()[0].fail_s;
        // transfer 1 goes next after the failed attempt (earlier submit)…
        assert!(recs[1].start_s + 1e-9 >= fail_s, "{recs:?}");
        // …and the retry of 0 runs only after 1 finishes: re-contention
        assert!(recs[0].start_s + 1e-9 >= recs[1].end_s, "{recs:?}");
        assert!(recs[0].queue_wait_s() > 0.0, "the retry waited in the FIFO");
    }

    #[test]
    fn empty_brownout_schedule_is_bit_identical() {
        let run = |set: bool| {
            let mut sim = TransferScheduler::for_env(Env::Hpc, 4, 57);
            if set {
                sim.set_brownouts(Vec::new());
            }
            for i in 0..30u64 {
                sim.submit_at(i, i % 3, 120_000_000, (i % 7) as f64);
            }
            sim.run_to_completion();
            (sim.records().to_vec(), sim.stats())
        };
        assert_eq!(run(false), run(true), "empty schedule must be a no-op");
    }

    #[test]
    fn brownout_slows_inflight_transfers() {
        // Cloud: a lone stream's ~0.33 Gb/s ceiling fits under the
        // 0.504 Gb/s WAN, but not under half of it — the brownout binds
        let solo = {
            let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 63);
            sim.submit_at(0, 0, GB, 0.0);
            sim.run_to_completion();
            sim.records()[0].clone()
        };
        let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 63);
        sim.set_brownouts(vec![Brownout {
            start_s: 0.0,
            end_s: 1e9,
            factor: 0.5,
        }]);
        sim.submit_at(0, 0, GB, 0.0);
        sim.run_to_completion();
        let slowed = &sim.records()[0];
        assert!(
            slowed.end_s > solo.end_s * 1.2,
            "half capacity must slow the stream: {} vs {}",
            slowed.end_s,
            solo.end_s
        );
        assert_eq!(slowed.stream_gbps, solo.stream_gbps, "sampling is untouched");
    }

    #[test]
    fn brownout_boundary_recontends_mid_flight() {
        // a window opening mid-transfer delays completion, but less than
        // one covering the whole run
        let solo_end = {
            let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 67);
            sim.submit_at(0, 0, GB, 0.0);
            sim.run_to_completion();
            sim.records()[0].end_s
        };
        let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 67);
        sim.set_brownouts(vec![Brownout {
            start_s: solo_end * 0.5,
            end_s: solo_end * 0.9,
            factor: 0.25,
        }]);
        sim.submit_at(0, 0, GB, 0.0);
        sim.run_to_completion();
        let mid = sim.records()[0].end_s;

        let mut sim = TransferScheduler::for_env(Env::Cloud, 4, 67);
        sim.set_brownouts(vec![Brownout {
            start_s: 0.0,
            end_s: 1e9,
            factor: 0.25,
        }]);
        sim.submit_at(0, 0, GB, 0.0);
        sim.run_to_completion();
        let full = sim.records()[0].end_s;
        assert!(mid > solo_end, "mid-flight brownout must delay completion");
        assert!(mid < full, "a partial window beats a permanent one");
    }

    #[test]
    fn egress_stall_freezes_flows_until_window_end() {
        // factor 0: nothing moves inside the window; the stream drains
        // only after the stall lifts
        let mut sim = TransferScheduler::for_env(Env::Local, 2, 71);
        sim.set_brownouts(vec![Brownout {
            start_s: 0.0,
            end_s: 50.0,
            factor: 0.0,
        }]);
        sim.submit_at(0, 0, 1_000_000, 0.0);
        sim.run_to_completion();
        let r = &sim.records()[0];
        assert!(r.end_s > 50.0 - 1e-9, "stalled stream cannot finish early: {r:?}");
        assert!(r.end_s < 60.0, "it drains promptly once the stall lifts: {r:?}");
    }

    #[test]
    fn brownout_runs_are_deterministic() {
        let run = || {
            let mut sim = TransferScheduler::for_env(Env::Hpc, 3, 73);
            sim.set_brownouts(vec![
                Brownout {
                    start_s: 2.0,
                    end_s: 9.0,
                    factor: 0.3,
                },
                Brownout {
                    start_s: 12.0,
                    end_s: 14.0,
                    factor: 0.0,
                },
            ]);
            for i in 0..40u64 {
                sim.submit_at(i, i % 4, 90_000_000, (i % 6) as f64);
            }
            sim.run_to_completion();
            (sim.records().to_vec(), sim.stats())
        };
        assert_eq!(run(), run());
    }

    #[test]
    #[should_panic(expected = "precede all submissions")]
    fn brownouts_must_precede_submissions() {
        let mut sim = TransferScheduler::for_env(Env::Local, 2, 3);
        sim.submit_at(0, 0, 1_000, 0.0);
        sim.set_brownouts(Vec::new());
    }

    #[test]
    #[should_panic(expected = "factor")]
    fn brownouts_reject_over_unity_factor() {
        let mut sim = TransferScheduler::for_env(Env::Local, 2, 3);
        sim.set_brownouts(vec![Brownout {
            start_s: 0.0,
            end_s: 1.0,
            factor: 1.5,
        }]);
    }

    #[test]
    fn fault_runs_are_deterministic_by_seed() {
        let run = || {
            let mut sim = TransferScheduler::for_env(Env::Cloud, 2, 51);
            sim.set_faults(Injection::new(FaultModel::harsh().transfer_only(), 3, 13));
            for i in 0..50u64 {
                sim.submit_at(i, i % 2, 80_000_000, 0.0);
            }
            sim.run_to_completion();
            (sim.records().to_vec(), sim.fault_events().to_vec())
        };
        let (ra, fa) = run();
        let (rb, fb) = run();
        assert_eq!(ra, rb);
        assert_eq!(fa, fb);
    }
}
