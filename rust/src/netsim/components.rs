//! Compositional transfer-path model: the *derivation* behind the
//! calibrated aggregates in [`super::NetProfile`].
//!
//! The paper's §4 explains why the HPC path runs at 0.60 Gb/s despite a
//! 100 Gb fabric: the storage and compute ends are HDDs, and a store→node
//! copy pipelines disk-read → network → disk-write, so the composite
//! throughput is the harmonic combination 1/(1/r + 1/l + 1/w). This
//! module builds each environment's path from published component numbers
//! and *proves* (by unit test) that the composites land on the paper's
//! measured Table 1 values — i.e. the calibration isn't arbitrary.

use super::Env;

/// A pipeline stage's sustainable throughput in MB/s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stage {
    pub name: &'static str,
    pub mbps: f64,
}

/// One environment's storage→compute path.
#[derive(Debug, Clone)]
pub struct TransferPath {
    pub env: Env,
    pub stages: Vec<Stage>,
    /// One-way propagation + stack latency (ms).
    pub base_latency_ms: f64,
}

impl TransferPath {
    /// Composite throughput of a store-and-forward pipeline: the stages
    /// operate concurrently on a long stream, so total time per byte is
    /// the sum of per-stage times → harmonic composition.
    pub fn composite_mbps(&self) -> f64 {
        let inv: f64 = self.stages.iter().map(|s| 1.0 / s.mbps).sum();
        1.0 / inv
    }

    pub fn composite_gbps(&self) -> f64 {
        self.composite_mbps() * 8.0 / 1000.0
    }

    /// The slowest stage (the §4 explanation target).
    pub fn bottleneck(&self) -> Stage {
        *self
            .stages
            .iter()
            .min_by(|a, b| a.mbps.total_cmp(&b.mbps))
            .expect("non-empty path")
    }

    /// Component models per environment (published / typical numbers):
    pub fn of(env: Env) -> Self {
        match env {
            // RAID-Z2 HDD array read → 100 Gb fabric → node-local HDD write.
            // 7200rpm RAID reads ~155 MB/s sustained; node scratch writes
            // ~150 MB/s; fabric is effectively infinite here (12.5 GB/s).
            Env::Hpc => Self {
                env,
                stages: vec![
                    Stage { name: "store HDD read", mbps: 155.0 },
                    Stage { name: "100Gb fabric", mbps: 12_500.0 },
                    Stage { name: "node HDD write", mbps: 150.0 },
                ],
                base_latency_ms: 0.16,
            },
            // HDD read → institutional WAN egress (~63 MB/s sustained to
            // EC2) → EBS gp2 SSD write (fast). WAN RTT dominates latency.
            Env::Cloud => Self {
                env,
                stages: vec![
                    Stage { name: "store HDD read", mbps: 155.0 },
                    Stage { name: "WAN to EC2", mbps: 63.0 },
                    Stage { name: "EBS SSD write", mbps: 500.0 },
                ],
                base_latency_ms: 19.56,
            },
            // SATA SSD read → workstation 2.5 GbE LAN over NFS (protocol
            // overhead caps effective throughput ~170 MB/s) → SSD write.
            Env::Local => Self {
                env,
                stages: vec![
                    Stage { name: "SSD read", mbps: 520.0 },
                    Stage { name: "2.5GbE LAN (NFS)", mbps: 170.0 },
                    Stage { name: "SSD write", mbps: 480.0 },
                ],
                base_latency_ms: 1.64,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::NetProfile;
    use super::*;

    #[test]
    fn composites_derive_the_calibrated_aggregates() {
        // each compositional path must land within 10% of the measured
        // Table 1 value the aggregate model is calibrated to
        for env in Env::all() {
            let derived = TransferPath::of(env).composite_gbps();
            let calibrated = NetProfile::of(env).throughput_gbps.0;
            assert!(
                (derived - calibrated).abs() / calibrated < 0.10,
                "{env:?}: derived {derived:.3} vs calibrated {calibrated:.3}"
            );
        }
    }

    #[test]
    fn hpc_bottleneck_is_disk_not_fabric() {
        // the paper's §4 point: "<1 Gb/s … likely due to the added time to
        // read from the storage server and write to the compute server"
        let path = TransferPath::of(Env::Hpc);
        let b = path.bottleneck();
        assert!(b.name.contains("HDD"), "bottleneck was {b:?}");
        assert!(path.composite_gbps() < 1.0);
    }

    #[test]
    fn cloud_bottleneck_is_wan() {
        assert_eq!(TransferPath::of(Env::Cloud).bottleneck().name, "WAN to EC2");
    }

    #[test]
    fn latencies_match_profiles() {
        for env in Env::all() {
            let path = TransferPath::of(env);
            let prof = NetProfile::of(env);
            assert!((path.base_latency_ms - prof.latency_ms.0).abs() < 1e-9);
        }
    }

    #[test]
    fn composite_below_every_stage() {
        for env in Env::all() {
            let path = TransferPath::of(env);
            let c = path.composite_mbps();
            for s in &path.stages {
                assert!(c < s.mbps, "{env:?}: composite {c} ≥ stage {s:?}");
            }
        }
    }
}
