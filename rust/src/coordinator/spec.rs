//! `RunSpec` — the one composable front door to every co-simulation
//! entry point (PR 10's API redesign).
//!
//! The engines used to be reachable through a 14-way cartesian product
//! of names: `run_multi` / `run_multi_threaded` / `run_multi_chaos` /
//! `run_multi_chaos_threaded`, `execute` / `execute_threaded` /
//! `execute_chaos` / `execute_chaos_threaded` / `execute_pinned`, and
//! `run_tenants` / `run_tenants_threaded` / `run_tenants_chaos` /
//! `run_tenants_chaos_threaded` — every new axis (threads, outages,
//! SLO enforcement) doubled the surface. `RunSpec` collapses the axes
//! into builder options and leaves one run method per *input family*:
//!
//! * [`RunSpec::run_multi`] — raw staged jobs on caller-built backends;
//! * [`RunSpec::execute`] — a campaign placed across a fleet;
//! * [`RunSpec::run_tenants`] — N tenants arbitrated over one fleet.
//!
//! The old names survive as thin `#[deprecated]` shims delegating
//! here, so the four parity batteries (`engine_parity`,
//! `placement_parity`, `tenancy_parity`, `chaos_cosim`) pin
//! f64-record-identical equivalence between the legacy surface and the
//! builder. New call sites — `main.rs` and the streaming coordinator
//! (`coordinator::stream`) — compose a `RunSpec` instead of picking a
//! name from the matrix.
//!
//! Every option is orthogonal and defaulted: `RunSpec::new()` is the
//! sequential, chaos-free, report-only-SLO, cheapest-first run.
//!
//! ```no_run
//! use medflow::coordinator::RunSpec;
//! use medflow::coordinator::placement::{default_fleet, PlacementConfig, PlacementPolicy};
//! use medflow::coordinator::staged::synthetic_fault_campaign;
//! use medflow::faults::outage::{OutageSchedule, OutageSeverity};
//! use medflow::slurm::ClusterSpec;
//!
//! let jobs = synthetic_fault_campaign(500, 42);
//! let fleet = default_fleet(ClusterSpec::accre(), 2_000, 64, 8);
//! let schedule = OutageSchedule::synthetic(OutageSeverity::Mild, fleet.len(), 14_400.0, 42);
//! let out = RunSpec::new()
//!     .policy(PlacementPolicy::CheapestFirst)
//!     .outages(schedule)
//!     .threads(4)
//!     .execute(&jobs, &fleet, &PlacementConfig::default());
//! assert_eq!(out.staged.timings.len(), 500);
//! ```

use crate::faults::outage::OutageSchedule;
use crate::netsim::scheduler::TransferScheduler;

use super::placement::{
    plan, run_plan_chaos, BackendSpec, PlacementConfig, PlacementOutcome, PlacementPolicy,
};
use super::staged::{run_multi_impl, ChaosCosim, ComputeSim, StagedJob, StagedOutcome};
use super::tenancy::{run_tenants_impl, TenancyConfig, TenancyOutcome, TenantSpec};

/// Composable run options for the co-simulation engines (module docs).
///
/// Cloneable so a long-lived base spec (e.g. the streaming
/// coordinator's) can be re-composed per planning epoch.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub(crate) threads: usize,
    pub(crate) outages: Option<OutageSchedule>,
    pub(crate) enforce_slos: bool,
    pub(crate) policy: Option<PlacementPolicy>,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self::new()
    }
}

impl RunSpec {
    /// The sequential, chaos-free baseline: 1 thread, no outage
    /// schedule, SLOs report-only, cheapest-first placement.
    pub fn new() -> Self {
        Self {
            threads: 1,
            outages: None,
            enforce_slos: false,
            policy: None,
        }
    }

    /// Shard the compute engines across `n` worker threads under
    /// conservative time-window sync (DESIGN.md §16). `n = 1` is
    /// byte-identical to the sequential loop; any `n` is
    /// f64-record-identical (`rust/tests/parallel_parity.rs`).
    pub fn threads(mut self, n: usize) -> Self {
        assert!(n >= 1, "RunSpec::threads: need at least one worker thread");
        self.threads = n;
        self
    }

    /// Arm an infrastructure-fault schedule (DESIGN.md §15): per-backend
    /// Down/Drain windows, link brownouts, orphan re-placement. An
    /// *empty* schedule still marks the run as chaos-aware (outage
    /// telemetry is reported, as zeros) — exactly the legacy
    /// `execute_chaos` / `run_tenants_chaos` contract. Panics if the
    /// schedule fails [`OutageSchedule::validate`].
    pub fn outages(mut self, schedule: OutageSchedule) -> Self {
        if let Err(e) = schedule.validate() {
            panic!("RunSpec::outages: {e}");
        }
        self.outages = Some(schedule);
        self
    }

    /// Arm SLO *enforcement* for tenancy runs (DESIGN.md §15): budget
    /// burn-down stops admission, deadline misses escalate to the
    /// fastest backend. `false` (the default) keeps SLOs report-only.
    /// Ignored by the staged and placement families, which have no
    /// per-tenant SLOs.
    pub fn enforce_slos(mut self, on: bool) -> Self {
        self.enforce_slos = on;
        self
    }

    /// Placement policy for [`RunSpec::execute`] (default
    /// [`PlacementPolicy::CheapestFirst`]). Ignored by
    /// [`RunSpec::run_multi`] (the caller already assigned backends)
    /// and [`RunSpec::run_tenants`] (each tenant carries its own
    /// policy in its [`TenantSpec`]).
    pub fn policy(mut self, p: PlacementPolicy) -> Self {
        self.policy = Some(p);
        self
    }

    /// The staged family: co-simulate pre-assigned jobs on caller-built
    /// backends against one shared transfer scheduler. `replace` is the
    /// chaos re-placement hook — `(job, orphan instant, old backend) →
    /// (new backend, rescaled job)`; `None` re-stages orphans to their
    /// original backend. Outage windows on the *engines* are the
    /// caller's to install here (the engines are the caller's);
    /// [`Self::outages`] drives the fleet families, which own their
    /// engines.
    pub fn run_multi(
        &self,
        jobs: &[StagedJob],
        assignment: &[usize],
        backends: &mut [&mut dyn ComputeSim],
        transfers: &mut TransferScheduler,
        replace: Option<&mut dyn FnMut(usize, f64, usize) -> (usize, StagedJob)>,
    ) -> (StagedOutcome, ChaosCosim) {
        run_multi_impl(jobs, assignment, backends, transfers, replace, self.threads)
    }

    /// The placement family: plan `jobs` across `fleet` under
    /// [`Self::policy`], then co-simulate every backend's engine in
    /// lockstep against the shared staging path — with
    /// [`Self::outages`]' windows on the engines and its brownouts on
    /// the link when armed.
    pub fn execute(
        &self,
        jobs: &[StagedJob],
        fleet: &[BackendSpec],
        cfg: &PlacementConfig,
    ) -> PlacementOutcome {
        let policy = self.policy.unwrap_or(PlacementPolicy::CheapestFirst);
        run_plan_chaos(
            fleet,
            plan(jobs, fleet, policy),
            cfg,
            self.outages.as_ref(),
            self.threads,
        )
    }

    /// The tenancy family: arbitrate N tenants' campaigns over one
    /// shared fleet and staging path (weighted fair-share + strict
    /// priority at admission), with [`Self::outages`] and
    /// [`Self::enforce_slos`] applied when armed.
    pub fn run_tenants(
        &self,
        tenants: &[TenantSpec],
        fleet: &[BackendSpec],
        cfg: &TenancyConfig,
    ) -> TenancyOutcome {
        run_tenants_impl(
            tenants,
            fleet,
            cfg,
            self.outages.as_ref(),
            self.enforce_slos,
            self.threads,
        )
    }
}

#[cfg(test)]
// the equivalence tests drive the deprecated shims on purpose: they
// pin that every legacy name is a pure delegation to the builder
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{default_fleet, execute, execute_chaos_threaded};
    use crate::coordinator::staged::synthetic_fault_campaign;
    use crate::coordinator::tenancy::{run_tenants, synthetic_tenants};
    use crate::faults::outage::OutageSeverity;
    use crate::slurm::ClusterSpec;

    fn small_fleet() -> Vec<BackendSpec> {
        default_fleet(ClusterSpec::accre(), 64, 8, 4)
    }

    #[test]
    fn builder_defaults_are_the_sequential_chaos_free_run() {
        let s = RunSpec::new();
        assert_eq!(s.threads, 1);
        assert!(s.outages.is_none());
        assert!(!s.enforce_slos);
        assert!(s.policy.is_none());
    }

    #[test]
    fn execute_matches_legacy_shim_exactly() {
        let jobs = synthetic_fault_campaign(120, 7);
        let fleet = small_fleet();
        let cfg = PlacementConfig::default();
        let a = RunSpec::new().policy(PlacementPolicy::CheapestFirst).execute(&jobs, &fleet, &cfg);
        let b = execute(&jobs, &fleet, PlacementPolicy::CheapestFirst, &cfg);
        assert_eq!(a.staged.timings, b.staged.timings);
        assert_eq!(a.total_cost_dollars, b.total_cost_dollars);
        assert!(a.outage.is_none() && b.outage.is_none());
    }

    #[test]
    fn chaos_options_compose_like_the_threaded_chaos_shim() {
        let jobs = synthetic_fault_campaign(90, 11);
        let fleet = small_fleet();
        let cfg = PlacementConfig::default();
        let schedule = OutageSchedule::synthetic(OutageSeverity::Mild, fleet.len(), 4_000.0, 11);
        let a = RunSpec::new()
            .policy(PlacementPolicy::CheapestFirst)
            .outages(schedule.clone())
            .threads(2)
            .execute(&jobs, &fleet, &cfg);
        let b = execute_chaos_threaded(
            &jobs,
            &fleet,
            PlacementPolicy::CheapestFirst,
            &cfg,
            &schedule,
            2,
        );
        assert_eq!(a.staged.timings, b.staged.timings);
        assert_eq!(a.outage, b.outage);
    }

    #[test]
    fn tenancy_defaults_match_legacy_run_tenants() {
        let tenants = synthetic_tenants(3, 15, 5);
        let fleet = small_fleet();
        let cfg = TenancyConfig {
            seed: 5,
            ..Default::default()
        };
        let a = RunSpec::new().run_tenants(&tenants, &fleet, &cfg);
        let b = run_tenants(&tenants, &fleet, &cfg);
        assert_eq!(a.staged.timings, b.staged.timings);
        assert_eq!(a.report.total_cost_dollars, b.report.total_cost_dollars);
        assert!(!a.report.enforced);
    }

    #[test]
    #[should_panic(expected = "RunSpec::threads")]
    fn zero_threads_is_rejected() {
        let _ = RunSpec::new().threads(0);
    }
}
