//! The semi-automated workflow engine (paper §2.3, Fig. 3) — medflow's L3
//! contribution. One *campaign* = the paper's single-line flow:
//!
//!   query archive → generate scripts → submit (SLURM array or local
//!   burst) → stage → containerized compute (PJRT artifact) → verified
//!   copy-back → provenance → mark processed
//!
//! plus the §2.3 resource monitor (cluster utilization + storage headroom)
//! that informs whether to submit to the HPC or burst to a local server,
//! with bounded in-flight backpressure on the local path.
//!
//! Campaign data movement is **staged** (DESIGN.md §9): stage-in,
//! compute, and copy-back overlap per job, and all transfers share the
//! environment's storage path through the contention-aware
//! [`crate::netsim::scheduler`] instead of independent samples — see
//! [`staged`].

pub mod placement;
pub mod planner;
pub mod soa;
pub mod spec;
pub mod staged;
pub mod stream;
pub(crate) mod sync;
pub mod tenancy;

pub use self::spec::RunSpec;

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::archive::{Archive, SessionKey};
use crate::bids::{BidsDataset, BidsName, Modality};
use crate::compute::{env_speed_factor, Executor, JobOutcome};
use crate::cost::{compute_cost, staged_job_cost};
use crate::faults::{FaultEvent, FaultModel, FaultTelemetry, Injection};
use crate::container::{ContainerArchive, ImageDef};
use crate::netsim::scheduler::{Topology, TransferScheduler, TransferStats};
use crate::netsim::Env;
use crate::pipeline::{by_name, PipelineSpec};
use crate::provenance::Provenance;
use crate::query::{IncrementalEngine, JobSpec, QueryResult, QueryStats};
use crate::runtime::Runtime;
use crate::scripts::{instance_script, local_runner_script, slurm_array_script, SlurmOptions};
use crate::slurm::{ArrayHandle, ClusterSpec, Maintenance, Scheduler};

use self::placement::{BackendUsage, PlacementConfig, PlacementPolicy};
use self::staged::{run_staged, LanePool, SlurmSim, StagedJob, StagedOutcome, StagedTiming};
use crate::util::pool::run_parallel;
use crate::util::rng::Rng;
use crate::util::units::mean_std;

/// Where a campaign ran (paper Fig. 3's two submit paths, plus the
/// heterogeneous placement fleet of DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitTarget {
    /// SLURM job array on the HPC.
    Hpc,
    /// Local-burst parallel runner.
    LocalBurst { workers: usize },
    /// Split across the heterogeneous fleet (HPC + cloud + local) by
    /// the policy in [`CampaignConfig::placement`]
    /// ([`placement::PlacementPolicy::CheapestFirst`] when unset).
    Placement,
}

/// Campaign configuration.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub user: String,
    pub slurm: SlurmOptions,
    pub seed: u64,
    /// Backpressure: max in-flight local jobs (bounded queue).
    pub local_max_in_flight: usize,
    /// Threads for the parallel shard scan of the incremental query.
    pub query_workers: usize,
    /// Average input bytes staged per job (from archive stats when real).
    pub input_bytes_per_job: u64,
    /// Concurrent transfer streams allowed on the campaign's staging
    /// path (the per-host cap of the contention-aware transfer
    /// scheduler, DESIGN.md §9); further transfers queue FIFO.
    pub transfer_streams: usize,
    /// Failure model applied per attempt (None = fault-free baseline).
    /// Injected **inside** the discrete-event engines (DESIGN.md §11):
    /// compute-side bands into the SLURM simulator / lane pool, the
    /// checksum band into the transfer scheduler — retried work
    /// re-contends for slots and links instead of being scaled post hoc.
    pub faults: Option<FaultModel>,
    /// Resubmissions allowed per job when faults are enabled.
    pub max_retries: u32,
    /// Base requeue delay after a failed compute attempt (doubles per
    /// retry — the submit loop's resubmit backoff).
    pub retry_backoff_s: f64,
    /// Policy for [`SubmitTarget::Placement`] campaigns; `None` falls
    /// back to [`PlacementPolicy::CheapestFirst`].
    pub placement: Option<PlacementPolicy>,
    /// Cloud lane-pool width of the placement fleet (the local width is
    /// `local_max_in_flight`; the HPC backend is the coordinator's
    /// cluster).
    pub cloud_lanes: usize,
    /// Worker threads for the parallel event engines (DESIGN.md §16):
    /// multi-backend co-simulations shard their compute engines across
    /// this many workers under conservative time-window sync. `1` is
    /// byte-identical to the sequential path; any value is
    /// f64-record-identical to it. Single-backend campaigns always run
    /// sequentially (one engine cannot shard).
    pub threads: usize,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            user: "medflow".into(),
            slurm: SlurmOptions::default(),
            seed: 42,
            local_max_in_flight: 8,
            query_workers: 4,
            input_bytes_per_job: 30_000_000,
            transfer_streams: 8,
            faults: None,
            max_retries: 3,
            retry_backoff_s: 60.0,
            placement: None,
            cloud_lanes: 32,
            threads: 1,
        }
    }
}

/// Result of one campaign.
#[derive(Debug)]
pub struct CampaignReport {
    pub pipeline: String,
    pub dataset: String,
    pub target: SubmitTarget,
    pub queried: usize,
    pub skipped: usize,
    pub completed: usize,
    pub failed: usize,
    /// Simulated wall-clock of the whole campaign, seconds.
    pub makespan_s: f64,
    /// Mean ± std of per-job modeled compute minutes.
    pub compute_minutes: (f64, f64),
    pub total_cost_dollars: f64,
    /// Generated artifacts (scripts, skip CSV) for inspection.
    pub skip_csv: String,
    pub array_script: String,
    /// Mean measured PJRT execution seconds per artifact-backed job.
    pub artifact_exec_s: f64,
    /// Telemetry from the incremental archive query: how much was
    /// evaluated vs replayed from the persistent indexes.
    pub query_stats: QueryStats,
    /// Telemetry from the contention-aware transfer scheduler: link
    /// utilization, peak concurrent streams, queue waits (DESIGN.md §9).
    pub transfer: TransferStats,
    /// Telemetry from the in-engine failure injection (DESIGN.md §11):
    /// per-mode retry/abort counts, re-stages, wasted compute minutes,
    /// and the closed-form §4 overrun as a cross-check. All-default when
    /// the campaign ran fault-free.
    pub faults: FaultTelemetry,
    /// Per-backend usage of a [`SubmitTarget::Placement`] campaign
    /// (DESIGN.md §12); `None` for single-backend targets.
    pub placement: Option<Vec<BackendUsage>>,
}

/// Resource-monitor snapshot (paper §2.3: "a simple query for both
/// resource usage and storage to inform our team").
#[derive(Debug, Clone, Copy)]
pub struct ResourceStatus {
    pub cluster_utilization: f64,
    pub cluster_in_maintenance: bool,
    pub general_store_used_bytes: u64,
    pub gdpr_store_used_bytes: u64,
}

/// The coordinator.
pub struct Coordinator<'rt> {
    pub archive: Archive,
    pub containers: ContainerArchive,
    runtime: Option<&'rt Runtime>,
    pub cluster: ClusterSpec,
    maintenance: Vec<Maintenance>,
    /// Incremental query engines cached per dataset root, so back-to-back
    /// campaigns (e.g. a 16-pipeline sweep) parse the persisted index
    /// once instead of per campaign.
    engines: BTreeMap<PathBuf, IncrementalEngine>,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(
        archive: Archive,
        containers: ContainerArchive,
        runtime: Option<&'rt Runtime>,
    ) -> Self {
        Self {
            archive,
            containers,
            runtime,
            cluster: ClusterSpec::accre(),
            maintenance: Vec::new(),
            engines: BTreeMap::new(),
        }
    }

    /// Declare an upcoming maintenance window (drives burst decisions).
    pub fn add_maintenance(&mut self, w: Maintenance) {
        self.maintenance.push(w);
    }

    /// Ensure a container image exists for the pipeline (build-on-demand,
    /// immutable thereafter).
    pub fn ensure_image(&mut self, spec: &PipelineSpec) -> Result<String> {
        if let Some(img) = self.containers.latest(spec.name) {
            return Ok(img.def.sif_name());
        }
        let img = self.containers.build(ImageDef {
            pipeline: spec.name.to_string(),
            version: spec.version.to_string(),
            base_env: "ubuntu22.04+xla0.5.1".into(),
            artifact: spec.artifact.map(String::from),
        })?;
        Ok(img.def.sif_name())
    }

    /// The §2.3 resource monitor.
    pub fn resource_status(&self, at_s: f64, utilization: f64) -> Result<ResourceStatus> {
        Ok(ResourceStatus {
            cluster_utilization: utilization,
            cluster_in_maintenance: self
                .maintenance
                .iter()
                .any(|w| at_s >= w.start_s && at_s < w.end_s),
            general_store_used_bytes: self
                .archive
                .tier_usage(crate::archive::SecurityTier::General)?,
            gdpr_store_used_bytes: self.archive.tier_usage(crate::archive::SecurityTier::Gdpr)?,
        })
    }

    /// Pick the submit target: burst to local iff the cluster is in (or
    /// about to enter) maintenance at submit time (paper §2.3).
    pub fn choose_target(&self, submit_s: f64, local_workers: usize) -> SubmitTarget {
        let blocked = self
            .maintenance
            .iter()
            .any(|w| submit_s >= w.start_s && submit_s < w.end_s);
        if blocked {
            SubmitTarget::LocalBurst {
                workers: local_workers,
            }
        } else {
            SubmitTarget::Hpc
        }
    }

    /// Run a full campaign of `pipeline` over `dataset`.
    pub fn run_campaign(
        &mut self,
        ds: &BidsDataset,
        pipeline_name: &str,
        target: SubmitTarget,
        cfg: &CampaignConfig,
    ) -> Result<CampaignReport> {
        let spec = by_name(pipeline_name)
            .with_context(|| format!("unknown pipeline '{pipeline_name}'"))?;
        let sif = self.ensure_image(&spec)?;

        // 1. automated archive query — incremental: the persistent entity
        // index and processed-set replace the per-campaign full rescan, so
        // an unchanged archive costs O(changes), not O(all sessions). The
        // engine is cached per dataset across campaigns (taken out of the
        // map for the duration so `self` stays borrowable).
        let mut engine = match self.engines.remove(&ds.root) {
            Some(engine) => engine,
            None => IncrementalEngine::open(ds)?,
        };
        let (QueryResult { runnable, skipped }, query_stats) =
            engine.query(ds, &spec, cfg.query_workers)?;
        let skip_csv = QueryResult {
            runnable: vec![],
            skipped: skipped.clone(),
        }
        .skip_csv();

        // 2. script generation (durable artifacts)
        let scripts: Vec<String> = runnable
            .iter()
            .map(|j| instance_script(j, &sif, &cfg.user))
            .collect();
        let array_script = slurm_array_script(&runnable, &cfg.slurm);
        let _local_script = local_runner_script(&runnable, cfg.local_max_in_flight);

        // 3-5. submit + execute + copy-back
        let outcome = match target {
            SubmitTarget::Hpc => self.execute_hpc(ds, &spec, &runnable, cfg, &mut engine)?,
            SubmitTarget::LocalBurst { workers } => {
                self.execute_local(ds, &spec, &runnable, workers, cfg, &mut engine)?
            }
            SubmitTarget::Placement => self.execute_placed(ds, &spec, &runnable, cfg, &mut engine)?,
        };
        // persist query state (processed-set, skip cache; index shards
        // only when changed) so the next campaign — even in a fresh
        // process — starts from it, then return the engine to the cache
        engine.save(ds)?;
        self.engines.insert(ds.root.clone(), engine);

        let _ = scripts; // per-instance scripts also available via scripts::*
        let (mean_min, std_min) = mean_std(&outcome.per_job_minutes);
        Ok(CampaignReport {
            pipeline: spec.name.to_string(),
            dataset: ds.name.clone(),
            target,
            queried: runnable.len() + skipped.len(),
            skipped: skipped.len(),
            completed: outcome.completed,
            failed: outcome.failed,
            makespan_s: outcome.makespan_s,
            compute_minutes: (mean_min, std_min),
            total_cost_dollars: outcome.total_cost,
            skip_csv,
            array_script,
            artifact_exec_s: outcome.artifact_exec_mean_s,
            query_stats,
            transfer: outcome.transfer,
            faults: outcome.faults,
            placement: outcome.placement,
        })
    }

    fn execute_hpc(
        &mut self,
        ds: &BidsDataset,
        spec: &PipelineSpec,
        jobs: &[JobSpec],
        cfg: &CampaignConfig,
        engine: &mut IncrementalEngine,
    ) -> Result<ExecOutcome> {
        let mut rng = Rng::new(cfg.seed);
        let executor = Executor::new(Env::Hpc, self.runtime);
        // sample compute outcomes (duration model + real artifact
        // execution); transfer times come from the staged co-simulation
        let mut outcomes = Vec::with_capacity(jobs.len());
        for job in jobs {
            outcomes.push(executor.run_compute(job, spec, &mut rng, None)?);
        }
        // staged execution with in-engine failure injection (DESIGN.md
        // §11): stage-in through the shared HPC path, SLURM array
        // compute, copy-back — overlapped per job, with failed attempts
        // re-contending for nodes and links. The pre-co-simulation
        // closed-form scaling survives only as the telemetry cross-check.
        let mut sched = Scheduler::new(self.cluster.clone());
        for w in &self.maintenance {
            sched.add_maintenance(*w);
        }
        if let Some(inj) = compute_injection(cfg)? {
            sched.set_faults(inj);
        }
        let handle = ArrayHandle {
            array_id: 1,
            max_concurrent: cfg.slurm.max_concurrent,
        };
        let mut compute_sim = SlurmSim::new(sched, &cfg.user, Some(handle));
        let mut transfers = campaign_transfers(Env::Hpc, cfg);
        if let Some(inj) = transfer_injection(cfg)? {
            transfers.set_faults(inj);
        }
        let plan = staged_plan(jobs, &outcomes, spec, cfg);
        let staged = run_staged(&plan, &mut compute_sim, &mut transfers);
        let faults = collect_faults(
            cfg,
            compute_sim.scheduler().fault_events(),
            compute_sim.scheduler().aborted_ids().len(),
            transfers.fault_events(),
            transfers.aborted_ids().len(),
            &mut outcomes,
        );
        fold_staged_timings(Env::Hpc, &mut outcomes, &staged);
        // jobs the cluster could never place (oversized for every node)
        // or that exhausted their fault retries never reached a verified
        // copy-back: they must not be finalized or recorded as processed
        // — they count as failed and stay runnable
        let (jobs, outcomes, dropped) = retain_completed(jobs, outcomes, &staged);
        self.finalize(ds, spec, &jobs, &outcomes, &vec![Env::Hpc; jobs.len()], cfg, engine)?;
        let mut out = ExecOutcome::collect(&outcomes, staged.makespan_s);
        out.total_cost += dropped_attempt_cost(
            Env::Hpc,
            compute_sim.scheduler().fault_events(),
            &staged.timings,
            &plan,
        );
        out.failed = dropped;
        out.transfer = staged.transfer;
        out.faults = faults;
        Ok(out)
    }

    fn execute_local(
        &mut self,
        ds: &BidsDataset,
        spec: &PipelineSpec,
        jobs: &[JobSpec],
        workers: usize,
        cfg: &CampaignConfig,
        engine: &mut IncrementalEngine,
    ) -> Result<ExecOutcome> {
        // Local burst: bounded-concurrency pool (backpressure = bounded
        // in-flight set). The PJRT client holds thread-local state (Rc
        // internals in the xla crate), so artifact-backed pipelines execute
        // serially; model-only pipelines fan out across the pool like the
        // generated Python runner would. Staging and makespan come from
        // the staged co-simulation: a LanePool of `workers` lanes for
        // compute, the local shared path for transfers.
        let seed = cfg.seed;
        let workers = workers.min(cfg.local_max_in_flight).max(1);
        let mut outcomes: Vec<JobOutcome> = if self.runtime.is_some() {
            let ex = Executor::new(Env::Local, self.runtime);
            jobs.iter()
                .enumerate()
                .map(|(i, job)| {
                    let mut rng = Rng::new(seed.wrapping_add(i as u64));
                    ex.run_compute(job, spec, &mut rng, None)
                })
                .collect::<Result<Vec<_>>>()?
        } else {
            let tasks: Vec<_> = jobs
                .iter()
                .enumerate()
                .map(|(i, job)| {
                    let job = job.clone();
                    let spec = spec.clone();
                    move || {
                        let mut rng = Rng::new(seed.wrapping_add(i as u64));
                        let ex = Executor::new(Env::Local, None);
                        ex.run_compute(&job, &spec, &mut rng, None)
                    }
                })
                .collect();
            run_parallel(workers, tasks)
                .into_iter()
                .collect::<Result<Vec<_>>>()?
        };
        let mut lanes = LanePool::new(workers);
        if let Some(inj) = compute_injection(cfg)? {
            lanes.set_faults(inj);
        }
        let mut transfers = campaign_transfers(Env::Local, cfg);
        if let Some(inj) = transfer_injection(cfg)? {
            transfers.set_faults(inj);
        }
        let plan = staged_plan(jobs, &outcomes, spec, cfg);
        let staged = run_staged(&plan, &mut lanes, &mut transfers);
        let faults = collect_faults(
            cfg,
            lanes.fault_events(),
            lanes.aborted_ids().len(),
            transfers.fault_events(),
            transfers.aborted_ids().len(),
            &mut outcomes,
        );
        fold_staged_timings(Env::Local, &mut outcomes, &staged);
        // a fault-free LanePool never drops jobs, but keep the same
        // completion contract as the HPC path (aborts drop out here too)
        let (jobs, outcomes, dropped) = retain_completed(jobs, outcomes, &staged);
        self.finalize(ds, spec, &jobs, &outcomes, &vec![Env::Local; jobs.len()], cfg, engine)?;
        let mut out = ExecOutcome::collect(&outcomes, staged.makespan_s);
        out.total_cost +=
            dropped_attempt_cost(Env::Local, lanes.fault_events(), &staged.timings, &plan);
        out.failed = dropped;
        out.transfer = staged.transfer;
        out.faults = faults;
        Ok(out)
    }

    /// Placement campaign (DESIGN.md §12): split the runnable set across
    /// the heterogeneous fleet — this coordinator's cluster, a cloud
    /// lane pool, local workstations — by [`CampaignConfig::placement`]
    /// and co-simulate every backend against the one shared staging
    /// path. Compute durations are sampled on the HPC basis (speed
    /// factor 1); the plan rescales each job to its assigned backend.
    fn execute_placed(
        &mut self,
        ds: &BidsDataset,
        spec: &PipelineSpec,
        jobs: &[JobSpec],
        cfg: &CampaignConfig,
        engine: &mut IncrementalEngine,
    ) -> Result<ExecOutcome> {
        let mut rng = Rng::new(cfg.seed);
        let executor = Executor::new(Env::Hpc, self.runtime);
        let mut outcomes = Vec::with_capacity(jobs.len());
        for job in jobs {
            outcomes.push(executor.run_compute(job, spec, &mut rng, None)?);
        }
        let mut fleet = placement::default_fleet(
            self.cluster.clone(),
            cfg.slurm.max_concurrent,
            cfg.cloud_lanes.max(1),
            cfg.local_max_in_flight.max(1),
        );
        if let Some(model) = &cfg.faults {
            model.validate().map_err(|e| anyhow!("campaign fault model: {e}"))?;
            for backend in &mut fleet {
                backend.faults = Some(*model);
            }
        }
        let pcfg = PlacementConfig {
            seed: cfg.seed,
            transfer_faults: cfg.faults,
            max_retries: cfg.max_retries,
            retry_backoff_s: cfg.retry_backoff_s,
        };
        let policy = cfg.placement.unwrap_or(PlacementPolicy::CheapestFirst);
        let plan_jobs = staged_plan(jobs, &outcomes, spec, cfg);
        let placed = RunSpec::new()
            .policy(policy)
            .threads(cfg.threads)
            .execute(&plan_jobs, &fleet, &pcfg);

        // fold the co-simulated timings and the assigned backend's
        // pricing back into each job outcome; wasted attempts are billed
        // into effective minutes BEFORE pricing, exactly like the
        // single-backend paths (collect_faults precedes the cost fold)
        let envs_all: Vec<Env> = placed.plan.assignment.iter().map(|&k| fleet[k].env).collect();
        let mut wasted_min = vec![0.0f64; outcomes.len()];
        for ev in &placed.compute_events {
            if let Some(w) = wasted_min.get_mut(ev.id as usize) {
                *w += ev.wasted_s / 60.0;
            }
        }
        for (i, (out, t)) in outcomes.iter_mut().zip(&placed.staged.timings).enumerate() {
            out.compute_minutes = placed.plan.effective[i].compute_s / 60.0 + wasted_min[i];
            out.stage_in_s = t.stage_in_s;
            out.stage_out_s = t.stage_out_s;
            out.cost_dollars =
                staged_job_cost(envs_all[i], out.compute_minutes, t.stage_in_s + t.stage_out_s);
        }
        let faults = FaultTelemetry::collect(
            cfg.faults.as_ref(),
            cfg.max_retries,
            cfg.seed,
            &placed.compute_events,
            &placed.transfer_events,
            placed.aborted,
        );
        let envs_kept: Vec<Env> = envs_all
            .iter()
            .zip(&placed.staged.timings)
            .filter(|(_, t)| t.completed)
            .map(|(&e, _)| e)
            .collect();
        let (jobs, outcomes, dropped) = retain_completed(jobs, outcomes, &placed.staged);
        self.finalize(ds, spec, &jobs, &outcomes, &envs_kept, cfg, engine)?;
        let mut out = ExecOutcome::collect(&outcomes, placed.makespan_s);
        // the placement fold is the authoritative bill: per-backend slot
        // rates, wasted attempts, and dropped-job spend included
        out.total_cost = placed.total_cost_dollars;
        out.failed = dropped;
        out.transfer = placed.transfer;
        out.faults = faults;
        out.placement = Some(placed.per_backend);
        Ok(out)
    }

    /// Copy-back phase: write derivative outputs + provenance, and record
    /// the completion into the persistent processed index (so the next
    /// query replays it instead of rescanning). `envs` carries each
    /// job's executing environment (uniform for the Hpc/LocalBurst
    /// targets; per the assigned backend for placement campaigns) so
    /// the provenance record names where the job actually ran.
    fn finalize(
        &mut self,
        ds: &BidsDataset,
        spec: &PipelineSpec,
        jobs: &[JobSpec],
        outcomes: &[crate::compute::JobOutcome],
        envs: &[Env],
        cfg: &CampaignConfig,
        engine: &mut IncrementalEngine,
    ) -> Result<()> {
        assert_eq!(jobs.len(), envs.len(), "one executing env per finalized job");
        let sif = self.ensure_image(spec)?;
        let sha = self
            .containers
            .latest(spec.name)
            .map(|i| i.sha256.clone())
            .unwrap_or_default();
        for (i, (job, out)) in jobs.iter().zip(outcomes).enumerate() {
            let name = BidsName::new(&job.subject, job.session.as_deref(), Modality::T1w);
            let dir = ds.derivative_dir(spec.name, &name);
            std::fs::create_dir_all(&dir)?;
            // QA stats file (the pipeline's native output format)
            let mut stats = String::new();
            for (k, v) in &out.qa {
                stats.push_str(&format!("{k}\t{v}\n"));
            }
            stats.push_str(&format!("compute_minutes\t{}\n", out.compute_minutes));
            std::fs::write(dir.join("stats.tsv"), stats)?;
            Provenance {
                pipeline: spec.name.to_string(),
                container_image: sif.clone(),
                container_sha: sha.clone(),
                user: cfg.user.clone(),
                timestamp: 1_720_000_000.0 + i as f64,
                inputs: job.inputs.clone(),
                compute_env: format!("{:?}", envs[i]),
                job_id: Some(i as u64),
            }
            .save(&dir)?;
            engine.record_completion(
                spec.name,
                &SessionKey::new(&job.subject, job.session.as_deref()),
            );
        }
        // check speed factor consistency (documentation invariant)
        debug_assert!(envs.iter().all(|&e| env_speed_factor(e) > 0.0));
        Ok(())
    }
}

/// The campaign's transfer scheduler: the environment's shared component
/// path with the configured concurrent-stream cap. The seed is salted so
/// transfer sampling is independent of the compute-duration stream.
fn campaign_transfers(env: Env, cfg: &CampaignConfig) -> TransferScheduler {
    let topo = Topology::of(env).with_stream_cap(cfg.transfer_streams.max(1));
    TransferScheduler::new(topo, cfg.seed ^ 0x7472_616e_7366_6572) // "transfer"
}

/// Build the staged-execution plan from the queried jobs and their
/// sampled compute outcomes.
fn staged_plan(
    jobs: &[JobSpec],
    outcomes: &[JobOutcome],
    spec: &PipelineSpec,
    cfg: &CampaignConfig,
) -> Vec<StagedJob> {
    jobs.iter()
        .zip(outcomes)
        .map(|(job, out)| StagedJob {
            cores: job.cores,
            ram_gb: job.ram_gb,
            compute_s: out.compute_minutes * 60.0,
            bytes_in: cfg.input_bytes_per_job,
            bytes_out: spec.output_bytes,
        })
        .collect()
}

/// Fold the staged timings back into the job outcomes: the
/// scheduler-observed (contended) transfer times replace the zeroed
/// staging fields, and the slot cost picks up those transfer seconds
/// ([`staged_job_cost`]) instead of independent single-stream samples.
fn fold_staged_timings(env: Env, outcomes: &mut [JobOutcome], staged: &StagedOutcome) {
    for (out, t) in outcomes.iter_mut().zip(&staged.timings) {
        out.stage_in_s = t.stage_in_s;
        out.stage_out_s = t.stage_out_s;
        out.cost_dollars = staged_job_cost(env, out.compute_minutes, t.stage_in_s + t.stage_out_s);
    }
}

/// Keep only jobs whose staged execution ran to verified copy-back
/// ([`staged::StagedTiming::completed`]); jobs the compute backend
/// dropped are returned as a failure count and are neither finalized
/// nor recorded into the processed index — the next query re-offers
/// them.
fn retain_completed(
    jobs: &[JobSpec],
    outcomes: Vec<JobOutcome>,
    staged: &StagedOutcome,
) -> (Vec<JobSpec>, Vec<JobOutcome>, usize) {
    let mut kept_jobs = Vec::with_capacity(jobs.len());
    let mut kept = Vec::with_capacity(jobs.len());
    let mut dropped = 0;
    for ((job, out), t) in jobs.iter().zip(outcomes).zip(&staged.timings) {
        if t.completed {
            kept_jobs.push(job.clone());
            kept.push(out);
        } else {
            dropped += 1;
        }
    }
    (kept_jobs, kept, dropped)
}

/// Compute-side in-engine injection from the campaign config: the
/// pipeline / node / timeout bands go to the compute backend (timeouts
/// parked so the staged loop re-stages inputs); the checksum band
/// belongs to the transfer engine ([`transfer_injection`]). Validated
/// here so an over-unity rate set surfaces as a campaign error instead
/// of a silently truncated Timeout band.
fn compute_injection(cfg: &CampaignConfig) -> Result<Option<Injection>> {
    let Some(model) = cfg.faults else { return Ok(None) };
    model.validate().map_err(|e| anyhow!("campaign fault model: {e}"))?;
    Ok(Some(Injection::campaign_compute(
        &model,
        cfg.max_retries,
        cfg.seed,
        cfg.retry_backoff_s,
    )))
}

/// Transfer-side injection (checksum mismatches). No backoff: a failed
/// verification re-enqueues immediately and the host FIFO itself is the
/// wait.
fn transfer_injection(cfg: &CampaignConfig) -> Result<Option<Injection>> {
    let Some(model) = cfg.faults else { return Ok(None) };
    model.validate().map_err(|e| anyhow!("campaign fault model: {e}"))?;
    Ok(Some(Injection::campaign_transfer(&model, cfg.max_retries, cfg.seed)))
}

/// Fold both engines' fault events into campaign telemetry and bill the
/// wasted compute allocation into each job's effective minutes (the cost
/// fold then prices retries at the slot rate, replacing the old post-hoc
/// duration scaling). Wasted *transfer* seconds are reported but not
/// billed to the slot: while a transfer retries, the job holds no
/// allocation (stage-in precedes it; copy-back follows its release).
fn collect_faults(
    cfg: &CampaignConfig,
    compute_events: &[FaultEvent],
    compute_aborts: usize,
    transfer_events: &[FaultEvent],
    transfer_aborts: usize,
    outcomes: &mut [JobOutcome],
) -> FaultTelemetry {
    // bill each failed compute attempt's wasted allocation into the
    // job's effective minutes (compute ids are job indices — run_staged
    // submits them so); the telemetry fold itself is shared with the
    // `medflow faults` CLI via FaultTelemetry::collect
    for ev in compute_events {
        if let Some(out) = outcomes.get_mut(ev.id as usize) {
            out.compute_minutes += ev.wasted_s / 60.0;
        }
    }
    FaultTelemetry::collect(
        cfg.faults.as_ref(),
        cfg.max_retries,
        cfg.seed,
        compute_events,
        transfer_events,
        (compute_aborts + transfer_aborts) as u64,
    )
}

/// Slot cost of the allocation consumed by jobs that never reached a
/// verified copy-back: their outcomes are dropped by
/// [`retain_completed`] (so the per-job billing in [`collect_faults`]
/// never reaches the campaign total), but the cluster time they burned
/// was real spend — paper §4's cost of "resubmitting failed jobs" does
/// not vanish with the job. Two components: every failed attempt's
/// wasted allocation, plus the full nominal allocation of dropped jobs
/// whose compute *did* finish (a copy-back or re-stage transfer abort
/// after a successful run).
fn dropped_attempt_cost(
    env: Env,
    events: &[FaultEvent],
    timings: &[StagedTiming],
    plan: &[StagedJob],
) -> f64 {
    let wasted_min: f64 = events
        .iter()
        .filter(|ev| !timings.get(ev.id as usize).is_some_and(|t| t.completed))
        .map(|ev| ev.wasted_s / 60.0)
        .sum();
    let computed_min: f64 = timings
        .iter()
        .zip(plan)
        .filter(|(t, _)| !t.completed && t.compute_end_s > 0.0)
        .map(|(_, j)| j.compute_s / 60.0)
        .sum();
    compute_cost(env, wasted_min + computed_min)
}

struct ExecOutcome {
    completed: usize,
    failed: usize,
    makespan_s: f64,
    per_job_minutes: Vec<f64>,
    total_cost: f64,
    artifact_exec_mean_s: f64,
    transfer: TransferStats,
    faults: FaultTelemetry,
    /// Per-backend usage of a placement campaign (DESIGN.md §12).
    placement: Option<Vec<BackendUsage>>,
}

impl ExecOutcome {
    fn collect(outcomes: &[crate::compute::JobOutcome], makespan_s: f64) -> Self {
        let per_job_minutes: Vec<f64> = outcomes.iter().map(|o| o.compute_minutes).collect();
        let total_cost = outcomes.iter().map(|o| o.cost_dollars).sum();
        let execs: Vec<f64> = outcomes
            .iter()
            .filter(|o| o.artifact_exec_s > 0.0)
            .map(|o| o.artifact_exec_s)
            .collect();
        Self {
            completed: outcomes.len(),
            failed: 0,
            makespan_s,
            per_job_minutes,
            total_cost,
            artifact_exec_mean_s: if execs.is_empty() {
                0.0
            } else {
                execs.iter().sum::<f64>() / execs.len() as f64
            },
            transfer: TransferStats::default(),
            faults: FaultTelemetry::default(),
            placement: None,
        }
    }
}

/// Convenience: build a full simulated deployment (archive + containers +
/// coordinator) under one root directory.
pub fn deployment_at<'rt>(
    root: &std::path::Path,
    runtime: Option<&'rt Runtime>,
) -> Result<Coordinator<'rt>> {
    let archive = Archive::at(&root.join("store"))?;
    let containers = ContainerArchive::open(&root.join("containers"))?;
    Ok(Coordinator::new(archive, containers, runtime))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::SecurityTier;
    use crate::workload::{ingest_cohort, SynthCohort};

    fn setup(tag: &str) -> (PathBuf, BidsDataset, Coordinator<'static>) {
        let root = std::env::temp_dir().join(format!("medflow_coord_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let mut archive = Archive::at(&root.join("store")).unwrap();
        let cohort = SynthCohort {
            name: "MINI".into(),
            participants: 3,
            sessions: 4,
            tier: SecurityTier::General,
        };
        let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 11).unwrap();
        let containers = ContainerArchive::open(&root.join("containers")).unwrap();
        let mut coord = Coordinator::new(archive, containers, None);
        coord.cluster = ClusterSpec::small(4, 8, 64);
        (root, ds, coord)
    }

    #[test]
    fn campaign_processes_all_runnable_then_idempotent() {
        let (root, ds, mut coord) = setup("camp");
        let cfg = CampaignConfig::default();
        let r1 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(r1.completed > 0);
        assert_eq!(r1.failed, 0);
        assert!(r1.makespan_s > 0.0);
        assert!(r1.total_cost_dollars > 0.0);
        // second run finds nothing new (idempotency invariant)
        let r2 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert_eq!(r2.completed, 0);
        assert_eq!(r2.skipped, r1.queried);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn second_campaign_performs_no_full_rescan() {
        let (root, ds, mut coord) = setup("norescan");
        let cfg = CampaignConfig::default();
        let r1 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(r1.completed > 0);
        // unchanged archive: every session answered from the persistent
        // indexes — zero sessions re-evaluated, no filesystem walk
        let r2 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(!r2.query_stats.full_scan);
        assert_eq!(r2.query_stats.sessions_examined, 0, "{:?}", r2.query_stats);
        assert_eq!(r2.query_stats.new_sessions, 0);
        assert_eq!(r2.query_stats.sessions_replayed, r1.queried);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn provenance_written_per_instance() {
        let (root, ds, mut coord) = setup("prov");
        let cfg = CampaignConfig::default();
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        let mut provs = 0;
        for sub in ds.subjects().unwrap() {
            for ses in ds.sessions(&sub).unwrap() {
                let name = BidsName::new(&sub, ses.as_deref(), Modality::T1w);
                let p = ds.derivative_dir("freesurfer", &name).join("provenance.json");
                if p.exists() {
                    let prov = Provenance::load(&p).unwrap();
                    assert_eq!(prov.pipeline, "freesurfer");
                    assert_eq!(prov.user, "medflow");
                    provs += 1;
                }
            }
        }
        assert_eq!(provs, r.completed);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn local_burst_completes_same_work() {
        let (root, ds, mut coord) = setup("burst");
        let cfg = CampaignConfig::default();
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::LocalBurst { workers: 2 }, &cfg)
            .unwrap();
        assert!(r.completed > 0);
        assert_eq!(r.failed, 0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn placement_campaign_completes_and_reports_backends() {
        let (root, ds, mut coord) = setup("placed");
        let cfg = CampaignConfig {
            placement: Some(PlacementPolicy::CheapestFirst),
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Placement, &cfg)
            .unwrap();
        assert!(r.completed > 0);
        assert_eq!(r.failed, 0);
        let usage = r.placement.as_ref().expect("placement campaigns report backend usage");
        assert_eq!(usage.iter().map(|u| u.jobs).sum::<usize>(), r.completed);
        // cheapest-first degenerates to all-HPC at the paper's rates
        assert_eq!(usage[0].jobs, r.completed, "{usage:?}");
        assert!(r.total_cost_dollars > 0.0);
        assert_eq!(r.transfer.transfers, 2 * r.completed);
        // idempotency holds through the placement path too
        let r2 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Placement, &cfg)
            .unwrap();
        assert_eq!(r2.completed, 0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn placement_campaign_with_faults_conserves_jobs() {
        let (root, ds, mut coord) = setup("placedf");
        let cfg = CampaignConfig {
            placement: Some(PlacementPolicy::DeadlineAware { deadline_s: 3.0 * 3600.0 }),
            faults: Some(FaultModel {
                p_checksum: 0.05,
                p_pipeline: 0.4,
                p_node: 0.05,
                p_timeout: 0.1,
            }),
            max_retries: 4,
            retry_backoff_s: 10.0,
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Placement, &cfg)
            .unwrap();
        assert_eq!(r.completed + r.failed, r.queried - r.skipped);
        assert!(r.faults.counts.total() > 0, "{:?}", r.faults);
        assert!(r.total_cost_dollars > 0.0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn maintenance_triggers_burst_choice() {
        let (root, _ds, mut coord) = setup("maint");
        coord.add_maintenance(Maintenance {
            start_s: 0.0,
            end_s: 3600.0,
        });
        assert_eq!(
            coord.choose_target(100.0, 4),
            SubmitTarget::LocalBurst { workers: 4 }
        );
        assert_eq!(coord.choose_target(7200.0, 4), SubmitTarget::Hpc);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn fault_model_inflates_cost_and_reports_aborts() {
        let (root, ds, mut coord) = setup("faults");
        let clean_cfg = CampaignConfig::default();
        // a deliberately heavy model so the 12-session MINI campaign
        // deterministically sees failed attempts in every band
        let heavy_cfg = CampaignConfig {
            faults: Some(FaultModel {
                p_checksum: 0.05,
                p_pipeline: 0.4,
                p_node: 0.05,
                p_timeout: 0.1,
            }),
            max_retries: 4,
            retry_backoff_s: 10.0,
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &heavy_cfg)
            .unwrap();
        // completed + aborted = all runnable
        assert_eq!(r.completed + r.failed, r.queried - r.skipped);
        // the in-engine injection must have recorded real events…
        assert!(r.faults.counts.total() > 0, "{:?}", r.faults);
        assert!(r.faults.wasted_compute_minutes > 0.0, "{:?}", r.faults);
        assert!(r.faults.compute_retries >= r.faults.restages);
        // …and the closed-form §4 cross-check must agree on the sign
        assert!(r.faults.expected_overrun_factor > 1.0);
        // the same campaign fault-free on a twin dataset costs the naive
        // per-job rate; with faults the per-job cost is higher (wasted
        // attempts are billed at the slot rate)
        let per_job_faulty = r.total_cost_dollars / r.completed.max(1) as f64;
        let (root2, ds2, mut coord2) = setup("faults2");
        let r2 = coord2
            .run_campaign(&ds2, "freesurfer", SubmitTarget::Hpc, &clean_cfg)
            .unwrap();
        assert_eq!(r2.faults, crate::faults::FaultTelemetry::default());
        let per_job_clean = r2.total_cost_dollars / r2.completed.max(1) as f64;
        assert!(
            per_job_faulty > per_job_clean,
            "faulty {per_job_faulty} must exceed clean {per_job_clean}"
        );
        std::fs::remove_dir_all(&root).unwrap();
        std::fs::remove_dir_all(&root2).unwrap();
    }

    #[test]
    fn aborted_jobs_still_bill_their_wasted_attempts() {
        // every attempt fails → every job aborts after max_retries + 1
        // attempts; the campaign completes nothing but the cluster time
        // those attempts burned is real spend and must reach the total
        let (root, ds, mut coord) = setup("abortcost");
        let cfg = CampaignConfig {
            faults: Some(FaultModel {
                p_checksum: 0.0,
                p_pipeline: 1.0,
                p_node: 0.0,
                p_timeout: 0.0,
            }),
            max_retries: 1,
            retry_backoff_s: 1.0,
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert_eq!(r.completed, 0);
        assert_eq!(r.failed, r.queried - r.skipped);
        assert!(r.faults.wasted_compute_minutes > 0.0, "{:?}", r.faults);
        assert!(
            r.total_cost_dollars > 0.0,
            "wasted attempts of aborted jobs are real cluster spend"
        );
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn invalid_fault_model_is_a_campaign_error() {
        let (root, ds, mut coord) = setup("badfaults");
        let cfg = CampaignConfig {
            faults: Some(FaultModel {
                p_checksum: 0.0,
                p_pipeline: 0.9,
                p_node: 0.0,
                p_timeout: 0.9,
            }),
            ..Default::default()
        };
        let err = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fault model"), "{err}");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn local_burst_campaign_injects_faults_too() {
        let (root, ds, mut coord) = setup("lfaults");
        let cfg = CampaignConfig {
            faults: Some(FaultModel {
                p_checksum: 0.05,
                p_pipeline: 0.4,
                p_node: 0.05,
                p_timeout: 0.1,
            }),
            max_retries: 4,
            retry_backoff_s: 5.0,
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::LocalBurst { workers: 2 }, &cfg)
            .unwrap();
        assert_eq!(r.completed + r.failed, r.queried - r.skipped);
        assert!(r.faults.counts.total() > 0, "{:?}", r.faults);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn oversized_jobs_fail_and_stay_unprocessed() {
        let (root, ds, mut coord) = setup("oversz");
        // freesurfer wants 8 GB; no node has more than 4 → every job is
        // unplaceable and must surface as failed, not completed
        coord.cluster = ClusterSpec::small(2, 2, 4);
        let cfg = CampaignConfig::default();
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert_eq!(r.completed, 0, "nothing computed on an unplaceable cluster");
        assert!(r.failed > 0);
        assert_eq!(r.failed, r.queried - r.skipped);
        // nothing was recorded as processed: a capable cluster re-runs it
        coord.cluster = ClusterSpec::small(4, 8, 64);
        let r2 = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert_eq!(r2.completed, r.failed, "dropped jobs must be re-offered");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn campaign_reports_transfer_contention() {
        let (root, ds, mut coord) = setup("xfer");
        let cfg = CampaignConfig {
            transfer_streams: 2,
            ..Default::default()
        };
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(r.completed > 0);
        // one stage-in and one verified copy-back per completed job
        assert_eq!(r.transfer.transfers, 2 * r.completed);
        assert!(r.transfer.peak_streams >= 1 && r.transfer.peak_streams <= 2);
        assert!(r.transfer.link_utilization > 0.0);
        assert!(r.transfer.link_utilization <= 1.0 + 1e-9);
        let cap = crate::netsim::scheduler::Topology::of(Env::Hpc).bottleneck_gbps();
        assert!(r.transfer.aggregate_gbps <= cap + 1e-9);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stream_cap_one_queues_transfers_wide_cap_does_not() {
        // MINI has 12 sessions: a cap of 1 must serialize the stage-in
        // storm (queue waits), while a cap wider than the whole campaign
        // never queues anything
        let (root1, ds1, mut coord1) = setup("cap1");
        let narrow = CampaignConfig {
            transfer_streams: 1,
            ..Default::default()
        };
        let r1 = coord1
            .run_campaign(&ds1, "freesurfer", SubmitTarget::Hpc, &narrow)
            .unwrap();
        assert!(r1.transfer.mean_queue_wait_s > 0.0, "{:?}", r1.transfer);
        assert_eq!(r1.transfer.peak_streams, 1);

        let (root2, ds2, mut coord2) = setup("capwide");
        let wide = CampaignConfig {
            transfer_streams: 64,
            ..Default::default()
        };
        let r2 = coord2
            .run_campaign(&ds2, "freesurfer", SubmitTarget::Hpc, &wide)
            .unwrap();
        assert_eq!(r2.transfer.mean_queue_wait_s, 0.0, "{:?}", r2.transfer);
        assert!(r2.transfer.peak_streams > 1);
        std::fs::remove_dir_all(&root1).unwrap();
        std::fs::remove_dir_all(&root2).unwrap();
    }

    #[test]
    fn staged_outcomes_carry_scheduler_transfer_times() {
        let (root, ds, mut coord) = setup("stagedt");
        let cfg = CampaignConfig::default();
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::LocalBurst { workers: 2 }, &cfg)
            .unwrap();
        assert!(r.completed > 0);
        assert!(r.makespan_s > 0.0);
        assert!(r.transfer.busy_s > 0.0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn skip_csv_emitted() {
        let (root, ds, mut coord) = setup("skipcsv");
        let cfg = CampaignConfig::default();
        let r = coord
            .run_campaign(&ds, "freesurfer", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(r.skip_csv.contains("subject,session,skip_reason"));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn resource_status_reports_storage() {
        let (root, _ds, coord) = setup("status");
        let st = coord.resource_status(0.0, 0.5).unwrap();
        assert!(st.general_store_used_bytes > 0);
        assert_eq!(st.gdpr_store_used_bytes, 0);
        assert!(!st.cluster_in_maintenance);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn dependent_pipeline_unlocked_by_campaign() {
        let (root, ds, mut coord) = setup("dep");
        let cfg = CampaignConfig::default();
        // tractseg blocked until prequal runs
        let r0 = coord
            .run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert_eq!(r0.completed, 0);
        let _ = coord
            .run_campaign(&ds, "prequal", SubmitTarget::Hpc, &cfg)
            .unwrap();
        let r1 = coord
            .run_campaign(&ds, "tractseg", SubmitTarget::Hpc, &cfg)
            .unwrap();
        assert!(r1.completed > 0, "tractseg should now run");
        std::fs::remove_dir_all(&root).unwrap();
    }
}
