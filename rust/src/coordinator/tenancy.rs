//! Multi-tenant fleet co-simulation (DESIGN.md §13).
//!
//! Everything below `coordinator` so far simulates *one* campaign with
//! the whole fleet to itself. The paper's engine exists so a team —
//! and, in the brainlife.io brokering sense, many independent owners —
//! can process national-study data on one shared low-cost fleet. This
//! module co-simulates N independent campaigns ([`TenantSpec`]: owner,
//! priority, fair-share weight, budget, deadline, job list) against one
//! shared fleet of [`BackendSpec`]s and **one** shared
//! [`TransferScheduler`], generalizing [`super::staged::run_multi`] the
//! way placement generalized `run_staged`:
//!
//! * every tenant's jobs are planned per-tenant (its own
//!   [`PlacementPolicy`]) and then flattened into one global job-id
//!   space, tenant by tenant — ids keep `run_multi`'s `2i`/`2i+1`
//!   transfer-id scheme unique on the single scheduler, and they are
//!   what decorrelates two tenants' same-numbered jobs in every
//!   engine's per-(id, attempt) fault stream
//!   ([`crate::faults::attempt_rng`]);
//! * **admission arbitration**: jobs enter the co-simulation through a
//!   fleet-wide queue-depth cap ([`TenancyConfig::queue_depth`]).
//!   Whenever a slot frees, the next job is drawn from the
//!   highest-priority tier with pending work (admission-level
//!   preemption: a higher-priority tenant's pending job always jumps
//!   ahead of lower-priority pending work; running attempts are never
//!   killed), and within the tier from the tenant with the lowest
//!   *virtual service* — admitted effective compute seconds divided by
//!   its weight — which is weighted fair-share in its
//!   deficit-round-robin form. Tenants beyond the cap wait in their
//!   per-tenant pending pool;
//! * per-tenant telemetry folds into a [`TenancyReport`]: dollars (the
//!   same [billing rule](super::placement) placement prices with),
//!   makespan, queue-wait p50/p95, share of fleet compute actually
//!   received, and the contended-window share the fairness gates assert
//!   against.
//!
//! **Single-tenant parity** is the design constraint everything above
//! bends around: with one tenant and no depth cap, the sequence of
//! engine calls — engine construction, the shared scheduler's seed,
//! every submission and `advance_to` instant — is identical call for
//! call to `coordinator::placement`'s path, so N=1 outcomes are
//! f64-record-identical to `placement::execute` for every policy
//! (enforced by `rust/tests/tenancy_parity.rs`, the same golden
//! discipline as `engine_parity.rs`). That is why this module *shares*
//! placement's `build_engine`, billing fold, and topology rather than
//! re-implementing them.

use std::collections::{BTreeMap, VecDeque};

use crate::compute::env_speed_factor;
use crate::cost::staged_job_cost;
use crate::faults::outage::{OutageSchedule, OutageStats};
use crate::faults::{tenant_seed, FaultEvent, FaultModel, Injection};
use crate::netsim::scheduler::{TransferScheduler, TransferStats};
use crate::util::units::percentiles;

use super::spec::RunSpec;

use super::placement::{
    build_engine, collect_compute_faults, fold_backend_usage, job_billing, plan, rate_order,
    shared_topology, transfer_estimate_s, BackendEngine, BackendSpec, BackendUsage,
    PlacementConfig, PlacementPolicy, PLACEMENT_TRANSFER_SALT,
};
use super::staged::{
    stage_in_id, stage_out_id, synthetic_fault_campaign, ComputeSim, MergedEvents, StagedJob,
    StagedOutcome, StagedTiming,
};
use super::sync::{with_driver, BackendStep, WindowDriver};

/// One tenant of a shared fleet: an independent campaign with its own
/// owner, arbitration knobs, and SLOs.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Owner label ("lab-a", "uker-7", …) — reporting only.
    pub name: String,
    /// Weighted fair-share weight (finite, > 0): within a priority
    /// tier, admitted service converges to weights' proportions.
    pub weight: f64,
    /// Strict admission tier: a pending job of a higher-priority tenant
    /// always admits before any lower-priority pending job.
    pub priority: u32,
    /// Placement policy for *this tenant's* jobs across the shared
    /// fleet (each tenant plans independently; arbitration happens at
    /// admission, not planning).
    pub policy: PlacementPolicy,
    /// Dollar budget SLO; `None` = unconstrained. Reported by default
    /// ([`TenantUsage::budget_met`]); [`run_tenants_chaos`] with
    /// `enforce = true` additionally stops admitting this tenant once
    /// projected committed spend would burn through it (DESIGN.md §15).
    pub budget_dollars: Option<f64>,
    /// Deadline SLO in simulated seconds; `None` = unconstrained.
    pub deadline_s: Option<f64>,
    pub jobs: Vec<StagedJob>,
}

impl TenantSpec {
    /// A default tenant: weight 1, priority 0, cheapest-first, no SLOs.
    pub fn new(name: impl Into<String>, jobs: Vec<StagedJob>) -> Self {
        Self {
            name: name.into(),
            weight: 1.0,
            priority: 0,
            policy: PlacementPolicy::CheapestFirst,
            budget_dollars: None,
            deadline_s: None,
            jobs,
        }
    }
}

/// Knobs of a multi-tenant run. Mirrors [`PlacementConfig`] (same
/// defaults) plus the fleet-wide admission cap.
#[derive(Debug, Clone, Copy)]
pub struct TenancyConfig {
    pub seed: u64,
    /// Checksum-failure model on the shared staging path.
    pub transfer_faults: Option<FaultModel>,
    pub max_retries: u32,
    pub retry_backoff_s: f64,
    /// Max jobs admitted fleet-wide at once (≥ 1); `None` = unbounded,
    /// which is also the N=1 parity configuration — with no cap every
    /// job is admitted at t=0 exactly like `run_multi`.
    pub queue_depth: Option<usize>,
}

impl Default for TenancyConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            transfer_faults: None,
            max_retries: 3,
            retry_backoff_s: 60.0,
            queue_depth: None,
        }
    }
}

impl TenancyConfig {
    /// The placement-layer view of these knobs — engine construction
    /// and the shared scheduler go through the *same* config type so
    /// the N=1 path cannot drift.
    pub fn placement(&self) -> PlacementConfig {
        PlacementConfig {
            seed: self.seed,
            transfer_faults: self.transfer_faults,
            max_retries: self.max_retries,
            retry_backoff_s: self.retry_backoff_s,
        }
    }
}

/// One tenant's measured share of a co-simulated fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantUsage {
    pub name: String,
    pub priority: u32,
    pub weight: f64,
    pub jobs: usize,
    /// Jobs that reached a verified copy-back.
    pub completed: usize,
    /// Jobs dropped before completion (retries exhausted anywhere in
    /// the staged pipeline, or never admitted under SLO enforcement).
    pub aborted: usize,
    /// Jobs never admitted because SLO enforcement stopped this tenant
    /// (budget burned) — billed $0, a subset of `aborted`. Always 0
    /// without enforcement.
    pub slo_aborted: usize,
    /// Jobs escalated to the fleet's fastest backend because they were
    /// admitted past this tenant's deadline (enforcement only).
    pub escalated: usize,
    /// Compute-fault events on this tenant's jobs.
    pub failed_attempts: usize,
    /// Billed effective minutes (wasted attempts included).
    pub compute_minutes: f64,
    pub cost_dollars: f64,
    /// Last instant any of this tenant's jobs finished (copy-back, or
    /// compute end for jobs dropped later in the pipeline).
    pub makespan_s: f64,
    /// p50/p95 of per-job queue wait: time spent in the pending pool
    /// (admission instant − t=0) plus time queued for a transfer
    /// stream. Jobs never admitted are excluded.
    pub queue_wait_p50_s: f64,
    pub queue_wait_p95_s: f64,
    /// Share of the fleet's billed compute-minutes this tenant actually
    /// received over the whole run (demand-dominated once queues
    /// drain — see `contended_share` for the fairness signal).
    pub fleet_share: f64,
    /// Share of admitted effective-compute service granted while
    /// *every* tenant still had pending work — the window where
    /// arbitration, not demand, decides shares. 0.0 when the run never
    /// contends (e.g. no depth cap). Fairness gates compare this to
    /// `entitlement` (DESIGN.md §13 states the tolerance).
    pub contended_share: f64,
    /// weight / Σ weights.
    pub entitlement: f64,
    pub budget_dollars: Option<f64>,
    pub deadline_s: Option<f64>,
    pub budget_met: bool,
    pub deadline_met: bool,
}

/// The fleet-wide fold of a multi-tenant run — `CampaignReport`'s
/// multi-tenant sibling.
#[derive(Debug, Clone, PartialEq)]
pub struct TenancyReport {
    pub tenants: Vec<TenantUsage>,
    /// Per-backend usage, identical fold to a placement run's.
    pub per_backend: Vec<BackendUsage>,
    pub total_cost_dollars: f64,
    pub makespan_s: f64,
    pub transfer: TransferStats,
    /// Jobs + transfers dropped after exhausting retries, fleet-wide.
    pub aborted: u64,
    pub queue_depth: Option<usize>,
    /// Infrastructure-outage telemetry (DESIGN.md §15): `Some` exactly
    /// when the run went through [`run_tenants_chaos`].
    pub outage: Option<OutageStats>,
    /// True when SLO enforcement (budget stop + deadline escalation)
    /// was armed for this run.
    pub enforced: bool,
}

/// Full result of [`run_tenants`]: the report plus the flattened
/// record-level detail the test battery asserts on.
#[derive(Debug)]
pub struct TenancyOutcome {
    pub report: TenancyReport,
    /// Flattened staged outcome over the global job-id space.
    pub staged: StagedOutcome,
    /// Global job → backend index.
    pub assignment: Vec<usize>,
    /// Global job → tenant index.
    pub tenant_of: Vec<usize>,
    /// Global job → admission instant (`f64::INFINITY` = never
    /// admitted; cannot happen while slots are released on aborts).
    pub admit_s: Vec<f64>,
    /// Tenant index → `[start, end)` of its jobs in the global space.
    pub tenant_ranges: Vec<(usize, usize)>,
    pub compute_events: Vec<FaultEvent>,
    pub transfer_events: Vec<FaultEvent>,
}

/// N tenants with decorrelated synthetic campaigns: tenant `k` draws
/// its jobs from [`synthetic_fault_campaign`] seeded
/// [`tenant_seed`]`(seed, k)` — the per-tenant analogue of placement's
/// per-backend salt. Shared by `medflow tenants`, the tenancy benches,
/// and the fairness battery so all three replay the same fleet.
pub fn synthetic_tenants(n_tenants: usize, jobs_per_tenant: usize, seed: u64) -> Vec<TenantSpec> {
    (0..n_tenants)
        .map(|k| {
            TenantSpec::new(
                format!("tenant-{k:04}"),
                synthetic_fault_campaign(jobs_per_tenant, tenant_seed(seed, k)),
            )
        })
        .collect()
}

/// Admission arbiter: per-tenant pending pools, strict priority tiers,
/// weighted fair-share (lowest virtual service first) within a tier,
/// and the contended-window tallies the fairness gates read.
struct Admission {
    /// Per-tenant FIFO of global job indices not yet admitted.
    pending: Vec<VecDeque<usize>>,
    weight: Vec<f64>,
    priority: Vec<u32>,
    /// Admitted effective compute seconds / weight, per tenant.
    vtime: Vec<f64>,
    /// Global job → effective compute seconds (the service a grant
    /// charges against the tenant's virtual time).
    service: Vec<f64>,
    in_flight: usize,
    /// `usize::MAX` = unbounded.
    depth: usize,
    /// Tenants that started with ≥ 1 job — the population whose
    /// simultaneous pending-ness defines the contended window.
    active_total: usize,
    contended_service: Vec<f64>,
    contended_total: f64,
    /// SLO enforcement armed ([`Admission::with_enforcement`]): budget
    /// gates below are live, `committed`/`proj_cost` are populated.
    enforce: bool,
    /// Per-tenant budget SLO (enforcement only; `None` = unconstrained).
    budget: Vec<Option<f64>>,
    /// Projected dollars committed by this tenant's grants so far.
    committed: Vec<f64>,
    /// Global job → projected dollars (planner estimate, the admission
    /// analogue of the placement policies' `staged_job_cost` ranking).
    proj_cost: Vec<f64>,
}

impl Admission {
    fn new(
        tenants: &[TenantSpec],
        ranges: &[(usize, usize)],
        effective: &[StagedJob],
        queue_depth: Option<usize>,
    ) -> Self {
        let pending: Vec<VecDeque<usize>> =
            ranges.iter().map(|&(lo, hi)| (lo..hi).collect()).collect();
        Self {
            active_total: pending.iter().filter(|q| !q.is_empty()).count(),
            service: effective.iter().map(|j| j.compute_s).collect(),
            weight: tenants.iter().map(|t| t.weight).collect(),
            priority: tenants.iter().map(|t| t.priority).collect(),
            vtime: vec![0.0; tenants.len()],
            contended_service: vec![0.0; tenants.len()],
            contended_total: 0.0,
            in_flight: 0,
            depth: queue_depth.unwrap_or(usize::MAX),
            enforce: false,
            budget: vec![None; tenants.len()],
            committed: vec![0.0; tenants.len()],
            proj_cost: Vec::new(),
            pending,
        }
    }

    /// Arm SLO enforcement (DESIGN.md §15): [`Admission::next`] stops
    /// admitting a tenant once its committed projected spend plus the
    /// head job's projection would exceed its budget — the stranded
    /// jobs drain as [`TenantUsage::slo_aborted`], billed $0.
    fn with_enforcement(mut self, tenants: &[TenantSpec], proj_cost: Vec<f64>) -> Self {
        assert_eq!(proj_cost.len(), self.service.len(), "one projection per job");
        self.enforce = true;
        self.budget = tenants.iter().map(|t| t.budget_dollars).collect();
        self.proj_cost = proj_cost;
        self
    }

    /// Grant one admission slot: highest priority tier first, lowest
    /// virtual service within the tier, lowest tenant index on exact
    /// ties — fully deterministic. Charges the job's service to the
    /// tenant and to the contended tallies when every active tenant
    /// still had pending work.
    fn next(&mut self) -> Option<usize> {
        let mut best: Option<usize> = None;
        let mut contending = 0usize;
        for k in 0..self.pending.len() {
            if self.pending[k].is_empty() {
                continue;
            }
            // a budget-stopped tenant is done contending: budgets only
            // burn, so its FIFO head can never admit again
            if self.enforce {
                if let Some(b) = self.budget[k] {
                    let head = *self.pending[k].front().expect("non-empty pending pool");
                    if self.committed[k] + self.proj_cost[head] > b + 1e-9 {
                        continue;
                    }
                }
            }
            contending += 1;
            best = Some(match best {
                None => k,
                Some(b) => {
                    let wins = self.priority[k] > self.priority[b]
                        || (self.priority[k] == self.priority[b] && self.vtime[k] < self.vtime[b]);
                    if wins {
                        k
                    } else {
                        b
                    }
                }
            });
        }
        let k = best?;
        let contended = contending == self.active_total;
        let i = self.pending[k].pop_front().expect("best tenant has pending work");
        let service = self.service[i];
        self.vtime[k] += service / self.weight[k];
        if self.enforce {
            self.committed[k] += self.proj_cost[i];
        }
        if contended {
            self.contended_service[k] += service;
            self.contended_total += service;
        }
        self.in_flight += 1;
        Some(i)
    }

    fn release(&mut self) {
        self.in_flight = self.in_flight.saturating_sub(1);
    }
}

/// Graceful-degradation context threaded through [`run_admitted`] on
/// the chaos path (DESIGN.md §15): the outage schedule driving orphan
/// re-placement, plus the SLO-escalation inputs when enforcement is on.
struct DegradeCtx<'a> {
    schedule: &'a OutageSchedule,
    fleet: &'a [BackendSpec],
    /// Fleet in $/hr-ascending order — orphans re-place onto the first
    /// backend alive at the orphan instant.
    by_rate: Vec<usize>,
    /// Escalation target: highest speed factor, lowest index on ties.
    fastest: usize,
    enforce: bool,
    /// Per-tenant deadline SLO.
    deadline: Vec<Option<f64>>,
    tenant_of: &'a [usize],
    /// Global job → nominal (speed-factor-free) compute seconds, so a
    /// moved job's compute rescales from the invariant, not the last
    /// backend's scaled value.
    nominal_s: Vec<f64>,
}

/// What the degradation machinery did during one run.
#[derive(Default)]
struct DegradeTally {
    orphaned: u64,
    re_placed: u64,
    /// Per-tenant count of deadline-escalated jobs.
    escalated: Vec<usize>,
}

/// Deadline escalation (enforcement only): a job granted admission
/// *after* its tenant's deadline can no longer meet it on a cheap
/// backend — move it to the fleet's fastest and rescale its compute.
fn escalate_if_late(
    ctx: &DegradeCtx,
    i: usize,
    when: f64,
    effective: &mut [StagedJob],
    assignment: &mut [usize],
    escalated: &mut [usize],
) {
    if !ctx.enforce {
        return;
    }
    let k = ctx.tenant_of[i];
    let Some(deadline) = ctx.deadline[k] else { return };
    if when <= deadline || assignment[i] == ctx.fastest {
        return;
    }
    assignment[i] = ctx.fastest;
    effective[i] = StagedJob {
        compute_s: ctx.nominal_s[i] / env_speed_factor(ctx.fleet[ctx.fastest].env),
        ..effective[i]
    };
    escalated[k] += 1;
}

/// [`super::staged::run_multi`]'s co-simulation loop with admission
/// control threaded through: stage-ins are submitted when a job is
/// *admitted* (not unconditionally at t=0), and a finished or dead job
/// releases its fleet-wide admission slot to the arbiter.
///
/// With an unbounded depth the initial admission loop grants every job
/// up front — for a single tenant that is `run_multi`'s
/// all-stage-ins-at-zero loop in the same job order, and nothing below
/// ever re-enters the arbiter, so the engine-call sequence is identical
/// call for call (the N=1 parity gate).
///
/// With `chaos` present, orphans handed back at outage onsets re-place
/// exactly like `placement::execute_chaos` (cheapest alive at the
/// orphan instant), and grants past an enforced deadline escalate
/// ([`escalate_if_late`]). `chaos = None` adds no engine calls.
fn run_admitted(
    effective: &mut [StagedJob],
    assignment: &mut [usize],
    engines: &mut [BackendEngine],
    transfers: &mut TransferScheduler,
    adm: &mut Admission,
    chaos: Option<&DegradeCtx>,
    threads: usize,
) -> (StagedOutcome, Vec<f64>, DegradeTally) {
    let mut backends: Vec<&mut dyn ComputeSim> =
        engines.iter_mut().map(|e| e.as_compute()).collect();
    with_driver(&mut backends, threads, |driver| {
        run_admitted_windows(driver, effective, assignment, transfers, adm, chaos)
    })
}

/// The window loop of [`run_admitted`], generic over the
/// [`WindowDriver`] so the same code path serves sequential and
/// sharded-by-thread execution (`coordinator::sync` module docs).
fn run_admitted_windows(
    driver: &mut dyn WindowDriver,
    effective: &mut [StagedJob],
    assignment: &mut [usize],
    transfers: &mut TransferScheduler,
    adm: &mut Admission,
    chaos: Option<&DegradeCtx>,
) -> (StagedOutcome, Vec<f64>, DegradeTally) {
    let n = effective.len();
    let mut timings = vec![StagedTiming::default(); n];
    let mut admit_s = vec![f64::INFINITY; n];
    let mut tally = DegradeTally {
        escalated: vec![0; adm.pending.len()],
        ..Default::default()
    };
    while adm.in_flight < adm.depth {
        let Some(i) = adm.next() else { break };
        if let Some(ctx) = chaos {
            escalate_if_late(ctx, i, 0.0, effective, assignment, &mut tally.escalated);
        }
        admit_s[i] = 0.0;
        transfers.submit_at(stage_in_id(i), assignment[i] as u64, effective[i].bytes_in, 0.0);
    }
    // transfer ids ≥ 2·jobs are re-stages; the map recovers their job
    let mut next_restage_id = (n as u64) * 2;
    let mut restage_job: BTreeMap<u64, usize> = BTreeMap::new();
    let mut events = MergedEvents::new();
    let mut seen = 0usize;
    let mut seen_engine_aborts = vec![0usize; driver.next_events().len()];
    let mut seen_transfer_aborts = 0usize;
    let mut steps: Vec<BackendStep> = Vec::new();
    loop {
        events.arm(transfers.next_event_time());
        for &next in driver.next_events() {
            events.arm(next);
        }
        let Some(t) = events.pop_earliest() else { break };
        transfers.advance_to(t);
        // instants at which an admission slot freed this iteration
        let mut freed: Vec<f64> = Vec::new();
        {
            // borrow, don't clone: this loop only reads the new
            // completions (it mutates the engines and `timings`)
            let records = transfers.records();
            let new_from = seen;
            seen = records.len();
            for r in &records[new_from..] {
                let (i, stage_in) = match restage_job.get(&r.id) {
                    Some(&i) => (i, true),
                    None => ((r.id / 2) as usize, r.id % 2 == 0),
                };
                if stage_in {
                    timings[i].stage_in_wait_s = r.queue_wait_s();
                    timings[i].stage_in_s = r.transfer_s();
                    driver.submit(assignment[i], i as u64, r.end_s, effective[i]);
                } else {
                    timings[i].stage_out_wait_s = r.queue_wait_s();
                    timings[i].stage_out_s = r.transfer_s();
                    timings[i].done_s = r.end_s;
                    timings[i].completed = true;
                    freed.push(r.end_s);
                }
            }
        }
        driver.advance(t, &mut steps);
        for step in &steps {
            for &(id, end_s) in &step.done {
                let i = id as usize;
                timings[i].compute_end_s = end_s;
                timings[i].compute_start_s = end_s - effective[i].compute_s;
                transfers.submit_at(
                    stage_out_id(i),
                    assignment[i] as u64,
                    effective[i].bytes_out,
                    end_s,
                );
            }
            // timed-out attempts hand back here: their scratch inputs are
            // gone, so the retry waits on a fresh (re-contending) stage-in
            for &(id, fail_s) in &step.restage {
                let i = id as usize;
                let rid = next_restage_id;
                next_restage_id += 1;
                restage_job.insert(rid, i);
                transfers.submit_at(
                    rid,
                    assignment[i] as u64,
                    effective[i].bytes_in,
                    fail_s.max(transfers.clock()),
                );
            }
            // outage onsets hand orphans back here: re-place onto the
            // cheapest backend alive at the orphan instant (the original
            // when none survives — its engine blocks until window end),
            // re-stage inputs there, resubmit when they land
            if let Some(ctx) = chaos {
                for &(id, orphan_s) in &step.orphans {
                    let i = id as usize;
                    tally.orphaned += 1;
                    let to = ctx
                        .by_rate
                        .iter()
                        .copied()
                        .find(|&k| ctx.schedule.in_window(k, orphan_s).is_none())
                        .unwrap_or(assignment[i]);
                    if to != assignment[i] {
                        tally.re_placed += 1;
                        assignment[i] = to;
                        effective[i] = StagedJob {
                            compute_s: ctx.nominal_s[i] / env_speed_factor(ctx.fleet[to].env),
                            ..effective[i]
                        };
                    }
                    let rid = next_restage_id;
                    next_restage_id += 1;
                    restage_job.insert(rid, i);
                    transfers.submit_at(
                        rid,
                        assignment[i] as u64,
                        effective[i].bytes_in,
                        orphan_s.max(transfers.clock()),
                    );
                }
            }
        }
        // dead jobs release their slots too, or a faulty run would leak
        // admission capacity and starve the pending pool: the compute
        // engines record retry-exhausted jobs, the transfer scheduler
        // records dropped stage-ins/copy-backs — each dead job lands in
        // exactly one of those lists
        for (k, step) in steps.iter().enumerate() {
            for _ in seen_engine_aborts[k]..step.aborted {
                freed.push(t);
            }
            seen_engine_aborts[k] = step.aborted;
        }
        let transfer_aborts = transfers.aborted_ids().len();
        for _ in seen_transfer_aborts..transfer_aborts {
            freed.push(t);
        }
        seen_transfer_aborts = transfer_aborts;
        // grant each freed slot to the next arbitrated pending job at
        // the instant it freed
        for at in freed {
            adm.release();
            if adm.in_flight < adm.depth {
                if let Some(i) = adm.next() {
                    let when = at.max(transfers.clock());
                    if let Some(ctx) = chaos {
                        escalate_if_late(ctx, i, when, effective, assignment, &mut tally.escalated);
                    }
                    admit_s[i] = when;
                    transfers.submit_at(
                        stage_in_id(i),
                        assignment[i] as u64,
                        effective[i].bytes_in,
                        when,
                    );
                }
            }
        }
    }
    let makespan_s = timings
        .iter()
        .map(|x| x.compute_end_s)
        .fold(transfers.stats().makespan_s, f64::max);
    (
        StagedOutcome {
            makespan_s,
            transfer: transfers.stats(),
            timings,
        },
        admit_s,
        tally,
    )
}

/// Co-simulate N tenants against one shared fleet and one shared
/// transfer scheduler (module docs; DESIGN.md §13).
///
/// Panics on invalid specs — non-finite or non-positive weights, a
/// zero depth cap, an empty tenant list or fleet — matching the
/// assert-early convention of `run_multi` and `Rng::below(0)`.
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec and call RunSpec::run_tenants"
)]
pub fn run_tenants(
    tenants: &[TenantSpec],
    fleet: &[BackendSpec],
    cfg: &TenancyConfig,
) -> TenancyOutcome {
    RunSpec::new().run_tenants(tenants, fleet, cfg)
}

/// [`run_tenants`] with the compute engines sharded across `threads`
/// worker threads (`coordinator::sync`). `threads = 1` is byte-identical
/// to [`run_tenants`]; any thread count is f64-record-identical
/// (`rust/tests/parallel_parity.rs`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .threads(n) and call RunSpec::run_tenants"
)]
pub fn run_tenants_threaded(
    tenants: &[TenantSpec],
    fleet: &[BackendSpec],
    cfg: &TenancyConfig,
    threads: usize,
) -> TenancyOutcome {
    RunSpec::new().threads(threads).run_tenants(tenants, fleet, cfg)
}

/// [`run_tenants`] under an infrastructure-fault schedule with optional
/// SLO *enforcement* (DESIGN.md §15) — the landing of ROADMAP item 1's
/// "enforced SLOs":
///
/// * backend outage windows and link brownouts co-simulate exactly as
///   in [`super::placement::execute_chaos`]; orphaned jobs re-place
///   onto the cheapest backend alive at the orphan instant;
/// * `enforce = true` arms degradation control: a tenant whose
///   *projected committed spend* would burn through its
///   [`TenantSpec::budget_dollars`] stops being admitted (the stranded
///   jobs drain as [`TenantUsage::slo_aborted`], billed $0), and a job
///   granted admission past its tenant's [`TenantSpec::deadline_s`]
///   escalates to the fleet's fastest backend;
/// * `enforce = false` keeps SLOs report-only — with an empty schedule
///   the outcome is f64-record-identical to [`run_tenants`]
///   (`rust/tests/chaos_cosim.rs`).
///
/// Panics if the schedule fails [`OutageSchedule::validate`].
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .outages(s).enforce_slos(b) and call RunSpec::run_tenants"
)]
pub fn run_tenants_chaos(
    tenants: &[TenantSpec],
    fleet: &[BackendSpec],
    cfg: &TenancyConfig,
    schedule: &OutageSchedule,
    enforce: bool,
) -> TenancyOutcome {
    RunSpec::new()
        .outages(schedule.clone())
        .enforce_slos(enforce)
        .run_tenants(tenants, fleet, cfg)
}

/// [`run_tenants_chaos`] with the compute engines sharded across
/// `threads` worker threads (`coordinator::sync`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .outages(s).enforce_slos(b).threads(n) and call RunSpec::run_tenants"
)]
pub fn run_tenants_chaos_threaded(
    tenants: &[TenantSpec],
    fleet: &[BackendSpec],
    cfg: &TenancyConfig,
    schedule: &OutageSchedule,
    enforce: bool,
    threads: usize,
) -> TenancyOutcome {
    RunSpec::new()
        .outages(schedule.clone())
        .enforce_slos(enforce)
        .threads(threads)
        .run_tenants(tenants, fleet, cfg)
}

/// The one tenancy funnel every entry point drains into
/// ([`crate::coordinator::RunSpec::run_tenants`] and, through it, the
/// deprecated `run_tenants*` shims).
pub(crate) fn run_tenants_impl(
    tenants: &[TenantSpec],
    fleet: &[BackendSpec],
    cfg: &TenancyConfig,
    schedule: Option<&OutageSchedule>,
    enforce: bool,
    threads: usize,
) -> TenancyOutcome {
    assert!(!tenants.is_empty(), "run_tenants needs at least one tenant");
    assert!(!fleet.is_empty(), "run_tenants needs at least one backend");
    for t in tenants {
        assert!(
            t.weight.is_finite() && t.weight > 0.0,
            "tenant '{}': weight must be finite and > 0 (got {})",
            t.name,
            t.weight
        );
    }
    if let Some(depth) = cfg.queue_depth {
        assert!(depth >= 1, "queue depth cap must be at least 1");
    }
    let pcfg = cfg.placement();
    // per-tenant plans over the shared fleet, flattened tenant-by-tenant
    // into one global job-id space: unique transfer ids 2i/2i+1 on the
    // ONE shared scheduler, and per-(tenant, job, attempt) fault
    // decorrelation, both fall out of the flattening
    let mut effective: Vec<StagedJob> = Vec::new();
    let mut assignment: Vec<usize> = Vec::new();
    let mut tenant_of: Vec<usize> = Vec::new();
    let mut tenant_ranges: Vec<(usize, usize)> = Vec::with_capacity(tenants.len());
    for (k, t) in tenants.iter().enumerate() {
        let start = effective.len();
        if !t.jobs.is_empty() {
            let p = plan(&t.jobs, fleet, t.policy);
            effective.extend(p.effective);
            assignment.extend(p.assignment);
        }
        tenant_of.resize(effective.len(), k);
        tenant_ranges.push((start, effective.len()));
    }
    let mut engines: Vec<BackendEngine> = fleet
        .iter()
        .enumerate()
        .map(|(k, b)| build_engine(b, k, &pcfg))
        .collect();
    let mut transfers =
        TransferScheduler::new(shared_topology(fleet), cfg.seed ^ PLACEMENT_TRANSFER_SALT);
    if let Some(m) = cfg.transfer_faults {
        transfers.set_faults(Injection::campaign_transfer(&m, cfg.max_retries, cfg.seed));
    }
    if let Some(s) = schedule {
        transfers.set_brownouts(s.brownouts.clone());
        for (k, engine) in engines.iter_mut().enumerate() {
            engine.set_outages(s.windows_for(k), s.kill_backoff_s);
        }
    }
    let mut adm = Admission::new(tenants, &tenant_ranges, &effective, cfg.queue_depth);
    if enforce {
        let bottleneck_gbps = shared_topology(fleet).bottleneck_gbps();
        let proj: Vec<f64> = effective
            .iter()
            .zip(&assignment)
            .map(|(j, &k)| {
                staged_job_cost(
                    fleet[k].env,
                    j.compute_s / 60.0,
                    transfer_estimate_s(j, bottleneck_gbps),
                )
            })
            .collect();
        adm = adm.with_enforcement(tenants, proj);
    }
    let ctx = schedule.map(|s| {
        let mut fastest = 0usize;
        for k in 1..fleet.len() {
            if env_speed_factor(fleet[k].env) > env_speed_factor(fleet[fastest].env) {
                fastest = k;
            }
        }
        DegradeCtx {
            schedule: s,
            fleet,
            by_rate: rate_order(fleet),
            fastest,
            enforce,
            deadline: tenants.iter().map(|t| t.deadline_s).collect(),
            tenant_of: &tenant_of,
            nominal_s: effective
                .iter()
                .zip(&assignment)
                .map(|(j, &k)| j.compute_s * env_speed_factor(fleet[k].env))
                .collect(),
        }
    });
    let (staged, admit_s, tally) = run_admitted(
        &mut effective,
        &mut assignment,
        &mut engines,
        &mut transfers,
        &mut adm,
        ctx.as_ref(),
        threads,
    );
    drop(ctx);
    let (wasted_min, compute_events) = collect_compute_faults(&engines, effective.len());
    let per_backend = fold_backend_usage(
        fleet,
        &effective,
        &assignment,
        &staged.timings,
        &wasted_min,
        &engines,
    );
    let aborted = engines.iter().map(|e| e.aborted_count()).sum::<usize>()
        + transfers.aborted_ids().len();

    let weight_total: f64 = tenants.iter().map(|t| t.weight).sum();
    let fleet_minutes_total: f64 = per_backend.iter().map(|u| u.compute_minutes).sum();
    let mut failed_by_tenant = vec![0usize; tenants.len()];
    for ev in &compute_events {
        if let Some(&k) = tenant_of.get(ev.id as usize) {
            failed_by_tenant[k] += 1;
        }
    }
    let mut usages = Vec::with_capacity(tenants.len());
    for (k, spec) in tenants.iter().enumerate() {
        let (lo, hi) = tenant_ranges[k];
        let mut completed = 0usize;
        let mut slo_aborted = 0usize;
        let mut minutes = 0.0f64;
        let mut dollars = 0.0f64;
        let mut makespan = 0.0f64;
        let mut waits: Vec<f64> = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            let t = &staged.timings[i];
            if t.completed {
                completed += 1;
            } else if !admit_s[i].is_finite() {
                // never admitted: only SLO enforcement strands jobs in
                // the pending pool (aborts release their slots)
                slo_aborted += 1;
            }
            let (m, d) =
                job_billing(fleet[assignment[i]].env, effective[i].compute_s, wasted_min[i], t);
            minutes += m;
            dollars += d;
            makespan = makespan.max(t.done_s).max(t.compute_end_s);
            if admit_s[i].is_finite() {
                waits.push(admit_s[i] + t.stage_in_wait_s);
            }
        }
        let ps = percentiles(&waits, &[50.0, 95.0]);
        usages.push(TenantUsage {
            name: spec.name.clone(),
            priority: spec.priority,
            weight: spec.weight,
            jobs: hi - lo,
            completed,
            aborted: (hi - lo) - completed,
            slo_aborted,
            escalated: tally.escalated[k],
            failed_attempts: failed_by_tenant[k],
            compute_minutes: minutes,
            cost_dollars: dollars,
            makespan_s: makespan,
            queue_wait_p50_s: ps[0],
            queue_wait_p95_s: ps[1],
            fleet_share: if fleet_minutes_total > 0.0 {
                minutes / fleet_minutes_total
            } else {
                0.0
            },
            contended_share: if adm.contended_total > 0.0 {
                adm.contended_service[k] / adm.contended_total
            } else {
                0.0
            },
            entitlement: spec.weight / weight_total,
            budget_dollars: spec.budget_dollars,
            deadline_s: spec.deadline_s,
            budget_met: spec.budget_dollars.is_none_or(|b| dollars <= b),
            deadline_met: spec.deadline_s.is_none_or(|d| makespan <= d),
        });
    }
    let outage = schedule.map(|s| OutageStats {
        windows: s.compute.len(),
        brownouts: s.brownouts.len(),
        killed: engines.iter().map(|e| e.outage_killed()).sum(),
        orphaned: tally.orphaned,
        re_placed: tally.re_placed,
        killed_wasted_s: engines.iter().map(|e| e.outage_wasted_s()).sum(),
    });
    let report = TenancyReport {
        tenants: usages,
        // total from the per-backend fold, in fleet order — the same
        // accumulation placement sums, so N=1 totals match f64-exactly
        total_cost_dollars: per_backend.iter().map(|u| u.cost_dollars).sum(),
        makespan_s: staged.makespan_s,
        transfer: staged.transfer,
        per_backend,
        aborted: aborted as u64,
        queue_depth: cfg.queue_depth,
        outage,
        enforced: enforce,
    };
    TenancyOutcome {
        report,
        assignment,
        tenant_of,
        admit_s,
        tenant_ranges,
        compute_events,
        transfer_events: transfers.fault_events().to_vec(),
        staged,
    }
}

#[cfg(test)]
// the unit tests deliberately exercise the deprecated shims: they are
// the compatibility surface the parity batteries pin
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coordinator::placement::BackendKind;
    use crate::netsim::Env;

    fn uniform_jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s,
                bytes_in: 20_000_000,
                bytes_out: 5_000_000,
            })
            .collect()
    }

    fn lanes_fleet(workers: usize) -> Vec<BackendSpec> {
        vec![BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Lanes { workers },
            faults: None,
            transfer_streams: 4,
        }]
    }

    fn spec(name: &str, weight: f64, priority: u32, jobs: Vec<StagedJob>) -> TenantSpec {
        TenantSpec {
            weight,
            priority,
            ..TenantSpec::new(name, jobs)
        }
    }

    #[test]
    fn arbiter_splits_service_by_weight() {
        // uniform service, weights 1:2:4 — grant counts track weights
        // within one-job granularity at every prefix of the sequence
        let tenants = vec![
            spec("w1", 1.0, 0, uniform_jobs(70, 100.0)),
            spec("w2", 2.0, 0, uniform_jobs(70, 100.0)),
            spec("w4", 4.0, 0, uniform_jobs(70, 100.0)),
        ];
        let ranges = [(0usize, 70usize), (70, 140), (140, 210)];
        let effective: Vec<StagedJob> = tenants.iter().flat_map(|t| t.jobs.clone()).collect();
        let mut adm = Admission::new(&tenants, &ranges, &effective, Some(1));
        let mut counts = [0usize; 3];
        for _ in 0..70 {
            let i = adm.next().expect("work pending");
            counts[ranges.iter().position(|&(lo, hi)| (lo..hi).contains(&i)).unwrap()] += 1;
            adm.release();
        }
        // after 70 grants at weights 1:2:4, entitlements are 10/20/40
        assert!((counts[0] as i64 - 10).abs() <= 1, "{counts:?}");
        assert!((counts[1] as i64 - 20).abs() <= 1, "{counts:?}");
        assert!((counts[2] as i64 - 40).abs() <= 1, "{counts:?}");
        // contended tallies cover the whole prefix (nobody drained)
        assert!(adm.contended_total > 0.0);
    }

    #[test]
    fn arbiter_priority_preempts_pending_work() {
        // the priority-2 tenant's pending jobs all admit before any
        // priority-0 job, regardless of weights
        let tenants = vec![
            spec("low", 100.0, 0, uniform_jobs(5, 10.0)),
            spec("high", 1.0, 2, uniform_jobs(5, 10.0)),
        ];
        let ranges = [(0usize, 5usize), (5, 10)];
        let effective: Vec<StagedJob> = tenants.iter().flat_map(|t| t.jobs.clone()).collect();
        let mut adm = Admission::new(&tenants, &ranges, &effective, None);
        let order: Vec<usize> = std::iter::from_fn(|| adm.next()).collect();
        assert_eq!(order[..5], [5, 6, 7, 8, 9], "high tier first");
        assert_eq!(order[5..], [0, 1, 2, 3, 4]);
    }

    #[test]
    fn depth_cap_serializes_and_unbounded_matches_multi() {
        let tenants = vec![spec("solo", 1.0, 0, uniform_jobs(4, 50.0))];
        let fleet = lanes_fleet(4);
        // depth 1: at most one job in flight — each admission waits for
        // the previous job's copy-back
        let capped = run_tenants(
            &tenants,
            &fleet,
            &TenancyConfig {
                queue_depth: Some(1),
                ..Default::default()
            },
        );
        assert!(capped.staged.timings.iter().all(|t| t.completed));
        for i in 1..4 {
            let prev_done = capped.staged.timings[i - 1].done_s;
            assert!(
                capped.admit_s[i] >= prev_done,
                "admission {i} at {} before predecessor finished at {prev_done}",
                capped.admit_s[i]
            );
        }
        // unbounded: everything admitted at t=0, finishing sooner
        let open = run_tenants(&tenants, &fleet, &TenancyConfig::default());
        assert!(open.admit_s.iter().all(|&a| a == 0.0));
        assert!(open.report.makespan_s < capped.report.makespan_s);
    }

    #[test]
    fn zero_job_tenant_reports_empty_telemetry() {
        let tenants = vec![
            spec("busy", 1.0, 0, uniform_jobs(3, 30.0)),
            spec("idle", 1.0, 0, Vec::new()),
        ];
        let out = run_tenants(&tenants, &lanes_fleet(2), &TenancyConfig::default());
        let idle = &out.report.tenants[1];
        assert_eq!((idle.jobs, idle.completed, idle.aborted), (0, 0, 0));
        assert_eq!(idle.cost_dollars, 0.0);
        assert_eq!(idle.makespan_s, 0.0);
        // empty queue-wait folds hit util::units' documented 0.0 return
        assert_eq!((idle.queue_wait_p50_s, idle.queue_wait_p95_s), (0.0, 0.0));
        assert_eq!(out.report.tenants[0].completed, 3);
    }

    #[test]
    fn tenants_with_identical_jobs_draw_decorrelated_faults() {
        // same job list, harsh faults: the flattened id space must keep
        // the two tenants' retry traces apart
        let jobs = uniform_jobs(40, 200.0);
        let mut fleet = lanes_fleet(8);
        fleet[0].faults = Some(crate::faults::FaultModel::harsh());
        let tenants = vec![
            spec("a", 1.0, 0, jobs.clone()),
            spec("b", 1.0, 0, jobs),
        ];
        let out = run_tenants(&tenants, &fleet, &TenancyConfig::default());
        assert!(!out.compute_events.is_empty(), "harsh faults must fire");
        let (alo, ahi) = out.tenant_ranges[0];
        let a: Vec<(u64, u32)> = out
            .compute_events
            .iter()
            .filter(|e| (alo..ahi).contains(&(e.id as usize)))
            .map(|e| (e.id, e.attempt))
            .collect();
        let b: Vec<(u64, u32)> = out
            .compute_events
            .iter()
            .filter(|e| !(alo..ahi).contains(&(e.id as usize)))
            .map(|e| (e.id - ahi as u64, e.attempt))
            .collect();
        assert_ne!(a, b, "tenants must not replay each other's verdicts");
    }

    #[test]
    #[should_panic(expected = "weight must be finite and > 0")]
    fn zero_weight_is_rejected() {
        let tenants = vec![spec("bad", 0.0, 0, uniform_jobs(1, 10.0))];
        run_tenants(&tenants, &lanes_fleet(1), &TenancyConfig::default());
    }

    #[test]
    #[should_panic(expected = "queue depth cap must be at least 1")]
    fn zero_depth_is_rejected() {
        let tenants = vec![spec("t", 1.0, 0, uniform_jobs(1, 10.0))];
        run_tenants(
            &tenants,
            &lanes_fleet(1),
            &TenancyConfig {
                queue_depth: Some(0),
                ..Default::default()
            },
        );
    }

    use crate::faults::outage::{ComputeOutage, OutageMode};

    fn tiny_jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
        // 1-byte staging: projected job cost ≈ billed job cost, which
        // the budget-quantum assertions lean on
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s,
                bytes_in: 1,
                bytes_out: 1,
            })
            .collect()
    }

    fn duo_fleet() -> Vec<BackendSpec> {
        vec![
            BackendSpec {
                name: "hpc".into(),
                env: Env::Hpc,
                kind: BackendKind::Lanes { workers: 2 },
                faults: None,
                transfer_streams: 4,
            },
            BackendSpec {
                name: "cloud".into(),
                env: Env::Cloud,
                kind: BackendKind::Lanes { workers: 4 },
                faults: None,
                transfer_streams: 4,
            },
        ]
    }

    #[test]
    fn enforcement_off_empty_schedule_matches_run_tenants() {
        let tenants = vec![
            spec("a", 1.0, 0, uniform_jobs(6, 120.0)),
            spec("b", 2.0, 1, uniform_jobs(4, 90.0)),
        ];
        let fleet = lanes_fleet(2);
        let cfg = TenancyConfig {
            queue_depth: Some(3),
            ..Default::default()
        };
        let plain = run_tenants(&tenants, &fleet, &cfg);
        let chaos = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), false);
        assert_eq!(plain.staged.timings, chaos.staged.timings);
        assert_eq!(plain.admit_s, chaos.admit_s);
        assert_eq!(plain.report.tenants, chaos.report.tenants);
        assert_eq!(plain.report.per_backend, chaos.report.per_backend);
        assert_eq!(plain.report.total_cost_dollars, chaos.report.total_cost_dollars);
        assert!(plain.report.outage.is_none() && !plain.report.enforced);
        assert_eq!(chaos.report.outage, Some(OutageStats::default()));
    }

    #[test]
    fn budget_enforcement_stops_admission_within_one_job_quantum() {
        let mut tenants = vec![spec("capped", 1.0, 0, tiny_jobs(10, 600.0))];
        let fleet = lanes_fleet(2);
        let cfg = TenancyConfig::default();
        // no budget: enforcement admits (and bills) everything
        let free = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), true);
        assert_eq!(free.report.tenants[0].slo_aborted, 0);
        let total = free.report.tenants[0].cost_dollars;
        assert!(total > 0.0);

        let budget = total * 0.4;
        tenants[0].budget_dollars = Some(budget);
        let capped = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), true);
        let usage = &capped.report.tenants[0];
        assert!(usage.slo_aborted > 0, "a 40% budget must strand jobs");
        assert_eq!(
            usage.completed + usage.slo_aborted,
            10,
            "clean run: every admitted job finishes, every stranded job is counted"
        );
        let quantum = total / 10.0;
        assert!(
            usage.cost_dollars <= budget + quantum + 1e-9,
            "billed {} vs budget {budget} + one-job quantum {quantum}",
            usage.cost_dollars
        );
        // reported-only SLOs admit everything and blow the budget
        let reported = run_tenants_chaos(&tenants, &fleet, &cfg, &OutageSchedule::empty(), false);
        assert_eq!(reported.report.tenants[0].slo_aborted, 0);
        assert!(reported.report.tenants[0].cost_dollars > usage.cost_dollars);
        assert!(!reported.report.tenants[0].budget_met);
    }

    #[test]
    fn deadline_escalation_moves_late_grants_to_the_fastest_backend() {
        // cheapest-first plans everything on 1-lane hpc; depth 1
        // serializes admissions, so grants from ~600 s on land past the
        // deadline and escalate to cloud (the highest speed factor)
        let mut fleet = duo_fleet();
        fleet[0].kind = BackendKind::Lanes { workers: 1 };
        let mut t = spec("slo", 1.0, 0, tiny_jobs(6, 300.0));
        t.deadline_s = Some(500.0);
        let cfg = TenancyConfig {
            queue_depth: Some(1),
            ..Default::default()
        };
        let out = run_tenants_chaos(&[t], &fleet, &cfg, &OutageSchedule::empty(), true);
        let usage = &out.report.tenants[0];
        assert!(usage.escalated > 0, "late grants must escalate");
        assert!(usage.escalated < 6, "early grants stay on the planned backend");
        assert_eq!(usage.completed, 6);
        let moved = out.assignment.iter().filter(|&&k| k == 1).count();
        assert_eq!(moved, usage.escalated);
        for (i, &k) in out.assignment.iter().enumerate() {
            if k == 1 {
                assert!(out.admit_s[i] > 500.0, "only past-deadline grants move");
                let ran_s = out.staged.timings[i].compute_end_s - out.staged.timings[i].compute_start_s;
                assert!(ran_s < 299.0, "escalated compute rescales to cloud speed: {ran_s}");
            }
        }
    }

    #[test]
    fn outage_orphans_re_place_and_the_fleet_degrades_gracefully() {
        let fleet = duo_fleet(); // cheapest-first plans everything on hpc
        let tenants = vec![spec("lab", 1.0, 0, uniform_jobs(8, 300.0))];
        let mut schedule = OutageSchedule::empty();
        schedule.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Down,
            start_s: 350.0,
            end_s: 1.0e6,
        });
        let out =
            run_tenants_chaos(&tenants, &fleet, &TenancyConfig::default(), &schedule, false);
        let stats = out.report.outage.expect("chaos path reports stats");
        assert!(stats.orphaned > 0, "queued jobs behind 2 lanes must orphan");
        assert_eq!(stats.re_placed, stats.orphaned, "cloud survives: every orphan moves");
        assert!(stats.killed >= 1, "the running wave dies with hpc");
        assert_eq!(out.report.tenants[0].completed, 8, "degradation, not loss");
        let on_cloud = out.assignment.iter().filter(|&&k| k == 1).count();
        assert_eq!(on_cloud as u64, stats.re_placed);
    }

    #[test]
    fn synthetic_tenants_are_deterministic_and_decorrelated() {
        let a = synthetic_tenants(3, 5, 7);
        let b = synthetic_tenants(3, 5, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.jobs, y.jobs, "same seed replays the same fleet");
        }
        assert_ne!(a[0].jobs, a[1].jobs, "tenants draw distinct campaigns");
    }
}
