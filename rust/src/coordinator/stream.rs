//! Streaming coordinator (DESIGN.md §17): a long-running ingest loop
//! driven by a seeded, replay-deterministic arrival process.
//!
//! The batch coordinator answers "run this campaign now"; real archives
//! do not arrive as one batch. Longitudinal studies land in waves,
//! scanners follow day/night duty cycles, and retrospective backfills
//! dump months of sessions in an afternoon. This module simulates that
//! regime end to end: an [`ArrivalPattern`] lays sessions across a
//! simulated horizon, a [`crate::query::DeltaLedger`] feeds each
//! planning epoch exactly the newly-arrived delta (the simulated-time
//! analogue of the incremental query), and the loop re-plans placement
//! per epoch through a [`RunSpec`] — so compute, transfers, faults,
//! outages, and (optionally) tenancy keep contending across epochs
//! through the same windowed parallel engines as the one-shot paths.
//!
//! The epoch contract (the replay guarantee the determinism lint and
//! `rust/tests/stream_cosim.rs` pin):
//!
//! * planning instants are multiples of [`StreamConfig::epoch_s`] and
//!   never precede the stream clock;
//! * each epoch admits the full arrived-unadmitted backlog, re-plans it
//!   (fresh placement — `coordinator::placement` re-decides as backlog
//!   and effective rates shift), and co-simulates it to completion on
//!   epoch-fresh engines; the stream clock then advances over the
//!   epoch's makespan to the next epoch boundary;
//! * idle gaps jump straight to the boundary covering the next arrival
//!   — no empty epochs are simulated;
//! * epoch `e` runs under seed `seed ^ (e · SALT)` — epoch 0 is
//!   bit-identical to a one-shot [`RunSpec`] run of the same batch
//!   (the t=0 parity contract), later epochs decorrelate;
//! * an armed outage schedule is absolute on the stream clock: each
//!   epoch sees the suffix of windows still ahead of its plan instant,
//!   shifted into epoch-local time.
//!
//! Steady-state telemetry folds into a [`StreamReport`]:
//! ingest-to-processed latency percentiles, backlog depth over time,
//! cost per session, and re-plan/escalation counts.

use crate::faults::outage::{Brownout, ComputeOutage, OutageSchedule, OutageStats};
use crate::query::DeltaLedger;
use crate::util::rng::Rng;
use crate::util::units::percentiles;

use super::placement::{BackendSpec, PlacementConfig, PlacementPolicy};
use super::spec::RunSpec;
use super::staged::{synthetic_fault_campaign, StagedJob, StagedTiming};
use super::tenancy::{TenancyConfig, TenantSpec};

/// Salt decorrelating the arrival-process stream from the workload
/// stream sharing [`StreamConfig::seed`].
pub const STREAM_ARRIVAL_SALT: u64 = 0x6172_7269_7665_3031; // "arrive01"

/// Per-epoch seed salt: epoch `e` runs under `seed ^ (e · SALT)`, so
/// epoch 0 keeps the base seed bit-for-bit (the t=0 parity contract)
/// and later epochs draw decorrelated fault/transfer streams.
pub const STREAM_EPOCH_SALT: u64 = 0x6570_6f63_6873_3137; // "epochs17"

/// Seconds per simulated day (the scanner duty cycle of
/// [`ArrivalPattern::DayNight`]).
pub const DAY_S: f64 = 86_400.0;

/// How sessions land across the simulated horizon. Every pattern is a
/// pure function of `(sessions, horizon_s, seed)` — see
/// [`arrival_times`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Everything lands at t = 0 — degenerates to one planning epoch,
    /// the parity anchor against the one-shot [`RunSpec`] paths.
    AtStart,
    /// Uniform arrivals over the horizon (a steady prospective study).
    Steady,
    /// `count` recruitment waves: normal clusters centered at the wave
    /// midpoints (longitudinal study visits).
    Waves { count: usize },
    /// Scanner day/night duty cycle: ~85% of sessions land in the
    /// 07:00–19:00 half of each simulated day.
    DayNight,
    /// Steady baseline plus a tight retrospective-backfill burst
    /// (`burst_fraction` of all sessions) at 60% of the horizon.
    Backfill { burst_fraction: f64 },
}

impl ArrivalPattern {
    pub fn label(self) -> &'static str {
        match self {
            ArrivalPattern::AtStart => "t0",
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Waves { .. } => "waves",
            ArrivalPattern::DayNight => "daynight",
            ArrivalPattern::Backfill { .. } => "backfill",
        }
    }
}

/// Sorted arrival instants for `sessions` sessions over `[0,
/// horizon_s)` — deterministic in the seed, shared by `medflow stream`,
/// the co-sim tests, and `benches/stream_ingest.rs`.
pub fn arrival_times(
    pattern: ArrivalPattern,
    sessions: usize,
    horizon_s: f64,
    seed: u64,
) -> Vec<f64> {
    assert!(
        horizon_s > 0.0 && horizon_s.is_finite(),
        "arrival horizon must be finite and > 0"
    );
    let mut rng = Rng::new(seed ^ STREAM_ARRIVAL_SALT);
    // clamp ceiling just inside the horizon so `poll(horizon)` at the
    // final boundary always drains a cutoff-free run completely
    let hi = horizon_s * (1.0 - 1e-9);
    let mut times: Vec<f64> = match pattern {
        ArrivalPattern::AtStart => vec![0.0; sessions],
        ArrivalPattern::Steady => (0..sessions)
            .map(|_| rng.range_f64(0.0, horizon_s).min(hi))
            .collect(),
        ArrivalPattern::Waves { count } => {
            let waves = count.max(1) as f64;
            let spread = horizon_s / (waves * 8.0);
            (0..sessions)
                .map(|_| {
                    let w = rng.below(count.max(1) as u64) as f64;
                    let center = (w + 0.5) * horizon_s / waves;
                    (center + rng.normal() * spread).clamp(0.0, hi)
                })
                .collect()
        }
        ArrivalPattern::DayNight => {
            let days = (horizon_s / DAY_S).ceil().max(1.0) as u64;
            (0..sessions)
                .map(|_| {
                    let day = rng.below(days) as f64;
                    let hour = if rng.next_f64() < 0.85 {
                        // daytime block: 07:00–19:00
                        rng.range_f64(7.0, 19.0)
                    } else {
                        // night block: 19:00–07:00, wrapped past midnight
                        let h = rng.range_f64(19.0, 31.0);
                        if h >= 24.0 {
                            h - 24.0
                        } else {
                            h
                        }
                    };
                    (day * DAY_S + hour * 3_600.0).clamp(0.0, hi)
                })
                .collect()
        }
        ArrivalPattern::Backfill { burst_fraction } => {
            assert!(
                (0.0..=1.0).contains(&burst_fraction) && burst_fraction.is_finite(),
                "backfill burst fraction must be in [0, 1] (got {burst_fraction})"
            );
            let burst = ((sessions as f64) * burst_fraction).round() as usize;
            let center = 0.60 * horizon_s;
            let width = 0.01 * horizon_s;
            (0..sessions)
                .map(|i| {
                    if i < burst {
                        rng.range_f64(center, center + width).min(hi)
                    } else {
                        rng.range_f64(0.0, horizon_s).min(hi)
                    }
                })
                .collect()
        }
    };
    times.sort_by(|a, b| a.total_cmp(b));
    times
}

/// Configuration of one streaming run.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Total sessions the arrival process lays over the horizon.
    pub sessions: usize,
    /// Simulated ingest horizon, seconds (arrivals land in `[0, horizon)`).
    pub horizon_s: f64,
    /// Re-planning period: planning instants are multiples of this.
    pub epoch_s: f64,
    pub pattern: ArrivalPattern,
    /// Seeds the workload, the arrival process (salted), and — XORed
    /// per epoch — every epoch's engines.
    pub seed: u64,
    /// Tenants to arbitrate each epoch's batch across (round-robin
    /// split); 1 = plain placement, no tenancy layer.
    pub tenants: usize,
    /// Stop admitting at this instant: sessions arriving later stay in
    /// the ledger and surface as final backlog (operator shutdown /
    /// budget-freeze drills). `None` runs the stream to drain.
    pub cutoff_s: Option<f64>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self {
            sessions: 1_000,
            horizon_s: 30.0 * DAY_S,
            epoch_s: DAY_S,
            pattern: ArrivalPattern::Steady,
            seed: 42,
            tenants: 1,
            cutoff_s: None,
        }
    }
}

impl StreamConfig {
    fn validate(&self) {
        assert!(
            self.horizon_s > 0.0 && self.horizon_s.is_finite(),
            "stream horizon must be finite and > 0"
        );
        assert!(
            self.epoch_s > 0.0 && self.epoch_s.is_finite(),
            "stream epoch must be finite and > 0"
        );
        assert!(self.tenants >= 1, "stream needs at least one tenant");
        if let Some(c) = self.cutoff_s {
            assert!(c >= 0.0 && c.is_finite(), "stream cutoff must be finite and ≥ 0");
        }
    }
}

/// The deterministic per-session workload of a streaming run — session
/// `i` of the run is job `i` here. Public so the parity tests and the
/// bench can hand the *same* batch to a one-shot [`RunSpec`] run.
pub fn stream_campaign(cfg: &StreamConfig) -> Vec<StagedJob> {
    synthetic_fault_campaign(cfg.sessions, cfg.seed)
}

/// One planning epoch's fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochStats {
    pub index: usize,
    /// Planning instant on the stream clock (a multiple of `epoch_s`).
    pub t_plan_s: f64,
    /// Backlog admitted at the plan instant (= arrived, unadmitted).
    pub admitted: usize,
    pub processed: usize,
    pub aborted: usize,
    /// Epoch-local makespan of the admitted batch.
    pub makespan_s: f64,
    pub cost_dollars: f64,
    /// Whether backlog pressure escalated the placement policy this
    /// epoch (see [`run_stream`]).
    pub escalated: bool,
}

/// Steady-state telemetry of one streaming run.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamReport {
    pub pattern: &'static str,
    /// Total sessions the arrival process ingested.
    pub sessions: usize,
    /// Sessions that reached a verified copy-back.
    pub processed: usize,
    /// Admitted sessions dropped by their epoch (retry exhaustion).
    pub aborted: usize,
    /// Sessions never admitted (nonzero only under a cutoff).
    pub backlog_final: usize,
    /// Planning epochs executed = placement re-plans.
    pub epochs: usize,
    /// Epochs where backlog pressure escalated the policy.
    pub escalations: usize,
    /// Final stream clock (last epoch's plan instant + makespan).
    pub stream_clock_s: f64,
    /// Ingest-to-processed latency (arrival → verified copy-back).
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_mean_s: f64,
    /// Deepest per-epoch admitted backlog.
    pub backlog_peak: usize,
    pub total_cost_dollars: f64,
    /// Total cost over *processed* sessions (0 when nothing processed).
    pub cost_per_session_dollars: f64,
    /// Outage telemetry summed across epochs; `Some` exactly when the
    /// base [`RunSpec`] armed a schedule.
    pub outage: Option<OutageStats>,
}

/// Full result of [`run_stream`]: the report plus the record-level
/// detail the co-sim battery asserts on.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub report: StreamReport,
    pub epochs: Vec<EpochStats>,
    /// Ingest-to-processed latency per processed session, in epoch
    /// completion order.
    pub latencies_s: Vec<f64>,
}

/// An armed schedule is absolute on the stream clock; an epoch's
/// engines run in epoch-local time. Keep the windows still (partly)
/// ahead of the plan instant, shifted by `-t_plan` with starts clamped
/// to 0.
fn shift_schedule(sched: &OutageSchedule, t_plan_s: f64) -> OutageSchedule {
    OutageSchedule {
        compute: sched
            .compute
            .iter()
            .filter(|w| w.end_s > t_plan_s)
            .map(|w| ComputeOutage {
                backend: w.backend,
                mode: w.mode,
                start_s: (w.start_s - t_plan_s).max(0.0),
                end_s: w.end_s - t_plan_s,
            })
            .collect(),
        brownouts: sched
            .brownouts
            .iter()
            .filter(|b| b.end_s > t_plan_s)
            .map(|b| Brownout {
                start_s: (b.start_s - t_plan_s).max(0.0),
                end_s: b.end_s - t_plan_s,
                factor: b.factor,
            })
            .collect(),
        kill_backoff_s: sched.kill_backoff_s,
    }
}

fn sum_outage(acc: &mut Option<OutageStats>, epoch: Option<OutageStats>) {
    if let Some(e) = epoch {
        let a = acc.get_or_insert_with(OutageStats::default);
        a.windows += e.windows;
        a.brownouts += e.brownouts;
        a.killed += e.killed;
        a.orphaned += e.orphaned;
        a.re_placed += e.re_placed;
        a.killed_wasted_s += e.killed_wasted_s;
    }
}

/// One epoch's engine-level fold, shared by the placement and tenancy
/// paths: timings indexed by the epoch's admitted order, plus cost and
/// makespan.
struct EpochRun {
    timings: Vec<StagedTiming>,
    makespan_s: f64,
    cost_dollars: f64,
    outage: Option<OutageStats>,
}

/// Run the streaming coordinator: lay `cfg.sessions` arrivals over the
/// horizon, then loop planning epochs until the ledger drains (or the
/// cutoff stops admission). `spec` carries the composed run options —
/// threads, outage schedule (absolute on the stream clock), SLO
/// enforcement, base placement policy; the loop re-composes it per
/// epoch (epoch seed, shifted schedule, possibly escalated policy).
///
/// Backlog-pressure escalation: when an epoch (after the first) admits
/// more than 2× the expected per-epoch arrivals, the epoch plans
/// [`PlacementPolicy::DeadlineAware`] with the epoch period as the
/// deadline — placement re-decides toward faster backends to drain the
/// backlog, and the switch is counted in
/// [`StreamReport::escalations`]. Epoch 0 never escalates, preserving
/// the t=0 parity contract.
pub fn run_stream(
    cfg: &StreamConfig,
    fleet: &[BackendSpec],
    pcfg: &PlacementConfig,
    spec: &RunSpec,
) -> StreamOutcome {
    cfg.validate();
    assert!(!fleet.is_empty(), "stream needs a non-empty fleet");

    let jobs = stream_campaign(cfg);
    let arrivals = arrival_times(cfg.pattern, cfg.sessions, cfg.horizon_s, cfg.seed);
    let mut ledger = DeltaLedger::from_arrivals(&arrivals);
    let base_policy = spec.policy.unwrap_or(PlacementPolicy::CheapestFirst);
    let expected_per_epoch = cfg.sessions as f64 * cfg.epoch_s / cfg.horizon_s;

    let mut epochs: Vec<EpochStats> = Vec::new();
    let mut latencies_s: Vec<f64> = Vec::new();
    let mut processed = 0usize;
    let mut aborted = 0usize;
    let mut escalations = 0usize;
    let mut total_cost = 0.0f64;
    let mut outage: Option<OutageStats> = None;
    let mut clock = 0.0f64;
    let mut t_plan = 0.0f64;

    loop {
        if let Some(c) = cfg.cutoff_s {
            if t_plan > c {
                break;
            }
        }
        let admitted = ledger.poll(t_plan);
        if admitted.is_empty() {
            // idle gap: jump to the epoch boundary covering the next
            // arrival instead of simulating empty epochs
            let Some(next) = ledger.next_arrival_s() else { break };
            let mut jump = (next / cfg.epoch_s).ceil() * cfg.epoch_s;
            if jump <= t_plan {
                jump = t_plan + cfg.epoch_s;
            }
            t_plan = jump;
            continue;
        }

        let index = epochs.len();
        let escalate = index > 0 && (admitted.len() as f64) > 2.0 * expected_per_epoch;
        let policy = if escalate {
            PlacementPolicy::DeadlineAware { deadline_s: cfg.epoch_s }
        } else {
            base_policy
        };
        // epoch 0 XORs with 0: bit-identical to the one-shot seed
        let epoch_seed = pcfg.seed ^ (index as u64).wrapping_mul(STREAM_EPOCH_SALT);
        let mut epoch_spec = spec.clone().policy(policy);
        epoch_spec.outages = spec.outages.as_ref().map(|s| shift_schedule(s, t_plan));

        let batch: Vec<StagedJob> = admitted.iter().map(|&id| jobs[id as usize]).collect();
        let run = run_epoch(cfg, &batch, fleet, pcfg, epoch_seed, &epoch_spec);

        let mut epoch_processed = 0usize;
        for (i, t) in run.timings.iter().enumerate() {
            if t.completed {
                epoch_processed += 1;
                latencies_s.push(t_plan + t.done_s - arrivals[admitted[i] as usize]);
            }
        }
        ledger.record_completion(epoch_processed as u64);
        processed += epoch_processed;
        aborted += admitted.len() - epoch_processed;
        total_cost += run.cost_dollars;
        sum_outage(&mut outage, run.outage);
        if escalate {
            escalations += 1;
        }
        epochs.push(EpochStats {
            index,
            t_plan_s: t_plan,
            admitted: admitted.len(),
            processed: epoch_processed,
            aborted: admitted.len() - epoch_processed,
            makespan_s: run.makespan_s,
            cost_dollars: run.cost_dollars,
            escalated: escalate,
        });

        clock = t_plan + run.makespan_s;
        let mut next = (clock / cfg.epoch_s).ceil() * cfg.epoch_s;
        if next <= t_plan {
            next = t_plan + cfg.epoch_s;
        }
        t_plan = next;
    }

    let lat = percentiles(&latencies_s, &[50.0, 95.0]);
    let latency_mean_s = if latencies_s.is_empty() {
        0.0
    } else {
        latencies_s.iter().sum::<f64>() / latencies_s.len() as f64
    };
    let report = StreamReport {
        pattern: cfg.pattern.label(),
        sessions: cfg.sessions,
        processed,
        aborted,
        backlog_final: ledger.pending(),
        epochs: epochs.len(),
        escalations,
        stream_clock_s: clock,
        latency_p50_s: lat[0],
        latency_p95_s: lat[1],
        latency_mean_s,
        backlog_peak: epochs.iter().map(|e| e.admitted).max().unwrap_or(0),
        total_cost_dollars: total_cost,
        cost_per_session_dollars: if processed > 0 {
            total_cost / processed as f64
        } else {
            0.0
        },
        outage,
    };
    StreamOutcome {
        report,
        epochs,
        latencies_s,
    }
}

/// Execute one epoch's admitted batch through the composed spec: plain
/// placement for a single tenant, the tenancy arbiter for several
/// (round-robin split of the batch). Returns timings re-ordered to the
/// epoch's admitted order.
fn run_epoch(
    cfg: &StreamConfig,
    batch: &[StagedJob],
    fleet: &[BackendSpec],
    pcfg: &PlacementConfig,
    epoch_seed: u64,
    epoch_spec: &RunSpec,
) -> EpochRun {
    let n_tenants = cfg.tenants.min(batch.len());
    if n_tenants <= 1 {
        let epoch_pcfg = PlacementConfig {
            seed: epoch_seed,
            ..*pcfg
        };
        let out = epoch_spec.execute(batch, fleet, &epoch_pcfg);
        return EpochRun {
            timings: out.staged.timings,
            makespan_s: out.makespan_s,
            cost_dollars: out.total_cost_dollars,
            outage: out.outage,
        };
    }
    // round-robin split: tenant k owns batch indices k, k + T, k + 2T…
    let tenants: Vec<TenantSpec> = (0..n_tenants)
        .map(|k| {
            let jobs: Vec<StagedJob> =
                batch.iter().skip(k).step_by(n_tenants).copied().collect();
            let mut t = TenantSpec::new(format!("stream-{k:02}"), jobs);
            t.policy = epoch_spec.policy.unwrap_or(PlacementPolicy::CheapestFirst);
            t
        })
        .collect();
    let tcfg = TenancyConfig {
        seed: epoch_seed,
        transfer_faults: pcfg.transfer_faults,
        max_retries: pcfg.max_retries,
        retry_backoff_s: pcfg.retry_backoff_s,
        queue_depth: None,
    };
    let out = epoch_spec.run_tenants(&tenants, fleet, &tcfg);
    // un-flatten the tenant-major global job space back to batch order
    let mut timings = vec![StagedTiming::default(); batch.len()];
    for (k, &(start, end)) in out.tenant_ranges.iter().enumerate() {
        for g in start..end {
            timings[(g - start) * n_tenants + k] = out.staged.timings[g];
        }
    }
    EpochRun {
        timings,
        makespan_s: out.report.makespan_s,
        cost_dollars: out.report.total_cost_dollars,
        outage: out.report.outage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::default_fleet;
    use crate::slurm::ClusterSpec;

    fn small_fleet() -> Vec<BackendSpec> {
        default_fleet(ClusterSpec::accre(), 64, 8, 4)
    }

    #[test]
    fn arrival_patterns_are_sorted_in_range_and_deterministic() {
        let horizon = 14.0 * DAY_S;
        for pattern in [
            ArrivalPattern::AtStart,
            ArrivalPattern::Steady,
            ArrivalPattern::Waves { count: 4 },
            ArrivalPattern::DayNight,
            ArrivalPattern::Backfill { burst_fraction: 0.3 },
        ] {
            let a = arrival_times(pattern, 500, horizon, 7);
            let b = arrival_times(pattern, 500, horizon, 7);
            assert_eq!(a, b, "{} must replay from the seed", pattern.label());
            assert_eq!(a.len(), 500);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{} sorted", pattern.label());
            assert!(
                a.iter().all(|&t| (0.0..horizon).contains(&t)),
                "{} in range",
                pattern.label()
            );
        }
        assert!(arrival_times(ArrivalPattern::AtStart, 10, horizon, 7)
            .iter()
            .all(|&t| t == 0.0));
    }

    #[test]
    fn daynight_concentrates_daytime() {
        let a = arrival_times(ArrivalPattern::DayNight, 2_000, 7.0 * DAY_S, 3);
        let daytime = a
            .iter()
            .filter(|&&t| {
                let h = (t % DAY_S) / 3_600.0;
                (7.0..19.0).contains(&h)
            })
            .count();
        assert!(daytime as f64 > 0.75 * a.len() as f64, "daytime {daytime}/{}", a.len());
    }

    #[test]
    fn stream_conserves_sessions_and_reports_latency() {
        let cfg = StreamConfig {
            sessions: 200,
            horizon_s: 4.0 * DAY_S,
            epoch_s: DAY_S / 2.0,
            pattern: ArrivalPattern::Steady,
            seed: 11,
            ..Default::default()
        };
        let out = run_stream(&cfg, &small_fleet(), &PlacementConfig::default(), &RunSpec::new());
        let r = &out.report;
        assert_eq!(r.processed + r.aborted + r.backlog_final, r.sessions);
        assert_eq!(r.backlog_final, 0, "cutoff-free streams drain fully");
        assert_eq!(r.processed, out.latencies_s.len());
        assert!(r.epochs > 1, "steady arrivals need several epochs, got {}", r.epochs);
        assert!(r.latency_p95_s >= r.latency_p50_s);
        assert!(r.latency_p50_s > 0.0);
        assert!(r.cost_per_session_dollars > 0.0);
        assert!(r.outage.is_none());
        assert_eq!(
            out.epochs.iter().map(|e| e.admitted).sum::<usize>(),
            r.sessions
        );
    }

    #[test]
    fn at_start_runs_one_epoch_bit_identical_to_one_shot() {
        let cfg = StreamConfig {
            sessions: 150,
            horizon_s: 2.0 * DAY_S,
            pattern: ArrivalPattern::AtStart,
            seed: 9,
            ..Default::default()
        };
        let pcfg = PlacementConfig {
            seed: 9,
            ..Default::default()
        };
        let fleet = small_fleet();
        let spec = RunSpec::new();
        let streamed = run_stream(&cfg, &fleet, &pcfg, &spec);
        assert_eq!(streamed.report.epochs, 1);
        let one_shot = spec.execute(&stream_campaign(&cfg), &fleet, &pcfg);
        assert_eq!(streamed.epochs[0].makespan_s, one_shot.makespan_s);
        assert_eq!(streamed.report.total_cost_dollars, one_shot.total_cost_dollars);
    }

    #[test]
    fn cutoff_strands_late_arrivals_as_backlog() {
        let cfg = StreamConfig {
            sessions: 120,
            horizon_s: 10.0 * DAY_S,
            epoch_s: DAY_S,
            pattern: ArrivalPattern::Steady,
            seed: 4,
            cutoff_s: Some(3.0 * DAY_S),
            ..Default::default()
        };
        let out = run_stream(&cfg, &small_fleet(), &PlacementConfig::default(), &RunSpec::new());
        let r = &out.report;
        assert!(r.backlog_final > 0, "arrivals past the cutoff must strand");
        assert_eq!(r.processed + r.aborted + r.backlog_final, r.sessions);
    }

    #[test]
    fn schedule_shift_keeps_future_windows_and_drops_past_ones() {
        let sched = OutageSchedule {
            compute: vec![
                ComputeOutage {
                    backend: 0,
                    mode: crate::faults::outage::OutageMode::Drain,
                    start_s: 100.0,
                    end_s: 200.0,
                },
                ComputeOutage {
                    backend: 1,
                    mode: crate::faults::outage::OutageMode::Down,
                    start_s: 500.0,
                    end_s: 900.0,
                },
            ],
            brownouts: vec![Brownout {
                start_s: 250.0,
                end_s: 700.0,
                factor: 0.5,
            }],
            kill_backoff_s: 15.0,
        };
        let shifted = shift_schedule(&sched, 600.0);
        // the ended drain is gone; the in-flight Down window clamps to 0
        assert_eq!(shifted.compute.len(), 1);
        assert_eq!(shifted.compute[0].start_s, 0.0);
        assert_eq!(shifted.compute[0].end_s, 300.0);
        assert_eq!(shifted.brownouts[0].start_s, 0.0);
        assert_eq!(shifted.brownouts[0].end_s, 100.0);
        assert_eq!(shifted.kill_backoff_s, 15.0);
        assert!(shifted.validate().is_ok());
    }

    #[test]
    fn multi_tenant_stream_conserves_sessions() {
        let cfg = StreamConfig {
            sessions: 90,
            horizon_s: 3.0 * DAY_S,
            epoch_s: DAY_S,
            pattern: ArrivalPattern::Waves { count: 3 },
            seed: 21,
            tenants: 3,
            ..Default::default()
        };
        let out = run_stream(&cfg, &small_fleet(), &PlacementConfig::default(), &RunSpec::new());
        let r = &out.report;
        assert_eq!(r.processed + r.aborted + r.backlog_final, r.sessions);
        assert_eq!(r.backlog_final, 0);
        assert!(r.latency_p50_s > 0.0);
    }
}
